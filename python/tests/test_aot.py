"""AOT pipeline sanity: the artifact plan and the emitted manifest.

These tests exercise `aot.build_artifact_plan` without re-lowering all 16
artifacts (that is `make artifacts`' job); when `artifacts/` already exists
they additionally validate the emitted files against the plan.
"""

import json
import os

import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def plan():
    return aot.build_artifact_plan(M.TINY)


class TestPlan:
    def test_bucket_coverage(self, plan):
        names = {e["name"] for e in plan}
        for b in aot.BATCH_BUCKETS:
            assert f"embed_decode_b{b}" in names
            assert f"lm_head_b{b}" in names
            assert f"decode_full_b{b}_s{aot.SEQ_CAP}" in names
            for l in aot.L_BUCKETS:
                assert f"decode_partial_b{b}_s{aot.SEQ_CAP}_l{l}" in names
            for sp in aot.PROMPT_BUCKETS:
                assert f"prefill_b{b}_p{sp}" in names

    def test_unique_names(self, plan):
        names = [e["name"] for e in plan]
        assert len(names) == len(set(names))

    def test_l_buckets_fit_capacity(self):
        assert all(0 < l < aot.SEQ_CAP for l in aot.L_BUCKETS)
        # contiguous-prefix trick requires room for the new token
        assert all(sp < aot.SEQ_CAP for sp in aot.PROMPT_BUCKETS)

    def test_decode_partial_signature(self, plan):
        e = next(x for x in plan if x["name"] == "decode_partial_b1_s128_l64")
        byname = {i: s for i, s in zip(e["in_names"], e["in_specs"])}
        assert tuple(byname["x_pre"].shape) == (1, 64, M.TINY.hidden)
        assert tuple(byname["k_rest"].shape) == (1, 64, M.TINY.hidden)
        assert byname["kv_len"].dtype == jnp.int32
        # weights follow the canonical order
        assert e["in_names"][5:] == list(M.LAYER_WEIGHT_NAMES)

    def test_prefill_signature(self, plan):
        e = next(x for x in plan if x["name"] == "prefill_b4_p32")
        assert len(e["in_specs"]) == 1 + 4 + M.TINY.n_layers * 16
        assert tuple(e["in_specs"][0].shape) == (4, 32)

    def test_rest_plus_l_equals_capacity(self, plan):
        for e in plan:
            if e["fn"] == "decode_partial":
                byname = dict(zip(e["in_names"], e["in_specs"]))
                assert byname["x_pre"].shape[1] + byname["k_rest"].shape[1] == e["s"]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART_DIR, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestEmittedManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART_DIR, "manifest.json")) as f:
            return json.load(f)

    def test_all_files_exist(self, manifest):
        for a in manifest["artifacts"]:
            path = os.path.join(ART_DIR, a["file"])
            assert os.path.exists(path), a["file"]
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head

    def test_manifest_matches_plan(self, manifest, plan):
        assert {a["name"] for a in manifest["artifacts"]} == {e["name"] for e in plan}

    def test_model_geometry(self, manifest):
        m = manifest["model"]
        assert m["hidden"] == M.TINY.hidden
        assert m["n_layers"] == M.TINY.n_layers
        assert manifest["layer_weight_names"] == list(M.LAYER_WEIGHT_NAMES)

    def test_io_signatures_complete(self, manifest):
        for a in manifest["artifacts"]:
            assert a["inputs"] and a["outputs"]
            for io in a["inputs"] + a["outputs"]:
                assert io["dtype"] in ("float32", "int32")
                assert all(d > 0 for d in io["shape"]) or io["shape"] == []

    def test_decode_outputs(self, manifest):
        for a in manifest["artifacts"]:
            if a["fn"] in ("decode_full", "decode_partial"):
                assert [o["name"] for o in a["outputs"]] == ["y", "k_new", "v_new"]
                h = manifest["model"]["hidden"]
                assert a["outputs"][0]["shape"] == [a["b"], 1, h]
