"""L1 correctness: fused LayerNorm+KV-recompute Pallas kernel vs the
pure-jnp oracle.

This is the paper's Eq. (7) — the recomputation path must be *exact*
(KVPR computes exact attention, no approximation), so the kernel is held to
tight float32 tolerances against the naive reference.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.kv_recompute import kv_recompute
from compile.kernels import ref


def _mk(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape), jnp.float32) * scale


def _params(rng, h):
    ln_g = 1.0 + _mk(rng, h, scale=0.02)
    ln_b = _mk(rng, h, scale=0.02)
    wk, bk = _mk(rng, h, h, scale=0.05), _mk(rng, h, scale=0.05)
    wv, bv = _mk(rng, h, h, scale=0.05), _mk(rng, h, scale=0.05)
    return ln_g, ln_b, wk, bk, wv, bv


def _run_both(b, l, h, seed=0, blk_l=64):
    rng = np.random.default_rng(seed)
    x = _mk(rng, b, l, h)
    p = _params(rng, h)
    got = kv_recompute(x, *p, blk_l=blk_l)
    want = ref.kv_recompute_ref(x, *p)
    return got, want


class TestKvRecomputeBasic:
    def test_matches_ref_square(self):
        (k, v), (kr, vr) = _run_both(2, 64, 128)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-5)

    def test_matches_ref_batch1(self):
        (k, v), (kr, vr) = _run_both(1, 32, 64)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("l", [32, 64, 96, 128])
    def test_all_l_buckets(self, l):
        """Every static L bucket the AOT plan emits."""
        (k, v), (kr, vr) = _run_both(2, l, 128)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(v, vr, rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("blk", [16, 32, 64, 128])
    def test_block_size_invariance(self, blk):
        """The tiling is a schedule, not semantics — results must not move."""
        (k1, v1), _ = _run_both(1, 128, 64, blk_l=blk)
        (k2, v2), _ = _run_both(1, 128, 64, blk_l=128)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_non_divisible_l_falls_back(self):
        """l=96 with blk 64 → kernel picks a dividing tile instead of failing."""
        (k, v), (kr, vr) = _run_both(1, 96, 64)
        np.testing.assert_allclose(k, kr, rtol=1e-5, atol=1e-5)

    def test_zero_weight_leaves_bias(self):
        rng = np.random.default_rng(3)
        h = 64
        x = _mk(rng, 1, 32, h)
        ln_g, ln_b = 1.0 + _mk(rng, h, scale=0.02), _mk(rng, h, scale=0.02)
        wk = jnp.zeros((h, h), jnp.float32)
        bk = _mk(rng, h)
        wv = jnp.zeros((h, h), jnp.float32)
        bv = _mk(rng, h)
        k, v = kv_recompute(x, ln_g, ln_b, wk, bk, wv, bv)
        np.testing.assert_allclose(k, jnp.broadcast_to(bk, k.shape), atol=1e-6)
        np.testing.assert_allclose(v, jnp.broadcast_to(bv, v.shape), atol=1e-6)

    def test_k_and_v_independent(self):
        """K must only depend on (W_K, b_K) and V on (W_V, b_V)."""
        rng = np.random.default_rng(5)
        h = 64
        x = _mk(rng, 1, 32, h)
        ln_g, ln_b, wk, bk, wv, bv = _params(rng, h)
        k1, _ = kv_recompute(x, ln_g, ln_b, wk, bk, wv, bv)
        k2, _ = kv_recompute(x, ln_g, ln_b, wk, bk, wv * 2.0, bv + 1.0)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))
        _, v1 = kv_recompute(x, ln_g, ln_b, wk, bk, wv, bv)
        _, v2 = kv_recompute(x, ln_g, ln_b, wk * 2.0, bk + 1.0, wv, bv)
        np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2))

    def test_layernorm_is_fused(self):
        """Kernel output == projection of the *normalised* input — feeding
        pre-normalised input with identity LN must agree."""
        rng = np.random.default_rng(6)
        h = 64
        x = _mk(rng, 1, 32, h)
        ln_g, ln_b, wk, bk, wv, bv = _params(rng, h)
        k1, v1 = kv_recompute(x, ln_g, ln_b, wk, bk, wv, bv)
        ln = ref.layernorm_ref(x, ln_g, ln_b)
        ident_g = jnp.ones((h,), jnp.float32)
        zero_b = jnp.zeros((h,), jnp.float32)
        # identity LN is only identity on already-normalised rows; re-LN of
        # ln(x) is NOT ln(x), so instead check against the pure oracle
        kr, vr = ref.kv_recompute_ref(x, ln_g, ln_b, wk, bk, wv, bv)
        del ln, ident_g, zero_b
        np.testing.assert_allclose(k1, kr, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(v1, vr, rtol=1e-5, atol=1e-5)


class TestKvRecomputeProperty:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        l_mult=st.integers(1, 4),
        h=st.sampled_from([32, 64, 128]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_random_shapes(self, b, l_mult, h, seed):
        (k, v), (kr, vr) = _run_both(b, 32 * l_mult, h, seed=seed)
        np.testing.assert_allclose(k, kr, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(v, vr, rtol=1e-4, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), scale=st.floats(0.5, 20.0))
    def test_scale_invariance(self, seed, scale):
        """LayerNorm is scale-invariant: f(a·X) == f(X) for a > 0."""
        rng = np.random.default_rng(seed)
        h = 32
        x = _mk(rng, 1, 32, h)
        p = _params(rng, h)
        k1, v1 = kv_recompute(x, *p)
        k2, v2 = kv_recompute(scale * x, *p)
        np.testing.assert_allclose(k1, k2, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(v1, v2, rtol=1e-4, atol=1e-4)
