"""L2 correctness: the decoder step functions the AOT pipeline exports.

The heart of the paper's exactness claim lives here: the *partial
recomputation* decode step must produce bit-identical attention to the
*full transfer* decode step whenever the activation prefix and the
transferred KV remainder are mutually consistent.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

CFG = M.TINY
H = CFG.hidden


@pytest.fixture(scope="module")
def weights():
    return M.init_weights(CFG, seed=0)


def _layer_tuple(lw):
    return tuple(lw[n] for n in M.LAYER_WEIGHT_NAMES)


def _consistent_state(w, b, s_cap, l, kv_len, seed=0):
    """Random decode state where KV[0:l] really is the projection of x_pre."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(b, 1, H)), jnp.float32) * 0.1
    x_pre = jnp.asarray(rng.normal(size=(b, l, H)), jnp.float32) * 0.1
    k_re, v_re = ref.kv_recompute_ref(
        x_pre, w["ln1_g"], w["ln1_b"], w["wk"], w["bk"], w["wv"], w["bv"])
    k_rest = jnp.asarray(rng.normal(size=(b, s_cap - l, H)), jnp.float32) * 0.1
    v_rest = jnp.asarray(rng.normal(size=(b, s_cap - l, H)), jnp.float32) * 0.1
    k_cache = jnp.concatenate([k_re, k_rest], axis=1)
    v_cache = jnp.concatenate([v_re, v_rest], axis=1)
    return x, x_pre, k_rest, v_rest, k_cache, v_cache, kv_len


class TestExactness:
    """Partial recomputation == full transfer (paper §3: 'exact attention')."""

    @pytest.mark.parametrize("l", [32, 64, 96])
    def test_partial_equals_full(self, weights, l):
        _, lws = weights
        w = lws[0]
        wt = _layer_tuple(w)
        x, x_pre, k_rest, v_rest, k_cache, v_cache, kv_len = _consistent_state(
            w, b=2, s_cap=128, l=l, kv_len=max(l, 100))
        yf, kf, vf = M.decode_layer_full(x, k_cache, v_cache, kv_len, *wt)
        yp, kp, vp = M.decode_layer_partial(x, x_pre, k_rest, v_rest, kv_len, *wt)
        np.testing.assert_allclose(yf, yp, rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(kf), np.asarray(kp))
        np.testing.assert_array_equal(np.asarray(vf), np.asarray(vp))

    def test_partial_l_equals_kvlen(self, weights):
        """Recompute *everything* (l == kv_len): rest segment is all padding."""
        _, lws = weights
        w = lws[0]
        wt = _layer_tuple(w)
        x, x_pre, k_rest, v_rest, k_cache, v_cache, _ = _consistent_state(
            w, b=1, s_cap=128, l=96, kv_len=96)
        yf, _, _ = M.decode_layer_full(x, k_cache, v_cache, 96, *wt)
        yp, _, _ = M.decode_layer_partial(x, x_pre, k_rest, v_rest, 96, *wt)
        np.testing.assert_allclose(yf, yp, rtol=1e-5, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**16),
           l=st.sampled_from([32, 64, 96]),
           extra=st.integers(0, 31))
    def test_partial_equals_full_random(self, weights, seed, l, extra):
        _, lws = weights
        w = lws[1]
        wt = _layer_tuple(w)
        kv_len = min(l + extra, 127)
        x, x_pre, k_rest, v_rest, k_cache, v_cache, _ = _consistent_state(
            w, b=1, s_cap=128, l=l, kv_len=kv_len, seed=seed)
        yf, _, _ = M.decode_layer_full(x, k_cache, v_cache, kv_len, *wt)
        yp, _, _ = M.decode_layer_partial(x, x_pre, k_rest, v_rest, kv_len, *wt)
        np.testing.assert_allclose(yf, yp, rtol=1e-4, atol=1e-5)


class TestPallasVsPure:
    def test_decode_full_pallas_matches_pure(self, weights):
        _, lws = weights
        wt = _layer_tuple(lws[0])
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(2, 1, H)), jnp.float32) * 0.1
        kc = jnp.asarray(rng.normal(size=(2, 128, H)), jnp.float32) * 0.1
        vc = jnp.asarray(rng.normal(size=(2, 128, H)), jnp.float32) * 0.1
        y1, k1, v1 = M.decode_layer_full(x, kc, vc, 77, *wt, use_pallas=True)
        y2, k2, v2 = M.decode_layer_full(x, kc, vc, 77, *wt, use_pallas=False)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))

    def test_decode_partial_pallas_matches_pure(self, weights):
        _, lws = weights
        w = lws[0]
        wt = _layer_tuple(w)
        x, x_pre, k_rest, v_rest, _, _, kv_len = _consistent_state(
            w, b=1, s_cap=128, l=64, kv_len=90, seed=3)
        y1, _, _ = M.decode_layer_partial(x, x_pre, k_rest, v_rest, kv_len, *wt,
                                          use_pallas=True)
        y2, _, _ = M.decode_layer_partial(x, x_pre, k_rest, v_rest, kv_len, *wt,
                                          use_pallas=False)
        np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-5)


class TestDecodeSemantics:
    def test_new_token_kv_matches_projection(self, weights):
        """k_new/v_new outputs are exactly the projections of LN(x)."""
        _, lws = weights
        w = lws[0]
        wt = _layer_tuple(w)
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(1, 1, H)), jnp.float32) * 0.1
        kc = jnp.zeros((1, 128, H), jnp.float32)
        vc = jnp.zeros((1, 128, H), jnp.float32)
        _, k_new, v_new = M.decode_layer_full(x, kc, vc, 5, *wt)
        ln1 = ref.layernorm_ref(x, w["ln1_g"], w["ln1_b"])
        np.testing.assert_allclose(k_new, ln1 @ w["wk"] + w["bk"], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(v_new, ln1 @ w["wv"] + w["bv"], rtol=1e-5, atol=1e-6)

    def test_cache_rows_beyond_kvlen_dont_matter(self, weights):
        _, lws = weights
        wt = _layer_tuple(lws[0])
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(1, 1, H)), jnp.float32) * 0.1
        kc = jnp.asarray(rng.normal(size=(1, 128, H)), jnp.float32)
        vc = jnp.asarray(rng.normal(size=(1, 128, H)), jnp.float32)
        kv_len = 60
        y1, _, _ = M.decode_layer_full(x, kc, vc, kv_len, *wt)
        kc2 = kc.at[:, kv_len + 1:, :].set(99.0)  # poison padding (kv_len row
        vc2 = vc.at[:, kv_len + 1:, :].set(-99.0)  # is overwritten by new kv)
        y2, _, _ = M.decode_layer_full(x, kc2, vc2, kv_len, *wt)
        np.testing.assert_allclose(y1, y2, rtol=1e-6, atol=1e-6)

    def test_matches_ref_layer(self, weights):
        """Step fn == the standalone oracle decoder layer."""
        _, lws = weights
        w = lws[2]
        wt = _layer_tuple(w)
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.normal(size=(2, 1, H)), jnp.float32) * 0.1
        kc = jnp.asarray(rng.normal(size=(2, 128, H)), jnp.float32) * 0.1
        vc = jnp.asarray(rng.normal(size=(2, 128, H)), jnp.float32) * 0.1
        y, kn, vn = M.decode_layer_full(x, kc, vc, 50, *wt)
        yr, knr, vnr = ref.decoder_layer_full_ref(x, kc, vc, 50, w, CFG.n_heads)
        np.testing.assert_allclose(y, yr, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(kn, knr, rtol=1e-5, atol=1e-6)


class TestPrefillDecodeChain:
    def test_prefill_then_decode_consistent(self, weights):
        """Generate token t via (a) prefill(s) + decode and (b) prefill(s+1);
        the KV rows written by decode must equal prefill's rows."""
        mw, lws = weights
        flat = tuple(w[n] for w in lws for n in M.LAYER_WEIGHT_NAMES)
        rng = np.random.default_rng(7)
        b, sp = 1, 16
        ids = jnp.asarray(rng.integers(0, CFG.vocab, size=(b, sp + 1)), jnp.int32)

        logits_a, k_a, v_a, _x_a = M.prefill_model(
            ids[:, :sp], mw["tok_table"], mw["pos_table"], mw["lnf_g"], mw["lnf_b"], *flat)
        # decode one step with the true next token
        x = M.embed_decode(ids[:, sp], jnp.int32(sp), mw["tok_table"], mw["pos_table"])
        s_cap = 128
        kv_len = sp
        for i, w in enumerate(lws):
            wt = _layer_tuple(w)
            kc = jnp.zeros((b, s_cap, H), jnp.float32).at[:, :sp, :].set(k_a[i])
            vc = jnp.zeros((b, s_cap, H), jnp.float32).at[:, :sp, :].set(v_a[i])
            x, k_new, v_new = M.decode_layer_full(x, kc, vc, kv_len, *wt)
            # compare against prefill over sp+1 tokens
            _, k_b, v_b, _xb = M.prefill_model(
                ids[:, :sp + 1], mw["tok_table"], mw["pos_table"],
                mw["lnf_g"], mw["lnf_b"], *flat)
            np.testing.assert_allclose(k_new[:, 0, :], k_b[i][:, sp, :],
                                       rtol=1e-4, atol=1e-5)

    def test_prefill_causality(self, weights):
        """Changing a later prompt token must not change earlier KV rows."""
        mw, lws = weights
        flat = tuple(w[n] for w in lws for n in M.LAYER_WEIGHT_NAMES)
        rng = np.random.default_rng(8)
        ids = jnp.asarray(rng.integers(0, CFG.vocab, size=(1, 16)), jnp.int32)
        ids2 = ids.at[0, -1].set((ids[0, -1] + 1) % CFG.vocab)
        _, k1, _, _ = M.prefill_model(ids, mw["tok_table"], mw["pos_table"],
                                   mw["lnf_g"], mw["lnf_b"], *flat)
        _, k2, _, _ = M.prefill_model(ids2, mw["tok_table"], mw["pos_table"],
                                   mw["lnf_g"], mw["lnf_b"], *flat)
        np.testing.assert_allclose(k1[:, :, :15, :], k2[:, :, :15, :],
                                   rtol=1e-6, atol=1e-6)
        assert not np.allclose(k1[:, :, 15, :], k2[:, :, 15, :])


class TestHeadsAndEmbed:
    def test_embed_decode_shape_and_content(self, weights):
        mw, _ = weights
        ids = jnp.asarray([3, 7], jnp.int32)
        x = M.embed_decode(ids, jnp.int32(5), mw["tok_table"], mw["pos_table"])
        assert x.shape == (2, 1, H)
        want = mw["tok_table"][3] + mw["pos_table"][5]
        np.testing.assert_allclose(x[0, 0], want, rtol=1e-6)

    def test_lm_head_tied_embedding(self, weights):
        mw, _ = weights
        rng = np.random.default_rng(9)
        x = jnp.asarray(rng.normal(size=(2, 1, H)), jnp.float32)
        logits = M.lm_head(x, mw["tok_table"], mw["lnf_g"], mw["lnf_b"])
        assert logits.shape == (2, CFG.vocab)
        ln = ref.layernorm_ref(x, mw["lnf_g"], mw["lnf_b"])
        np.testing.assert_allclose(logits, (ln @ mw["tok_table"].T)[:, 0, :],
                                   rtol=1e-5, atol=1e-5)
