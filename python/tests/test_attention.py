"""L1 correctness: length-masked single-query decode attention kernel.

The online-softmax accumulation must agree with the materialised-softmax
oracle for every valid cache length, including boundaries (kv_len = 1,
block edges, full capacity) — these are exactly the states the Rust engine
drives the artifact through during generation.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.decode_attention import decode_attention
from compile.kernels import ref


def _inputs(b, nh, s, d, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, nh, 1, d)), jnp.float32) * scale
    k = jnp.asarray(rng.normal(size=(b, nh, s, d)), jnp.float32) * scale
    v = jnp.asarray(rng.normal(size=(b, nh, s, d)), jnp.float32) * scale
    return q, k, v


class TestDecodeAttentionBasic:
    @pytest.mark.parametrize("kv_len", [1, 2, 37, 63, 64, 65, 100, 127, 128])
    def test_matches_ref_across_lengths(self, kv_len):
        q, k, v = _inputs(2, 4, 128, 32)
        got = decode_attention(q, k, v, kv_len)
        want = ref.decode_attention_ref(q, k, v, kv_len)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_single_position_is_value_row(self):
        """kv_len=1 → softmax over one score → output = V[:, :, 0]."""
        q, k, v = _inputs(1, 2, 64, 16, seed=7)
        got = decode_attention(q, k, v, 1)
        np.testing.assert_allclose(got[:, :, 0, :], v[:, :, 0, :], rtol=1e-6)

    def test_padding_is_ignored(self):
        """Garbage beyond kv_len must not leak into the output."""
        q, k, v = _inputs(1, 2, 128, 16, seed=8)
        kv_len = 50
        k_poison = k.at[:, :, kv_len:, :].set(1e4)
        v_poison = v.at[:, :, kv_len:, :].set(-1e4)
        a = decode_attention(q, k, v, kv_len)
        b = decode_attention(q, k_poison, v_poison, kv_len)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_uniform_keys_average_values(self):
        """Identical keys → uniform probs → output = mean of valid V rows."""
        b, nh, s, d, kv_len = 1, 1, 64, 8, 40
        q = jnp.ones((b, nh, 1, d), jnp.float32)
        k = jnp.ones((b, nh, s, d), jnp.float32)
        rng = np.random.default_rng(9)
        v = jnp.asarray(rng.normal(size=(b, nh, s, d)), jnp.float32)
        got = decode_attention(q, k, v, kv_len)
        want = v[:, :, :kv_len, :].mean(axis=2, keepdims=True)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_size_invariance(self):
        q, k, v = _inputs(2, 2, 128, 16, seed=10)
        a = decode_attention(q, k, v, 97, blk_s=32)
        b = decode_attention(q, k, v, 97, blk_s=64)
        c = decode_attention(q, k, v, 97, blk_s=128)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(a, c, rtol=1e-6, atol=1e-6)

    def test_large_magnitude_scores_stable(self):
        """Online softmax must not overflow where naive exp would."""
        q, k, v = _inputs(1, 1, 64, 16, seed=11, scale=30.0)
        got = decode_attention(q, k, v, 64)
        want = ref.decode_attention_ref(q, k, v, 64)
        assert np.isfinite(np.asarray(got)).all()
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_softmax_output_in_value_hull(self):
        """Attention output is a convex combination of valid value rows."""
        q, k, v = _inputs(1, 2, 64, 8, seed=12)
        kv_len = 33
        got = np.asarray(decode_attention(q, k, v, kv_len))
        vv = np.asarray(v)[:, :, :kv_len, :]
        assert (got <= vv.max(axis=2, keepdims=True) + 1e-5).all()
        assert (got >= vv.min(axis=2, keepdims=True) - 1e-5).all()


class TestDecodeAttentionProperty:
    @settings(max_examples=12, deadline=None)
    @given(
        b=st.integers(1, 3),
        nh=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([64, 128]),
        d=st.sampled_from([8, 16, 32]),
        frac=st.floats(0.01, 1.0),
        seed=st.integers(0, 2**16),
    )
    def test_matches_ref_random(self, b, nh, s, d, frac, seed):
        kv_len = max(1, int(s * frac))
        q, k, v = _inputs(b, nh, s, d, seed=seed)
        got = decode_attention(q, k, v, kv_len)
        want = ref.decode_attention_ref(q, k, v, kv_len)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**16), kv_len=st.integers(1, 128))
    def test_extending_padding_is_noop(self, seed, kv_len):
        """Attention over S=128 padded cache == attention over a smaller
        padded cache holding the same valid prefix (when it fits)."""
        q, k, v = _inputs(1, 2, 128, 16, seed=seed)
        big = decode_attention(q, k, v, kv_len)
        if kv_len <= 64:
            small = decode_attention(q, k[:, :, :64, :], v[:, :, :64, :], kv_len)
            np.testing.assert_allclose(big, small, rtol=1e-5, atol=1e-5)
