"""AOT pipeline: lower every model step function × shape bucket to HLO text.

Emits ``artifacts/<name>.hlo.txt`` plus ``artifacts/manifest.json`` which the
Rust runtime (`rust/src/runtime/artifacts.rs`) parses to know each
executable's input/output signature.

Interchange format is HLO **text**, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` 0.1.6 crate binds) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M

# ---------------------------------------------------------------------------
# shape buckets (DESIGN.md §4) — kept in sync with rust/src/config/model.rs
# ---------------------------------------------------------------------------

BATCH_BUCKETS = (1, 4)
SEQ_CAP = 128           # padded KV capacity S of every decode artifact
L_BUCKETS = (32, 64, 96)  # static split-point grid for decode_partial
PROMPT_BUCKETS = (16, 32)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(names, specs):
    return [
        {"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
        for n, s in zip(names, specs)
    ]


def _layer_weight_specs(cfg):
    shapes = M.layer_weight_shapes(cfg)
    return [_spec(shapes[n]) for n in M.LAYER_WEIGHT_NAMES]


def build_artifact_plan(cfg: M.ModelConfig):
    """Every (function, bucket) pair we AOT-compile, with full signatures."""
    h, v, p = cfg.hidden, cfg.vocab, cfg.max_pos
    lw_names = list(M.LAYER_WEIGHT_NAMES)
    lw_specs = _layer_weight_specs(cfg)
    plan = []

    for b in BATCH_BUCKETS:
        # --- embed_decode ---------------------------------------------------
        names = ["ids", "pos", "tok_table", "pos_table"]
        specs = [_spec((b,), jnp.int32), _spec((), jnp.int32),
                 _spec((v, h)), _spec((p, h))]
        plan.append(dict(
            name=f"embed_decode_b{b}", fn="embed_decode", b=b, s=0, l=0, sp=0,
            fun=M.embed_decode, in_names=names, in_specs=specs,
            out_names=["x"],
        ))

        # --- lm_head ---------------------------------------------------------
        names = ["x", "tok_table", "lnf_g", "lnf_b"]
        specs = [_spec((b, 1, h)), _spec((v, h)), _spec((h,)), _spec((h,))]
        plan.append(dict(
            name=f"lm_head_b{b}", fn="lm_head", b=b, s=0, l=0, sp=0,
            fun=M.lm_head, in_names=names, in_specs=specs,
            out_names=["logits"],
        ))

        # --- decode_full ------------------------------------------------------
        s = SEQ_CAP
        names = ["x", "k_cache", "v_cache", "kv_len"] + lw_names
        specs = [_spec((b, 1, h)), _spec((b, s, h)), _spec((b, s, h)),
                 _spec((), jnp.int32)] + lw_specs
        plan.append(dict(
            name=f"decode_full_b{b}_s{s}", fn="decode_full", b=b, s=s, l=0, sp=0,
            fun=functools.partial(M.decode_layer_full, cfg=cfg),
            in_names=names, in_specs=specs,
            out_names=["y", "k_new", "v_new"],
        ))

        # --- decode_partial (fused) + split pair per L bucket -----------------
        for l in L_BUCKETS:
            names = ["x", "x_pre", "k_rest", "v_rest", "kv_len"] + lw_names
            specs = [_spec((b, 1, h)), _spec((b, l, h)),
                     _spec((b, s - l, h)), _spec((b, s - l, h)),
                     _spec((), jnp.int32)] + lw_specs
            plan.append(dict(
                name=f"decode_partial_b{b}_s{s}_l{l}", fn="decode_partial",
                b=b, s=s, l=l, sp=0,
                fun=functools.partial(M.decode_layer_partial, cfg=cfg),
                in_names=names, in_specs=specs,
                out_names=["y", "k_new", "v_new"],
            ))
            # split schedule: recompute runs while KV[L:] is still in flight
            plan.append(dict(
                name=f"recompute_b{b}_l{l}", fn="recompute",
                b=b, s=0, l=l, sp=0,
                fun=M.recompute_kv,
                in_names=["x_pre", "ln1_g", "ln1_b", "wk", "bk", "wv", "bv"],
                in_specs=[_spec((b, l, h)), _spec((h,)), _spec((h,)),
                          _spec((h, h)), _spec((h,)),
                          _spec((h, h)), _spec((h,))],
                out_names=["k_re", "v_re"],
            ))
            names = ["x", "k_re", "v_re", "k_rest", "v_rest", "kv_len"] + lw_names
            specs = [_spec((b, 1, h)), _spec((b, l, h)), _spec((b, l, h)),
                     _spec((b, s - l, h)), _spec((b, s - l, h)),
                     _spec((), jnp.int32)] + lw_specs
            plan.append(dict(
                name=f"decode_merge_b{b}_s{s}_l{l}", fn="decode_merge",
                b=b, s=s, l=l, sp=0,
                fun=functools.partial(M.decode_layer_merge, cfg=cfg),
                in_names=names, in_specs=specs,
                out_names=["y", "k_new", "v_new"],
            ))

        # --- prefill per prompt bucket ----------------------------------------
        for sp in PROMPT_BUCKETS:
            names = (["ids"] + list(M.MODEL_WEIGHT_NAMES)
                     + [f"L{i}.{n}" for i in range(cfg.n_layers) for n in lw_names])
            specs = ([_spec((b, sp), jnp.int32),
                      _spec((v, h)), _spec((p, h)), _spec((h,)), _spec((h,))]
                     + lw_specs * cfg.n_layers)
            plan.append(dict(
                name=f"prefill_b{b}_p{sp}", fn="prefill", b=b, s=0, l=0, sp=sp,
                fun=functools.partial(M.prefill_model, cfg=cfg),
                in_names=names, in_specs=specs,
                out_names=["logits", "k_stack", "v_stack", "x_stack"],
            ))
    return plan


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = M.TINY
    plan = build_artifact_plan(cfg)
    manifest = {
        "model": {
            "name": cfg.name, "hidden": cfg.hidden, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "ffn": cfg.ffn, "vocab": cfg.vocab,
            "max_pos": cfg.max_pos,
        },
        "buckets": {
            "batch": list(BATCH_BUCKETS), "seq_cap": SEQ_CAP,
            "l": list(L_BUCKETS), "prompt": list(PROMPT_BUCKETS),
        },
        "layer_weight_names": list(M.LAYER_WEIGHT_NAMES),
        "model_weight_names": list(M.MODEL_WEIGHT_NAMES),
        "artifacts": [],
    }

    for entry in plan:
        fname = f"{entry['name']}.hlo.txt"
        lowered = jax.jit(entry["fun"]).lower(*entry["in_specs"])
        text = to_hlo_text(lowered)
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        out_shapes = lowered.out_info
        out_leaves = jax.tree_util.tree_leaves(out_shapes)
        manifest["artifacts"].append({
            "name": entry["name"], "file": fname, "fn": entry["fn"],
            "b": entry["b"], "s": entry["s"], "l": entry["l"], "sp": entry["sp"],
            "inputs": _sig(entry["in_names"], entry["in_specs"]),
            "outputs": _sig(entry["out_names"], out_leaves),
        })
        print(f"  lowered {entry['name']:34s} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(plan)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
