"""L1 Pallas kernel: fused LayerNorm + K/V recomputation from transferred
activations.

This is the compute hot-spot of KVPR (paper Eq. (7)):

    K[0:l] = LN(X[0:l]) @ W_K + b_K
    V[0:l] = LN(X[0:l]) @ W_V + b_V

The CPU sends the *layer-input activations* ``X[0:l]`` (half the bytes of
the KV pair they regenerate) and the GPU recomputes both projections while
the rest of the KV cache streams over the link.  The paper's Eq. (7) writes
the projection without the pre-attention LayerNorm; in a real pre-LN
decoder the cached K/V are projections of the *normalised* input, so the
kernel fuses the LayerNorm in — one more reason the recompute path is
HBM-friendly (X is read once, normalised in VMEM, and hits the MXU twice).

Hardware adaptation (DESIGN.md §3): the paper performs these GEMMs with
cuBLAS on an A100.  On TPU-style Pallas we fuse the two projections into a
single kernel so the ``X`` tile is read from HBM once and both GEMMs hit the
MXU back-to-back.  ``BlockSpec``s tile ``(l, h) @ (h, h)`` into
``(BLK_L, h) x (h, BLK_H)`` VMEM tiles; the VMEM working set plays the role
the paper's SMEM staging plays (see DESIGN.md §8 for the footprint math).

The kernel is lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls; interpret mode lowers to plain HLO so the same
artifact runs everywhere.  Correctness is pinned against ``ref.py`` by
``python/tests/test_kv_recompute.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size along the token (l) axis.  64 divides every L bucket the AOT
# pipeline emits (32 is the smallest bucket; handled by the min() below).
DEFAULT_BLK_L = 128


LN_EPS = 1e-5


def _kv_recompute_kernel(x_ref, g_ref, b_ref, wk_ref, bk_ref, wv_ref, bv_ref,
                         k_ref, v_ref):
    """One grid step: LayerNorm a (BLK_L, h) tile of X, project into K and V.

    Both GEMMs share the single normalised X tile — the fusion that makes
    the recompute path HBM-read-once.
    """
    x = x_ref[0]  # (BLK_L, h) — batch dim is blocked at 1
    # row-wise layernorm entirely in VMEM
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    ln = (x - mu) * jax.lax.rsqrt(var + LN_EPS) * g_ref[...] + b_ref[...]
    # MXU-targeted matmuls; f32 accumulation is explicit so the kernel is
    # numerically identical under interpret mode and on real hardware.
    k = jnp.dot(ln, wk_ref[...], preferred_element_type=jnp.float32)
    v = jnp.dot(ln, wv_ref[...], preferred_element_type=jnp.float32)
    k_ref[0] = k + bk_ref[...]
    v_ref[0] = v + bv_ref[...]


@functools.partial(jax.jit, static_argnames=("blk_l",))
def kv_recompute(x, ln_g, ln_b, wk, bk, wv, bv, *, blk_l: int = DEFAULT_BLK_L):
    """Recompute K and V for the layer-input activation prefix ``x``.

    Args:
      x:    f32[b, l, h] — transferred input activations X[0:l] (pre-LN).
      ln_g: f32[h], ln_b: f32[h] — pre-attention LayerNorm parameters.
      wk:   f32[h, h], bk: f32[h] — key projection.
      wv:   f32[h, h], bv: f32[h] — value projection.
      blk_l: tile size along the token axis.

    Returns:
      (K, V): each f32[b, l, h].
    """
    b, l, h = x.shape
    # largest tile ≤ blk_l that evenly divides l (L buckets are multiples of
    # 32, so this lands on 64 or 32 in practice)
    blk = min(blk_l, l)
    while l % blk != 0:
        blk -= 1
    grid = (b, l // blk)

    kernel = pl.pallas_call(
        _kv_recompute_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk, h), lambda i, j: (i, j, 0)),  # x tile
            pl.BlockSpec((h,), lambda i, j: (0,)),              # ln gamma
            pl.BlockSpec((h,), lambda i, j: (0,)),              # ln beta
            pl.BlockSpec((h, h), lambda i, j: (0, 0)),          # W_K resident
            pl.BlockSpec((h,), lambda i, j: (0,)),              # b_K
            pl.BlockSpec((h, h), lambda i, j: (0, 0)),          # W_V resident
            pl.BlockSpec((h,), lambda i, j: (0,)),              # b_V
        ],
        out_specs=[
            pl.BlockSpec((1, blk, h), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, blk, h), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, l, h), jnp.float32),
            jax.ShapeDtypeStruct((b, l, h), jnp.float32),
        ],
        interpret=True,
    )
    return tuple(kernel(x, ln_g, ln_b, wk, bk, wv, bv))
