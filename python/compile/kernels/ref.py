"""Pure-jnp oracles for the Pallas kernels and the decoder-layer math.

Everything the kernels (and the Rust reference implementation mirrored in
``rust/src/model/reference.rs``) compute is restated here in the most naive
possible jnp so the tests have an unambiguous ground truth.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def kv_recompute_ref(x, ln_g, ln_b, wk, bk, wv, bv):
    """K = LN(X) @ W_K + b_K, V = LN(X) @ W_V + b_V — paper Eq. (7) with the
    pre-attention LayerNorm made explicit (the cached K/V of a pre-LN
    decoder are projections of the normalised layer input)."""
    ln = layernorm_ref(x, ln_g, ln_b)
    k = jnp.einsum("blh,hd->bld", ln, wk) + bk
    v = jnp.einsum("blh,hd->bld", ln, wv) + bv
    return k, v


def decode_attention_ref(q, k, v, kv_len):
    """Masked single-query attention, materialised softmax."""
    b, nh, _, d = q.shape
    s = k.shape[2]
    scale = 1.0 / (d ** 0.5)
    scores = jnp.einsum("bhqd,bhsd->bhqs", q, k) * scale
    mask = jnp.arange(s)[None, None, None, :] < jnp.asarray(kv_len, jnp.int32)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqs,bhsd->bhqd", probs, v)


def layernorm_ref(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def split_heads(x, n_heads):
    """[b, t, h] -> [b, nh, t, d]"""
    b, t, h = x.shape
    d = h // n_heads
    return x.reshape(b, t, n_heads, d).transpose(0, 2, 1, 3)


def merge_heads(x):
    """[b, nh, t, d] -> [b, t, h]"""
    b, nh, t, d = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, nh * d)


def decoder_layer_full_ref(x, k_cache, v_cache, kv_len, w, n_heads):
    """One pre-LN decoder layer on a single decode token, full-KV path.

    ``k_cache``/``v_cache`` are padded [b, S, h] with ``kv_len`` valid rows.
    Returns (y, k_new, v_new) exactly like the AOT artifact.
    """
    ln1 = layernorm_ref(x, w["ln1_g"], w["ln1_b"])
    q = ln1 @ w["wq"] + w["bq"]
    k_new = ln1 @ w["wk"] + w["bk"]
    v_new = ln1 @ w["wv"] + w["bv"]

    # merged, padded cache: valid rows [0, kv_len) + the new token appended
    # at physical position S (attention is permutation-invariant under the
    # mask, so physical placement does not matter).
    k_all = jnp.concatenate([k_cache, k_new], axis=1)  # [b, S+1, h]
    v_all = jnp.concatenate([v_cache, v_new], axis=1)

    s = k_cache.shape[1]
    valid = jnp.concatenate(
        [jnp.arange(s) < jnp.asarray(kv_len, jnp.int32), jnp.ones((1,), bool)]
    )

    qh = split_heads(q, n_heads)
    kh = split_heads(k_all, n_heads)
    vh = split_heads(v_all, n_heads)
    d = qh.shape[-1]
    scores = jnp.einsum("bhqd,bhsd->bhqs", qh, kh) / (d ** 0.5)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    attn = merge_heads(jnp.einsum("bhqs,bhsd->bhqd", probs, vh))

    x = x + attn @ w["wo"] + w["bo"]
    ln2 = layernorm_ref(x, w["ln2_g"], w["ln2_b"])
    ffn = jnp.maximum(ln2 @ w["w1"] + w["b1"], 0.0) @ w["w2"] + w["b2"]
    y = x + ffn
    return y, k_new, v_new


def decoder_layer_partial_ref(x, x_pre, k_rest, v_rest, kv_len, w, n_heads):
    """KVPR path: recompute KV[0:l] from activations, merge with the
    transferred remainder, attend.  Must match the full path bit-for-bit
    given consistent inputs (the paper's exactness claim).

    ``x_pre``:   [b, L, h]   activation prefix (L = static split bucket)
    ``k_rest``:  [b, S-L, h] transferred keys for positions [L, kv_len)
    """
    k_re, v_re = kv_recompute_ref(
        x_pre, w["ln1_g"], w["ln1_b"], w["wk"], w["bk"], w["wv"], w["bv"])
    k_cache = jnp.concatenate([k_re, k_rest], axis=1)  # [b, S, h]
    v_cache = jnp.concatenate([v_re, v_rest], axis=1)
    y, k_new, v_new = decoder_layer_full_ref(x, k_cache, v_cache, kv_len, w, n_heads)
    return y, k_new, v_new, k_re, v_re


def prefill_layer_ref(x, w, n_heads):
    """One pre-LN decoder layer over a full prompt with causal masking.

    Returns (y, K, V) where K/V are the cache rows for every position.
    """
    b, t, h = x.shape
    ln1 = layernorm_ref(x, w["ln1_g"], w["ln1_b"])
    q = ln1 @ w["wq"] + w["bq"]
    k = ln1 @ w["wk"] + w["bk"]
    v = ln1 @ w["wv"] + w["bv"]

    qh, kh, vh = (split_heads(t_, n_heads) for t_ in (q, k, v))
    d = qh.shape[-1]
    scores = jnp.einsum("bhqd,bhsd->bhqs", qh, kh) / (d ** 0.5)
    causal = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(causal[None, None, :, :], scores, NEG_INF)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    attn = merge_heads(jnp.einsum("bhqs,bhsd->bhqd", probs, vh))

    x = x + attn @ w["wo"] + w["bo"]
    ln2 = layernorm_ref(x, w["ln2_g"], w["ln2_b"])
    ffn = jnp.maximum(ln2 @ w["w1"] + w["b1"], 0.0) @ w["w2"] + w["b2"]
    return x + ffn, k, v
