"""L1 Pallas kernel: single-query (decode-step) attention over a merged,
length-masked KV cache.

KVPR's decode step attends over three physically-contiguous segments —
the GPU-recomputed prefix ``KV[0:l]``, the link-transferred remainder
``KV[l:s']`` and the freshly projected token — concatenated into one padded
buffer of capacity ``S``.  Only the first ``kv_len`` positions are valid;
the kernel masks the padding with an explicit length scalar so *one static
artifact serves a whole sequence-length bucket* (DESIGN.md §4).

Hardware adaptation: Flash-Decoding on the A100 splits KV into chunks per
threadblock with a second-pass combine.  The TPU analogue here is a
single-sweep online softmax: the grid walks KV blocks resident in VMEM,
carrying the running max / normaliser / weighted accumulator in the output
refs, so HBM reads each K/V element exactly once.

Lowered with ``interpret=True`` (see kv_recompute.py for why) and pinned
against ``ref.decode_attention_ref`` by ``python/tests/test_attention.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLK_S = 128
NEG_INF = -1e30


def _decode_attn_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, d_ref, *, blk_s, scale):
    """One grid step: fold one (BLK_S, d) KV block into the online softmax.

    Grid layout: (batch, kv_block) — all heads of a batch element ride in
    one grid step (§Perf iter 2).  The kv_block axis is the innermost
    (fastest-varying) so the (m, d, o) carry in the output refs refers to
    the same batch element across consecutive steps.
    """
    s_blk = pl.program_id(1)
    n_blk = pl.num_programs(1)

    @pl.when(s_blk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        d_ref[...] = jnp.zeros_like(d_ref)

    q = q_ref[0, :, 0]       # (nh, d) — all heads of the single decode query
    k = k_ref[0]             # (nh, blk_s, d)
    v = v_ref[0]             # (nh, blk_s, d)
    kv_len = len_ref[0]

    # scores over this block for every head, masked to the valid prefix
    s = jnp.einsum("hd,hsd->hs", q, k,
                   preferred_element_type=jnp.float32) * scale  # (nh, blk_s)
    pos = s_blk * blk_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < kv_len, s, NEG_INF)

    m_prev = m_ref[0, :, 0]  # (nh,)
    d_prev = d_ref[0, :, 0]  # (nh,)
    o_prev = o_ref[0, :, 0]  # (nh, d)

    m_cur = jnp.max(s, axis=-1)                  # (nh,)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)              # rescale factor for carry
    p = jnp.exp(s - m_new[:, None])              # (nh, blk_s)
    d_new = d_prev * alpha + jnp.sum(p, axis=-1)
    o_new = o_prev * alpha[:, None] + jnp.einsum(
        "hs,hsd->hd", p, v, preferred_element_type=jnp.float32)

    m_ref[0, :, 0] = m_new
    d_ref[0, :, 0] = d_new
    o_ref[0, :, 0] = o_new

    # Final block: normalise the accumulator into the true attention output.
    @pl.when(s_blk == n_blk - 1)
    def _finalize():
        o_ref[0, :, 0] = o_ref[0, :, 0] / d_ref[0, :, 0][:, None]


@functools.partial(jax.jit, static_argnames=("blk_s",))
def decode_attention(q, k, v, kv_len, *, blk_s: int = DEFAULT_BLK_S):
    """Single-token attention with length masking.

    Args:
      q: f32[b, nh, 1, d] — the decode-step query.
      k: f32[b, nh, S, d] — padded key cache (merged segments).
      v: f32[b, nh, S, d] — padded value cache.
      kv_len: i32[] or i32[1] — number of valid positions (≤ S).
      blk_s: KV block size walked by the grid.

    Returns:
      f32[b, nh, 1, d] attention output.
    """
    b, nh, _, d = q.shape
    s = k.shape[2]
    blk = min(blk_s, s)
    if s % blk != 0:
        raise ValueError(f"S={s} must be a multiple of blk_s={blk}")
    # all heads ride in one grid step (they share the mask and the carry
    # structure), so the grid is only (batch, kv blocks) — §Perf iter 2
    grid = (b, s // blk)
    scale = 1.0 / (d ** 0.5)
    kv_len = jnp.asarray(kv_len, jnp.int32).reshape((1,))

    out, _m, _d = pl.pallas_call(
        functools.partial(_decode_attn_kernel, blk_s=blk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i, sb: (0,)),                   # kv_len
            pl.BlockSpec((1, nh, 1, d), lambda i, sb: (i, 0, 0, 0)),  # q
            pl.BlockSpec((1, nh, blk, d), lambda i, sb: (i, 0, sb, 0)),
            pl.BlockSpec((1, nh, blk, d), lambda i, sb: (i, 0, sb, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, nh, 1, d), lambda i, sb: (i, 0, 0, 0)),  # o
            pl.BlockSpec((1, nh, 1), lambda i, sb: (i, 0, 0)),        # m carry
            pl.BlockSpec((1, nh, 1), lambda i, sb: (i, 0, 0)),        # denom carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, 1, d), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, nh, 1), jnp.float32),
        ],
        interpret=True,
    )(kv_len, q, k, v)
    return out
