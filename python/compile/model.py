"""L2: OPT-style decoder model step functions, calling the L1 Pallas kernels.

These are the functions `aot.py` lowers to HLO text, one artifact per shape
bucket (DESIGN.md §4).  The Rust engine (`rust/src/engine/`) drives them
layer-by-layer so it can interleave KV-cache / activation / weight transfers
with compute exactly as the paper's runtime module does.

Canonical weight ordering — the Rust side passes weights positionally, so
both languages pin this list:

    LAYER_WEIGHT_NAMES  (16 per decoder layer)
    MODEL_WEIGHT_NAMES  (embedding tables + final layernorm)

Two decode-step variants exist:

* ``decode_layer_full``    — baseline: the whole padded KV cache is an input
  (it was transferred over the link).
* ``decode_layer_partial`` — KVPR: the activation prefix X[0:L] is an input;
  KV[0:L] is *recomputed on device* by the fused Pallas kernel while only
  KV[L:] was transferred.  Exact same attention output as the full path.

The new token's K/V is written into the padded cache at position ``kv_len``
with ``dynamic_update_slice`` so the valid region stays a contiguous prefix
(length ``kv_len+1``) — that is what lets one static artifact serve a whole
sequence-length bucket via the kernel's length mask.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.decode_attention import decode_attention
from compile.kernels.kv_recompute import kv_recompute

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

LAYER_WEIGHT_NAMES: Tuple[str, ...] = (
    "ln1_g", "ln1_b",
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2_g", "ln2_b",
    "w1", "b1", "w2", "b2",
)

MODEL_WEIGHT_NAMES: Tuple[str, ...] = ("tok_table", "pos_table", "lnf_g", "lnf_b")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of the model. Mirrors `rust/src/config/model.rs`."""

    name: str = "kvpr-tiny"
    hidden: int = 256
    n_heads: int = 4
    n_layers: int = 4
    ffn: int = 1024
    vocab: int = 512
    max_pos: int = 512

    @property
    def head_dim(self) -> int:
        return self.hidden // self.n_heads


TINY = ModelConfig()


def layer_weight_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    h, f = cfg.hidden, cfg.ffn
    return {
        "ln1_g": (h,), "ln1_b": (h,),
        "wq": (h, h), "bq": (h,),
        "wk": (h, h), "bk": (h,),
        "wv": (h, h), "bv": (h,),
        "wo": (h, h), "bo": (h,),
        "ln2_g": (h,), "ln2_b": (h,),
        "w1": (h, f), "b1": (f,),
        "w2": (f, h), "b2": (h,),
    }


def model_weight_shapes(cfg: ModelConfig) -> Dict[str, Tuple[int, ...]]:
    return {
        "tok_table": (cfg.vocab, cfg.hidden),
        "pos_table": (cfg.max_pos, cfg.hidden),
        "lnf_g": (cfg.hidden,),
        "lnf_b": (cfg.hidden,),
    }


def _wdict(weights: Sequence[jax.Array]) -> Dict[str, jax.Array]:
    assert len(weights) == len(LAYER_WEIGHT_NAMES), len(weights)
    return dict(zip(LAYER_WEIGHT_NAMES, weights))


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _mha_decode(x, k_cache, v_cache, kv_len, w, cfg: ModelConfig, use_pallas: bool):
    """Decode-step MHA over a padded cache with contiguous valid prefix.

    Writes the new token's K/V at position ``kv_len`` and attends over the
    (kv_len+1)-long valid prefix via the length-masked Pallas kernel.
    """
    ln1 = _layernorm(x, w["ln1_g"], w["ln1_b"])
    q = ln1 @ w["wq"] + w["bq"]                       # [b, 1, h]
    k_new = ln1 @ w["wk"] + w["bk"]
    v_new = ln1 @ w["wv"] + w["bv"]

    kv_len = jnp.asarray(kv_len, jnp.int32).reshape(())
    k_all = jax.lax.dynamic_update_slice(k_cache, k_new, (0, kv_len, 0))
    v_all = jax.lax.dynamic_update_slice(v_cache, v_new, (0, kv_len, 0))

    qh = ref.split_heads(q, cfg.n_heads)
    kh = ref.split_heads(k_all, cfg.n_heads)
    vh = ref.split_heads(v_all, cfg.n_heads)
    if use_pallas:
        attn = decode_attention(qh, kh, vh, kv_len + 1)
    else:
        attn = ref.decode_attention_ref(qh, kh, vh, kv_len + 1)
    attn = ref.merge_heads(attn)

    x = x + attn @ w["wo"] + w["bo"]
    return x, k_new, v_new


def _ffn(x, w):
    ln2 = _layernorm(x, w["ln2_g"], w["ln2_b"])
    return x + jnp.maximum(ln2 @ w["w1"] + w["b1"], 0.0) @ w["w2"] + w["b2"]


# ---------------------------------------------------------------------------
# AOT-exported step functions
# ---------------------------------------------------------------------------

def embed_decode(ids, pos, tok_table, pos_table):
    """ids: i32[b] token ids, pos: i32[] position → x f32[b, 1, h]."""
    tok = jnp.take(tok_table, ids, axis=0)                      # [b, h]
    pe = jax.lax.dynamic_slice_in_dim(pos_table, pos, 1, 0)     # [1, h]
    return (tok + pe)[:, None, :]


def decode_layer_full(x, k_cache, v_cache, kv_len, *weights,
                      cfg: ModelConfig = TINY, use_pallas: bool = True):
    """Baseline decode step for one layer: the full KV cache was transferred.

    x: f32[b,1,h]; k_cache/v_cache: f32[b,S,h] padded, kv_len valid rows
    (kv_len < S).  Returns (y, k_new, v_new).
    """
    w = _wdict(weights)
    x, k_new, v_new = _mha_decode(x, k_cache, v_cache, kv_len, w, cfg, use_pallas)
    return _ffn(x, w), k_new, v_new


def decode_layer_partial(x, x_pre, k_rest, v_rest, kv_len, *weights,
                         cfg: ModelConfig = TINY, use_pallas: bool = True):
    """KVPR decode step for one layer (paper §3.2, Fig 3b).

    x:      f32[b,1,h]   current token's activation
    x_pre:  f32[b,L,h]   transferred activation prefix — KV[0:L] is
                         recomputed from it on device (Pallas kernel)
    k_rest: f32[b,S-L,h] transferred keys for positions [L, kv_len)
    v_rest: f32[b,S-L,h] transferred values
    kv_len: i32[]        valid cache length (L ≤ kv_len < S)

    Returns (y, k_new, v_new) — identical to decode_layer_full on
    consistent inputs: recomputation is exact, not an approximation.
    """
    w = _wdict(weights)
    if use_pallas:
        k_re, v_re = kv_recompute(
            x_pre, w["ln1_g"], w["ln1_b"], w["wk"], w["bk"], w["wv"], w["bv"])
    else:
        k_re, v_re = ref.kv_recompute_ref(
            x_pre, w["ln1_g"], w["ln1_b"], w["wk"], w["bk"], w["wv"], w["bv"])
    k_cache = jnp.concatenate([k_re, k_rest], axis=1)
    v_cache = jnp.concatenate([v_re, v_rest], axis=1)
    x, k_new, v_new = _mha_decode(x, k_cache, v_cache, kv_len, w, cfg, use_pallas)
    return _ffn(x, w), k_new, v_new


def recompute_kv(x_pre, ln_g, ln_b, wk, bk, wv, bv):
    """Standalone KV recomputation artifact (Pallas kernel only).

    The engine's *split* schedule executes this as soon as the activation
    prefix lands on device, **while** KV[L:] is still in flight on the link
    — that is the paper's compute/transfer overlap made real.  The merged
    attention then runs as ``decode_layer_merge``.
    """
    return kv_recompute(x_pre, ln_g, ln_b, wk, bk, wv, bv)


def decode_layer_merge(x, k_re, v_re, k_rest, v_rest, kv_len, *weights,
                       cfg: ModelConfig = TINY, use_pallas: bool = True):
    """Second half of the split KVPR step: attention over the merged cache
    (recomputed prefix ‖ transferred remainder) + FFN.

    Semantically ``decode_layer_partial`` = ``recompute_kv`` ∘ this.
    """
    w = _wdict(weights)
    k_cache = jnp.concatenate([k_re, k_rest], axis=1)
    v_cache = jnp.concatenate([v_re, v_rest], axis=1)
    x, k_new, v_new = _mha_decode(x, k_cache, v_cache, kv_len, w, cfg, use_pallas)
    return _ffn(x, w), k_new, v_new


def lm_head(x, tok_table, lnf_g, lnf_b):
    """Final layernorm + tied-embedding projection. x: f32[b,1,h] → f32[b,V]."""
    ln = _layernorm(x, lnf_g, lnf_b)
    return jnp.einsum("bih,vh->biv", ln, tok_table)[:, 0, :]


def prefill_model(ids, tok_table, pos_table, lnf_g, lnf_b, *layer_weights,
                  cfg: ModelConfig = TINY):
    """Whole-model prefill over a padded prompt (pure jnp — the paper's
    technique only touches decoding; prefill is compute-bound already).

    ids: i32[b, s_p].  Returns (logits f32[b,V] for the first generated
    token, K f32[n_layers,b,s_p,h], V likewise, X f32[n_layers,b,s_p,h]).

    ``X[i]`` is the *input activation* of layer i — exactly the tensor KVPR
    keeps on the host so the GPU can recompute KV[0:l] later (paper Eq. 7).
    """
    n = cfg.n_layers
    assert len(layer_weights) == n * len(LAYER_WEIGHT_NAMES)
    b, s_p = ids.shape
    x = jnp.take(tok_table, ids.reshape(-1), axis=0).reshape(b, s_p, cfg.hidden)
    x = x + pos_table[:s_p][None, :, :]

    ks, vs, xs = [], [], []
    for i in range(n):
        xs.append(x)
        w = _wdict(layer_weights[i * 16:(i + 1) * 16])
        x, k, v = ref.prefill_layer_ref(x, w, cfg.n_heads)
        ks.append(k)
        vs.append(v)
    logits = lm_head(x[:, -1:, :], tok_table, lnf_g, lnf_b)
    return logits, jnp.stack(ks), jnp.stack(vs), jnp.stack(xs)


# ---------------------------------------------------------------------------
# deterministic weight init (tests only — Rust generates its own weights and
# feeds them through the artifacts as runtime inputs)
# ---------------------------------------------------------------------------

def init_weights(cfg: ModelConfig, seed: int = 0):
    """Small-magnitude deterministic weights keeping activations O(1)."""
    key = jax.random.PRNGKey(seed)
    out_model, out_layers = {}, []
    for name, shape in model_weight_shapes(cfg).items():
        key, sub = jax.random.split(key)
        base = jnp.ones(shape) if name.endswith("_g") else jnp.zeros(shape)
        out_model[name] = base + 0.02 * jax.random.normal(sub, shape)
    for _ in range(cfg.n_layers):
        lw = {}
        for name, shape in layer_weight_shapes(cfg).items():
            key, sub = jax.random.split(key)
            if name.endswith("_g"):
                lw[name] = jnp.ones(shape) + 0.02 * jax.random.normal(sub, shape)
            elif len(shape) == 1:
                lw[name] = 0.02 * jax.random.normal(sub, shape)
            else:
                scale = (2.0 / (shape[0] + shape[1])) ** 0.5
                lw[name] = scale * jax.random.normal(sub, shape)
        out_layers.append(lw)
    return out_model, out_layers
