//! Vendored subset of the `anyhow` error-handling crate.
//!
//! The CI container has no crates.io access, so this path dependency
//! re-implements exactly the surface the `kvpr` crate uses:
//!
//! * [`Error`] — a cheap string-chain error (context frames + source chain).
//!   Unlike real `anyhow` it stores rendered strings rather than the live
//!   error values; `kvpr` never downcasts, so nothing is lost.
//! * [`Result<T>`] — alias for `Result<T, Error>`.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction macros.
//!
//! Display behaviour mirrors upstream: `{}` shows the outermost message,
//! `{:#}` joins the whole context chain with `": "`.

use std::fmt;

/// A string-chain error: `frames[0]` is the outermost context, later frames
/// are the causes (innermost last).
pub struct Error {
    frames: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { frames: vec![m.to_string()] }
    }

    fn push_context(mut self, c: String) -> Self {
        self.frames.insert(0, c);
        self
    }

    /// Iterate the context/cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.frames.iter().map(|s| s.as_str())
    }

    /// The innermost cause's message.
    pub fn root_cause(&self) -> &str {
        self.frames.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.frames.join(": "))
        } else {
            write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.frames.first().map(String::as_str).unwrap_or(""))?;
        if self.frames.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for frame in &self.frames[1..] {
                write!(f, "\n    {frame}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that is
// what keeps the blanket `From` impl below coherent (same trick as upstream).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut frames = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            frames.push(s.to_string());
            src = s.source();
        }
        Error { frames }
    }
}

/// `anyhow::Result<T>`: the crate-wide fallible type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach human context to an error as it propagates upward.
pub trait Context<T> {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error value with lazily evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().push_context(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "file missing")
    }

    #[test]
    fn context_chains_and_formats() {
        let r: Result<()> = Err(io_err().into());
        let r = r.context("loading manifest");
        let e = r.unwrap_err();
        assert_eq!(format!("{e}"), "loading manifest");
        assert_eq!(format!("{e:#}"), "loading manifest: file missing");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing value").unwrap_err();
        assert_eq!(e.to_string(), "missing value");
        assert_eq!(Some(5).context("x").unwrap(), 5);
    }

    #[test]
    fn bail_and_anyhow() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("failed with code {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with code 7");
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
