//! **Deterministic trace dump**: replay a seeded workload trace through
//! the continuous server on the *deterministic step clock* — twice — and
//! prove the observability layer is reproducible: the two runs must
//! produce byte-identical Chrome `trace_event` JSON and bit-identical
//! tokens.  The verified export lands in `TRACE_dump.json`, loadable in
//! Perfetto or `chrome://tracing`.
//!
//! What makes the byte-identity possible: `ClockMode::Step` derives every
//! latency stamp from the decode-step counter instead of wall time,
//! `preload_requests` lands every arrival event on the serving thread in
//! submission order before the first step, and the untiered path keeps
//! all event emission on that one thread (no migration-link workers).
//!
//! ```bash
//! cargo run --release --example trace_dump -- [mix] [requests]
//! # mix: bursty_chat (default) | diurnal_mixed | rag_long_context
//! ```
//!
//! Runs with or without `make artifacts` (interpreter fallback).

use kvpr::coordinator::{ContinuousConfig, ContinuousServer, Submit};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::obs::{chrome_trace, TracerConfig};
use kvpr::transfer::LinkConfig;
use kvpr::util::clock::ClockMode;
use kvpr::workload::{Trace, WorkloadSpec};

/// One full replay: returns the Chrome-trace JSON and every response's
/// token stream (both must be identical across replays).
fn replay(trace: &Trace) -> anyhow::Result<(String, Vec<Vec<i32>>)> {
    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.weights_offloaded = true;
    ecfg.link = LinkConfig::with_bandwidth(100e6);
    ecfg.seed = 42;
    let mut cfg = ContinuousConfig::new("artifacts", ecfg);
    cfg.max_group = 4;
    cfg.max_groups = 2;
    cfg.clock = ClockMode::Step { step_s: 0.05 };
    cfg.preload_requests = trace.requests.len();
    cfg.trace = Some(TracerConfig::default());
    let server = ContinuousServer::start(cfg)?;
    let handles = server.dispatch(trace);
    let mut tokens = Vec::with_capacity(handles.len());
    for h in handles {
        tokens.push(h.wait()?.tokens);
    }
    let tracer = server.tracer();
    server.shutdown()?;
    Ok((chrome_trace(&tracer.events()).to_string(), tokens))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let mix = args.get(1).map(String::as_str).unwrap_or("bursty_chat");
    let Some(mut spec) = WorkloadSpec::named(mix) else {
        eprintln!("trace_dump: unknown mix {mix:?}; available: {:?}", WorkloadSpec::mix_names());
        std::process::exit(2);
    };
    spec.requests = match args.get(2) {
        Some(n) => n.parse().map_err(|e| anyhow::anyhow!("bad request count {n:?}: {e}"))?,
        None => 6,
    };
    let trace = spec.generate();
    println!(
        "trace_dump: mix {} — {} requests over {} arrival steps, deterministic step clock",
        trace.name,
        trace.requests.len(),
        trace.max_step() + 1
    );

    let (json1, toks1) = replay(&trace)?;
    let (json2, toks2) = replay(&trace)?;
    anyhow::ensure!(toks1 == toks2, "tokens diverged between seeded replays");
    anyhow::ensure!(json1 == json2, "Chrome trace JSON diverged between seeded replays");

    std::fs::write("TRACE_dump.json", &json1)?;
    println!(
        "two replays byte-identical ({} bytes); wrote TRACE_dump.json",
        json1.len()
    );
    Ok(())
}
