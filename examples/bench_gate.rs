//! CI bench-regression gate: compare a fresh `BENCH_kvstore.json` against
//! the committed `BENCH_baseline.json` and exit non-zero when any policy's
//! throughput dropped beyond the allowed fraction.
//!
//! ```bash
//! cargo bench --bench perf_hotpath
//! cargo run --release --example bench_gate -- BENCH_baseline.json BENCH_kvstore.json
//! ```
//!
//! The gate logic (and its tests) live in `kvpr::util::benchgate`; this is
//! the file-reading, exit-code-setting shell around it.

use kvpr::util::benchgate::{compare, DEFAULT_MAX_DROP};
use kvpr::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    if args.len() < 3 || args.len() > 4 {
        eprintln!("usage: bench_gate <baseline.json> <fresh.json> [max_drop_frac]");
        std::process::exit(2);
    }
    let max_drop = match args.get(3) {
        Some(s) => match s.parse::<f64>() {
            Ok(v) if v >= 0.0 && v < 1.0 => v,
            _ => {
                eprintln!("bench_gate: max_drop_frac must be a fraction in [0, 1): {s}");
                std::process::exit(2);
            }
        },
        None => DEFAULT_MAX_DROP,
    };
    let read = |path: &str| -> Json {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) => {
                eprintln!("bench_gate: cannot read {path}: {e}");
                std::process::exit(2);
            }
        };
        match Json::parse(&text) {
            Ok(j) => j,
            Err(e) => {
                eprintln!("bench_gate: {path}: {e}");
                std::process::exit(2);
            }
        }
    };
    let baseline = read(&args[1]);
    let fresh = read(&args[2]);
    let report = compare(&baseline, &fresh, max_drop);
    if report.provisional {
        println!(
            "bench_gate: baseline {} is provisional — structure checked only.\n\
             bench_gate: refresh it from a trusted machine with:\n\
             bench_gate:   cargo bench --bench perf_hotpath && cp BENCH_kvstore.json {}",
            args[1], args[1]
        );
    }
    println!(
        "bench_gate: {} metric path(s) checked against {} (max drop {:.0}%)",
        report.checked,
        args[1],
        max_drop * 100.0
    );
    for f in &report.failures {
        eprintln!("bench_gate: FAIL {f}");
    }
    if !report.passed() {
        std::process::exit(1);
    }
    println!("bench_gate: OK");
}
