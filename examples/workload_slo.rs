//! **Workload SLO driver**: replay one named workload mix through the
//! continuous-batching server and its analytic twin, then print an SLO
//! table — TTFT/TPOT percentiles, SLO attainment, and throughput from
//! both executions — and write the numbers to `SLO_workload.json`
//! (the artifact the CI smoke job uploads).
//!
//! The served half measures wall-clock latency against the mix's declared
//! [`SloTargets`]; the sim half replays the identical trace through
//! [`EvictionSimConfig::from_trace`] on the shared decode-step clock, so
//! its per-mix `steps_per_s` and queueing-delay TTFT are wall-clock-free
//! reference numbers (`rust/tests/workload_trace.rs` pins how tightly the
//! two executions must agree).
//!
//! The served replay runs with tracing enabled: a Chrome `trace_event`
//! export of the whole run lands in `TRACE_workload.json` (load it in
//! Perfetto / `chrome://tracing`), the tracer's plan-vs-actual residual
//! summary prints after the SLO table, and the flight recorder dumps on
//! any TTFT SLO breach.
//!
//! ```bash
//! cargo run --release --example workload_slo -- [mix] [requests]
//! # mix: bursty_chat (default) | diurnal_mixed | rag_long_context
//! # requests: optional override of the mix's request count (CI smoke: 8)
//! ```
//!
//! Runs with or without `make artifacts` (interpreter fallback).

use std::time::{Duration, Instant};

use kvpr::config::{HardwareConfig, ModelConfig};
use kvpr::coordinator::{ContinuousConfig, ContinuousServer, Submit};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::kvstore::{simulate_eviction, EvictionSimConfig, RecomputeAware};
use kvpr::obs::{chrome_trace, AnomalyConfig, TracerConfig};
use kvpr::scheduler::CostModel;
use kvpr::transfer::LinkConfig;
use kvpr::util::stats::Summary;
use kvpr::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let mix = args.get(1).map(String::as_str).unwrap_or("bursty_chat");
    let Some(mut spec) = WorkloadSpec::named(mix) else {
        eprintln!("workload_slo: unknown mix {mix:?}; available: {:?}", WorkloadSpec::mix_names());
        std::process::exit(2);
    };
    if let Some(n) = args.get(2) {
        spec.requests = n
            .parse()
            .map_err(|e| anyhow::anyhow!("bad request count {n:?}: {e}"))?;
    }
    let trace = spec.generate();
    println!(
        "workload_slo: mix {} — {} requests over {} arrival steps, {} gen tokens",
        trace.name,
        trace.requests.len(),
        trace.max_step() + 1,
        trace.total_gen_tokens()
    );

    // -- analytic replay: the trace through the eviction sim ----------------
    let cost = CostModel::from_hardware(&HardwareConfig::a100_x16(), &ModelConfig::opt_6_7b(), 32);
    let sim_cfg = EvictionSimConfig::from_trace(cost.clone(), &trace);
    let sim = simulate_eviction(&sim_cfg, &RecomputeAware::new(cost));
    let mut delays = Summary::new();
    for &d in &sim.admit_delay_steps {
        delays.add(d as f64);
    }
    let sim_ttft_p99_steps = if delays.count() == 0 { 0.0 } else { delays.p99() };

    // -- served replay: the same trace through the continuous loop ----------
    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.weights_offloaded = true;
    ecfg.link = LinkConfig::with_bandwidth(100e6);
    ecfg.seed = 42;
    let mut cfg = ContinuousConfig::new("artifacts", ecfg);
    cfg.max_group = 4;
    cfg.max_groups = 4;
    cfg.admit_wait = Duration::from_millis(5);
    // full tracing: every event retained for the Chrome export, and the
    // flight recorder dumps its ring on any TTFT SLO breach
    cfg.trace = Some(TracerConfig {
        anomaly: AnomalyConfig { ttft_slo_s: Some(spec.slo.ttft_s), ..AnomalyConfig::default() },
        ..TracerConfig::default()
    });
    let server = ContinuousServer::start(cfg)?;
    server.metrics().set_slo(spec.slo);
    let t0 = Instant::now();
    let handles = server.dispatch(&trace);
    for (h, r) in handles.into_iter().zip(&trace.requests) {
        let resp = h.wait()?;
        assert_eq!(resp.tokens.len(), r.gen_tokens, "request {} length", r.id);
    }
    let wall = t0.elapsed().as_secs_f64();

    let m = server.metrics();
    let ttft = m.ttft_stats();
    let tpot = m.tpot_stats();
    let slo = m.slo_attainment();
    let tok_per_s = m.tokens() as f64 / wall;
    let peak = m.peak_occupancy();

    println!("\n  metric              p50        p95        p99     target  attainment");
    println!(
        "  TTFT        {:9.4}s {:9.4}s {:9.4}s {:9.3}s      {:5.1}%",
        ttft.p50,
        ttft.p95,
        ttft.p99,
        spec.slo.ttft_s,
        slo.ttft_frac() * 100.0
    );
    println!(
        "  TPOT        {:9.4}s {:9.4}s {:9.4}s {:9.3}s      {:5.1}%",
        tpot.p50,
        tpot.p95,
        tpot.p99,
        spec.slo.tpot_s,
        slo.tpot_frac() * 100.0
    );
    println!(
        "\n  served: {:.1} tok/s over {:.2}s wall, peak occupancy {:.0}, backpressure {}",
        tok_per_s,
        wall,
        peak,
        m.backpressure_events()
    );
    println!(
        "  sim:    {:.0} steps/s (analytic), peak concurrency {}, p99 TTFT {:.0} steps, {} completed",
        sim.steps_per_s, sim.peak_concurrency, sim_ttft_p99_steps, sim.completed
    );

    let json = format!(
        "{{\n  \"workload\": \"{}\",\n  \"requests\": {},\n  \"slo\": {{ \"ttft_s\": {}, \"tpot_s\": {} }},\n  \"served\": {{ \"ttft_p50_s\": {:.6}, \"ttft_p95_s\": {:.6}, \"ttft_p99_s\": {:.6}, \"tpot_p50_s\": {:.6}, \"tpot_p95_s\": {:.6}, \"tpot_p99_s\": {:.6}, \"ttft_attainment\": {:.4}, \"tpot_attainment\": {:.4}, \"tok_per_s\": {:.3}, \"peak_occupancy\": {:.1}, \"backpressure\": {} }},\n  \"sim\": {{ \"steps_per_s\": {:.3}, \"ttft_p99_steps\": {:.1}, \"peak_concurrency\": {}, \"completed\": {} }}\n}}\n",
        trace.name,
        trace.requests.len(),
        spec.slo.ttft_s,
        spec.slo.tpot_s,
        ttft.p50,
        ttft.p95,
        ttft.p99,
        tpot.p50,
        tpot.p95,
        tpot.p99,
        slo.ttft_frac(),
        slo.tpot_frac(),
        tok_per_s,
        peak,
        m.backpressure_events(),
        sim.steps_per_s,
        sim_ttft_p99_steps,
        sim.peak_concurrency,
        sim.completed
    );
    let tracer = server.tracer();
    server.shutdown()?;

    // -- observability artifacts -------------------------------------------
    if let Some(pva) = tracer.plan_vs_actual() {
        println!();
        print!("{}", pva.summary_table().render());
    }
    let dumps = tracer.dumps();
    if !dumps.is_empty() {
        println!(
            "  flight recorder: {} dump(s) — first: {:?} at step {}",
            dumps.len(),
            dumps[0].reason,
            dumps[0].step
        );
    }
    let trace_json = chrome_trace(&tracer.events()).to_string();
    std::fs::write("TRACE_workload.json", &trace_json)?;
    std::fs::write("SLO_workload.json", &json)?;
    println!("\nwrote SLO_workload.json and TRACE_workload.json");
    Ok(())
}
