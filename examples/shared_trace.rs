//! **Prefix-sharing trace**: replay the `shared_chat` workload mix — 80 %
//! multi-turn assistant traffic over a class-wide system preamble, 20 %
//! never-shared private traffic — through the continuous serving loop
//! twice, once with cross-request prefix sharing enabled and once without.
//! The sharing run adopts the registered preamble blocks at admission
//! (`ShareTotals` hits, `share_hit` instants on the trace timeline); the
//! private run registers nothing.  The sharing run's serving timeline
//! lands in `TRACE_shared.json` (the CI perfetto artifact).
//!
//! ```bash
//! cargo run --release --example shared_trace -- [requests]
//! ```
//!
//! Runs with or without `make artifacts` (interpreter fallback).

use kvpr::coordinator::{ContinuousConfig, ContinuousServer, ShareTotals, Submit};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::obs::{chrome_trace, TracerConfig};
use kvpr::transfer::LinkConfig;
use kvpr::util::clock::ClockMode;
use kvpr::workload::{Trace, WorkloadSpec};

fn serve(trace: &Trace, sharing: bool) -> anyhow::Result<(ShareTotals, usize, String)> {
    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.weights_offloaded = true;
    ecfg.link = LinkConfig::with_bandwidth(100e6);
    ecfg.seed = 42;
    let cfg = ContinuousConfig::builder("artifacts", ecfg)
        .max_group(1) // one group per request: sharing happens across groups
        .max_groups(4)
        .clock(ClockMode::Step { step_s: 0.05 })
        .trace(TracerConfig::default())
        .prefix_sharing(sharing)
        .build();
    let server = ContinuousServer::start(cfg)?;
    let mut tokens = 0usize;
    for h in server.dispatch(trace) {
        tokens += h.wait()?.tokens.len();
    }
    let share = server.metrics().share_totals();
    let tracer = server.tracer();
    server.shutdown()?;
    let json = chrome_trace(&tracer.events()).to_string();
    Ok((share, tokens, json))
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let requests: usize = match args.get(1) {
        Some(n) => n.parse().map_err(|e| anyhow::anyhow!("bad request count {n:?}: {e}"))?,
        None => 12,
    };
    let mut spec = WorkloadSpec::named("shared_chat").expect("named mix exists");
    spec.requests = requests;
    let trace = spec.generate();
    let sharers =
        trace.requests.iter().filter(|r| r.shared_prefix_tokens > 0).count();
    println!(
        "shared_trace: {} requests (mix {}), {} carry a shared preamble",
        trace.requests.len(),
        trace.name,
        sharers
    );

    let (on, tokens_on, json) = serve(&trace, true)?;
    let (off, tokens_off, _) = serve(&trace, false)?;
    println!(
        "sharing on:  {} hits, {} blocks / {} tokens adopted | {} tokens served",
        on.hits, on.blocks, on.tokens, tokens_on
    );
    println!("sharing off: {} hits | {} tokens served", off.hits, tokens_off);

    anyhow::ensure!(sharers >= 2, "shared_chat must generate adoptable preambles");
    anyhow::ensure!(on.hits >= 1, "sharing run must adopt the registered preamble");
    anyhow::ensure!(on.tokens >= on.blocks, "adopted blocks cover whole-block tokens");
    anyhow::ensure!(off == ShareTotals::default(), "sharing-off run must record no hits");
    anyhow::ensure!(tokens_on == tokens_off, "sharing must not change served token counts");
    anyhow::ensure!(json.contains("share_hit"), "export must carry the share_hit instants");
    std::fs::write("TRACE_shared.json", &json)?;
    println!(
        "wrote TRACE_shared.json ({} bytes) — share_hit instants on the step track",
        json.len()
    );
    Ok(())
}
