//! Quickstart: load the tiny model's AOT artifacts, generate text for one
//! prompt with the KVPR engine, and print what the scheduler decided.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use kvpr::engine::{Engine, EngineConfig, EnginePolicy};
use kvpr::model::ByteTokenizer;
use kvpr::transfer::LinkConfig;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");

    // An engine with the emulated PCIe link throttled to 30 MB/s — the
    // point where, for the tiny model, KV transfer dominates decode compute
    // exactly as PCIe 4.0 does for OPT-30B on an A100 (DESIGN.md §2).
    let mut cfg = EngineConfig::new(EnginePolicy::Kvpr);
    cfg.link = LinkConfig::with_bandwidth(30e6);
    let engine = Engine::new(artifacts, cfg)?;

    println!("profiled system: {:#?}", engine.profile());

    let tok = ByteTokenizer::new();
    let prompt = "the quick brown fox jumps over";
    let ids = vec![tok.encode(prompt, 32)];

    let result = engine.generate(&ids, 24)?;

    println!("prompt : {prompt:?}");
    println!("tokens : {:?}", result.tokens[0]);
    println!("text   : {:?}", tok.decode(&result.tokens[0]));
    println!();
    println!(
        "prefill {:.3}s | decode {:.3}s ({:.1} tok/s)",
        result.metrics.prefill_s,
        result.metrics.decode_s,
        result.metrics.decode_tok_per_s()
    );
    println!("split points per step (the scheduler's l): {:?}", result.metrics.splits);
    println!("breakdown: {:#?}", result.metrics.breakdown);
    println!(
        "GPU compute utilization during decode: {:.1}%",
        result.metrics.breakdown.compute_utilization() * 100.0
    );
    Ok(())
}
