//! **Sharded serving trace**: replay one bursty workload trace through the
//! sharded [`Router`] — N continuous-batching worker shards over shared
//! host tiers, each shard's cross-shard hop declared as a remote rung in
//! its tier topology — with tracing enabled on every shard, then merge the
//! shards' serving loops into one Chrome `trace_event` document.  Each
//! shard lands on its own named process track (`shard-0`, `shard-1`, ...),
//! so Perfetto / `chrome://tracing` shows the loops' steps side by side.
//! The export lands in `TRACE_shards.json` (the CI perfetto artifact).
//!
//! ```bash
//! cargo run --release --example shard_trace -- [shards] [requests]
//! ```
//!
//! Runs with or without `make artifacts` (interpreter fallback).

use kvpr::coordinator::{ContinuousConfig, Router, RouterConfig, Submit};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::obs::TracerConfig;
use kvpr::transfer::LinkConfig;
use kvpr::util::clock::ClockMode;
use kvpr::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let shards: usize = match args.get(1) {
        Some(n) => n.parse().map_err(|e| anyhow::anyhow!("bad shard count {n:?}: {e}"))?,
        None => 2,
    };
    let requests: usize = match args.get(2) {
        Some(n) => n.parse().map_err(|e| anyhow::anyhow!("bad request count {n:?}: {e}"))?,
        None => 6,
    };
    let mut spec = WorkloadSpec::named("bursty_chat").expect("named mix exists");
    spec.requests = requests;
    let trace = spec.generate();

    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.weights_offloaded = true;
    ecfg.link = LinkConfig::with_bandwidth(100e6);
    ecfg.seed = 42;
    let base = ContinuousConfig::builder("artifacts", ecfg)
        .max_group(4)
        .max_groups(2)
        .clock(ClockMode::Step { step_s: 0.05 })
        .trace(TracerConfig::default())
        .build();
    let router = Router::start(RouterConfig::new(shards, base))?;
    println!(
        "shard_trace: {} requests through {} shards (mix {})",
        trace.requests.len(),
        router.n_shards(),
        trace.name
    );

    for h in router.dispatch(&trace) {
        h.wait()?;
    }
    let t = router.totals();
    println!(
        "placement: {} fresh, {} affinity hits, {} steals | {} tokens over {} decode steps",
        t.fresh,
        t.affinity_hits,
        t.steals,
        router.total_tokens(),
        router.total_steps()
    );
    for i in 0..router.n_shards() {
        let m = router.shard(i).metrics();
        println!("  shard-{i}: {} requests, {} steps", m.requests(), m.steps());
    }

    let json = router.export_chrome_trace().to_string();
    router.shutdown()?;
    anyhow::ensure!(json.contains("shard-0"), "export must name the shard process tracks");
    std::fs::write("TRACE_shards.json", &json)?;
    println!("wrote TRACE_shards.json ({} bytes) — one process track per shard", json.len());
    Ok(())
}
