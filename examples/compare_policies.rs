//! Engine-mode wall-clock A/B: run the *real* PJRT stack under every engine
//! policy across a sweep of emulated link bandwidths, and watch the
//! crossover — at low bandwidth (transfer-bound, the paper's regime) KVPR
//! wins; as the link speeds up the policies converge, exactly the
//! bandwidth sensitivity Fig 6/7 imply.
//!
//! Every run also cross-checks exactness: all policies must emit the same
//! tokens.
//!
//! ```bash
//! cargo run --release --example compare_policies
//! ```

use kvpr::engine::{Engine, EngineConfig, EnginePolicy};
use kvpr::model::ByteTokenizer;
use kvpr::transfer::LinkConfig;
use kvpr::util::table::Table;
use std::path::Path;

const GEN_LEN: usize = 40;

fn main() -> anyhow::Result<()> {
    let tok = ByteTokenizer::new();
    let prompts = vec![
        tok.encode("the pcie bus is the bottleneck for offloaded kv caches", 32),
        tok.encode("recompute part of the cache while the rest streams in", 32),
        tok.encode("a linear program picks the split point adaptively", 32),
        tok.encode("exact attention, no approximation, faster decode", 32),
    ];

    let policies = [
        EnginePolicy::FullTransferSync,
        EnginePolicy::FullTransferOverlap,
        EnginePolicy::KvprFused,
        EnginePolicy::Kvpr,
    ];

    let mut t = Table::new(
        &format!("compare_policies — real-engine decode seconds ({GEN_LEN} tokens, batch 4)"),
        &["link MB/s", "full-sync", "full-overlap", "kvpr-fused", "kvpr", "kvpr vs overlap"],
    );

    for mbps in [15.0f64, 30.0, 60.0, 120.0] {
        let mut row = vec![format!("{mbps:.0}")];
        let mut times = Vec::new();
        let mut reference_tokens: Option<Vec<Vec<i32>>> = None;
        for policy in policies {
            let mut cfg = EngineConfig::new(policy);
            cfg.link = LinkConfig::with_bandwidth(mbps * 1e6);
            cfg.seed = 7;
            let engine = Engine::new(Path::new("artifacts"), cfg)?;
            let r = engine.generate(&prompts, GEN_LEN)?;
            match &reference_tokens {
                None => reference_tokens = Some(r.tokens.clone()),
                Some(want) => assert_eq!(
                    want, &r.tokens,
                    "exactness violation under {policy:?} at {mbps} MB/s"
                ),
            }
            times.push(r.metrics.decode_s);
            row.push(format!("{:.2}", r.metrics.decode_s));
        }
        let overlap = times[1];
        let kvpr = times[3];
        row.push(format!("{:+.1}%", (kvpr / overlap - 1.0) * 100.0));
        t.row(&row);
        // progress feedback (each cell is a full engine construction + run)
        eprintln!("  finished {mbps} MB/s sweep");
    }

    std::fs::create_dir_all("reports").ok();
    t.emit("compare_policies");
    println!("✓ all policies produced identical tokens at every bandwidth");
    Ok(())
}
