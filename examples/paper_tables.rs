//! Regenerate every table and figure of the paper's evaluation in one run
//! and write them to `reports/` (same outputs as `cargo bench`, bundled).
//!
//! ```bash
//! cargo run --release --example paper_tables
//! ```

fn main() {
    std::fs::create_dir_all("reports").ok();
    let t0 = std::time::Instant::now();

    kvpr::paper::table1().emit("table1_pcie_vs_compute");
    kvpr::paper::fig6_seq_sweep().emit("fig6_seq_sweep");
    kvpr::paper::fig6_batch_sweep().emit("fig6_batch_sweep");
    kvpr::paper::fig7_latency().emit("fig7_latency");
    let (summary, timeline) = kvpr::paper::fig8_utilization();
    summary.emit("fig8_utilization");
    timeline.emit("fig8_timeline");
    kvpr::paper::fig9_compression().emit("fig9_compression");
    kvpr::paper::fig10_breakdown().emit("fig10_breakdown");
    kvpr::paper::table2_hiding().emit("table2_hiding_ablation");
    kvpr::paper::fig12_splits().emit("fig12_split_points");
    kvpr::paper::table34_detailed().emit("table34_detailed");
    kvpr::paper::table5_lowend().emit("table5_lowend");
    kvpr::paper::fig13_llama().emit("fig13_llama");
    kvpr::paper::fig14_multigpu().emit("fig14_multigpu");

    println!(
        "regenerated 14 tables/figures into reports/ in {:.1}s",
        t0.elapsed().as_secs_f64()
    );
}
