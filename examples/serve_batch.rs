//! **The end-to-end serving driver** (DESIGN.md §7): run the same request
//! trace through both serving modes over the full stack — coordinator →
//! scheduler → engine → artifacts over the emulated PCIe link:
//!
//!   1. the whole-batch [`Server`] (batcher forms a batch, decodes it to
//!      completion) for KVPR vs the full-transfer baseline, and
//!   2. the **continuous-batching** [`ContinuousServer`] event loop
//!      (per-step admission/retirement, per-batch Eq. 11 re-planning,
//!      KV-budget backpressure) against its own no-batching configuration.
//!
//! Three invariants are checked, matching the paper's claims:
//!   * **Exactness** — every mode/policy emits identical tokens for
//!     identical requests (recomputation and batching are not
//!     approximations).
//!   * **Performance** — with the link throttled so transfer dominates,
//!     KVPR's decode beats full transfer.
//!   * **Serving** — continuous batching beats one-request-at-a-time
//!     throughput on the same hardware.
//!
//! Runs with or without `make artifacts` (interpreter fallback).
//!
//! ```bash
//! cargo run --release --example serve_batch
//! ```

use std::time::{Duration, Instant};

use kvpr::coordinator::{Batcher, ContinuousConfig, ContinuousServer, Server, ServerConfig, Submit};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::transfer::LinkConfig;

const GEN_LEN: usize = 24;
const N_REQUESTS: usize = 8;
const LINK_MBPS: f64 = 10.0;

fn trace() -> Vec<String> {
    (0..N_REQUESTS)
        .map(|i| {
            [
                "the quick brown fox jumps over the lazy dog",
                "kv cache partial recomputation hides the pcie bottleneck",
                "profile, schedule, overlap: the kvpr recipe",
                "large language models decode one token at a time",
            ][i % 4]
                .to_string()
        })
        .collect()
}

fn run_batch_policy(policy: EnginePolicy) -> anyhow::Result<(Vec<Vec<i32>>, f64, f64, f64)> {
    let mut ecfg = EngineConfig::new(policy);
    ecfg.link = LinkConfig::with_bandwidth(LINK_MBPS * 1e6);
    ecfg.seed = 42; // identical weights across engines
    let mut scfg = ServerConfig::new("artifacts", ecfg);
    scfg.batcher = Batcher::new(4, Duration::from_millis(20));
    let server = Server::start(scfg)?;

    let t0 = Instant::now();
    let handles: Vec<_> = trace()
        .iter()
        .map(|p| server.dispatch((p.as_str(), GEN_LEN)).pop().unwrap())
        .collect();
    let mut tokens = Vec::with_capacity(N_REQUESTS);
    let mut decode_total = 0.0;
    for h in handles {
        let r = h.wait()?;
        decode_total += r.decode_s;
        tokens.push(r.tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mean_lat, _p50, p99) = server.metrics().latency_stats();
    let tput = server.metrics().tokens() as f64 / wall;
    println!(
        "  {:18} wall {:6.2}s | mean latency {:6.3}s p99 {:6.3}s | {:6.1} tok/s | decode-sum {:6.2}s",
        format!("{policy:?}"),
        wall,
        mean_lat,
        p99,
        tput,
        decode_total
    );
    server.shutdown()?;
    Ok((tokens, wall, mean_lat, tput))
}

fn run_continuous(max_group: usize, label: &str) -> anyhow::Result<(Vec<Vec<i32>>, f64)> {
    let mut ecfg = EngineConfig::new(EnginePolicy::Kvpr);
    ecfg.weights_offloaded = true; // throughput regime: weight traffic amortises
    ecfg.link = LinkConfig::with_bandwidth(100e6);
    ecfg.seed = 42;
    let mut cfg = ContinuousConfig::new("artifacts", ecfg);
    cfg.max_group = max_group;
    // the serial baseline must be strictly one request at a time — with
    // max_groups > 1 two singleton groups would still interleave
    cfg.max_groups = if max_group == 1 { 1 } else { 2 };
    cfg.prompt_bucket = 32;
    cfg.admit_wait = Duration::from_millis(50);
    let server = ContinuousServer::start(cfg)?;

    let t0 = Instant::now();
    let handles: Vec<_> = trace()
        .iter()
        .map(|p| server.dispatch((p.as_str(), GEN_LEN)).pop().unwrap())
        .collect();
    let mut tokens = Vec::with_capacity(N_REQUESTS);
    for h in handles {
        tokens.push(h.wait()?.tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    let (mean_step, p99_step) = m.step_stats();
    println!(
        "  {:18} wall {:6.2}s | {:6.1} tok/s | {} steps, occupancy {:4.1}, step mean {:.1} ms p99 {:.1} ms, queue depth {:4.1}, backpressure {}",
        label,
        wall,
        m.tokens() as f64 / wall,
        m.steps(),
        m.mean_occupancy(),
        mean_step * 1e3,
        p99_step * 1e3,
        m.mean_queue_depth(),
        m.backpressure_events(),
    );
    let tput = m.tokens() as f64 / wall;
    server.shutdown()?;
    Ok((tokens, tput))
}

fn main() -> anyhow::Result<()> {
    println!(
        "serve_batch: {N_REQUESTS} requests x {GEN_LEN} tokens, link {LINK_MBPS} MB/s, batch<=4\n"
    );

    println!("whole-batch server, KVPR vs full-transfer baseline:");
    let (tok_full, wall_full, lat_full, tput_full) =
        run_batch_policy(EnginePolicy::FullTransferOverlap)?;
    let (tok_kvpr, wall_kvpr, lat_kvpr, tput_kvpr) = run_batch_policy(EnginePolicy::Kvpr)?;

    // 1. exactness: identical tokens
    assert_eq!(
        tok_full, tok_kvpr,
        "EXACTNESS VIOLATION: policies produced different tokens"
    );
    println!("\n✓ exactness: KVPR tokens identical to full-transfer baseline");

    // 2. performance
    println!(
        "✓ decode wall: full-transfer {:.2}s vs KVPR {:.2}s ({:+.1}%)",
        wall_full,
        wall_kvpr,
        (wall_kvpr / wall_full - 1.0) * 100.0
    );
    println!(
        "  mean latency {:.3}s -> {:.3}s | throughput {:.1} -> {:.1} tok/s ({:+.1}%)",
        lat_full,
        lat_kvpr,
        tput_full,
        tput_kvpr,
        (tput_kvpr / tput_full - 1.0) * 100.0
    );
    if wall_kvpr < wall_full {
        println!("  KVPR wins on this link, as the paper predicts for transfer-bound decode.");
    } else {
        println!("  (link fast enough that transfer no longer dominates — raise LINK_MBPS down)");
    }

    // 3. continuous batching vs one-request-at-a-time on the same hardware
    println!("\ncontinuous-batching loop (weights offloaded, link 100 MB/s):");
    let (tok_cont, tput_cont) = run_continuous(N_REQUESTS, "continuous x8")?;
    let (tok_serial, tput_serial) = run_continuous(1, "serial x1")?;
    // the interpreter is bitwise-deterministic across batch buckets;
    // compiled XLA may legally reorder reductions per bucket, so the
    // cross-bucket comparison is pinned only on the interpreter backend
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        assert_eq!(
            tok_cont, tok_serial,
            "EXACTNESS VIOLATION: continuous batching changed tokens"
        );
        println!("\n✓ exactness: continuous tokens identical to serial decode");
    }
    println!(
        "\n✓ continuous batching: {:.1} tok/s vs serial {:.1} tok/s ({:+.1}%)",
        tput_cont,
        tput_serial,
        (tput_cont / tput_serial - 1.0) * 100.0
    );
    Ok(())
}
