//! **The end-to-end driver** (DESIGN.md §7): start the coordinator, serve
//! batched requests through the full stack — router → batcher → engine →
//! PJRT artifacts over the emulated PCIe link — for KVPR and for the
//! full-transfer baseline, and report latency/throughput.
//!
//! Two invariants are checked, matching the paper's claims:
//!   1. **Exactness** — both policies emit identical tokens for identical
//!      requests (recomputation is not an approximation).
//!   2. **Performance** — with the link throttled so KV transfer dominates,
//!      KVPR's decode is faster.
//!
//! The run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_batch
//! ```

use std::time::{Duration, Instant};

use kvpr::coordinator::{Batcher, Server, ServerConfig};
use kvpr::engine::{EngineConfig, EnginePolicy};
use kvpr::transfer::LinkConfig;

const GEN_LEN: usize = 48;
const N_REQUESTS: usize = 8;
const LINK_MBPS: f64 = 10.0;

fn run_policy(policy: EnginePolicy) -> anyhow::Result<(Vec<Vec<i32>>, f64, f64, f64)> {
    let mut ecfg = EngineConfig::new(policy);
    ecfg.link = LinkConfig::with_bandwidth(LINK_MBPS * 1e6);
    ecfg.seed = 42; // identical weights across engines
    let mut scfg = ServerConfig::new("artifacts", ecfg);
    scfg.batcher = Batcher::new(4, Duration::from_millis(20));
    let server = Server::start(scfg)?;

    let prompts: Vec<String> = (0..N_REQUESTS)
        .map(|i| {
            [
                "the quick brown fox jumps over the lazy dog",
                "kv cache partial recomputation hides the pcie bottleneck",
                "profile, schedule, overlap: the kvpr recipe",
                "large language models decode one token at a time",
            ][i % 4]
                .to_string()
        })
        .collect();

    let t0 = Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p, GEN_LEN))
        .collect();
    let mut tokens = Vec::with_capacity(N_REQUESTS);
    let mut decode_total = 0.0;
    for h in handles {
        let r = h.wait()?;
        decode_total += r.decode_s;
        tokens.push(r.tokens);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (mean_lat, _p50, p99) = server.metrics().latency_stats();
    let tput = server.metrics().tokens() as f64 / wall;
    println!(
        "  {:18} wall {:6.2}s | mean latency {:6.3}s p99 {:6.3}s | {:6.1} tok/s | decode-sum {:6.2}s",
        format!("{policy:?}"),
        wall,
        mean_lat,
        p99,
        tput,
        decode_total
    );
    server.shutdown()?;
    Ok((tokens, wall, mean_lat, tput))
}

fn main() -> anyhow::Result<()> {
    println!(
        "serve_batch: {N_REQUESTS} requests x {GEN_LEN} tokens, link {LINK_MBPS} MB/s, batch<=4\n"
    );

    let (tok_full, wall_full, lat_full, tput_full) =
        run_policy(EnginePolicy::FullTransferOverlap)?;
    let (tok_kvpr, wall_kvpr, lat_kvpr, tput_kvpr) = run_policy(EnginePolicy::Kvpr)?;

    // 1. exactness: identical tokens
    assert_eq!(
        tok_full, tok_kvpr,
        "EXACTNESS VIOLATION: policies produced different tokens"
    );
    println!("\n✓ exactness: KVPR tokens identical to full-transfer baseline");

    // 2. performance
    println!(
        "✓ decode wall: full-transfer {:.2}s vs KVPR {:.2}s ({:+.1}%)",
        wall_full,
        wall_kvpr,
        (wall_kvpr / wall_full - 1.0) * 100.0
    );
    println!(
        "  mean latency {:.3}s -> {:.3}s | throughput {:.1} -> {:.1} tok/s ({:+.1}%)",
        lat_full,
        lat_kvpr,
        tput_full,
        tput_kvpr,
        (tput_kvpr / tput_full - 1.0) * 100.0
    );
    if wall_kvpr < wall_full {
        println!("  KVPR wins on this link, as the paper predicts for transfer-bound decode.");
    } else {
        println!("  (link fast enough that transfer no longer dominates — raise LINK_MBPS down)");
    }
    Ok(())
}
