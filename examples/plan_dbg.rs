// perf probe: per-artifact call times at b=4 (used by the §Perf pass)
use kvpr::model::ModelWeights;
use kvpr::runtime::{ArgValue, Runtime};
use std::time::Instant;

fn time_calls<F: FnMut()>(n: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..n { f(); }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() -> anyhow::Result<()> {
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    let m = rt.manifest().clone();
    let h = m.model.hidden;
    let w = ModelWeights::generate(&m.model, 1);
    let b = 4;
    let wargs = |layer: usize| -> Vec<ArgValue> {
        w.layer(layer).iter().map(|(_, d, _)| ArgValue::F32(d.as_slice())).collect()
    };

    let x = vec![0.1f32; b * h];
    let kc = vec![0.1f32; b * 128 * h];
    let vc = vec![0.1f32; b * 128 * h];
    let full = rt.artifact(&m.decode_full_name(b))?;
    let mut args: Vec<ArgValue> = vec![ArgValue::F32(&x), ArgValue::F32(&kc), ArgValue::F32(&vc), ArgValue::I32(100)];
    args.extend(wargs(0));
    let t = time_calls(20, || { full.call(&args).unwrap(); });
    println!("decode_full_b4      {:.2} ms/call", t * 1e3);

    for l in [32usize, 64, 96] {
        let x_pre = vec![0.1f32; b * l * h];
        let rec = rt.artifact(&m.recompute_name(b, l))?;
        let lw = w.layer(0);
        let rargs = vec![ArgValue::F32(&x_pre), ArgValue::F32(lw.get("ln1_g")), ArgValue::F32(lw.get("ln1_b")),
            ArgValue::F32(lw.get("wk")), ArgValue::F32(lw.get("bk")), ArgValue::F32(lw.get("wv")), ArgValue::F32(lw.get("bv"))];
        let t = time_calls(20, || { rec.call(&rargs).unwrap(); });
        println!("recompute_b4_l{l:<3}   {:.2} ms/call", t * 1e3);

        let k_re = vec![0.1f32; b * l * h];
        let k_rest = vec![0.1f32; b * (128 - l) * h];
        let merge = rt.artifact(&m.decode_merge_name(b, l))?;
        let mut margs: Vec<ArgValue> = vec![ArgValue::F32(&x), ArgValue::F32(&k_re), ArgValue::F32(&k_re),
            ArgValue::F32(&k_rest), ArgValue::F32(&k_rest), ArgValue::I32(100)];
        margs.extend(wargs(0));
        let t = time_calls(20, || { merge.call(&margs).unwrap(); });
        println!("decode_merge_b4_l{l:<2} {:.2} ms/call", t * 1e3);
    }

    let head = rt.artifact(&m.lm_head_name(b))?;
    let hargs = vec![ArgValue::F32(&x), ArgValue::F32(&w.tok_table), ArgValue::F32(&w.lnf_g), ArgValue::F32(&w.lnf_b)];
    let t = time_calls(50, || { head.call(&hargs).unwrap(); });
    println!("lm_head_b4          {:.2} ms/call", t * 1e3);
    Ok(())
}
