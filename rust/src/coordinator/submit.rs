//! One submission surface for every serving front end.
//!
//! The first serving PRs grew three entry points — `submit(prompt, n)`,
//! `submit_request(Request)`, `submit_trace(&Trace)` — duplicated on each
//! server type.  This module collapses them: anything submittable converts
//! into a [`SubmitTarget`], and every front end ([`ContinuousServer`],
//! the whole-batch [`Server`], the sharded [`Router`]) implements the
//! [`Submit`] trait, whose [`dispatch`](Submit::dispatch) method is the
//! single public path — the old per-server methods rode one PR as
//! `#[deprecated]` shims and have been deleted.
//!
//! [`ContinuousServer`]: super::ContinuousServer
//! [`Server`]: super::Server
//! [`Router`]: super::Router

use super::request::Request;
use super::server::ResponseHandle;
use crate::workload::Trace;

/// Anything a serving front end accepts: built from a `(prompt, gen_len)`
/// pair, a pre-built [`Request`], or a workload [`Trace`] via `From`/`Into`
/// — callers normally pass those directly to [`Submit::dispatch`] and never
/// name this type.
#[derive(Debug, Clone)]
pub enum SubmitTarget {
    /// A single prompt; the front end assigns the request id.
    Prompt { prompt: String, gen_len: usize },
    /// A pre-built request, submitted verbatim (id, arrival step and
    /// remote-prefix tag included).
    Request(Request),
    /// Every request of a generated workload trace, step-indexed:
    /// admission holds each one until the serving loop's decode-step
    /// clock reaches its arrival step, so the trace's arrival schedule —
    /// not channel delivery order or wall time — decides when it can join
    /// a group.
    Trace(Trace),
}

impl From<(&str, usize)> for SubmitTarget {
    fn from((prompt, gen_len): (&str, usize)) -> Self {
        SubmitTarget::Prompt { prompt: prompt.to_string(), gen_len }
    }
}

impl From<(String, usize)> for SubmitTarget {
    fn from((prompt, gen_len): (String, usize)) -> Self {
        SubmitTarget::Prompt { prompt, gen_len }
    }
}

impl From<Request> for SubmitTarget {
    fn from(req: Request) -> Self {
        SubmitTarget::Request(req)
    }
}

impl From<Trace> for SubmitTarget {
    fn from(trace: Trace) -> Self {
        SubmitTarget::Trace(trace)
    }
}

impl From<&Trace> for SubmitTarget {
    fn from(trace: &Trace) -> Self {
        SubmitTarget::Trace(trace.clone())
    }
}

/// The submission surface shared by every serving front end.
///
/// Implementors provide id allocation and the raw enqueue; the provided
/// [`dispatch`](Submit::dispatch) method maps any [`SubmitTarget`] onto
/// them, so prompt/request/trace submission behaves identically on a
/// [`ContinuousServer`](super::ContinuousServer), the whole-batch
/// [`Server`](super::Server) and the sharded [`Router`](super::Router).
pub trait Submit {
    /// Allocate the next request id (monotonic per front end).
    fn next_request_id(&self) -> u64;

    /// Enqueue one pre-built request; returns a waitable handle.
    fn enqueue(&self, req: Request) -> ResponseHandle;

    /// Submit anything convertible into a [`SubmitTarget`]; returns one
    /// handle per request, in submission order (a prompt or request yields
    /// exactly one, a trace yields one per trace request).
    fn dispatch(&self, target: impl Into<SubmitTarget>) -> Vec<ResponseHandle>
    where
        Self: Sized,
    {
        match target.into() {
            SubmitTarget::Prompt { prompt, gen_len } => {
                let id = self.next_request_id();
                vec![self.enqueue(Request::new(id, &prompt, gen_len))]
            }
            SubmitTarget::Request(req) => vec![self.enqueue(req)],
            SubmitTarget::Trace(trace) => trace
                .requests
                .iter()
                .map(|r| {
                    let id = self.next_request_id();
                    self.enqueue(Request::at_step(
                        id,
                        &r.prompt_text(),
                        r.gen_tokens.max(1),
                        r.step,
                    ))
                })
                .collect(),
        }
    }
}
