//! The continuous-batching serving loop: the step-driven event loop that
//! finally wires coordinator → scheduler → engine together.
//!
//! One worker thread owns the engine and advances the world one **decode
//! step** at a time:
//!
//! 1. **Admission** — queued requests are grouped (up to `max_group`) and
//!    prefilled into a fresh [`DecodeSession`]; a session's full KV-cache
//!    reservation is charged against the `kv_budget_bytes` [`MemPool`]
//!    *before* prefill, so an exhausted budget holds requests in the queue
//!    (backpressure) instead of over-committing host memory.
//! 2. **Batch re-planning** — each formed group re-solves the paper's
//!    Eq. (11) for this step via
//!    [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch),
//!    aggregating every
//!    member's cached-token count s' into the Eq. (10) cost model.  Because
//!    membership changes step to step (admissions, retirements), the split
//!    point is re-planned on every step, exactly as §3.2 prescribes for a
//!    growing s'.
//! 3. **Step** — every group advances one token
//!    ([`Engine::decode_step_with_plan`]).
//! 4. **Retirement** — members whose generation budget is met (or whose
//!    group hit KV capacity) transition `Decoding → Done` and are responded
//!    to immediately; a fully-retired group frees its KV reservation, which
//!    unblocks admission.
//!
//! Under tiering, the hardware shape is **declared, not hard-coded**: the
//! [`TieredKvConfig`] carries a [`TierTopology`] — the ordered chain of
//! tiers with capacities, links and wire widths — which the loop
//! calibrates against the engine's wire at startup and hands to both the
//! [`KvStore`] (pool layout + emulated migration wires) and the planner
//! ([`Planner::with_topology`](crate::scheduler::Planner::with_topology)).
//! Every step then *polls* the store's
//! [`MigrationEngine`](crate::kvstore::MigrationEngine) — landing finished
//! promotions/demotions/spills, aligning the engine's device-resident
//! window to the settled suffix, queueing prefetch — plans each group via
//! one [`PlanInput`] (residency, dropped floor, per-tier prefix spans),
//! and grants the migration engine exactly the idle-link budget those
//! plans predict ([`StepPlan::link_slack_bytes`](crate::scheduler::StepPlan::link_slack_bytes)):
//! the **adaptive step budget** — migrations soak up the wire time the
//! split freed, a zero-slack (full-transfer) step grants only the
//! progress-guarantee minimum, and no static budget knob exists to tune.
//! Nothing on this thread ever waits on the migration links: a full gpu
//! tier is drained by asynchronous demotions whose gpu bytes free at
//! issuance, and with a disk rung declared in the topology a crowded dram
//! tier is drained the same way by watermark-driven spills whose NVMe
//! writebacks ride leftover step budget — admission that would have
//! backpressured parks cold blocks on disk instead, and the planner's
//! topology fold charges disk-resident prefixes their extra hops.
//!
//! Requests move through `Queued → Prefill → Decoding → Done`
//! ([`RequestState`]); per-step latency, queue depth and occupancy land in
//! [`ServeMetrics`], and retirement additionally records each request's
//! TTFT/TPOT sample for the workload harness's SLO table.
//!
//! **Trace replay** (a [`Trace`](crate::workload::Trace) through
//! [`Submit::dispatch`](super::Submit::dispatch)): a request carrying
//! [`Request::arrival_step`] is held in the queue until the loop's
//! decode-step clock reaches that step — admission respects the trace's
//! arrival schedule, not just queue order — and idle stretches fast-forward
//! the clock to the next arrival, so think-time gaps cost no wall time.
//! The analytic sim replays the identical trace on the identical step
//! clock ([`EvictionSimConfig::from_trace`](crate::kvstore::EvictionSimConfig::from_trace)),
//! which is what makes sim-vs-served agreement a testable claim.  Contrast with [`super::Server`], which forms one batch,
//! decodes it to completion, and only then looks at the queue again: under
//! concurrent load the continuous loop starts new work every step and
//! retires finished requests early — the property the KV-offloading serving
//! papers in PAPERS.md show is required for the PCIe bottleneck to even be
//! observable.
//!
//! **Pipelined step runtime** ([`ContinuousConfig::pipeline`]): in
//! [`PipelineMode::Overlapped`] the loop hides its host-side work in the
//! decode shadow twice over.  Across steps, a dedicated stage worker
//! receives one job per step at compute start — pump the migration grant,
//! then pre-solve every group's *next*-step plan against projected inputs
//! — and is collected right after compute; pre-solved plans carry validity
//! tokens ([`PlanHandoff`](crate::scheduler::PlanHandoff)), so any drift
//! (admission, retirement, placement) forces a counted inline re-solve
//! instead of executing a stale plan.  Within a step, the engine's build →
//! stage → submit → collect split double-buffers group staging
//! ([`StageSlots`](crate::engine::StageSlots)): group i+1's embed and
//! first-layer transfers stream while group i computes.  Tokens are
//! bit-identical to [`PipelineMode::Serial`] by construction — an adopted
//! plan is the planner's own solution for the very input the serial path
//! would have solved, and plans move bytes, never math.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::ServeMetrics;
use super::request::{Pending, Request, RequestState, Response};
use super::server::ResponseHandle;
use super::submit::Submit;
use crate::engine::{DecodeSession, Engine, EngineConfig, StageSlots, StepHandoff};
use crate::kvstore::{EvictKind, KvStore, KvStoreConfig, Prefetcher, SharedAdmit, SharedHostTiers};
use crate::memory::{MemPool, PoolGuard};
use crate::model::ByteTokenizer;
use crate::obs::{EventKind, Phase, StepRecord, Tracer, TracerConfig};
use crate::scheduler::{
    LinkSpec, PlanHandoff, PlanInput, Planner, Redemption, SchedulePolicy, TierTopology,
};
use crate::util::clock::{Clock, ClockMode};

/// Continuous-batching loop construction parameters.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    pub artifact_dir: PathBuf,
    pub engine: EngineConfig,
    /// Requests prefilled together into one decode group (rounded up to a
    /// batch bucket internally; keep ≤ the largest bucket).
    pub max_group: usize,
    /// Decode groups stepped concurrently (interleaved on the one engine).
    pub max_groups: usize,
    /// Prompt bucket used for padding (must exist in the manifest).
    pub prompt_bucket: usize,
    /// Host KV budget shared by all live sessions; admission backpressures
    /// against it.
    pub kv_budget_bytes: u64,
    /// How long an *idle* loop waits for more arrivals before prefilling a
    /// partial group (batching window; never delays active decoding).
    pub admit_wait: Duration,
    /// Tiered KV management ([`KvStore`]): when set, `kv_budget_bytes`
    /// becomes the gpu-hbm *tier* budget (a promotion-only cache),
    /// sessions are admitted against the pinned + dram host tiers (with
    /// recompute-aware reclamation) instead of hard backpressure, and a
    /// device-resident KV suffix shrinks every step's transfer term.
    pub tiering: Option<TieredKvConfig>,
    /// Serving clock mode.  [`ClockMode::Wall`] (the default) stamps
    /// latencies from monotonic wall time; [`ClockMode::Step`] makes every
    /// stamp a pure function of the decode-step counter, so two replays of
    /// the same trace produce identical latency samples and trace events.
    pub clock: ClockMode,
    /// When set the serving loop emits structured trace events (request /
    /// phase / migration lifecycle), records per-step plan-vs-actual
    /// telemetry and arms the flight recorder; read results off
    /// [`ContinuousServer::tracer`].  `None` installs the no-op sink — one
    /// predictable branch per would-be event, nothing allocated.
    pub trace: Option<TracerConfig>,
    /// Deterministic replay: block until this many requests have been
    /// received *before* the first step, so arrival events land on the
    /// serve thread in submission order instead of racing the step loop
    /// (0 disables).  Meant for step-clock trace replays; submitters must
    /// send at least this many requests or the loop never starts.
    pub preload_requests: usize,
    /// Step-pipeline mode: [`PipelineMode::Overlapped`] overlaps the next
    /// step's plan solve, group staging and the migration pump with this
    /// step's decode compute; [`PipelineMode::Serial`] keeps the strictly
    /// sequential loop as the A/B oracle.  Tokens are bit-identical either
    /// way.  [`ContinuousConfig::builder`] seeds this from the
    /// `KVPR_PIPELINE` env var so whole test suites flip without code
    /// changes.
    pub pipeline: PipelineMode,
}

impl ContinuousConfig {
    /// Shorthand for [`ContinuousConfig::builder`]`(..).build()` — the
    /// all-defaults config.
    pub fn new(artifact_dir: &str, engine: EngineConfig) -> Self {
        Self::builder(artifact_dir, engine).build()
    }

    /// Start a [`ContinuousConfigBuilder`] seeded with the defaults.  This
    /// is the documented construction path — every knob is a chainable
    /// setter — and the one place environment toggles are read: the
    /// builder seeds [`ContinuousConfig::pipeline`] from `KVPR_PIPELINE`
    /// ([`PipelineMode::from_env`]), and [`ContinuousConfig::new`]
    /// delegates here, so no second env-read site can drift.
    ///
    /// ```
    /// use kvpr::coordinator::ContinuousConfig;
    /// use kvpr::engine::{EngineConfig, EnginePolicy};
    /// use kvpr::scheduler::TierTopology;
    ///
    /// let cfg = ContinuousConfig::builder("artifacts", EngineConfig::new(EnginePolicy::Kvpr))
    ///     .topology(TierTopology::standard(0, 64 << 20, 256 << 20))
    ///     .max_group(2)
    ///     .kv_budget_bytes(64 << 20)
    ///     .build();
    /// assert_eq!(cfg.max_group, 2);
    /// assert!(cfg.tiering.is_some(), "`.topology(..)` switches tiering on");
    /// ```
    pub fn builder(artifact_dir: &str, engine: EngineConfig) -> ContinuousConfigBuilder {
        ContinuousConfigBuilder {
            cfg: ContinuousConfig {
                artifact_dir: PathBuf::from(artifact_dir),
                engine,
                max_group: 4,
                max_groups: 2,
                prompt_bucket: 32,
                kv_budget_bytes: 256 << 20,
                admit_wait: Duration::from_millis(20),
                tiering: None,
                clock: ClockMode::Wall,
                trace: None,
                preload_requests: 0,
                pipeline: PipelineMode::from_env(),
            },
        }
    }
}

/// Fluent constructor for [`ContinuousConfig`]
/// ([`ContinuousConfig::builder`]): chain setters, then [`build`](Self::build).
#[derive(Debug, Clone)]
pub struct ContinuousConfigBuilder {
    cfg: ContinuousConfig,
}

impl ContinuousConfigBuilder {
    /// Requests prefilled together into one decode group.
    pub fn max_group(mut self, n: usize) -> Self {
        self.cfg.max_group = n;
        self
    }

    /// Decode groups stepped concurrently.
    pub fn max_groups(mut self, n: usize) -> Self {
        self.cfg.max_groups = n;
        self
    }

    /// Prompt bucket used for padding (must exist in the manifest).
    pub fn prompt_bucket(mut self, n: usize) -> Self {
        self.cfg.prompt_bucket = n;
        self
    }

    /// Host KV budget (untiered) / gpu-hbm tier budget (tiered).
    pub fn kv_budget_bytes(mut self, bytes: u64) -> Self {
        self.cfg.kv_budget_bytes = bytes;
        self
    }

    /// Idle batching window before prefilling a partial group.
    pub fn admit_wait(mut self, wait: Duration) -> Self {
        self.cfg.admit_wait = wait;
        self
    }

    /// Full tiered-KV configuration (topology plus runtime knobs).
    pub fn tiering(mut self, t: TieredKvConfig) -> Self {
        self.cfg.tiering = Some(t);
        self
    }

    /// Declare the tier chain, switching tiered KV management on with
    /// default runtime knobs (or re-rooting the chain of a tiering config
    /// set earlier).
    pub fn topology(mut self, topo: TierTopology) -> Self {
        let mut t = self.cfg.tiering.take().unwrap_or_default();
        t.topology = topo;
        self.cfg.tiering = Some(t);
        self
    }

    /// Cross-request prefix sharing: admission adopts content-identical
    /// prompt-prefix blocks an earlier request already registered (see
    /// [`TieredKvConfig::prefix_sharing`]).  Creates a default tiering
    /// config when none was set earlier.
    pub fn prefix_sharing(mut self, on: bool) -> Self {
        let mut t = self.cfg.tiering.take().unwrap_or_default();
        t.prefix_sharing = on;
        self.cfg.tiering = Some(t);
        self
    }

    /// Serving clock mode (wall vs deterministic step clock).
    pub fn clock(mut self, mode: ClockMode) -> Self {
        self.cfg.clock = mode;
        self
    }

    /// Arm structured tracing, plan-vs-actual telemetry and the flight
    /// recorder.
    pub fn trace(mut self, tc: TracerConfig) -> Self {
        self.cfg.trace = Some(tc);
        self
    }

    /// Block the first step until this many requests arrived (trace replay).
    pub fn preload_requests(mut self, n: usize) -> Self {
        self.cfg.preload_requests = n;
        self
    }

    /// Step-pipeline mode, overriding the `KVPR_PIPELINE` seed.
    pub fn pipeline(mut self, mode: PipelineMode) -> Self {
        self.cfg.pipeline = mode;
        self
    }

    pub fn build(self) -> ContinuousConfig {
        self.cfg
    }
}

/// How the serving loop schedules one step's host-side work.  The two
/// modes are an A/B oracle pair: identical tokens by construction,
/// different wall-clock shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PipelineMode {
    /// Plan, pump, stage and compute strictly in sequence on the serve
    /// thread (the pre-pipeline loop).
    #[default]
    Serial,
    /// Pipelined step runtime: a stage worker solves the next step's plans
    /// and runs the migration pump while the engine computes, and the
    /// engine's stage/submit split double-buffers group staging.
    Overlapped,
}

impl PipelineMode {
    /// Read `KVPR_PIPELINE` (`serial` | `overlapped`, case-insensitive);
    /// anything else — including unset — is [`PipelineMode::Serial`].
    pub fn from_env() -> Self {
        match std::env::var("KVPR_PIPELINE") {
            Ok(v) if v.eq_ignore_ascii_case("overlapped") => PipelineMode::Overlapped,
            _ => PipelineMode::Serial,
        }
    }
}

/// Tier layout and policy for the serving loop's [`KvStore`].
///
/// The hardware shape lives in one place: the [`TierTopology`].  Tier
/// capacities, the dram spill watermark and the migration wire width are
/// all read off the chain (`TierTopology::standard(..).with_disk(..)`,
/// [`TierTopology::with_wire_elem_bytes`] for int4 wire quantization);
/// what remains here are the runtime knobs a chain does not describe —
/// block size, cool-downs, prefetch depth.
#[derive(Debug, Clone)]
pub struct TieredKvConfig {
    /// The declared tier chain at and below the gpu tier.  A zero
    /// capacity on the top (gpu) rung inherits
    /// [`ContinuousConfig::kv_budget_bytes`]; links the config leaves
    /// unresolved are calibrated against the engine's wire at startup
    /// ([`TierTopology::calibrated`]), so the store's emulated migration
    /// wires, the eviction scores and the planner's hop surcharges all
    /// read the same measured numbers.
    pub topology: TierTopology,
    /// Tokens per block; match the smallest artifact L bucket so dropped-KV
    /// floors land on a real recompute bucket.
    pub block_tokens: usize,
    /// Eviction policy (built with the engine's measured cost model).
    pub policy: EvictKind,
    /// Blocks promoted per group per step (prefetch lookahead).
    pub prefetch_blocks: usize,
    /// Bound on open migrations (queued or in flight) across all groups.
    pub max_inflight: usize,
    /// Anti-thrash hysteresis: a block demoted within the last this-many
    /// event-loop steps is not re-promoted (0 disables).
    pub promote_cooldown: u64,
    /// The spill-side mirror: a block whose disk→dram hop landed within
    /// the last this-many steps is not re-spillable (0 disables).
    pub spill_cooldown: u64,
    /// Dram-occupancy floor below the watermark: spill declines at or
    /// under this occupancy fraction (0.0 disables).
    pub spill_floor: f64,
    /// Spills issued per event-loop step at most.
    pub spill_max_per_step: usize,
    /// Pin the per-step migration grant to a fixed byte count instead of
    /// deriving it from the planner's predicted idle-link slack
    /// ([`StepPlan::link_slack_bytes`](crate::scheduler::StepPlan::link_slack_bytes))
    /// — an A/B lever for experiments (the e2e uses it to pin
    /// bit-identical tokens across budget policies).  `None` — the
    /// default, and the intended production setting — is the adaptive
    /// path.
    ///
    /// Note the adaptive grant is deliberately austere on a saturated
    /// wire: a workload whose plans never split (full transfer every
    /// step, or a non-partial engine policy, where the wire is busy end
    /// to end and the true slack *is* zero) grants only the 1-byte
    /// progress minimum — demand traffic trickles at one launch per step
    /// and spill writebacks (strictly leftover-budget, never given the
    /// progress override) wait for a step with real slack.  Their dram
    /// bytes were freed at issuance, so capacity relief is not delayed —
    /// only the background writeback is.  Pin an override if a workload
    /// needs tier traffic to overcommit the wire the way the old static
    /// knob did.
    pub step_budget_override: Option<u64>,
    /// Sharded serving: when set, the store's pinned/dram/deep-tier
    /// reservations draw from these `Arc`-shared host pools instead of
    /// private per-server ones, so N worker shards admitting concurrently
    /// compete for one host budget (the gpu tier stays per-shard).  The
    /// [`Router`](super::Router) builds one [`SharedHostTiers`] and clones
    /// it into every shard's config; a standalone server leaves this
    /// `None`.
    pub shared_host: Option<SharedHostTiers>,
    /// Cross-request prefix sharing
    /// ([`KvStore::enable_prefix_sharing`](crate::kvstore::KvStore::enable_prefix_sharing)):
    /// admission content-hashes each group's common prompt prefix and
    /// adopts blocks an earlier request already registered — zero new
    /// bytes, zero transfer, copy-on-write on divergence — and the
    /// planner's [`PlanInput::shared_prefix`] span prices the adopted
    /// tokens at zero wire.
    pub prefix_sharing: bool,
}

impl Default for TieredKvConfig {
    fn default() -> Self {
        TieredKvConfig {
            topology: TierTopology::standard(0, 64 << 20, 256 << 20),
            block_tokens: 32,
            policy: EvictKind::RecomputeAware,
            prefetch_blocks: 1,
            max_inflight: 8,
            promote_cooldown: 4,
            spill_cooldown: 4,
            spill_floor: 0.0,
            spill_max_per_step: 2,
            step_budget_override: None,
            shared_host: None,
            prefix_sharing: false,
        }
    }
}

/// One admitted request riding a group lane.  Times are serving-clock
/// seconds ([`Clock::now`]), so under the deterministic step clock every
/// latency sample is a pure function of step indices.
struct Member {
    req: Request,
    arrived: f64,
    admitted: f64,
    /// When this member's first token landed (TTFT sample at retirement).
    first_tok: Option<f64>,
    done: mpsc::Sender<Response>,
    lane: usize,
    state: RequestState,
}

/// The KV reservation backing one decode group: a flat budget guard (PR 1
/// hard backpressure) or a tiered-store session id.
enum KvHold {
    /// Freed (unblocking admission) when the group is dropped.
    Hard(PoolGuard),
    /// Released via [`KvStore::release`] at retirement.
    Tiered(u64),
}

/// One decode group: a session plus its members and KV reservation.
struct Group {
    /// Stable id keying this group's prestage plan tickets (lane indices
    /// shift as groups retire; this never does).
    gid: u64,
    sess: DecodeSession,
    members: Vec<Member>,
    kv: KvHold,
    /// Split the planner chose last step (recompute-aware eviction input).
    last_l: usize,
}

impl Group {
    fn active(&self) -> usize {
        self.members
            .iter()
            .filter(|m| m.state == RequestState::Decoding)
            .count()
    }
}

/// A continuous-batching server: same submit/shutdown surface as
/// [`super::Server`], but the worker runs the step-driven event loop.
pub struct ContinuousServer {
    tx: Option<mpsc::Sender<Pending>>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    metrics: ServeMetrics,
    next_id: std::sync::atomic::AtomicU64,
    clock: Clock,
    tracer: Tracer,
}

impl ContinuousServer {
    /// Spawn the worker; blocks until the engine is profiled and warm.
    pub fn start(cfg: ContinuousConfig) -> Result<ContinuousServer> {
        let (tx, rx) = mpsc::channel::<Pending>();
        let metrics = ServeMetrics::new();
        let m2 = metrics.clone();
        let clock = Clock::new(cfg.clock);
        let tracer = match cfg.trace {
            Some(tc) => Tracer::new(tc),
            None => Tracer::disabled(),
        };
        let (c2, t2) = (clock.clone(), tracer.clone());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let worker = std::thread::Builder::new()
            .name("kvpr-continuous".into())
            .spawn(move || serve_loop(cfg, rx, m2, ready_tx, c2, t2))
            .context("spawn continuous server thread")?;
        ready_rx
            .recv()
            .context("continuous server thread died during startup")??;
        Ok(ContinuousServer {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
            clock,
            tracer,
        })
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Handle to the serving loop's tracer — the shared event buffers,
    /// plan-vs-actual ledger and flight-recorder dumps.  The handle stays
    /// valid after [`shutdown`](Self::shutdown) (clone it out first); with
    /// tracing off ([`ContinuousConfig::trace`] `None`) this is the no-op
    /// sink and every read returns empty.
    pub fn tracer(&self) -> Tracer {
        self.tracer.clone()
    }

    /// Graceful shutdown: close the queue, let in-flight groups finish,
    /// join the worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            w.join()
                .map_err(|_| anyhow::anyhow!("continuous server thread panicked"))??;
        }
        Ok(())
    }
}

impl Submit for ContinuousServer {
    fn next_request_id(&self) -> u64 {
        self.next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    fn enqueue(&self, req: Request) -> ResponseHandle {
        let (done, rx) = mpsc::channel();
        let pending = Pending { req, arrived: self.clock.now(), done };
        self.tx
            .as_ref()
            .expect("server shut down")
            .send(pending)
            .expect("server thread gone");
        ResponseHandle::new(rx)
    }
}

impl Drop for ContinuousServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn serve_loop(
    cfg: ContinuousConfig,
    rx: mpsc::Receiver<Pending>,
    metrics: ServeMetrics,
    ready: mpsc::Sender<Result<()>>,
    clock: Clock,
    tracer: Tracer,
) -> Result<()> {
    let engine = match Engine::new(&cfg.artifact_dir, cfg.engine.clone()) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(anyhow::anyhow!(msg)));
            return Err(e);
        }
    };
    // weights stay device-resident for the server's whole lifetime in the
    // latency regime (one reservation, not one per session)
    let _resident = if !cfg.engine.weights_offloaded {
        Some(
            engine
                .gpu_pool()
                .alloc(engine.weights.total_bytes())
                .context("resident weights exceed device memory")?,
        )
    } else {
        None
    };
    let kv_pool = MemPool::new("host-kv-budget", cfg.kv_budget_bytes);
    // the declared tier chain, calibrated against the engine wire: links
    // the config left unresolved resolve to that wire (host rungs) or an
    // NVMe-shaped derivation of it (rungs below the base), so the store's
    // emulated wires, the eviction scores and the planner's hop
    // surcharges all read the same numbers; a zero-capacity gpu rung
    // inherits the serving KV budget
    let topo: Option<TierTopology> = cfg.tiering.as_ref().map(|t| {
        let mut topo = t.topology.calibrated(&LinkSpec::of(&cfg.engine.link));
        if topo.tier(0).capacity_bytes == 0 {
            topo.set_capacity(0, cfg.kv_budget_bytes);
        }
        topo
    });
    // the deepest below-base rung — an NVMe disk, or a sharded worker's
    // remote hop — maps to the store's deep-tier slot either way
    let disk_tier = topo.as_ref().and_then(|t| t.deep_tier());
    // the deep rung's extra-hop surcharge feeds the spill policy's
    // two-hop reload scoring (the planner reads it from the same chain)
    let nvme_factor = match (topo.as_ref(), disk_tier) {
        (Some(t), Some(i)) => t.hop_factor(i),
        _ => crate::transfer::NVME_BANDWIDTH_FACTOR,
    };
    // tiered mode: the budget becomes the gpu tier; admission goes through
    // the block-granular store and its reclaimable lower tiers instead.
    // The store sits behind a mutex so the overlapped pipeline's stage
    // worker can run the migration pump in the compute shadow; the serve
    // thread and the worker never contend past a step boundary (the job
    // channels are the barrier), so the lock is uncontended in practice.
    type SharedStore = (Arc<Mutex<KvStore>>, Prefetcher);
    let mut store: Option<SharedStore> = match (cfg.tiering.as_ref(), topo.as_ref()) {
        (Some(t), Some(topo)) => {
            let cost = engine.profile().cost_model(&engine.runtime().manifest().model);
            let mut scfg = KvStoreConfig::from_topology(topo, cfg.engine.link.chunk_bytes);
            scfg.block_tokens = t.block_tokens;
            scfg.promote_cooldown = t.promote_cooldown;
            scfg.spill_cooldown = t.spill_cooldown;
            scfg.spill_floor = t.spill_floor;
            scfg.spill_max_per_step = t.spill_max_per_step;
            scfg.shared_host = t.shared_host.clone();
            let mut s = KvStore::new(
                scfg,
                // the eviction/demotion/spill scores move bytes at the
                // exact wire width and NVMe ratio the migration engine
                // charges — both read off the same declared chain
                t.policy.build_for_wire(cost, topo.wire_elem_bytes(), nvme_factor),
            );
            // migration lifecycle events (queued → staged → in-flight →
            // landed) flow into the same step-stamped trace
            s.set_tracer(tracer.clone());
            if t.prefix_sharing {
                s.enable_prefix_sharing();
            }
            Some((Arc::new(Mutex::new(s)), Prefetcher::new(t.max_inflight)))
        }
        _ => None,
    };
    let prefetch_blocks = cfg.tiering.as_ref().map_or(1, |t| t.prefetch_blocks);
    let seq_cap = engine.runtime().manifest().seq_cap;
    let mut next_seq: u64 = 1;
    let mut next_gid: u64 = 1;
    let tok = ByteTokenizer::new();
    // per-lane planner (batch scaling happens in plan_batch); depends only
    // on the startup profile + the declared topology, so build it once,
    // off the step path.  Untiered, the engine roots it on the profile's
    // measured device⊃host chain; tiered, the calibrated serving chain
    // replaces that root so prefix spans resolve against the right rungs.
    let lane_planner = engine.config().policy.is_partial().then(|| {
        let p = engine.planner(1, SchedulePolicy::RowByRow);
        match topo.as_ref() {
            Some(t) => p.with_topology(t.clone()),
            None => p,
        }
    });

    // pipelined step runtime: a dedicated stage worker pre-solves the next
    // step's plans and runs the migration pump in this thread's compute
    // shadow.  One job per step — sent at compute start, collected right
    // after compute — so the channels double as the synchronization
    // barrier: the worker never holds the store while this thread polls,
    // admits or releases.
    let overlapped = cfg.pipeline == PipelineMode::Overlapped;
    let (stage_tx, stage_rx, stage_worker) = if overlapped {
        let (job_tx, job_rx) = mpsc::channel::<StageJob>();
        let (done_tx, done_rx) = mpsc::channel::<StageDone>();
        let planner = lane_planner.clone();
        let pump_store = store.as_ref().map(|(s, _)| Arc::clone(s));
        let w = std::thread::Builder::new()
            .name("kvpr-stage".into())
            .spawn(move || stage_worker_loop(job_rx, done_tx, planner, pump_store))
            .context("spawn pipeline stage worker thread")?;
        (Some(job_tx), Some(done_rx), Some(w))
    } else {
        (None, None, None)
    };
    // the plans the worker pre-solved for *this* step, keyed by group id
    let mut prestage: Option<PlanHandoff> = None;

    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut groups: Vec<Group> = Vec::new();
    // the decode-step clock lives in `clock` (advanced once per completed
    // loop step); trace-replay requests (Request::arrival_step) are
    // admissible only once it reaches their arrival step
    let mut seen_kv_drops: u64 = 0;
    // cumulative disk-traffic counters already surfaced to the metrics
    // (spills/hops can also be issued inside admission, before the step's
    // migration snapshot, so deltas are taken against these, not per-step)
    let mut seen_disk: (u64, u64, u64, u64) = (0, 0, 0, 0);

    // deterministic replay: gather the whole trace before stepping, so
    // arrival events land on this thread in submission order instead of
    // racing the step loop
    for _ in 0..cfg.preload_requests {
        match rx.recv() {
            Ok(p) => {
                tracer.emit(|| EventKind::ReqArrive { id: p.req.id });
                queue.push_back(p);
            }
            Err(_) => break,
        }
    }

    loop {
        tracer.set_step(clock.step());
        // -- 1. arrivals -----------------------------------------------------
        if groups.is_empty() && queue.is_empty() {
            // fully idle: block until work or shutdown
            match rx.recv() {
                Ok(p) => {
                    tracer.emit(|| EventKind::ReqArrive { id: p.req.id });
                    queue.push_back(p);
                }
                Err(_) => break, // channel closed and nothing in flight
            }
            // idle batching window: gather a fuller first group
            let deadline = Instant::now() + cfg.admit_wait;
            while queue.len() < cfg.max_group {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => {
                        tracer.emit(|| EventKind::ReqArrive { id: p.req.id });
                        queue.push_back(p);
                    }
                    Err(_) => break,
                }
            }
        }
        // never block while groups are decoding: drain whatever arrived
        while let Ok(p) = rx.try_recv() {
            tracer.emit(|| EventKind::ReqArrive { id: p.req.id });
            queue.push_back(p);
        }

        // -- 1b. trace clock: nothing is decoding and every queued request
        //        is step-indexed in the future — idle steps pass instantly,
        //        so jump the clock to the next arrival instead of spinning
        if groups.is_empty()
            && !queue.is_empty()
            && !queue.iter().any(|p| arrival_eligible(p, clock.step() as usize))
        {
            if let Some(next) = queue.iter().filter_map(|p| p.req.arrival_step).min() {
                clock.set_step(next as u64);
                tracer.set_step(clock.step());
            }
        }

        // the Step span encloses this iteration's stage / plan / compute
        // phases; every early `continue` below closes it to keep begin/end
        // events balanced in the exported trace
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Step });
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Stage });

        // -- 2. admission (Queued → Prefill → Decoding) ----------------------
        // a step-indexed request whose arrival step is still in the future
        // is invisible here: admission respects the trace's arrival
        // schedule, not just queue order
        loop {
            if groups.len() >= cfg.max_groups {
                break;
            }
            let step_now = clock.step() as usize;
            let eligible = queue.iter().filter(|p| arrival_eligible(p, step_now)).count();
            if eligible == 0 {
                break;
            }
            let mut n = eligible.min(cfg.max_group.max(1));
            let mut hold = None;
            let mut shared = SharedAdmit::default();
            while n >= 1 {
                let need = engine.session_kv_bytes(n)?;
                let got = match store.as_ref() {
                    Some((s, _)) => {
                        // tiered admission: place the session's blocks
                        // across the host tiers, reclaiming (drop KV,
                        // keep X) before backpressuring.  Sharing-enabled
                        // stores first adopt whatever registered prefix the
                        // group's common prompt bytes already hash to.
                        let mut s = s.lock().unwrap();
                        let blocks = seq_cap.div_ceil(s.block_tokens());
                        let lcp = shared_prompt_prefix(&queue, step_now, n, cfg.prompt_bucket);
                        match s.admit_shared(next_seq, need, blocks, &lcp) {
                            Ok(sa) => {
                                shared = sa;
                                let seq = next_seq;
                                next_seq += 1;
                                Some(KvHold::Tiered(seq))
                            }
                            Err(_) => None,
                        }
                    }
                    None => kv_pool.alloc(need).ok().map(KvHold::Hard),
                };
                if let Some(got) = got {
                    hold = Some(got);
                    break;
                }
                if !groups.is_empty() {
                    break; // backpressure: a retirement will free budget
                }
                n /= 2; // idle engine: shrink the group to fit the budget
            }
            let Some(hold) = hold else {
                // KV budget exhausted: hold requests Queued until a group
                // retires and frees its reservation
                metrics.record_backpressure();
                tracer.emit(|| EventKind::Backpressure);
                if groups.is_empty() {
                    // tiered: a just-released group's canceled migrations
                    // may still be vacating tier reservations (the drain
                    // is poll-driven and nothing is stepping to poll) —
                    // nap, poll, and retry instead of failing the request
                    if let Some((s, _)) = store.as_ref() {
                        let mut s = s.lock().unwrap();
                        if s.draining_count() > 0 {
                            std::thread::sleep(Duration::from_millis(1));
                            s.poll_landed();
                            continue;
                        }
                    }
                    // not even a single-request session fits the configured
                    // budget — fail the first eligible request instead of
                    // spinning (the head may be a future trace arrival)
                    if let Some(pos) = queue.iter().position(|p| arrival_eligible(p, step_now)) {
                        let _ = queue.remove(pos);
                    }
                    continue;
                }
                break;
            };
            // pop the first n eligible requests, keeping future trace
            // arrivals (and any overflow) queued in order
            let mut taken: Vec<Pending> = Vec::with_capacity(n);
            let mut kept: VecDeque<Pending> = VecDeque::with_capacity(queue.len());
            while let Some(p) = queue.pop_front() {
                if taken.len() < n && arrival_eligible(&p, step_now) {
                    taken.push(p);
                } else {
                    kept.push_back(p);
                }
            }
            queue = kept;
            let prompts: Vec<Vec<i32>> = taken
                .iter()
                .map(|p| tok.encode(&p.req.prompt, cfg.prompt_bucket))
                .collect();
            let admitted = clock.now();
            // Queued → Prefill: members exist (and own their lanes) for the
            // duration of the prefill call...
            let mut members: Vec<Member> = taken
                .into_iter()
                .enumerate()
                .map(|(lane, p)| {
                    // under the step clock a trace request's queue wait is
                    // measured from its *scheduled* arrival step, not from
                    // whenever the submitting thread happened to enqueue it
                    let arrived = match (clock.step_seconds(), p.req.arrival_step) {
                        (Some(ss), Some(st)) => p.arrived.max(st as f64 * ss),
                        _ => p.arrived,
                    };
                    tracer.emit(|| EventKind::ReqAdmit { id: p.req.id, lane });
                    Member {
                        req: p.req,
                        arrived,
                        admitted,
                        first_tok: None,
                        done: p.done,
                        lane,
                        state: RequestState::Prefill,
                    }
                })
                .collect();
            let mut sess = engine.start_batch(&prompts)?;
            if let (KvHold::Tiered(_), Some(t)) = (&hold, cfg.tiering.as_ref()) {
                // gpu-tier residency: generated KV stays on device and the
                // store's placement decisions are mirrored every step
                engine.enable_residency(&mut sess, t.block_tokens);
            }
            // ...then Prefill → Decoding once the cache is populated
            for m in members.iter_mut() {
                m.state = RequestState::Decoding;
            }
            metrics.record_batch(n);
            if shared.matched_blocks > 0 {
                metrics.record_share(shared.matched_blocks as u64, shared.shared_tokens as u64);
                if let Some(m0) = members.first() {
                    let id = m0.req.id;
                    let (blocks, tokens) = (shared.matched_blocks, shared.shared_tokens);
                    tracer.emit(|| EventKind::ShareHit { id, blocks, tokens });
                }
            }
            // a stolen session's prefix KV lives on the shard it migrated
            // away from: park that prefix on the deep (remote) rung, so the
            // planner prices its re-fetch hops and the store's two-hop
            // promotions pull it across the shared host tiers
            let remote = members
                .iter()
                .map(|m| m.req.remote_prefix_tokens)
                .max()
                .unwrap_or(0);
            if remote > 0 {
                if let (KvHold::Tiered(seq), Some((s, _))) = (&hold, store.as_ref()) {
                    let parked = s
                        .lock()
                        .unwrap()
                        .park_prefix_deep(*seq, remote.min(cfg.prompt_bucket));
                    metrics.record_remote_prefix(parked as u64);
                }
            }
            groups.push(Group { gid: next_gid, sess, members, kv: hold, last_l: 0 });
            next_gid += 1;
        }
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Stage });

        if groups.is_empty() {
            tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Step });
            continue;
        }

        // -- 2b. tiered kvstore: poll landed migrations, sync residency,
        //        queue prefetch ---------------------------------------------
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::MigrationPoll });
        let mut mig_before = None;
        if let Some((s, pf)) = store.as_mut() {
            let mut s = s.lock().unwrap();
            // surface reclamation drops performed during admission
            let drops = s.stats().kv_drops;
            if drops > seen_kv_drops {
                let tokens = (drops - seen_kv_drops) * s.block_tokens() as u64;
                metrics.record_tiering(0, 0, tokens);
                seen_kv_drops = drops;
            }
            mig_before = Some((s.migration_stats(), s.stats()));
            // poll — never wait — the migrations previous steps launched
            pf.poll(&mut s);
            for g in groups.iter_mut() {
                let KvHold::Tiered(seq) = &g.kv else { continue };
                let seq = *seq;
                s.touch(seq, g.sess.kv_len(), g.last_l);
                // physically reclaim what the store's pressure valve
                // dropped: truncate the host K/V arcs and make the
                // recompute floor mandatory for every later plan
                let dropped = s.kv_dropped_tokens(seq);
                if dropped > 0 {
                    let freed = engine.truncate_dropped_kv(&mut g.sess, dropped);
                    if freed > 0 {
                        metrics.record_reclaimed(freed);
                    }
                }
                // mirror the engine's freely-grown device window into the
                // gpu tier's accounting, then queue deeper blocks for
                // promotion ahead of the step
                s.sync_device_suffix(seq, g.sess.resident_tokens());
                pf.pump(&mut s, seq, prefetch_blocks);
            }
            // second pass, after *every* group's pump: a later group's
            // promotion may have evicted an earlier group's block, so the
            // settled suffix and the demotion-in-flight flag are only
            // final now.  Align each engine window to the settled suffix —
            // an eviction's in-flight writeback already released gpu bytes
            // under the window, so those rows must go this step.
            for g in groups.iter_mut() {
                let KvHold::Tiered(seq) = &g.kv else { continue };
                let seq = *seq;
                let backed = s.gpu_resident_tokens(seq);
                let demoting = s.demotion_inflight_tokens(seq) > 0;
                let (p, d) = engine.sync_residency(&mut g.sess, backed, demoting);
                if p > 0 || d > 0 {
                    metrics.record_tiering(p as u64, d as u64, 0);
                }
            }
        }
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::MigrationPoll });

        // -- 3. re-plan every group over the declared chain ------------------
        // membership changed last step ⇒ the aggregate cost model changed
        // ⇒ re-solve Eq. (11) for each group now.  The engine decodes (and
        // transfers) every lane of the batch *bucket*, padding and retired
        // lanes included, so the aggregate uses the bucket's lane count —
        // not just the live members — at the members' shared s'.  Under
        // tiering the PlanInput also carries the device-resident suffix
        // (shrinks the transfer term), any dropped-KV prefix (floors the
        // recompute term) and the disk-resident prefix span (pays its
        // extra hops unless the fold raises the split over it).
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Plan });
        let mut plans: Vec<Option<usize>> = Vec::with_capacity(groups.len());
        let mut slack_total: u64 = 0;
        // summed predicted step time across groups — the prediction half of
        // the tracer's plan-vs-actual ledger (groups decode sequentially on
        // the one engine, so the step's predicted wall time is the sum)
        let mut predicted_s_total: f64 = 0.0;
        for (gi, g) in groups.iter_mut().enumerate() {
            let plan = lane_planner.as_ref().map(|p| {
                let lanes = vec![g.sess.kv_len(); g.sess.batch_bucket()];
                let mut input = PlanInput::new(lanes).resident(g.sess.resident_tokens());
                if let (KvHold::Tiered(seq), Some((s, _))) = (&g.kv, store.as_ref()) {
                    let s = s.lock().unwrap();
                    input = input.dropped_floor(s.kv_dropped_tokens(*seq));
                    input = input.shared_prefix(s.shared_prefix_tokens(*seq));
                    let disk = s.disk_resident_tokens(*seq);
                    if disk > 0 {
                        let tier = disk_tier
                            .expect("disk-resident tokens without a disk rung in the topology");
                        input = input.prefix(tier, disk);
                    }
                }
                // pipelined mode: redeem the worker's pre-solved plan.  A
                // ticket is adopted only when its projected input equals
                // the one just built from live state — membership or
                // placement drift forces a counted inline re-solve, never
                // a stale plan
                match prestage.as_mut().map(|h| h.redeem(g.gid, &input)) {
                    Some(Redemption::Hit(pl)) => pl,
                    Some(_) => {
                        tracer.emit(|| EventKind::ReplanFallback { group: gi });
                        p.plan_batch(&input)
                    }
                    None => p.plan_batch(&input),
                }
            });
            if let Some(pl) = &plan {
                g.last_l = pl.l();
                slack_total = slack_total.saturating_add(pl.link_slack_bytes);
                predicted_s_total += pl.predicted_s;
                tracer.emit(|| EventKind::Plan {
                    group: gi,
                    l: pl.l(),
                    predicted_s: pl.predicted_s,
                    slack_bytes: pl.link_slack_bytes,
                });
            }
            plans.push(plan.map(|pl| pl.l()));
        }
        // every live group has redeemed by now: whatever the report counted
        // (adoptions, forced re-solves) is this step's handoff tally; any
        // ticket still unclaimed belonged to a group that retired
        let handoff_report = prestage.take().map(PlanHandoff::into_report);

        // -- 3b. adaptive step budget: grant the migration engine exactly
        //        the idle-link bytes this step's plans predict (the static
        //        override pins a fixed grant for A/B runs).  A zero-slack
        //        step grants the 1-byte progress minimum, so demand traffic
        //        can still ride the engine's oversized-block override —
        //        one launch, nothing more.  Launch order under the grant:
        //        demand promotions, demotion writebacks, prefetch, spill.
        //        Overlapped mode skips the inline pump: the stage worker
        //        runs it in the compute shadow and the launch/landing
        //        deltas are booked at the handoff instead.
        let mut step_grant: u64 = 0;
        let mut step_launched: usize = 0;
        let mut step_landed: usize = 0;
        let mut step_launched_bytes: u64 = 0;
        if !overlapped {
            if let (Some((s, _)), Some(t)) = (store.as_ref(), cfg.tiering.as_ref()) {
                let mut s = s.lock().unwrap();
                let grant = t.step_budget_override.unwrap_or(slack_total.max(1));
                let launched_before = s.migration_stats().launched;
                s.pump_migrations(grant);
                let launched = s.migration_stats().launched - launched_before;
                metrics.record_step_budget(slack_total, grant, launched);
                step_grant = grant;
                step_launched = launched as usize;
                step_launched_bytes = s.step_launched_wire_bytes();
                tracer.emit(|| EventKind::StepBudget {
                    slack: slack_total,
                    granted: grant,
                    launched: launched as usize,
                    launched_bytes: step_launched_bytes,
                });
                if let Some((mig0, st0)) = mig_before.take() {
                    let (mig1, st1) = (s.migration_stats(), s.stats());
                    step_landed = (mig1.landed - mig0.landed) as usize;
                    metrics.record_migrations(
                        mig1.launched - mig0.launched,
                        mig1.landed - mig0.landed,
                        mig1.budget_deferrals - mig0.budget_deferrals,
                        st1.demotions - st0.demotions,
                        st1.demotions_landed - st0.demotions_landed,
                    );
                    let disk = (st1.spills, st1.spills_landed, st1.hops, st1.hops_landed);
                    metrics.record_disk(
                        disk.0 - seen_disk.0,
                        disk.1 - seen_disk.1,
                        disk.2 - seen_disk.2,
                        disk.3 - seen_disk.3,
                    );
                    seen_disk = disk;
                }
            }
        }
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Plan });

        // -- 4. step every group ---------------------------------------------
        let step_idx = clock.step();
        let t_step = clock.now();
        let mut step_tokens = 0usize;
        let mut step_overlap_s = 0.0f64;
        let active: usize = groups.iter().map(|g| g.active()).sum();
        if overlapped {
            // the Prestage span opens before compute: the stage worker
            // solves step N+1's plans (and pumps this step's migration
            // grant) in the compute shadow, and the span closes once this
            // thread has the results — its tail past `compute` is the
            // pipeline stall
            tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Prestage });
            let grant = match (store.as_ref(), cfg.tiering.as_ref()) {
                (Some(_), Some(t)) => Some(t.step_budget_override.unwrap_or(slack_total.max(1))),
                _ => None,
            };
            let mut predictions = Vec::new();
            if lane_planner.is_some() {
                predictions.reserve(groups.len());
                for g in groups.iter() {
                    // project step N+1: every lane one token longer, the
                    // residency window grown with it, tier placement as of
                    // now — drift is caught (and counted) at redemption
                    let lanes = vec![g.sess.kv_len() + 1; g.sess.batch_bucket()];
                    let grown = g.sess.resident_tokens() + usize::from(g.sess.residency_enabled());
                    let mut input = PlanInput::new(lanes).resident(grown);
                    if let (KvHold::Tiered(seq), Some((s, _))) = (&g.kv, store.as_ref()) {
                        let s = s.lock().unwrap();
                        input = input.dropped_floor(s.kv_dropped_tokens(*seq));
                        input = input.shared_prefix(s.shared_prefix_tokens(*seq));
                        let disk = s.disk_resident_tokens(*seq);
                        if disk > 0 {
                            let tier = disk_tier
                                .expect("disk-resident tokens without a disk rung in the topology");
                            input = input.prefix(tier, disk);
                        }
                    }
                    predictions.push((g.gid, input));
                }
            }
            stage_tx
                .as_ref()
                .expect("overlapped mode spawns a stage worker")
                .send(StageJob { grant, predictions })
                .map_err(|_| anyhow::anyhow!("pipeline stage worker died"))?;
        }
        tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Compute });
        if overlapped {
            // double-buffered group staging: stage(i+1) fills the free
            // slot — its embed and first-layer transfers go out on the
            // link workers — before submit(i) drains the other, so the
            // next group's staging streams under this group's compute
            let mut slots = StageSlots::new();
            let mut handoffs: Vec<Option<StepHandoff>> = Vec::with_capacity(groups.len());
            if let Some(g) = groups.first_mut() {
                let mut h = engine.build_step(&mut g.sess, plans[0])?;
                engine.stage_step(&mut g.sess, &mut h, &mut slots)?;
                handoffs.push(Some(h));
            }
            for i in 0..groups.len() {
                if i + 1 < groups.len() {
                    let g = &mut groups[i + 1];
                    let mut h = engine.build_step(&mut g.sess, plans[i + 1])?;
                    engine.stage_step(&mut g.sess, &mut h, &mut slots)?;
                    h.mark_overlapped();
                    step_overlap_s += h.staged_s();
                    handoffs.push(Some(h));
                }
                let g = &mut groups[i];
                let mut h = handoffs[i].take().expect("group staged before submit");
                let hidden = engine.submit_step(&mut g.sess, &mut h, &mut slots)?;
                engine.collect_step(&mut g.sess, h, hidden)?;
                step_tokens += g.active();
            }
        } else {
            for (g, plan_l) in groups.iter_mut().zip(&plans) {
                engine.decode_step_with_plan(&mut g.sess, *plan_l)?;
                step_tokens += g.active();
            }
        }
        // the completed decode advances the serving clock one step (under
        // the deterministic clock, exactly `step_s` seconds)
        clock.advance();
        let after_step = clock.now();
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Compute });
        if overlapped {
            // collect the worker's results; time blocked here is pipeline
            // stall — compute did not fully hide the prestage work
            let t_stall = Instant::now();
            let done = stage_rx
                .as_ref()
                .expect("overlapped mode spawns a stage worker")
                .recv()
                .map_err(|_| anyhow::anyhow!("pipeline stage worker died"))?;
            let step_stall_s = t_stall.elapsed().as_secs_f64();
            tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Prestage });
            tracer.emit(|| EventKind::PhaseBegin { phase: Phase::Handoff });
            // book what the worker did in the shadow: the step budget it
            // pumped under, the migration/disk deltas it caused, and the
            // next step's plan tickets
            if let Some((granted, launched, launched_bytes)) = done.pumped {
                metrics.record_step_budget(slack_total, granted, launched);
                step_grant = granted;
                step_launched = launched as usize;
                step_launched_bytes = launched_bytes;
                tracer.emit(|| EventKind::StepBudget {
                    slack: slack_total,
                    granted,
                    launched: launched as usize,
                    launched_bytes,
                });
                if let (Some((mig0, st0)), Some((s, _))) = (mig_before.take(), store.as_ref()) {
                    let s = s.lock().unwrap();
                    let (mig1, st1) = (s.migration_stats(), s.stats());
                    step_landed = (mig1.landed - mig0.landed) as usize;
                    metrics.record_migrations(
                        mig1.launched - mig0.launched,
                        mig1.landed - mig0.landed,
                        mig1.budget_deferrals - mig0.budget_deferrals,
                        st1.demotions - st0.demotions,
                        st1.demotions_landed - st0.demotions_landed,
                    );
                    let disk = (st1.spills, st1.spills_landed, st1.hops, st1.hops_landed);
                    metrics.record_disk(
                        disk.0 - seen_disk.0,
                        disk.1 - seen_disk.1,
                        disk.2 - seen_disk.2,
                        disk.3 - seen_disk.3,
                    );
                    seen_disk = disk;
                }
            }
            let rep = handoff_report.unwrap_or_default();
            metrics.record_pipeline(
                rep.fully_prestaged(),
                rep.hits,
                rep.fallbacks,
                step_stall_s,
                step_overlap_s,
            );
            // stall is serve-thread wall time (lands in Breakdown::total);
            // overlap was already booked per group by collect_step
            if let Some(g) = groups.first_mut() {
                g.sess.note_pipeline(0.0, step_stall_s);
            }
            prestage = Some(done.handoff);
            tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Handoff });
        }
        // every decoding member just produced a token: stamp first-token
        // times for the TTFT samples retirement reports
        for g in groups.iter_mut() {
            for m in g.members.iter_mut() {
                if m.state == RequestState::Decoding && m.first_tok.is_none() {
                    m.first_tok = Some(after_step);
                    tracer.emit(|| EventKind::ReqFirstToken { id: m.req.id });
                }
            }
        }

        // -- 5. retirement (Decoding → Done) ---------------------------------
        for g in groups.iter_mut() {
            let produced = g.sess.tokens_per_lane();
            let at_cap = g.sess.kv_len() >= g.sess.seq_cap();
            let decode_s = g.sess.metrics().decode_s;
            let prefill_s = g.sess.metrics().prefill_s;
            let splits = &g.sess.metrics().splits;
            for m in g.members.iter_mut() {
                if m.state != RequestState::Decoding {
                    continue;
                }
                if produced >= m.req.gen_len || at_cap {
                    let mut toks = g.sess.lane_tokens(m.lane).to_vec();
                    toks.truncate(m.req.gen_len);
                    let text = tok.decode(&toks);
                    let queue_s = (m.admitted - m.arrived).max(0.0);
                    let retired = clock.now();
                    let total_s = (retired - m.arrived).max(0.0);
                    metrics.record_request(total_s, queue_s, decode_s, toks.len());
                    let first = m.first_tok.unwrap_or(retired);
                    let tpot_s = if toks.len() > 1 {
                        Some((retired - first).max(0.0) / (toks.len() - 1) as f64)
                    } else {
                        None
                    };
                    let ttft_s = (first - m.arrived).max(0.0);
                    metrics.record_ttft_tpot(ttft_s, tpot_s);
                    tracer.emit(|| EventKind::ReqRetire {
                        id: m.req.id,
                        tokens: toks.len(),
                        ttft_s,
                    });
                    let _ = m.done.send(Response {
                        id: m.req.id,
                        text,
                        tokens: toks,
                        queue_s,
                        prefill_s,
                        decode_s,
                        total_s,
                        splits: splits.clone(),
                    });
                    m.state = RequestState::Done;
                }
            }
        }
        // dropping a finished group frees its KV reservation → admission
        // can proceed next step (tiered sessions release their blocks)
        let mut live = Vec::with_capacity(groups.len());
        for g in groups.drain(..) {
            if g.active() > 0 {
                live.push(g);
            } else if let (KvHold::Tiered(seq), Some((s, _))) = (&g.kv, store.as_ref()) {
                s.lock().unwrap().release(*seq);
            }
        }
        groups = live;

        metrics.record_step(queue.len(), active, clock.now() - t_step, step_tokens);
        tracer.emit(|| EventKind::PhaseEnd { phase: Phase::Step });
        // plan-vs-actual: the decode window is what `predicted_s` predicts,
        // so the ledger measures it alone (metrics keep the wider span)
        tracer.record_step(StepRecord {
            step: step_idx,
            predicted_s: predicted_s_total,
            slack_bytes: slack_total,
            granted_bytes: step_grant,
            measured_s: after_step - t_step,
            launched: step_launched,
            launched_wire_bytes: step_launched_bytes,
            landed: step_landed,
        });
    }
    // close the job channel and join the stage worker (it exits on the
    // closed channel; no job is ever in flight between steps)
    drop(stage_tx);
    if let Some(w) = stage_worker {
        let _ = w.join();
    }
    Ok(())
}

/// One overlapped step's order to the stage worker, sent as compute opens:
/// pump the migration grant, then pre-solve the next step's plans.
struct StageJob {
    /// `Some(bytes)` when a tiered store should be pumped under this grant.
    grant: Option<u64>,
    /// Projected next-step [`PlanInput`] per live group, keyed by group id.
    predictions: Vec<(u64, PlanInput)>,
}

/// What the worker hands back at the step's handoff point.
struct StageDone {
    /// Pre-solved next-step plans with their validity tokens.
    handoff: PlanHandoff,
    /// `(granted, launched, launched_wire_bytes)` when the job pumped.
    pumped: Option<(u64, u64, u64)>,
}

/// The stage worker: one job per serve-loop step, executed while the serve
/// thread is inside decode compute.  The pump runs first so migrations
/// ride the wire during compute rather than after the plan solves finish;
/// the launched-wire-bytes reading is taken under the same lock hold, so
/// the per-step grant audit sees exactly this pump's launches.
fn stage_worker_loop(
    jobs: mpsc::Receiver<StageJob>,
    done: mpsc::Sender<StageDone>,
    planner: Option<Planner>,
    store: Option<Arc<Mutex<KvStore>>>,
) {
    while let Ok(job) = jobs.recv() {
        let pumped = match (job.grant, store.as_ref()) {
            (Some(grant), Some(s)) => {
                let mut s = s.lock().unwrap();
                let before = s.migration_stats().launched;
                s.pump_migrations(grant);
                let launched = s.migration_stats().launched - before;
                Some((grant, launched, s.step_launched_wire_bytes()))
            }
            _ => None,
        };
        let mut handoff = PlanHandoff::new();
        if let Some(p) = planner.as_ref() {
            for (gid, input) in job.predictions {
                let plan = p.plan_batch(&input);
                handoff.push(gid, input, plan);
            }
        }
        if done.send(StageDone { handoff, pumped }).is_err() {
            break; // serve thread gone; nothing left to hand off
        }
    }
}

/// Whether a queued request may be admitted at the given decode-step clock
/// (wall-clock requests always; trace requests once their step arrives).
fn arrival_eligible(p: &Pending, step_clock: usize) -> bool {
    match p.req.arrival_step {
        Some(s) => s <= step_clock,
        None => true,
    }
}

/// Byte-wise longest common prefix of the first `n` admission-eligible
/// queued prompts, clamped to the prompt bucket (the cache holds exactly
/// that many byte-tokens per lane) — the content
/// [`KvStore::admit_shared`] hashes against the cross-request registry.
fn shared_prompt_prefix(
    queue: &VecDeque<Pending>,
    step_clock: usize,
    n: usize,
    prompt_bucket: usize,
) -> Vec<u8> {
    let mut it = queue
        .iter()
        .filter(|p| arrival_eligible(p, step_clock))
        .take(n)
        .map(|p| p.req.prompt.as_bytes());
    let Some(first) = it.next() else {
        return Vec::new();
    };
    let mut len = first.len().min(prompt_bucket);
    for other in it {
        let m = len.min(other.len());
        len = (0..m).take_while(|&i| first[i] == other[i]).count();
    }
    first[..len].to_vec()
}
