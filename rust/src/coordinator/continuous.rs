//! The continuous-batching serving loop: the step-driven event loop that
//! finally wires coordinator → scheduler → engine together.
//!
//! One worker thread owns the engine and advances the world one **decode
//! step** at a time:
//!
//! 1. **Admission** — queued requests are grouped (up to `max_group`) and
//!    prefilled into a fresh [`DecodeSession`]; a session's full KV-cache
//!    reservation is charged against the `kv_budget_bytes` [`MemPool`]
//!    *before* prefill, so an exhausted budget holds requests in the queue
//!    (backpressure) instead of over-committing host memory.
//! 2. **Batch re-planning** — each formed group re-solves the paper's
//!    Eq. (11) for this step via
//!    [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch),
//!    aggregating every
//!    member's cached-token count s' into the Eq. (10) cost model.  Because
//!    membership changes step to step (admissions, retirements), the split
//!    point is re-planned on every step, exactly as §3.2 prescribes for a
//!    growing s'.
//! 3. **Step** — every group advances one token
//!    ([`Engine::decode_step_with_plan`]).
//! 4. **Retirement** — members whose generation budget is met (or whose
//!    group hit KV capacity) transition `Decoding → Done` and are responded
//!    to immediately; a fully-retired group frees its KV reservation, which
//!    unblocks admission.
//!
//! Under tiering, every step additionally *polls* the KV store's
//! [`MigrationEngine`](crate::kvstore::MigrationEngine) — landing finished
//! promotions/demotions/spills, aligning the engine's device-resident
//! window to the settled suffix, queueing prefetch — and grants it a
//! link-byte budget ([`TieredKvConfig::step_link_budget_bytes`]).  Nothing
//! on this thread ever waits on the migration links: a full gpu tier is
//! drained by asynchronous demotions whose gpu bytes free at issuance,
//! and with a disk tier configured ([`TieredKvConfig::disk_bytes`]) a
//! crowded dram tier is drained the same way by watermark-driven spills
//! whose NVMe writebacks ride leftover step budget — admission that would
//! have backpressured parks cold blocks on disk instead, and the planner
//! charges disk-resident prefixes a two-hop transfer term
//! ([`Planner::plan_batch_four_tier`](crate::scheduler::Planner::plan_batch_four_tier)).
//!
//! Requests move through `Queued → Prefill → Decoding → Done`
//! ([`RequestState`]); per-step latency, queue depth and occupancy land in
//! [`ServeMetrics`].  Contrast with [`super::Server`], which forms one batch,
//! decodes it to completion, and only then looks at the queue again: under
//! concurrent load the continuous loop starts new work every step and
//! retires finished requests early — the property the KV-offloading serving
//! papers in PAPERS.md show is required for the PCIe bottleneck to even be
//! observable.

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::metrics::ServeMetrics;
use super::request::{Pending, Request, RequestState, Response};
use super::server::ResponseHandle;
use crate::engine::{DecodeSession, Engine, EngineConfig};
use crate::kvstore::{EvictKind, KvStore, KvStoreConfig, Prefetcher};
use crate::memory::{MemPool, PoolGuard};
use crate::model::ByteTokenizer;
use crate::scheduler::SchedulePolicy;

/// Continuous-batching loop construction parameters.
#[derive(Debug, Clone)]
pub struct ContinuousConfig {
    pub artifact_dir: PathBuf,
    pub engine: EngineConfig,
    /// Requests prefilled together into one decode group (rounded up to a
    /// batch bucket internally; keep ≤ the largest bucket).
    pub max_group: usize,
    /// Decode groups stepped concurrently (interleaved on the one engine).
    pub max_groups: usize,
    /// Prompt bucket used for padding (must exist in the manifest).
    pub prompt_bucket: usize,
    /// Host KV budget shared by all live sessions; admission backpressures
    /// against it.
    pub kv_budget_bytes: u64,
    /// How long an *idle* loop waits for more arrivals before prefilling a
    /// partial group (batching window; never delays active decoding).
    pub admit_wait: Duration,
    /// Tiered KV management ([`KvStore`]): when set, `kv_budget_bytes`
    /// becomes the gpu-hbm *tier* budget (a promotion-only cache),
    /// sessions are admitted against the pinned + dram host tiers (with
    /// recompute-aware reclamation) instead of hard backpressure, and a
    /// device-resident KV suffix shrinks every step's transfer term.
    pub tiering: Option<TieredKvConfig>,
}

impl ContinuousConfig {
    pub fn new(artifact_dir: &str, engine: EngineConfig) -> Self {
        ContinuousConfig {
            artifact_dir: PathBuf::from(artifact_dir),
            engine,
            max_group: 4,
            max_groups: 2,
            prompt_bucket: 32,
            kv_budget_bytes: 256 << 20,
            admit_wait: Duration::from_millis(20),
            tiering: None,
        }
    }
}

/// Tier layout and policy for the serving loop's [`KvStore`].
#[derive(Debug, Clone)]
pub struct TieredKvConfig {
    /// Pinned host tier capacity (also backs migration staging).
    pub pinned_bytes: u64,
    /// Cold cpu-dram tier capacity.
    pub dram_bytes: u64,
    /// NVMe disk tier capacity below dram; 0 keeps the PR 3 three-tier
    /// layout.  The disk tier's link is derived from the engine link
    /// ([`LinkConfig::nvme_below`](crate::transfer::LinkConfig::nvme_below)),
    /// and dram blocks spill to it under the watermark policy before
    /// admission has to backpressure.
    pub disk_bytes: u64,
    /// Capacity-aware spill: dram occupancy above this fraction spills
    /// cold blocks to disk (leftover-budget NVMe traffic).  Ignored when
    /// `disk_bytes` is 0.
    pub spill_watermark: f64,
    /// Spills issued per event-loop step at most.
    pub spill_max_per_step: usize,
    /// Tokens per block; match the smallest artifact L bucket so dropped-KV
    /// floors land on a real recompute bucket.
    pub block_tokens: usize,
    /// Eviction policy (built with the engine's measured cost model).
    pub policy: EvictKind,
    /// Blocks promoted per group per step (prefetch lookahead).
    pub prefetch_blocks: usize,
    /// Bound on open migrations (queued or in flight) across all groups.
    pub max_inflight: usize,
    /// Link bytes the migration engine may launch per event-loop step —
    /// the budget that keeps tier traffic from starving the step's own
    /// KV/activation transfers.  Queued migrations beyond it wait for the
    /// next step's grant.
    pub step_link_budget_bytes: u64,
    /// Charge migrations int4 wire bytes (0.625 B/elem) and score evicted
    /// blocks' transfer refills at the same width (paper §4.4 group-wise
    /// KV quantization applied to tier traffic).
    pub kv_quant_wire: bool,
    /// Anti-thrash hysteresis: a block demoted within the last this-many
    /// event-loop steps is not re-promoted (0 disables).
    pub promote_cooldown: u64,
}

impl Default for TieredKvConfig {
    fn default() -> Self {
        TieredKvConfig {
            pinned_bytes: 64 << 20,
            dram_bytes: 256 << 20,
            disk_bytes: 0,
            spill_watermark: 0.9,
            spill_max_per_step: 2,
            block_tokens: 32,
            policy: EvictKind::RecomputeAware,
            prefetch_blocks: 1,
            max_inflight: 8,
            step_link_budget_bytes: 4 << 20,
            kv_quant_wire: false,
            promote_cooldown: 4,
        }
    }
}

/// One admitted request riding a group lane.
struct Member {
    req: Request,
    arrived: Instant,
    admitted: Instant,
    done: mpsc::Sender<Response>,
    lane: usize,
    state: RequestState,
}

/// The KV reservation backing one decode group: a flat budget guard (PR 1
/// hard backpressure) or a tiered-store session id.
enum KvHold {
    /// Freed (unblocking admission) when the group is dropped.
    Hard(PoolGuard),
    /// Released via [`KvStore::release`] at retirement.
    Tiered(u64),
}

/// One decode group: a session plus its members and KV reservation.
struct Group {
    sess: DecodeSession,
    members: Vec<Member>,
    kv: KvHold,
    /// Split the planner chose last step (recompute-aware eviction input).
    last_l: usize,
}

impl Group {
    fn active(&self) -> usize {
        self.members
            .iter()
            .filter(|m| m.state == RequestState::Decoding)
            .count()
    }
}

/// A continuous-batching server: same submit/shutdown surface as
/// [`super::Server`], but the worker runs the step-driven event loop.
pub struct ContinuousServer {
    tx: Option<mpsc::Sender<Pending>>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    metrics: ServeMetrics,
    next_id: std::sync::atomic::AtomicU64,
}

impl ContinuousServer {
    /// Spawn the worker; blocks until the engine is profiled and warm.
    pub fn start(cfg: ContinuousConfig) -> Result<ContinuousServer> {
        let (tx, rx) = mpsc::channel::<Pending>();
        let metrics = ServeMetrics::new();
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let worker = std::thread::Builder::new()
            .name("kvpr-continuous".into())
            .spawn(move || serve_loop(cfg, rx, m2, ready_tx))
            .context("spawn continuous server thread")?;
        ready_rx
            .recv()
            .context("continuous server thread died during startup")??;
        Ok(ContinuousServer {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
        })
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Submit a prompt; returns a waitable handle.
    pub fn submit(&self, prompt: &str, gen_len: usize) -> ResponseHandle {
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.submit_request(Request::new(id, prompt, gen_len))
    }

    pub fn submit_request(&self, req: Request) -> ResponseHandle {
        let (done, rx) = mpsc::channel();
        let pending = Pending { req, arrived: Instant::now(), done };
        self.tx
            .as_ref()
            .expect("server shut down")
            .send(pending)
            .expect("server thread gone");
        ResponseHandle::new(rx)
    }

    /// Graceful shutdown: close the queue, let in-flight groups finish,
    /// join the worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            w.join()
                .map_err(|_| anyhow::anyhow!("continuous server thread panicked"))??;
        }
        Ok(())
    }
}

impl Drop for ContinuousServer {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn serve_loop(
    cfg: ContinuousConfig,
    rx: mpsc::Receiver<Pending>,
    metrics: ServeMetrics,
    ready: mpsc::Sender<Result<()>>,
) -> Result<()> {
    let engine = match Engine::new(&cfg.artifact_dir, cfg.engine.clone()) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(anyhow::anyhow!(msg)));
            return Err(e);
        }
    };
    // weights stay device-resident for the server's whole lifetime in the
    // latency regime (one reservation, not one per session)
    let _resident = if !cfg.engine.weights_offloaded {
        Some(
            engine
                .gpu_pool()
                .alloc(engine.weights.total_bytes())
                .context("resident weights exceed device memory")?,
        )
    } else {
        None
    };
    let kv_pool = MemPool::new("host-kv-budget", cfg.kv_budget_bytes);
    // the disk tier rides an NVMe-shaped wire derived from the engine
    // link; its speed ratio feeds both the spill policy's two-hop reload
    // scoring and the planner's two-hop transfer term
    let nvme_link = crate::transfer::LinkConfig::nvme_below(&cfg.engine.link);
    let nvme_factor = if nvme_link.bytes_per_sec.is_finite() && nvme_link.bytes_per_sec > 0.0 {
        cfg.engine.link.bytes_per_sec / nvme_link.bytes_per_sec
    } else {
        // unthrottled links: fall back to the link model's shape ratio
        crate::transfer::NVME_BANDWIDTH_FACTOR
    };
    // tiered mode: the budget becomes the gpu tier; admission goes through
    // the block-granular store and its reclaimable lower tiers instead
    let mut store: Option<(KvStore, Prefetcher)> = cfg.tiering.as_ref().map(|t| {
        let cost = engine.profile().cost_model(&engine.runtime().manifest().model);
        let s = KvStore::new(
            KvStoreConfig {
                gpu_bytes: cfg.kv_budget_bytes,
                pinned_bytes: t.pinned_bytes,
                dram_bytes: t.dram_bytes,
                disk_bytes: t.disk_bytes,
                block_tokens: t.block_tokens,
                link: cfg.engine.link.clone(),
                nvme_link: nvme_link.clone(),
                wire_elem_bytes: if t.kv_quant_wire {
                    crate::kvcache::ELEM_BYTES_INT4_G64
                } else {
                    crate::kvcache::ELEM_BYTES_F32
                },
                promote_cooldown: t.promote_cooldown,
                spill_watermark: t.spill_watermark,
                spill_max_per_step: t.spill_max_per_step,
            },
            // the eviction/demotion/spill scores move bytes at the same
            // wire width and NVMe ratio the migration engine charges
            t.policy.build_tiered(cost, t.kv_quant_wire, nvme_factor),
        );
        (s, Prefetcher::new(t.max_inflight))
    });
    let prefetch_blocks = cfg.tiering.as_ref().map_or(1, |t| t.prefetch_blocks);
    let seq_cap = engine.runtime().manifest().seq_cap;
    let mut next_seq: u64 = 1;
    let tok = ByteTokenizer::new();
    // per-lane planner (batch scaling happens in plan_batch); depends only
    // on the startup profile, so build it once, off the step path
    let lane_planner = engine
        .config()
        .policy
        .is_partial()
        .then(|| engine.planner(1, SchedulePolicy::RowByRow));

    let mut queue: VecDeque<Pending> = VecDeque::new();
    let mut groups: Vec<Group> = Vec::new();
    let mut seen_kv_drops: u64 = 0;
    // cumulative disk-traffic counters already surfaced to the metrics
    // (spills/hops can also be issued inside admission, before the step's
    // migration snapshot, so deltas are taken against these, not per-step)
    let mut seen_disk: (u64, u64, u64, u64) = (0, 0, 0, 0);

    loop {
        // -- 1. arrivals -----------------------------------------------------
        if groups.is_empty() && queue.is_empty() {
            // fully idle: block until work or shutdown
            match rx.recv() {
                Ok(p) => queue.push_back(p),
                Err(_) => break, // channel closed and nothing in flight
            }
            // idle batching window: gather a fuller first group
            let deadline = Instant::now() + cfg.admit_wait;
            while queue.len() < cfg.max_group {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(p) => queue.push_back(p),
                    Err(_) => break,
                }
            }
        }
        // never block while groups are decoding: drain whatever arrived
        while let Ok(p) = rx.try_recv() {
            queue.push_back(p);
        }

        // -- 2. admission (Queued → Prefill → Decoding) ----------------------
        while !queue.is_empty() && groups.len() < cfg.max_groups {
            let mut n = queue.len().min(cfg.max_group.max(1));
            let mut hold = None;
            while n >= 1 {
                let need = engine.session_kv_bytes(n)?;
                let got = match store.as_mut() {
                    Some((s, _)) => {
                        // tiered admission: place the session's blocks
                        // across the host tiers, reclaiming (drop KV,
                        // keep X) before backpressuring
                        let blocks = seq_cap.div_ceil(s.block_tokens());
                        if s.admit(next_seq, need, blocks).is_ok() {
                            let seq = next_seq;
                            next_seq += 1;
                            Some(KvHold::Tiered(seq))
                        } else {
                            None
                        }
                    }
                    None => kv_pool.alloc(need).ok().map(KvHold::Hard),
                };
                if let Some(got) = got {
                    hold = Some(got);
                    break;
                }
                if !groups.is_empty() {
                    break; // backpressure: a retirement will free budget
                }
                n /= 2; // idle engine: shrink the group to fit the budget
            }
            let Some(hold) = hold else {
                // KV budget exhausted: hold requests Queued until a group
                // retires and frees its reservation
                metrics.record_backpressure();
                if groups.is_empty() {
                    // tiered: a just-released group's canceled migrations
                    // may still be vacating tier reservations (the drain
                    // is poll-driven and nothing is stepping to poll) —
                    // nap, poll, and retry instead of failing the request
                    if let Some((s, _)) = store.as_mut() {
                        if s.draining_count() > 0 {
                            std::thread::sleep(Duration::from_millis(1));
                            s.poll_landed();
                            continue;
                        }
                    }
                    // not even a single-request session fits the configured
                    // budget — fail the head request instead of spinning
                    let p = queue.pop_front().unwrap();
                    drop(p);
                    continue;
                }
                break;
            };
            let mut taken: Vec<Pending> = Vec::with_capacity(n);
            for _ in 0..n {
                taken.push(queue.pop_front().unwrap());
            }
            let prompts: Vec<Vec<i32>> = taken
                .iter()
                .map(|p| tok.encode(&p.req.prompt, cfg.prompt_bucket))
                .collect();
            let admitted = Instant::now();
            // Queued → Prefill: members exist (and own their lanes) for the
            // duration of the prefill call...
            let mut members: Vec<Member> = taken
                .into_iter()
                .enumerate()
                .map(|(lane, p)| Member {
                    req: p.req,
                    arrived: p.arrived,
                    admitted,
                    done: p.done,
                    lane,
                    state: RequestState::Prefill,
                })
                .collect();
            let mut sess = engine.start_batch(&prompts)?;
            if let (KvHold::Tiered(_), Some(t)) = (&hold, cfg.tiering.as_ref()) {
                // gpu-tier residency: generated KV stays on device and the
                // store's placement decisions are mirrored every step
                engine.enable_residency(&mut sess, t.block_tokens);
            }
            // ...then Prefill → Decoding once the cache is populated
            for m in members.iter_mut() {
                m.state = RequestState::Decoding;
            }
            metrics.record_batch(n);
            groups.push(Group { sess, members, kv: hold, last_l: 0 });
        }

        if groups.is_empty() {
            continue;
        }

        // -- 2b. tiered kvstore: poll landed migrations, sync residency,
        //        queue prefetch, grant the step's link budget --------------
        if let Some((s, pf)) = store.as_mut() {
            // surface reclamation drops performed during admission
            let drops = s.stats().kv_drops;
            if drops > seen_kv_drops {
                let tokens = (drops - seen_kv_drops) * s.block_tokens() as u64;
                metrics.record_tiering(0, 0, tokens);
                seen_kv_drops = drops;
            }
            let (mig0, st0) = (s.migration_stats(), s.stats());
            // poll — never wait — the migrations previous steps launched
            pf.poll(s);
            for g in groups.iter_mut() {
                let KvHold::Tiered(seq) = &g.kv else { continue };
                let seq = *seq;
                s.touch(seq, g.sess.kv_len(), g.last_l);
                // mirror the engine's freely-grown device window into the
                // gpu tier's accounting, then queue deeper blocks for
                // promotion ahead of the step
                s.sync_device_suffix(seq, g.sess.resident_tokens());
                pf.pump(s, seq, prefetch_blocks);
            }
            // second pass, after *every* group's pump: a later group's
            // promotion may have evicted an earlier group's block, so the
            // settled suffix and the demotion-in-flight flag are only
            // final now.  Align each engine window to the settled suffix —
            // an eviction's in-flight writeback already released gpu bytes
            // under the window, so those rows must go this step.
            for g in groups.iter_mut() {
                let KvHold::Tiered(seq) = &g.kv else { continue };
                let seq = *seq;
                let backed = s.gpu_resident_tokens(seq);
                let demoting = s.demotion_inflight_tokens(seq) > 0;
                let (p, d) = engine.sync_residency(&mut g.sess, backed, demoting);
                if p > 0 || d > 0 {
                    metrics.record_tiering(p as u64, d as u64, 0);
                }
            }
            // one budgeted launch pass per step: demand promotions first,
            // then demotion writebacks, then prefetch
            let budget = cfg.tiering.as_ref().map_or(0, |t| t.step_link_budget_bytes);
            s.pump_migrations(budget);
            let (mig1, st1) = (s.migration_stats(), s.stats());
            metrics.record_migrations(
                mig1.launched - mig0.launched,
                mig1.landed - mig0.landed,
                mig1.budget_deferrals - mig0.budget_deferrals,
                st1.demotions - st0.demotions,
                st1.demotions_landed - st0.demotions_landed,
            );
            let disk = (st1.spills, st1.spills_landed, st1.hops, st1.hops_landed);
            metrics.record_disk(
                disk.0 - seen_disk.0,
                disk.1 - seen_disk.1,
                disk.2 - seen_disk.2,
                disk.3 - seen_disk.3,
            );
            seen_disk = disk;
        }

        // -- 3+4. re-plan and step every group -------------------------------
        let t_step = Instant::now();
        let mut step_tokens = 0usize;
        let active: usize = groups.iter().map(|g| g.active()).sum();
        for g in groups.iter_mut() {
            // membership changed last step ⇒ the aggregate cost model
            // changed ⇒ re-solve Eq. (11) for this group now.  The engine
            // decodes (and transfers) every lane of the batch *bucket*,
            // padding and retired lanes included, so the aggregate uses the
            // bucket's lane count — not just the live members — at the
            // members' shared s'.  Under tiering the plan also accounts the
            // device-resident suffix (shrinks the transfer term) and any
            // dropped-KV prefix (floors the recompute term).
            let plan_l = lane_planner.as_ref().map(|p| {
                let lanes = vec![g.sess.kv_len(); g.sess.batch_bucket()];
                let (floor, disk) = match (&g.kv, store.as_ref()) {
                    (KvHold::Tiered(seq), Some((s, _))) => {
                        (s.kv_dropped_tokens(*seq), s.disk_resident_tokens(*seq))
                    }
                    _ => (0, 0),
                };
                p.plan_batch_four_tier(&lanes, g.sess.resident_tokens(), floor, disk, nvme_factor)
                    .l()
            });
            if let Some(l) = plan_l {
                g.last_l = l;
            }
            engine.decode_step_with_plan(&mut g.sess, plan_l)?;
            step_tokens += g.active();
        }

        // -- 5. retirement (Decoding → Done) ---------------------------------
        for g in groups.iter_mut() {
            let produced = g.sess.tokens_per_lane();
            let at_cap = g.sess.kv_len() >= g.sess.seq_cap();
            let decode_s = g.sess.metrics().decode_s;
            let prefill_s = g.sess.metrics().prefill_s;
            let splits = &g.sess.metrics().splits;
            for m in g.members.iter_mut() {
                if m.state != RequestState::Decoding {
                    continue;
                }
                if produced >= m.req.gen_len || at_cap {
                    let mut toks = g.sess.lane_tokens(m.lane).to_vec();
                    toks.truncate(m.req.gen_len);
                    let text = tok.decode(&toks);
                    let queue_s = (m.admitted - m.arrived).as_secs_f64();
                    let total_s = m.arrived.elapsed().as_secs_f64();
                    metrics.record_request(total_s, queue_s, decode_s, toks.len());
                    let _ = m.done.send(Response {
                        id: m.req.id,
                        text,
                        tokens: toks,
                        queue_s,
                        prefill_s,
                        decode_s,
                        total_s,
                        splits: splits.clone(),
                    });
                    m.state = RequestState::Done;
                }
            }
        }
        // dropping a finished group frees its KV reservation → admission
        // can proceed next step (tiered sessions release their blocks)
        let mut live = Vec::with_capacity(groups.len());
        for g in groups.drain(..) {
            if g.active() > 0 {
                live.push(g);
            } else if let (KvHold::Tiered(seq), Some((s, _))) = (&g.kv, store.as_mut()) {
                s.release(*seq);
            }
        }
        groups = live;

        metrics.record_step(queue.len(), active, t_step.elapsed().as_secs_f64(), step_tokens);
    }
    Ok(())
}
