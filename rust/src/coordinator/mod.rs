//! Serving front end: admission, batching, and the decode event loop.
//!
//! Two serving modes share the request/response types and metrics:
//!
//! * [`ContinuousServer`] — the **continuous-batching** loop:
//!   a step-driven event loop with per-request state
//!   machines (`Queued → Prefill → Decoding → Done`), per-step admission
//!   and retirement, per-batch re-solving of the paper's Eq. (11) split
//!   point via [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch)
//!   over one [`PlanInput`](crate::scheduler::PlanInput) per group,
//!   and KV-budget backpressure through [`MemPool`](crate::memory::MemPool).
//!   With [`TieredKvConfig`] set, the hardware shape is a declarative
//!   [`TierTopology`](crate::scheduler::TierTopology) — calibrated
//!   against the engine's measured wire and shared by the store, the
//!   eviction scores and the planner — and the budget becomes the gpu
//!   tier of a block-granular [`KvStore`](crate::kvstore::KvStore):
//!   admission runs against the reclaimable host tiers (with
//!   recompute-aware drop-KV-keep-X reclamation) instead of hard
//!   backpressure, an async prefetcher promotes blocks ahead of each
//!   step, a device-resident KV suffix shrinks the per-step transfer
//!   term, and the migration engine's per-step link grant is derived
//!   adaptively from the plans' predicted idle-link slack
//!   ([`StepPlan::link_slack_bytes`](crate::scheduler::StepPlan::link_slack_bytes)).
//!   In [`PipelineMode::Overlapped`] the loop runs as a pipelined step
//!   runtime: a stage worker pre-solves the next step's plans and pumps
//!   the migration grant inside the decode-compute shadow, with
//!   validity-token handoff ([`PlanHandoff`](crate::scheduler::PlanHandoff))
//!   guaranteeing tokens stay bit-identical to [`PipelineMode::Serial`].
//!   This is the serving mode that exercises KVPR under concurrent load.
//! * [`Server`] — the simpler whole-batch mode: the [`Batcher`] groups
//!   queued requests, the engine decodes the batch to completion, then the
//!   next batch forms.  Kept as the one-batch-at-a-time baseline the
//!   continuous loop is measured against (`rust/tests/coordinator_e2e.rs`).
//!
//! The engine's runtime handles are thread-pinned, so each server spawns a
//! worker thread that *builds* its own [`Engine`](crate::engine::Engine) and
//! drains a request channel; the [`Router`] round-robins across several
//! servers (data-parallel multi-GPU, paper Appendix A.7).

mod batcher;
mod continuous;
mod metrics;
mod request;
mod router;
mod server;

pub use batcher::Batcher;
pub use continuous::{ContinuousConfig, ContinuousServer, PipelineMode, TieredKvConfig};
pub use metrics::{
    DemotionTotals, DiskTotals, LatencyPercentiles, MigrationTotals, PipelineTotals, ServeMetrics,
    SloAttainment, StepBudgetTotals, TieringTotals,
};
pub use request::{Request, RequestState, Response};
pub use router::Router;
pub use server::{ResponseHandle, Server, ServerConfig};
