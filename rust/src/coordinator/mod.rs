//! Serving front end: request queue → dynamic batcher → engine.
//!
//! The engine's PJRT handles are thread-pinned, so each [`Server`] spawns a
//! worker thread that *builds* its own [`Engine`](crate::engine::Engine) and
//! drains a request channel; the [`Batcher`] groups compatible requests into
//! the artifact batch buckets; the [`Router`] round-robins across several
//! servers (data-parallel multi-GPU, paper Appendix A.7).

mod batcher;
mod metrics;
mod request;
mod router;
mod server;

pub use batcher::Batcher;
pub use metrics::ServeMetrics;
pub use request::{Request, Response};
pub use router::Router;
pub use server::{Server, ServerConfig};
