//! Serving front end: admission, batching, and the decode event loop.
//!
//! Two serving modes share the request/response types and metrics:
//!
//! * [`ContinuousServer`] — the **continuous-batching** loop:
//!   a step-driven event loop with per-request state
//!   machines (`Queued → Prefill → Decoding → Done`), per-step admission
//!   and retirement, per-batch re-solving of the paper's Eq. (11) split
//!   point via [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch)
//!   over one [`PlanInput`](crate::scheduler::PlanInput) per group,
//!   and KV-budget backpressure through [`MemPool`](crate::memory::MemPool).
//!   With [`TieredKvConfig`] set, the hardware shape is a declarative
//!   [`TierTopology`](crate::scheduler::TierTopology) — calibrated
//!   against the engine's measured wire and shared by the store, the
//!   eviction scores and the planner — and the budget becomes the gpu
//!   tier of a block-granular [`KvStore`](crate::kvstore::KvStore):
//!   admission runs against the reclaimable host tiers (with
//!   recompute-aware drop-KV-keep-X reclamation) instead of hard
//!   backpressure, an async prefetcher promotes blocks ahead of each
//!   step, a device-resident KV suffix shrinks the per-step transfer
//!   term, and the migration engine's per-step link grant is derived
//!   adaptively from the plans' predicted idle-link slack
//!   ([`StepPlan::link_slack_bytes`](crate::scheduler::StepPlan::link_slack_bytes)).
//!   In [`PipelineMode::Overlapped`] the loop runs as a pipelined step
//!   runtime: a stage worker pre-solves the next step's plans and pumps
//!   the migration grant inside the decode-compute shadow, with
//!   validity-token handoff ([`PlanHandoff`](crate::scheduler::PlanHandoff))
//!   guaranteeing tokens stay bit-identical to [`PipelineMode::Serial`].
//!   This is the serving mode that exercises KVPR under concurrent load.
//! * [`Server`] — the simpler whole-batch mode: the [`Batcher`] groups
//!   queued requests, the engine decodes the batch to completion, then the
//!   next batch forms.  Kept as the one-batch-at-a-time baseline the
//!   continuous loop is measured against (`rust/tests/coordinator_e2e.rs`).
//!
//! The engine's runtime handles are thread-pinned, so each server spawns a
//! worker thread that *builds* its own [`Engine`](crate::engine::Engine)
//! and drains a request channel.
//!
//! Every front end shares one submission surface: the [`Submit`] trait's
//! [`dispatch`](Submit::dispatch) accepts anything convertible into a
//! [`SubmitTarget`] — a `(prompt, gen_len)` pair, a pre-built [`Request`],
//! or a workload [`Trace`](crate::workload::Trace).  It is the *only*
//! submission path: the pre-0.9 `submit`/`submit_trace`/`submit_request`
//! methods rode one PR as `#[deprecated]` shims and are gone.
//!
//! Above the single-worker servers sits the sharded [`Router`]
//! (data-parallel multi-GPU, paper Appendix A.7): N [`ContinuousServer`]
//! worker shards, each owning a private gpu tier, over host tiers shared
//! through one [`SharedHostTiers`](crate::kvstore::SharedHostTiers), with
//! each shard's cross-shard hop declared as a remote rung in its
//! [`TierTopology`](crate::scheduler::TierTopology) chain.  Placement is
//! suffix-affine (a session lands on the shard holding its resident
//! suffix), saturated shards shed sessions by work stealing, and a stolen
//! session's prefix KV is parked on the receiving shard's remote rung so
//! the planner prices the cross-shard re-fetch — see the [`router`
//! module](self::Router) docs.

mod batcher;
mod continuous;
mod metrics;
mod request;
mod router;
mod server;
mod submit;

pub use batcher::Batcher;
pub use continuous::{
    ContinuousConfig, ContinuousConfigBuilder, ContinuousServer, PipelineMode, TieredKvConfig,
};
pub use metrics::{
    DemotionTotals, DiskTotals, LatencyPercentiles, MigrationTotals, PipelineTotals, RouterTotals,
    ServeMetrics, ShareTotals, SloAttainment, StepBudgetTotals, TieringTotals,
};
pub use request::{Request, RequestState, Response};
pub use router::{Router, RouterConfig};
pub use server::{ResponseHandle, Server, ServerConfig};
pub use submit::{Submit, SubmitTarget};
