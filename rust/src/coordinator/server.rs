//! The serving loop: one worker thread owning an engine, fed by a batcher.

use std::path::PathBuf;
use std::sync::mpsc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::batcher::Batcher;
use super::metrics::ServeMetrics;
use super::request::{Pending, Request, Response};
use super::submit::Submit;
use crate::engine::{Engine, EngineConfig};
use crate::model::ByteTokenizer;
use crate::util::clock::Clock;

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub artifact_dir: PathBuf,
    pub engine: EngineConfig,
    pub batcher: Batcher,
    /// Prompt bucket used for padding (must exist in the manifest).
    pub prompt_bucket: usize,
}

impl ServerConfig {
    pub fn new(artifact_dir: &str, engine: EngineConfig) -> Self {
        ServerConfig {
            artifact_dir: PathBuf::from(artifact_dir),
            engine,
            batcher: Batcher::new(4, Duration::from_millis(20)),
            prompt_bucket: 32,
        }
    }
}

/// Handle to a completion.
pub struct ResponseHandle {
    rx: mpsc::Receiver<Response>,
}

impl ResponseHandle {
    pub(crate) fn new(rx: mpsc::Receiver<Response>) -> Self {
        ResponseHandle { rx }
    }

    pub fn wait(self) -> Result<Response> {
        self.rx.recv().context("server dropped the request")
    }
}

/// A single-engine server.  PJRT is thread-pinned, so the engine is built
/// *inside* the worker thread.
pub struct Server {
    tx: Option<mpsc::Sender<Pending>>,
    worker: Option<std::thread::JoinHandle<Result<()>>>,
    metrics: ServeMetrics,
    next_id: std::sync::atomic::AtomicU64,
    clock: Clock,
}

impl Server {
    /// Spawn the worker; blocks until the engine is profiled and warm.
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let (tx, rx) = mpsc::channel::<Pending>();
        let metrics = ServeMetrics::new();
        let m2 = metrics.clone();
        let clock = Clock::wall();
        let c2 = clock.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();

        let worker = std::thread::Builder::new()
            .name("kvpr-server".into())
            .spawn(move || serve_loop(cfg, rx, m2, ready_tx, c2))
            .context("spawn server thread")?;
        ready_rx
            .recv()
            .context("server thread died during startup")??;
        Ok(Server {
            tx: Some(tx),
            worker: Some(worker),
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(1),
            clock,
        })
    }

    pub fn metrics(&self) -> &ServeMetrics {
        &self.metrics
    }

    /// Graceful shutdown: close the queue, join the worker.
    pub fn shutdown(mut self) -> Result<()> {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            w.join().map_err(|_| anyhow::anyhow!("server thread panicked"))??;
        }
        Ok(())
    }
}

impl Submit for Server {
    fn next_request_id(&self) -> u64 {
        self.next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    }

    fn enqueue(&self, req: Request) -> ResponseHandle {
        let (done, rx) = mpsc::channel();
        let pending = Pending { req, arrived: self.clock.now(), done };
        self.tx
            .as_ref()
            .expect("server shut down")
            .send(pending)
            .expect("server thread gone");
        ResponseHandle { rx }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.tx.take();
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn serve_loop(
    cfg: ServerConfig,
    rx: mpsc::Receiver<Pending>,
    metrics: ServeMetrics,
    ready: mpsc::Sender<Result<()>>,
    clock: Clock,
) -> Result<()> {
    let engine = match Engine::new(&cfg.artifact_dir, cfg.engine.clone()) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let msg = format!("{e:#}");
            let _ = ready.send(Err(anyhow::anyhow!(msg)));
            return Err(e);
        }
    };
    let tok = ByteTokenizer::new();

    while let Some(batch) = cfg.batcher.next_batch(&rx) {
        metrics.record_batch(batch.len());
        let gen_len = Batcher::batch_gen_len(&batch);
        let prompts: Vec<Vec<i32>> = batch
            .iter()
            .map(|p| tok.encode(&p.req.prompt, cfg.prompt_bucket))
            .collect();
        let t0_s = clock.now();
        let result = engine.generate(&prompts, gen_len);
        match result {
            Ok(gen) => {
                let total_batch_s = clock.now() - t0_s;
                for (i, p) in batch.into_iter().enumerate() {
                    let mut toks = gen.tokens[i].clone();
                    toks.truncate(p.req.gen_len);
                    let text = tok.decode(&toks);
                    let queue_s = (t0_s - p.arrived).max(0.0);
                    let total_s = (clock.now() - p.arrived).max(0.0);
                    metrics.record_request(total_s, queue_s, gen.metrics.decode_s, toks.len());
                    let _ = p.done.send(Response {
                        id: p.req.id,
                        text,
                        tokens: toks,
                        queue_s,
                        prefill_s: gen.metrics.prefill_s,
                        decode_s: gen.metrics.decode_s,
                        total_s,
                        splits: gen.metrics.splits.clone(),
                    });
                    let _ = total_batch_s;
                }
            }
            Err(e) => {
                // drop the senders → submitters see an error
                eprintln!("batch failed: {e:#}");
            }
        }
    }
    Ok(())
}
