//! Dynamic batcher: groups queued requests into artifact batch buckets.
//!
//! Policy: wait up to `max_wait` for the queue to reach `max_batch`
//! requests, then flush whatever is there.  Within a flush, requests are
//! grouped so a batch shares one decode length (the max of its members —
//! shorter requests are truncated on return), mirroring the padded-batch
//! serving style of the paper's workloads.

use std::time::{Duration, Instant};

use super::request::Pending;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct Batcher {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Batcher {
    pub fn new(max_batch: usize, max_wait: Duration) -> Self {
        Batcher { max_batch, max_wait }
    }

    /// Drain the channel into a batch according to the policy.  Returns
    /// `None` when the channel is closed and empty (shutdown).
    pub(crate) fn next_batch(
        &self,
        rx: &std::sync::mpsc::Receiver<Pending>,
    ) -> Option<Vec<Pending>> {
        // block for the first request
        let first = rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.max_wait;
        while batch.len() < self.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => batch.push(p),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        Some(batch)
    }

    /// Shared decode length for a batch (max over members).
    pub(crate) fn batch_gen_len(batch: &[Pending]) -> usize {
        batch.iter().map(|p| p.req.gen_len).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::Request;
    use std::sync::mpsc;

    fn pending(id: u64, gen: usize) -> (Pending, mpsc::Receiver<crate::coordinator::Response>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                req: Request::new(id, "hi", gen),
                arrived: 0.0,
                done: tx,
            },
            rx,
        )
    }

    #[test]
    fn flushes_full_batch_immediately() {
        let (tx, rx) = mpsc::channel();
        let mut keep = Vec::new();
        for i in 0..4 {
            let (p, r) = pending(i, 8);
            keep.push(r);
            tx.send(p).unwrap();
        }
        let b = Batcher::new(4, Duration::from_secs(5));
        let t0 = Instant::now();
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 4);
        assert!(t0.elapsed() < Duration::from_millis(200), "must not wait");
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let (tx, rx) = mpsc::channel();
        let (p, _r) = pending(0, 8);
        tx.send(p).unwrap();
        let b = Batcher::new(4, Duration::from_millis(30));
        let batch = b.next_batch(&rx).unwrap();
        assert_eq!(batch.len(), 1);
    }

    #[test]
    fn none_on_disconnect() {
        let (tx, rx) = mpsc::channel::<Pending>();
        drop(tx);
        let b = Batcher::new(4, Duration::from_millis(10));
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn batch_gen_len_is_max() {
        let (p1, _r1) = pending(0, 8);
        let (p2, _r2) = pending(1, 16);
        assert_eq!(Batcher::batch_gen_len(&[p1, p2]), 16);
    }
}
