//! Sharded serving front end: N continuous-batching worker shards behind
//! one [`Router`], with cross-shard traffic priced as topology rungs.
//!
//! Models the paper's Appendix A.7 setup — several GPU workers above one
//! host — without forking any layer below the coordinator:
//!
//! * **Each shard owns a gpu-hbm tier and its own serving loop** (a
//!   [`ContinuousServer`] with a private gpu pool), while pinned / dram /
//!   deep reservations draw from one
//!   [`SharedHostTiers`](crate::kvstore::SharedHostTiers) — N shards
//!   admitting concurrently compete for one host budget, exactly as N
//!   GPUs over one host do.
//! * **The remote hop is a declared rung**: the router appends a
//!   `"remote"` [`TierSpec`](crate::scheduler::TierSpec) below each
//!   shard's chain
//!   ([`TierTopology::with_remote_hop`](crate::scheduler::TierTopology::with_remote_hop)),
//!   so the existing `plan_batch` transfer fold prices cross-shard
//!   fetches via
//!   [`hop_factor`](crate::scheduler::TierTopology::hop_factor) — no
//!   planner fork, no second cost model.
//! * **Suffix-affinity placement**: a session (keyed by its prompt, the
//!   byte-tokenizer's session identity) lands on the shard already
//!   holding its resident suffix; first-seen sessions go to the
//!   least-loaded shard (lowest index breaking ties), so placement is a
//!   pure function of the submission sequence — deterministic under the
//!   seeded step clock.
//! * **Work stealing**: when a session's affinity shard is saturated
//!   ([`RouterConfig::shard_capacity`] outstanding requests) and a
//!   strictly less-loaded shard exists, the session moves there; its
//!   prefix KV is then remote, so the request is tagged
//!   ([`Request::with_remote_prefix`]) and the receiving serve loop parks
//!   that prefix on its deep (remote) rung — the planner prices the
//!   re-fetch hops, and the store's two-hop promotions pull the blocks
//!   back through the shared host tiers.
//!
//! Tokens are placement-invariant: the engine's decode is a deterministic
//! function of (prompt, generation length), so an N-shard router serves a
//! trace bit-identically to a 1-shard one — the multi-worker e2e pins
//! this, and `benches/perf_hotpath.rs` gates aggregate steps/s at 1/2/4
//! shards.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use super::continuous::{ContinuousConfig, ContinuousServer};
use super::metrics::RouterTotals;
use super::request::Request;
use super::server::ResponseHandle;
use super::submit::Submit;
use crate::kvstore::{share_key, SharedHostTiers};
use crate::obs::chrome_trace_sharded;
use crate::scheduler::LinkSpec;
use crate::util::json::Json;

/// Sharded-serving construction parameters.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Worker shards (≥ 1); each runs its own serving loop over a private
    /// gpu tier.
    pub shards: usize,
    /// Per-shard serving config.  Its tiering (or
    /// [`TieredKvConfig::default`](super::TieredKvConfig) when unset — the
    /// router always serves tiered) is cloned into every shard with the
    /// topology extended by the remote rung and the host pools replaced by
    /// the shared ones.
    pub base: ContinuousConfig,
    /// Capacity of the remote rung appended to each shard's chain — the
    /// cross-shard KV the deep tier can hold.  Ignored when the base
    /// topology already declares a below-base rung (that rung then doubles
    /// as the remote hop).
    pub remote_capacity_bytes: u64,
    /// The declared interconnect of the remote hop (NVLink bridge, PCIe
    /// switch, RDMA fabric, ...).  [`LinkSpec::unresolved`] calibrates it
    /// against the engine wire like any other below-base rung.
    pub remote_link: LinkSpec,
    /// Outstanding-request threshold per shard beyond which placement
    /// steals a session to a less-loaded shard; 0 (the default) never
    /// steals.
    pub shard_capacity: usize,
    /// Prefix-affinity placement width: when > 0, the placement key is the
    /// content hash ([`share_key`]) of the prompt's first this-many
    /// byte-tokens instead of the whole prompt, so requests sharing a
    /// prompt prefix land on the same shard — and its
    /// [`PrefixRegistry`](crate::kvstore::PrefixRegistry) — maximising
    /// cross-request adoption.  0 (the default) keys on the full prompt.
    pub affinity_prefix_tokens: usize,
}

impl RouterConfig {
    pub fn new(shards: usize, base: ContinuousConfig) -> Self {
        RouterConfig {
            shards,
            base,
            remote_capacity_bytes: 1 << 30,
            remote_link: LinkSpec::unresolved(),
            shard_capacity: 0,
            affinity_prefix_tokens: 0,
        }
    }
}

/// The placement key a prompt maps to: the whole prompt when
/// `prefix_tokens` is 0, else the hex content hash of its first
/// `prefix_tokens` byte-tokens (so prefix-sharing siblings collide onto
/// one shard's registry).
fn affinity_key(prompt: &str, prefix_tokens: usize) -> String {
    if prefix_tokens == 0 {
        prompt.to_string()
    } else {
        format!("{:016x}", share_key(prompt.as_bytes(), prefix_tokens))
    }
}

/// How a placement decision was reached.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PlacementKind {
    /// The session's affinity shard had room.
    AffinityHit,
    /// First sight of this session: least-loaded shard.
    Fresh,
    /// Affinity shard saturated: stolen to a strictly less-loaded shard.
    Steal,
}

#[derive(Debug, Clone, Copy)]
struct Decision {
    shard: usize,
    kind: PlacementKind,
}

/// Suffix-affinity placement: a pure function of the submission sequence
/// and the per-shard load vector — no clocks, no randomness — so a
/// replayed trace places identically every run.
struct Placement {
    /// Session key (the prompt) → shard holding its resident suffix.
    affinity: HashMap<String, usize>,
    /// Outstanding threshold above which an affinity shard counts as
    /// saturated (0 = never).
    capacity: usize,
}

impl Placement {
    fn new(capacity: usize) -> Self {
        Placement { affinity: HashMap::new(), capacity }
    }

    fn least_loaded(loads: &[usize]) -> usize {
        loads
            .iter()
            .enumerate()
            .min_by_key(|&(i, &l)| (l, i))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    fn place(&mut self, key: &str, loads: &[usize]) -> Decision {
        match self.affinity.get(key).copied() {
            Some(s) if self.capacity == 0 || loads[s] < self.capacity => {
                Decision { shard: s, kind: PlacementKind::AffinityHit }
            }
            Some(s) => {
                let t = Self::least_loaded(loads);
                if t == s || loads[t] >= loads[s] {
                    // nowhere strictly better: stay home rather than
                    // bounce the suffix between equally saturated shards
                    Decision { shard: s, kind: PlacementKind::AffinityHit }
                } else {
                    self.affinity.insert(key.to_string(), t);
                    Decision { shard: t, kind: PlacementKind::Steal }
                }
            }
            None => {
                let t = Self::least_loaded(loads);
                self.affinity.insert(key.to_string(), t);
                Decision { shard: t, kind: PlacementKind::Fresh }
            }
        }
    }
}

/// The unified front end over [`ContinuousServer`] worker shards — see the
/// module docs for the placement/stealing/remote-hop semantics.  Submit
/// through the [`Submit`] trait, exactly as on a single server:
///
/// ```no_run
/// use kvpr::coordinator::{ContinuousConfig, Router, RouterConfig, Submit};
/// use kvpr::engine::{EngineConfig, EnginePolicy};
/// use kvpr::scheduler::TierTopology;
///
/// let base = ContinuousConfig::builder("artifacts", EngineConfig::new(EnginePolicy::Kvpr))
///     .topology(TierTopology::standard(0, 64 << 20, 256 << 20))
///     .build();
/// let router = Router::start(RouterConfig::new(2, base)).unwrap();
/// let resp = router.dispatch(("hello shards", 8)).pop().unwrap().wait().unwrap();
/// assert_eq!(resp.tokens.len(), 8);
/// router.shutdown().unwrap();
/// ```
pub struct Router {
    shards: Vec<ContinuousServer>,
    placement: Mutex<Placement>,
    totals: Mutex<RouterTotals>,
    /// Requests placed on each shard (outstanding = this − completed).
    submitted: Vec<AtomicU64>,
    next_id: AtomicU64,
    /// See [`RouterConfig::affinity_prefix_tokens`].
    affinity_prefix_tokens: usize,
}

impl Router {
    /// Start `cfg.shards` worker shards over one shared host: build the
    /// per-shard chain (base topology + remote rung), size the shared host
    /// pools off that chain, and clone both into every shard's serving
    /// config.
    pub fn start(cfg: RouterConfig) -> Result<Router> {
        anyhow::ensure!(cfg.shards >= 1, "a router needs at least one shard");
        let tiering = cfg.base.tiering.clone().unwrap_or_default();
        let topo = match tiering.topology.deep_tier() {
            // an already-declared below-base rung (e.g. a disk) doubles as
            // the remote hop; otherwise append the declared remote rung
            Some(_) => tiering.topology.clone(),
            None => tiering
                .topology
                .clone()
                .with_remote_hop(cfg.remote_capacity_bytes, cfg.remote_link),
        };
        let cap = |name: &str| topo.tier_named(name).map_or(0, |i| topo.tier(i).capacity_bytes);
        let deep = topo.deep_tier().map_or(0, |i| topo.tier(i).capacity_bytes);
        let shared = SharedHostTiers::new(cap("pinned"), cap("cpu-dram"), deep);
        let mut shards = Vec::with_capacity(cfg.shards);
        for _ in 0..cfg.shards {
            let mut t = tiering.clone();
            t.topology = topo.clone();
            t.shared_host = Some(shared.clone());
            let mut sc = cfg.base.clone();
            sc.tiering = Some(t);
            shards.push(ContinuousServer::start(sc)?);
        }
        let submitted = (0..cfg.shards).map(|_| AtomicU64::new(0)).collect();
        Ok(Router {
            shards,
            placement: Mutex::new(Placement::new(cfg.shard_capacity)),
            totals: Mutex::new(RouterTotals::default()),
            submitted,
            next_id: AtomicU64::new(1),
            affinity_prefix_tokens: cfg.affinity_prefix_tokens,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard `i`'s server (its [`ServeMetrics`](super::ServeMetrics),
    /// tracer, ...).
    pub fn shard(&self, i: usize) -> &ContinuousServer {
        &self.shards[i]
    }

    /// Requests placed on shard `i` whose responses have not completed.
    fn outstanding(&self, i: usize) -> usize {
        let placed = self.submitted[i].load(Ordering::Relaxed);
        placed.saturating_sub(self.shards[i].metrics().requests()) as usize
    }

    /// Placement totals (hits / fresh / steals / remote-tagged tokens).
    pub fn totals(&self) -> RouterTotals {
        *self.totals.lock().unwrap()
    }

    /// Aggregate generated tokens across shards.
    pub fn total_tokens(&self) -> u64 {
        self.shards.iter().map(|s| s.metrics().tokens()).sum()
    }

    /// Aggregate completed requests across shards.
    pub fn total_requests(&self) -> u64 {
        self.shards.iter().map(|s| s.metrics().requests()).sum()
    }

    /// Aggregate event-loop decode steps across shards.
    pub fn total_steps(&self) -> u64 {
        self.shards.iter().map(|s| s.metrics().steps()).sum()
    }

    /// One Chrome trace document with every shard's serving loop as its
    /// own process track (`pid` = shard + 1, named `shard-<i>`) — load the
    /// export in Perfetto to see the shards' steps side by side.  Empty
    /// tracks when tracing is off ([`ContinuousConfig::trace`] unset).
    pub fn export_chrome_trace(&self) -> Json {
        let per_shard: Vec<_> = self.shards.iter().map(|s| s.tracer().events()).collect();
        chrome_trace_sharded(&per_shard)
    }

    /// Graceful shutdown of every shard (drains in shard order).
    pub fn shutdown(self) -> Result<()> {
        for s in self.shards {
            s.shutdown()?;
        }
        Ok(())
    }
}

impl Submit for Router {
    fn next_request_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn enqueue(&self, req: Request) -> ResponseHandle {
        let loads: Vec<usize> = (0..self.shards.len()).map(|i| self.outstanding(i)).collect();
        let key = affinity_key(&req.prompt, self.affinity_prefix_tokens);
        // one lock covers decide + count + forward, so two concurrent
        // submitters of the same session cannot race the affinity map
        let mut placement = self.placement.lock().unwrap();
        let d = placement.place(&key, &loads);
        let req = match d.kind {
            // the byte tokenizer maps one prompt byte to one token, so the
            // stolen session's remote prefix is the prompt itself (the
            // serve loop clamps to its prompt bucket)
            PlacementKind::Steal => {
                let tokens = req.prompt.len();
                req.with_remote_prefix(tokens)
            }
            _ => req,
        };
        self.totals.lock().unwrap().record(
            d.kind == PlacementKind::AffinityHit,
            d.kind == PlacementKind::Steal,
            req.remote_prefix_tokens,
        );
        self.submitted[d.shard].fetch_add(1, Ordering::Relaxed);
        let handle = self.shards[d.shard].enqueue(req);
        drop(placement);
        handle
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_sessions_spread_by_load_lowest_index_ties() {
        let mut p = Placement::new(0);
        let d = p.place("a", &[0, 0, 0]);
        assert_eq!((d.shard, d.kind), (0, PlacementKind::Fresh), "tie → lowest index");
        let d = p.place("b", &[1, 0, 0]);
        assert_eq!((d.shard, d.kind), (1, PlacementKind::Fresh));
        let d = p.place("c", &[1, 1, 0]);
        assert_eq!((d.shard, d.kind), (2, PlacementKind::Fresh));
    }

    #[test]
    fn affinity_hits_return_to_the_suffix_shard() {
        let mut p = Placement::new(0);
        assert_eq!(p.place("sess", &[3, 0]).shard, 1);
        // load has shifted, but the suffix lives on shard 1
        let d = p.place("sess", &[0, 9]);
        assert_eq!((d.shard, d.kind), (1, PlacementKind::AffinityHit));
    }

    #[test]
    fn saturation_steals_to_a_strictly_less_loaded_shard() {
        let mut p = Placement::new(2);
        assert_eq!(p.place("sess", &[0, 1]).shard, 0);
        // shard 0 saturated (2 outstanding ≥ capacity 2), shard 1 idle
        let d = p.place("sess", &[2, 0]);
        assert_eq!((d.shard, d.kind), (1, PlacementKind::Steal));
        // the affinity moved with the steal: the session now hits shard 1
        let d = p.place("sess", &[0, 1]);
        assert_eq!((d.shard, d.kind), (1, PlacementKind::AffinityHit));
    }

    #[test]
    fn no_steal_when_every_shard_is_equally_saturated() {
        let mut p = Placement::new(1);
        assert_eq!(p.place("sess", &[0, 0]).shard, 0);
        let d = p.place("sess", &[1, 1]);
        assert_eq!(
            (d.shard, d.kind),
            (0, PlacementKind::AffinityHit),
            "bouncing between equally saturated shards would thrash the suffix"
        );
    }

    #[test]
    fn zero_capacity_never_steals() {
        let mut p = Placement::new(0);
        assert_eq!(p.place("sess", &[0, 0]).shard, 0);
        let d = p.place("sess", &[1_000_000, 0]);
        assert_eq!((d.shard, d.kind), (0, PlacementKind::AffinityHit));
    }

    #[test]
    fn prefix_affinity_key_collides_siblings_and_splits_strangers() {
        // width 0 keys on the whole prompt: siblings separate
        assert_ne!(affinity_key("sys-prompt A", 0), affinity_key("sys-prompt B", 0));
        // width 10 hashes only "sys-prompt": siblings collide …
        assert_eq!(affinity_key("sys-prompt A", 10), affinity_key("sys-prompt B", 10));
        // … and a different prefix still lands elsewhere
        assert_ne!(affinity_key("sys-prompt A", 10), affinity_key("other sys  A", 10));
        // the hash clamps to the prompt, so short prompts stay stable
        assert_eq!(affinity_key("abc", 64), affinity_key("abc", 64));
    }

    #[test]
    fn placement_is_a_pure_function_of_the_submission_sequence() {
        // the property the seeded step-clock e2e leans on: replaying the
        // same keys against the same load vectors decides identically
        let run = || {
            let mut p = Placement::new(2);
            let keys = ["a", "b", "a", "c", "b", "a"];
            let loads = [[0, 0], [1, 0], [2, 1], [2, 2], [1, 2], [2, 0]];
            keys.iter()
                .zip(loads.iter())
                .map(|(k, l)| {
                    let d = p.place(k, l);
                    (d.shard, d.kind)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
