//! Round-robin router over data-parallel servers.
//!
//! Models the paper's Appendix A.7 setup: several GPU workers behind one
//! entry point.  KVPR needs no shared CPU resource, so adding servers
//! scales linearly — the property Fig 14 contrasts with FastDecode's
//! CPU-bottleneck (reproduced in the simulator, `benches/fig14_multigpu`).

use std::sync::atomic::{AtomicUsize, Ordering};

use anyhow::Result;

use super::server::{ResponseHandle, Server, ServerConfig};

/// Round-robin dispatcher.
pub struct Router {
    servers: Vec<Server>,
    next: AtomicUsize,
}

impl Router {
    /// Start `n` identical servers.
    pub fn start(cfg: &ServerConfig, n: usize) -> Result<Router> {
        let mut servers = Vec::with_capacity(n);
        for _ in 0..n {
            servers.push(Server::start(cfg.clone())?);
        }
        Ok(Router { servers, next: AtomicUsize::new(0) })
    }

    pub fn n_servers(&self) -> usize {
        self.servers.len()
    }

    /// Dispatch to the next server in rotation.
    pub fn submit(&self, prompt: &str, gen_len: usize) -> ResponseHandle {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.servers.len();
        self.servers[i].submit(prompt, gen_len)
    }

    /// Aggregate generated-token throughput across workers.
    pub fn total_tokens(&self) -> u64 {
        self.servers.iter().map(|s| s.metrics().tokens()).sum()
    }

    pub fn total_requests(&self) -> u64 {
        self.servers.iter().map(|s| s.metrics().requests()).sum()
    }

    pub fn server(&self, i: usize) -> &Server {
        &self.servers[i]
    }

    pub fn shutdown(self) -> Result<()> {
        for s in self.servers {
            s.shutdown()?;
        }
        Ok(())
    }
}
