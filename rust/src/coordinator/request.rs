//! Request/response types and the per-request lifecycle state machine the
//! continuous-batching loop drives.

/// Lifecycle of a request inside the serving loop:
/// `Queued → Prefill → Decoding → Done`.
///
/// Transitions happen only at event-loop step boundaries — admission
/// (`Queued → Prefill → Decoding`) when a decode group is formed and its
/// prompts prefilled, retirement (`Decoding → Done`) when the request's
/// generation budget is met or the group's KV cache hits capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestState {
    /// Waiting in the admission queue (backpressure holds requests here).
    Queued,
    /// Being prefilled into a decode group.
    Prefill,
    /// Decoding one token per step as a lane of its group.
    Decoding,
    /// Completed and responded.
    Done,
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub gen_len: usize,
    /// Trace arrival step: admission holds the request until the serving
    /// loop's step clock reaches this step, so a replayed trace's arrival
    /// schedule is honoured independent of wall time.  `None` (the
    /// wall-clock path) is eligible immediately.
    pub arrival_step: Option<usize>,
    /// Sharded serving: prompt-prefix tokens whose KV lives on *another*
    /// shard.  The [`Router`](super::Router) sets this when work-stealing
    /// moves a session off its affinity shard; the receiving serve loop
    /// parks that prefix on its deep (remote-hop) tier at admission, so
    /// the planner prices the cross-shard re-fetch instead of assuming the
    /// KV is local.  Zero everywhere else.
    pub remote_prefix_tokens: usize,
}

impl Request {
    pub fn new(id: u64, prompt: &str, gen_len: usize) -> Self {
        Request {
            id,
            prompt: prompt.to_string(),
            gen_len,
            arrival_step: None,
            remote_prefix_tokens: 0,
        }
    }

    /// A step-indexed request (trace replay).
    pub fn at_step(id: u64, prompt: &str, gen_len: usize, step: usize) -> Self {
        Request {
            id,
            prompt: prompt.to_string(),
            gen_len,
            arrival_step: Some(step),
            remote_prefix_tokens: 0,
        }
    }

    /// Tag this request's first `tokens` prompt tokens as resident on a
    /// remote shard (see [`Request::remote_prefix_tokens`]).
    pub fn with_remote_prefix(mut self, tokens: usize) -> Self {
        self.remote_prefix_tokens = tokens;
        self
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Decoded generation (byte-level tokenizer).
    pub text: String,
    pub tokens: Vec<i32>,
    /// Time spent queued before the batch formed.
    pub queue_s: f64,
    /// Share of the batch prefill attributed to this request.
    pub prefill_s: f64,
    /// Decode wall time of the batch.
    pub decode_s: f64,
    /// End-to-end latency.
    pub total_s: f64,
    /// Split points the scheduler picked during this batch's decode.
    pub splits: Vec<usize>,
}

/// Internal envelope carrying arrival time + completion channel.
pub(crate) struct Pending {
    pub req: Request,
    /// Arrival stamp in seconds on the owning server's
    /// [`Clock`](crate::util::clock::Clock) — wall-elapsed or virtual
    /// step time depending on the server's clock mode, so every latency
    /// derived from it is reproducible under the deterministic clock.
    pub arrived: f64,
    pub done: std::sync::mpsc::Sender<Response>,
}
