//! Request/response types.

use std::time::Instant;

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub prompt: String,
    pub gen_len: usize,
}

impl Request {
    pub fn new(id: u64, prompt: &str, gen_len: usize) -> Self {
        Request { id, prompt: prompt.to_string(), gen_len }
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Decoded generation (byte-level tokenizer).
    pub text: String,
    pub tokens: Vec<i32>,
    /// Time spent queued before the batch formed.
    pub queue_s: f64,
    /// Share of the batch prefill attributed to this request.
    pub prefill_s: f64,
    /// Decode wall time of the batch.
    pub decode_s: f64,
    /// End-to-end latency.
    pub total_s: f64,
    /// Split points the scheduler picked during this batch's decode.
    pub splits: Vec<usize>,
}

/// Internal envelope carrying arrival time + completion channel.
pub(crate) struct Pending {
    pub req: Request,
    pub arrived: Instant,
    pub done: std::sync::mpsc::Sender<Response>,
}
