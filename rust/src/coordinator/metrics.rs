//! Serving metrics aggregation: per-request latency summaries plus the
//! per-step counters the continuous-batching loop emits (step latency,
//! queue depth, batch occupancy, KV-budget backpressure events), and the
//! per-request TTFT/TPOT samples the workload harness scores against a
//! mix's [`SloTargets`].

use std::sync::{Arc, Mutex};

use crate::util::stats::Summary;
use crate::workload::SloTargets;

/// Shared metrics sink: per-request latency summaries + token counters.
/// Clone-cheap (`Arc`-shared): the serving thread records, callers read.
#[derive(Debug, Clone, Default)]
pub struct ServeMetrics {
    inner: Arc<Mutex<Inner>>,
}

#[derive(Debug, Default)]
struct Inner {
    total_latency: Summary,
    queue: Summary,
    decode: Summary,
    requests: u64,
    tokens: u64,
    batches: u64,
    started: Option<std::time::Instant>,
    ended: Option<std::time::Instant>,
    // -- continuous-loop step counters --------------------------------------
    steps: u64,
    step_time: Summary,
    queue_depth: Summary,
    occupancy: Summary,
    step_tokens: u64,
    step_time_total: f64,
    backpressure: u64,
    // -- tiered kvstore counters --------------------------------------------
    promoted_tokens: u64,
    demoted_tokens: u64,
    kv_dropped_tokens: u64,
    // -- migration-engine lifecycle counters --------------------------------
    migrations_launched: u64,
    migrations_landed: u64,
    migration_deferrals: u64,
    demotions_issued: u64,
    demotions_polled: u64,
    // -- disk-tier counters --------------------------------------------------
    spills_issued: u64,
    spills_polled: u64,
    hops_issued: u64,
    hops_polled: u64,
    // -- sharded-serving counters ---------------------------------------------
    remote_parked_blocks: u64,
    // -- cross-request prefix sharing -----------------------------------------
    share: ShareTotals,
    // -- physical dropped-KV reclamation --------------------------------------
    kv_reclaimed_bytes: u64,
    // -- adaptive step-budget counters ---------------------------------------
    budget: StepBudgetTotals,
    // -- pipelined-runtime counters -------------------------------------------
    pipeline: PipelineTotals,
    // -- workload SLO samples -------------------------------------------------
    ttft: Summary,
    tpot: Summary,
    slo: Option<SloTargets>,
    slo_requests: u64,
    slo_ttft_ok: u64,
    slo_tpot_ok: u64,
}

/// Percentile snapshot of one latency dimension (all zeros when no sample
/// was recorded — never NaN, never a panic).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LatencyPercentiles {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

/// SLO attainment counters: of `requests` scored requests, how many met
/// the TTFT and TPOT targets.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SloAttainment {
    /// Requests recorded while a target was set.
    pub requests: u64,
    pub ttft_ok: u64,
    pub tpot_ok: u64,
}

impl SloAttainment {
    /// Fraction of scored requests meeting the TTFT target.  Documented
    /// edge: with zero scored requests the objective is vacuously met —
    /// 1.0, never NaN.
    pub fn ttft_frac(&self) -> f64 {
        if self.requests == 0 {
            1.0
        } else {
            self.ttft_ok as f64 / self.requests as f64
        }
    }

    /// Fraction meeting the TPOT target (same vacuous-1.0 edge).
    pub fn tpot_frac(&self) -> f64 {
        if self.requests == 0 {
            1.0
        } else {
            self.tpot_ok as f64 / self.requests as f64
        }
    }
}

/// Token totals of the tiered kvstore's residency churn.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TieringTotals {
    /// Tokens promoted into the device-resident window.
    pub promoted_tokens: u64,
    /// Tokens demoted out of it.
    pub demoted_tokens: u64,
    /// Prefix tokens whose KV the store dropped (keeping X) to reclaim
    /// capacity.
    pub kv_dropped_tokens: u64,
}

/// Migration-engine lifecycle totals.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationTotals {
    /// Migrations launched onto a wire.
    pub launched: u64,
    /// Migrations that landed and were installed.
    pub landed: u64,
    /// Pump passes deferred by the step's link-byte budget.
    pub budget_deferrals: u64,
}

/// Asynchronous gpu-eviction demotion totals: `issued` counts evictions
/// whose gpu bytes freed instantly; `polled` counts their writebacks
/// landing on a *later* step — both non-zero proves the serving path
/// never waited a demotion out.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DemotionTotals {
    pub issued: u64,
    pub polled: u64,
}

/// Disk-tier traffic totals.  Issued > 0 with polled > 0 proves every
/// disk transfer moved through the migration engine's poll path — the
/// step loop never blocked on NVMe.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DiskTotals {
    /// dram→disk spills issued (dram bytes freed instantly).
    pub spills_issued: u64,
    /// Spill NVMe writebacks polled in.
    pub spills_polled: u64,
    /// disk→dram promotion hops issued (first leg of the two-hop path).
    pub hops_issued: u64,
    /// Promotion hops landed.
    pub hops_polled: u64,
}

/// Placement totals of the sharded [`Router`](super::Router) front end.
/// Written by the router's placement path (not the per-shard serve loops);
/// read via [`Router::totals`](super::Router::totals).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RouterTotals {
    /// Requests the router placed (one per dispatched request).
    pub submitted: u64,
    /// Placements that landed on the shard already holding the session's
    /// resident suffix.
    pub affinity_hits: u64,
    /// First-seen sessions placed on the least-loaded shard.
    pub fresh: u64,
    /// Sessions moved off a saturated affinity shard (work stealing); the
    /// destination shard re-fetches their prefix over its remote hop.
    pub steals: u64,
    /// Prompt-prefix tokens tagged for cross-shard re-fetch by those
    /// steals.
    pub remote_prefix_tokens: u64,
}

impl RouterTotals {
    /// Fold one placement decision into the totals.
    pub(crate) fn record(&mut self, hit: bool, stolen: bool, remote_tokens: usize) {
        self.submitted += 1;
        if stolen {
            self.steals += 1;
        } else if hit {
            self.affinity_hits += 1;
        } else {
            self.fresh += 1;
        }
        self.remote_prefix_tokens += remote_tokens as u64;
    }
}

/// Cross-request prefix-sharing totals: admissions whose content-hashed
/// prompt prefix matched blocks an earlier request registered in the
/// store's [`PrefixRegistry`](crate::kvstore::PrefixRegistry), and the
/// blocks/tokens those hits adopted in place (zero new bytes, zero
/// transfer).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShareTotals {
    /// Admissions that adopted at least one registered shared block.
    pub hits: u64,
    /// Shared blocks adopted across all hits.
    pub blocks: u64,
    /// Prompt-prefix tokens those blocks cover.
    pub tokens: u64,
}

/// Aggregates of the per-step adaptive migration grant (the planner-slack
/// budget the serving loop hands [`KvStore::pump_migrations`](crate::kvstore::KvStore::pump_migrations)).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepBudgetTotals {
    /// Steps that granted a migration budget.
    pub steps: u64,
    /// Planner-predicted idle-link bytes, summed (saturating).
    pub slack_bytes: u64,
    /// Bytes actually granted, summed (saturating).
    pub granted_bytes: u64,
    /// Steps whose grant was not `max(slack, 1)` — stays 0 on the adaptive
    /// path, so any non-zero value means a static override (or a bug)
    /// detached the grant from the planner's slack.
    pub mismatch_steps: u64,
    /// Steps whose predicted slack was zero (the plan saved no link time).
    pub zero_slack_steps: u64,
    /// Most migrations launched in any zero-slack step: ≤ 1 proves only
    /// the engine's progress-guarantee override fires when the plan
    /// predicts no idle link time.
    pub zero_slack_launch_max: u64,
}

/// Totals of the overlapped pipeline's prestage/handoff machinery (all
/// zeros when the loop runs [`PipelineMode::Serial`](super::PipelineMode)).
/// `f64` stall/overlap accumulators make this `PartialEq` but not `Eq`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PipelineTotals {
    /// Steps the overlapped loop completed.
    pub steps: u64,
    /// Steps whose every executed plan came prebuilt out of the handoff.
    pub prestaged_steps: u64,
    /// Prebuilt plans adopted unchanged (handoff hits).
    pub plans_adopted: u64,
    /// Inline re-solves forced by a stale or missing prestage ticket.
    pub fallback_resolves: u64,
    /// Wall seconds the serve thread spent blocked on the stage worker's
    /// handoff after compute finished.
    pub stall_s: f64,
    /// Host seconds of staging work hidden under another group's compute
    /// (shadow time — also folded into
    /// [`Breakdown::overlap_s`](crate::engine::Breakdown)).
    pub overlap_s: f64,
}

impl ServeMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, n_requests: usize) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        let _ = n_requests;
    }

    pub fn record_request(&self, total_s: f64, queue_s: f64, decode_s: f64, tokens: usize) {
        let mut m = self.inner.lock().unwrap();
        let now = std::time::Instant::now();
        m.started.get_or_insert(now);
        m.ended = Some(now);
        m.total_latency.add(total_s);
        m.queue.add(queue_s);
        m.decode.add(decode_s);
        m.requests += 1;
        m.tokens += tokens as u64;
    }

    /// One event-loop step: `queue_depth` requests still waiting,
    /// `active` requests decoding, `step_s` wall seconds, `tokens` sampled.
    pub fn record_step(&self, queue_depth: usize, active: usize, step_s: f64, tokens: usize) {
        let mut m = self.inner.lock().unwrap();
        m.steps += 1;
        m.step_time.add(step_s);
        m.step_time_total += step_s;
        m.queue_depth.add(queue_depth as f64);
        m.occupancy.add(active as f64);
        m.step_tokens += tokens as u64;
    }

    /// Admission was refused because the KV budget was exhausted.
    pub fn record_backpressure(&self) {
        self.inner.lock().unwrap().backpressure += 1;
    }

    /// Tiered-kvstore activity this step: tokens promoted into / demoted
    /// out of the device-resident window, and prefix tokens whose KV the
    /// store dropped (keeping X) to reclaim capacity.
    pub fn record_tiering(&self, promoted: u64, demoted: u64, kv_dropped: u64) {
        let mut m = self.inner.lock().unwrap();
        m.promoted_tokens += promoted;
        m.demoted_tokens += demoted;
        m.kv_dropped_tokens += kv_dropped;
    }

    /// Token totals of the tiered kvstore's residency churn.
    pub fn tiering_totals(&self) -> TieringTotals {
        let m = self.inner.lock().unwrap();
        TieringTotals {
            promoted_tokens: m.promoted_tokens,
            demoted_tokens: m.demoted_tokens,
            kv_dropped_tokens: m.kv_dropped_tokens,
        }
    }

    /// Migration-engine lifecycle activity this step: migrations launched
    /// onto the link, migrations that landed and were installed, pump
    /// passes deferred by the step's link-byte budget, and asynchronous
    /// demotions issued / polled-in.
    pub fn record_migrations(
        &self,
        launched: u64,
        landed: u64,
        deferrals: u64,
        demotions_issued: u64,
        demotions_polled: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.migrations_launched += launched;
        m.migrations_landed += landed;
        m.migration_deferrals += deferrals;
        m.demotions_issued += demotions_issued;
        m.demotions_polled += demotions_polled;
    }

    /// Migration-engine lifecycle totals.
    pub fn migration_totals(&self) -> MigrationTotals {
        let m = self.inner.lock().unwrap();
        MigrationTotals {
            launched: m.migrations_launched,
            landed: m.migrations_landed,
            budget_deferrals: m.migration_deferrals,
        }
    }

    /// Asynchronous demotion totals (see [`DemotionTotals`]).
    pub fn demotion_totals(&self) -> DemotionTotals {
        let m = self.inner.lock().unwrap();
        DemotionTotals {
            issued: m.demotions_issued,
            polled: m.demotions_polled,
        }
    }

    /// Disk-tier traffic this step: dram→disk spills issued (dram bytes
    /// freed instantly) and their NVMe writebacks polled in, plus
    /// disk→dram promotion hops issued and landed (the first leg of the
    /// two-hop promotion path).
    pub fn record_disk(
        &self,
        spills_issued: u64,
        spills_polled: u64,
        hops_issued: u64,
        hops_polled: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.spills_issued += spills_issued;
        m.spills_polled += spills_polled;
        m.hops_issued += hops_issued;
        m.hops_polled += hops_polled;
    }

    /// Sharded serving: blocks this serve loop parked on its deep (remote)
    /// tier at admission because their KV lived on another shard.
    pub fn record_remote_prefix(&self, blocks: u64) {
        self.inner.lock().unwrap().remote_parked_blocks += blocks;
    }

    /// Blocks parked on the deep tier for cross-shard re-fetch (zero on an
    /// unsharded server).
    pub fn remote_parked_blocks(&self) -> u64 {
        self.inner.lock().unwrap().remote_parked_blocks
    }

    /// One admission's prefix-sharing hit: `blocks` registered blocks
    /// adopted in place, covering `tokens` prompt-prefix tokens.
    pub fn record_share(&self, blocks: u64, tokens: u64) {
        let mut m = self.inner.lock().unwrap();
        m.share.hits += 1;
        m.share.blocks += blocks;
        m.share.tokens += tokens;
    }

    /// Cross-request prefix-sharing totals (see [`ShareTotals`]).
    pub fn share_totals(&self) -> ShareTotals {
        self.inner.lock().unwrap().share
    }

    /// Host bytes physically freed by truncating a dropped-KV prefix out
    /// of the cache's K/V buffers (the X feedstock stays for recompute).
    pub fn record_reclaimed(&self, bytes: u64) {
        self.inner.lock().unwrap().kv_reclaimed_bytes += bytes;
    }

    /// Total host bytes reclaimed by dropped-KV truncation.
    pub fn kv_reclaimed_bytes(&self) -> u64 {
        self.inner.lock().unwrap().kv_reclaimed_bytes
    }

    /// Disk-tier traffic totals (see [`DiskTotals`]).
    pub fn disk_totals(&self) -> DiskTotals {
        let m = self.inner.lock().unwrap();
        DiskTotals {
            spills_issued: m.spills_issued,
            spills_polled: m.spills_polled,
            hops_issued: m.hops_issued,
            hops_polled: m.hops_polled,
        }
    }

    /// One step's migration grant: the planner-predicted idle-link slack,
    /// the bytes actually granted, and how many migrations the grant
    /// launched.
    pub fn record_step_budget(&self, slack_bytes: u64, granted_bytes: u64, launched: u64) {
        let mut m = self.inner.lock().unwrap();
        let b = &mut m.budget;
        b.steps += 1;
        b.slack_bytes = b.slack_bytes.saturating_add(slack_bytes);
        b.granted_bytes = b.granted_bytes.saturating_add(granted_bytes);
        if granted_bytes != slack_bytes.max(1) {
            b.mismatch_steps += 1;
        }
        if slack_bytes == 0 {
            b.zero_slack_steps += 1;
            b.zero_slack_launch_max = b.zero_slack_launch_max.max(launched);
        }
    }

    /// Aggregates of the adaptive per-step migration grant.
    pub fn budget_totals(&self) -> StepBudgetTotals {
        self.inner.lock().unwrap().budget
    }

    /// One overlapped step's pipeline accounting: whether every executed
    /// plan was prestaged, the handoff's hit/fallback tally, wall seconds
    /// stalled on the worker, and staging seconds hidden under compute.
    pub fn record_pipeline(
        &self,
        prestaged: bool,
        adopted: u64,
        fallbacks: u64,
        stall_s: f64,
        overlap_s: f64,
    ) {
        let mut m = self.inner.lock().unwrap();
        let p = &mut m.pipeline;
        p.steps += 1;
        if prestaged {
            p.prestaged_steps += 1;
        }
        p.plans_adopted += adopted;
        p.fallback_resolves += fallbacks;
        p.stall_s += stall_s;
        p.overlap_s += overlap_s;
    }

    /// Totals of the overlapped pipeline (zeros in serial mode).
    pub fn pipeline_totals(&self) -> PipelineTotals {
        self.inner.lock().unwrap().pipeline
    }

    /// Arm SLO scoring: subsequent [`record_ttft_tpot`](Self::record_ttft_tpot)
    /// calls are counted against these targets (samples recorded before a
    /// target is set only feed the percentile summaries).
    pub fn set_slo(&self, targets: SloTargets) {
        self.inner.lock().unwrap().slo = Some(targets);
    }

    /// One retired request's first-token latency and per-output-token
    /// pace.  `tpot_s` is `None` for single-token generations (no second
    /// token to pace) — such a request vacuously meets the TPOT target.
    pub fn record_ttft_tpot(&self, ttft_s: f64, tpot_s: Option<f64>) {
        let mut m = self.inner.lock().unwrap();
        m.ttft.add(ttft_s);
        if let Some(t) = tpot_s {
            m.tpot.add(t);
        }
        if let Some(slo) = m.slo {
            m.slo_requests += 1;
            if ttft_s <= slo.ttft_s {
                m.slo_ttft_ok += 1;
            }
            // a missed pace requires an actual second token; single-token
            // generations (tpot_s None) meet the target vacuously
            match tpot_s {
                Some(t) if t > slo.tpot_s => {}
                _ => m.slo_tpot_ok += 1,
            }
        }
    }

    /// TTFT percentile snapshot (zeros when no request was recorded).
    pub fn ttft_stats(&self) -> LatencyPercentiles {
        let m = self.inner.lock().unwrap();
        Self::percentiles(&m.ttft)
    }

    /// TPOT percentile snapshot (zeros when every generation was a single
    /// token, or nothing retired yet).
    pub fn tpot_stats(&self) -> LatencyPercentiles {
        let m = self.inner.lock().unwrap();
        Self::percentiles(&m.tpot)
    }

    fn percentiles(s: &Summary) -> LatencyPercentiles {
        if s.count() == 0 {
            return LatencyPercentiles::default();
        }
        LatencyPercentiles { mean: s.mean(), p50: s.p50(), p95: s.p95(), p99: s.p99() }
    }

    /// SLO attainment counters ([`set_slo`](Self::set_slo) arms scoring;
    /// all zeros before that, and the fractions are vacuously 1.0).
    pub fn slo_attainment(&self) -> SloAttainment {
        let m = self.inner.lock().unwrap();
        SloAttainment {
            requests: m.slo_requests,
            ttft_ok: m.slo_ttft_ok,
            tpot_ok: m.slo_tpot_ok,
        }
    }

    /// Highest number of requests decoding concurrently in any step.
    pub fn peak_occupancy(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.occupancy.count() == 0 {
            0.0
        } else {
            m.occupancy.max()
        }
    }

    pub fn requests(&self) -> u64 {
        self.inner.lock().unwrap().requests
    }

    pub fn batches(&self) -> u64 {
        self.inner.lock().unwrap().batches
    }

    pub fn tokens(&self) -> u64 {
        self.inner.lock().unwrap().tokens
    }

    /// Number of event-loop decode steps taken.
    pub fn steps(&self) -> u64 {
        self.inner.lock().unwrap().steps
    }

    /// Times admission hit KV-budget backpressure.
    pub fn backpressure_events(&self) -> u64 {
        self.inner.lock().unwrap().backpressure
    }

    /// (mean, p99) of one event-loop step's wall time in seconds.
    pub fn step_stats(&self) -> (f64, f64) {
        let m = self.inner.lock().unwrap();
        if m.step_time.count() == 0 {
            return (0.0, 0.0);
        }
        (m.step_time.mean(), m.step_time.p99())
    }

    /// Mean requests waiting in the admission queue per step.
    pub fn mean_queue_depth(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.queue_depth.count() == 0 {
            0.0
        } else {
            m.queue_depth.mean()
        }
    }

    /// Mean requests actively decoding per step (batch occupancy).
    pub fn mean_occupancy(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.occupancy.count() == 0 {
            0.0
        } else {
            m.occupancy.mean()
        }
    }

    /// Decode throughput over stepped time: tokens sampled per second of
    /// event-loop stepping (excludes prefill/queueing).
    pub fn step_tok_per_s(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.step_time_total > 0.0 {
            m.step_tokens as f64 / m.step_time_total
        } else {
            0.0
        }
    }

    /// (mean, p50, p99) of end-to-end latency in seconds.
    pub fn latency_stats(&self) -> (f64, f64, f64) {
        let m = self.inner.lock().unwrap();
        if m.total_latency.count() == 0 {
            return (0.0, 0.0, 0.0);
        }
        (m.total_latency.mean(), m.total_latency.p50(), m.total_latency.p99())
    }

    pub fn mean_queue_s(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.queue.count() == 0 {
            0.0
        } else {
            m.queue.mean()
        }
    }

    /// Serving throughput: generated tokens / wall time between first and
    /// last completion.
    pub fn tok_per_s(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        match (m.started, m.ended) {
            (Some(a), Some(b)) if b > a => m.tokens as f64 / (b - a).as_secs_f64(),
            _ => 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates() {
        let m = ServeMetrics::new();
        m.record_batch(4);
        m.record_request(1.0, 0.1, 0.8, 16);
        m.record_request(2.0, 0.2, 1.6, 16);
        assert_eq!(m.requests(), 2);
        assert_eq!(m.batches(), 1);
        assert_eq!(m.tokens(), 32);
        let (mean, p50, _p99) = m.latency_stats();
        assert!((mean - 1.5).abs() < 1e-9);
        assert!((p50 - 1.5).abs() < 1e-9);
        assert!((m.mean_queue_s() - 0.15).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let m = ServeMetrics::new();
        assert_eq!(m.latency_stats(), (0.0, 0.0, 0.0));
        assert_eq!(m.tok_per_s(), 0.0);
        assert_eq!(m.step_stats(), (0.0, 0.0));
        assert_eq!(m.step_tok_per_s(), 0.0);
    }

    #[test]
    fn clone_shares_state() {
        let a = ServeMetrics::new();
        let b = a.clone();
        b.record_request(1.0, 0.0, 0.5, 4);
        assert_eq!(a.requests(), 1);
    }

    #[test]
    fn step_counters() {
        let m = ServeMetrics::new();
        m.record_step(3, 8, 0.010, 8);
        m.record_step(0, 6, 0.030, 6);
        m.record_backpressure();
        assert_eq!(m.steps(), 2);
        assert_eq!(m.backpressure_events(), 1);
        let (mean, _p99) = m.step_stats();
        assert!((mean - 0.020).abs() < 1e-9);
        assert!((m.mean_queue_depth() - 1.5).abs() < 1e-9);
        assert!((m.mean_occupancy() - 7.0).abs() < 1e-9);
        assert!((m.peak_occupancy() - 8.0).abs() < 1e-9);
        assert!((m.step_tok_per_s() - 14.0 / 0.040).abs() < 1e-6);
    }

    #[test]
    fn tiering_counters() {
        let m = ServeMetrics::new();
        assert_eq!(m.tiering_totals(), TieringTotals::default());
        m.record_tiering(32, 0, 0);
        m.record_tiering(16, 8, 32);
        assert_eq!(
            m.tiering_totals(),
            TieringTotals {
                promoted_tokens: 48,
                demoted_tokens: 8,
                kv_dropped_tokens: 32,
            }
        );
        assert_eq!(m.peak_occupancy(), 0.0);
    }

    #[test]
    fn migration_counters() {
        let m = ServeMetrics::new();
        assert_eq!(m.migration_totals(), MigrationTotals::default());
        assert_eq!(m.demotion_totals(), DemotionTotals::default());
        m.record_migrations(3, 1, 1, 1, 0);
        m.record_migrations(0, 2, 0, 0, 1);
        assert_eq!(
            m.migration_totals(),
            MigrationTotals {
                launched: 3,
                landed: 3,
                budget_deferrals: 1,
            }
        );
        assert_eq!(m.demotion_totals(), DemotionTotals { issued: 1, polled: 1 });
    }

    #[test]
    fn disk_counters() {
        let m = ServeMetrics::new();
        assert_eq!(m.disk_totals(), DiskTotals::default());
        m.record_disk(2, 0, 1, 0);
        m.record_disk(0, 2, 0, 1);
        assert_eq!(
            m.disk_totals(),
            DiskTotals {
                spills_issued: 2,
                spills_polled: 2,
                hops_issued: 1,
                hops_polled: 1,
            }
        );
    }

    #[test]
    fn router_totals_classify_each_placement_once() {
        let mut t = RouterTotals::default();
        t.record(false, false, 0); // fresh
        t.record(true, false, 0); // affinity hit
        t.record(false, true, 32); // steal, 32 prefix tokens go remote
        t.record(true, true, 16); // a steal is a steal even off a hit shard
        assert_eq!(t.submitted, 4);
        assert_eq!((t.affinity_hits, t.fresh, t.steals), (1, 1, 2));
        assert_eq!(t.remote_prefix_tokens, 48);
    }

    #[test]
    fn remote_prefix_counter_accumulates() {
        let m = ServeMetrics::new();
        assert_eq!(m.remote_parked_blocks(), 0);
        m.record_remote_prefix(2);
        m.record_remote_prefix(1);
        assert_eq!(m.remote_parked_blocks(), 3);
    }

    #[test]
    fn share_and_reclaim_counters_accumulate() {
        let m = ServeMetrics::new();
        assert_eq!(m.share_totals(), ShareTotals::default());
        assert_eq!(m.kv_reclaimed_bytes(), 0);
        m.record_share(4, 128);
        m.record_share(1, 32);
        let s = m.share_totals();
        assert_eq!((s.hits, s.blocks, s.tokens), (2, 5, 160));
        m.record_reclaimed(4096);
        m.record_reclaimed(1024);
        assert_eq!(m.kv_reclaimed_bytes(), 5120);
    }

    #[test]
    fn empty_slo_math_is_documented_zeros_not_nan() {
        // documented values: no samples → all-zero percentiles, zero
        // attainment counters, vacuous 1.0 fractions — no NaN, no panic
        let m = ServeMetrics::new();
        assert_eq!(m.ttft_stats(), LatencyPercentiles::default());
        assert_eq!(m.tpot_stats(), LatencyPercentiles::default());
        let a = m.slo_attainment();
        assert_eq!(a, SloAttainment::default());
        assert_eq!(a.ttft_frac(), 1.0);
        assert_eq!(a.tpot_frac(), 1.0);
        assert!(!a.ttft_frac().is_nan() && !a.tpot_frac().is_nan());
    }

    #[test]
    fn single_sample_percentiles_are_that_sample() {
        let m = ServeMetrics::new();
        m.set_slo(SloTargets { ttft_s: 0.5, tpot_s: 0.1 });
        m.record_ttft_tpot(0.25, Some(0.05));
        let t = m.ttft_stats();
        assert_eq!((t.mean, t.p50, t.p95, t.p99), (0.25, 0.25, 0.25, 0.25));
        let p = m.tpot_stats();
        assert_eq!((p.p50, p.p99), (0.05, 0.05));
        let a = m.slo_attainment();
        assert_eq!((a.requests, a.ttft_ok, a.tpot_ok), (1, 1, 1));
    }

    #[test]
    fn tied_samples_collapse_to_the_tie() {
        let m = ServeMetrics::new();
        for _ in 0..5 {
            m.record_ttft_tpot(0.2, Some(0.04));
        }
        let t = m.ttft_stats();
        assert_eq!((t.mean, t.p50, t.p95, t.p99), (0.2, 0.2, 0.2, 0.2));
        let p = m.tpot_stats();
        assert_eq!((p.mean, p.p95), (0.04, 0.04));
    }

    #[test]
    fn samples_merge_across_batches() {
        // retirement happens batch by batch; the summaries must aggregate
        // across those calls identically to one big batch
        let a = ServeMetrics::new();
        let b = ServeMetrics::new();
        let samples = [0.1, 0.4, 0.2, 0.3, 0.9, 0.05, 0.6, 0.7];
        for x in samples {
            a.record_ttft_tpot(x, Some(x / 10.0));
        }
        for chunk in samples.chunks(3) {
            for x in chunk {
                b.record_ttft_tpot(*x, Some(*x / 10.0));
            }
        }
        let (ta, tb) = (a.ttft_stats(), b.ttft_stats());
        assert!((ta.mean - tb.mean).abs() < 1e-12);
        assert_eq!((ta.p50, ta.p95, ta.p99), (tb.p50, tb.p95, tb.p99));
        let (pa, pb) = (a.tpot_stats(), b.tpot_stats());
        assert_eq!((pa.p50, pa.p99), (pb.p50, pb.p99));
    }

    #[test]
    fn slo_counters_score_against_the_targets() {
        let m = ServeMetrics::new();
        // recorded before arming: feeds percentiles, not attainment
        m.record_ttft_tpot(9.0, Some(9.0));
        m.set_slo(SloTargets { ttft_s: 0.5, tpot_s: 0.1 });
        m.record_ttft_tpot(0.4, Some(0.05)); // both met
        m.record_ttft_tpot(0.6, Some(0.05)); // ttft missed
        m.record_ttft_tpot(0.4, Some(0.2)); // tpot missed
        m.record_ttft_tpot(0.4, None); // single token: tpot vacuously met
        let a = m.slo_attainment();
        assert_eq!(a.requests, 4);
        assert_eq!(a.ttft_ok, 3);
        assert_eq!(a.tpot_ok, 3);
        assert!((a.ttft_frac() - 0.75).abs() < 1e-12);
        assert!((a.tpot_frac() - 0.75).abs() < 1e-12);
        assert!(!m.ttft_stats().p99.is_nan());
    }

    #[test]
    fn pipeline_counters_fold_per_step_reports() {
        let m = ServeMetrics::new();
        assert_eq!(m.pipeline_totals(), PipelineTotals::default());
        m.record_pipeline(true, 2, 0, 0.001, 0.004);
        m.record_pipeline(false, 1, 1, 0.002, 0.003);
        let p = m.pipeline_totals();
        assert_eq!(p.steps, 2);
        assert_eq!(p.prestaged_steps, 1);
        assert_eq!(p.plans_adopted, 3);
        assert_eq!(p.fallback_resolves, 1);
        assert!((p.stall_s - 0.003).abs() < 1e-12);
        assert!((p.overlap_s - 0.007).abs() < 1e-12);
    }

    #[test]
    fn step_budget_counters_track_the_grant_rule() {
        let m = ServeMetrics::new();
        assert_eq!(m.budget_totals(), StepBudgetTotals::default());
        // adaptive steps: grant == max(slack, 1)
        m.record_step_budget(4096, 4096, 3);
        m.record_step_budget(0, 1, 1); // zero slack: progress-only grant
        m.record_step_budget(0, 1, 0);
        let b = m.budget_totals();
        assert_eq!(b.steps, 3);
        assert_eq!(b.slack_bytes, 4096);
        assert_eq!(b.granted_bytes, 4098);
        assert_eq!(b.mismatch_steps, 0, "adaptive grants track the slack");
        assert_eq!(b.zero_slack_steps, 2);
        assert_eq!(b.zero_slack_launch_max, 1);
        // a static override detaches the grant from the slack
        m.record_step_budget(4096, 1 << 20, 5);
        assert_eq!(m.budget_totals().mismatch_steps, 1);
        // saturating, never wrapping, under unthrottled-wire slack
        m.record_step_budget(u64::MAX, u64::MAX, 0);
        assert_eq!(m.budget_totals().slack_bytes, u64::MAX);
        assert_eq!(m.budget_totals().granted_bytes, u64::MAX);
    }
}
