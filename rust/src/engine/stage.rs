//! Staging helpers: seq-major host rows → padded `[batch, seq, hidden]`
//! artifact inputs, plus the per-component time breakdown (Fig 10).

/// Scatter `n_rows` seq-major rows (layout `[seq][batch*hidden]`) into a
/// zero-padded `[batch, rows_per_batch, hidden]` buffer.  The single-
/// segment special case of [`stage_padded2`].
pub fn stage_padded(
    rows_data: &[f32],
    n_rows: usize,
    batch: usize,
    hidden: usize,
    rows_per_batch: usize,
    out: &mut Vec<f32>,
) {
    assert_eq!(rows_data.len(), n_rows * batch * hidden);
    stage_padded2(rows_data, &[], batch, hidden, rows_per_batch, out);
}

/// [`stage_padded`] over two contiguous seq-major row segments — the
/// link-transferred remainder followed by the device-resident suffix the
/// tiered kvstore kept on the GPU — without concatenating them first.
/// Either segment may be empty; both must be whole rows.
pub fn stage_padded2(
    seg_a: &[f32],
    seg_b: &[f32],
    batch: usize,
    hidden: usize,
    rows_per_batch: usize,
    out: &mut Vec<f32>,
) {
    let row = batch * hidden;
    assert_eq!(seg_a.len() % row, 0, "segment A is not whole rows");
    assert_eq!(seg_b.len() % row, 0, "segment B is not whole rows");
    let rows_a = seg_a.len() / row;
    let n_rows = rows_a + seg_b.len() / row;
    assert!(n_rows <= rows_per_batch, "{n_rows} > {rows_per_batch}");
    out.clear();
    out.resize(batch * rows_per_batch * hidden, 0.0);
    for b in 0..batch {
        for s in 0..n_rows {
            let (buf, r) = if s < rows_a { (seg_a, s) } else { (seg_b, s - rows_a) };
            let src = r * row + b * hidden;
            let dst = (b * rows_per_batch + s) * hidden;
            out[dst..dst + hidden].copy_from_slice(&buf[src..src + hidden]);
        }
    }
}

/// Where a decode step's wall-clock went — the engine-level analogue of the
/// paper's Fig 10 runtime breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Breakdown {
    /// Blocked on weight transfer.
    pub wait_weights_s: f64,
    /// Blocked on the activation prefix.
    pub wait_act_s: f64,
    /// Blocked on KV-cache transfer.
    pub wait_kv_s: f64,
    /// Running the recompute artifact.
    pub recompute_s: f64,
    /// Running attention + FFN (merge/full artifacts).
    pub attn_ffn_s: f64,
    /// Everything else (embed, lm_head, staging, stores).
    pub other_s: f64,
    /// Host-side staging/plan time the pipelined step runtime hid under
    /// another group's compute (see [`crate::engine::pipeline`]).  Shadow
    /// time, not additional wall time: it is **excluded** from
    /// [`Breakdown::total`] precisely because the same seconds are already
    /// counted under whichever compute covered them.
    pub overlap_s: f64,
    /// Pipeline stall: wall time the step spent blocked on a stage handoff
    /// that was not ready (serial mode never stalls — the stages run
    /// back-to-back on one thread).
    pub stall_s: f64,
}

impl Breakdown {
    /// Wall-clock accounted to this step (the shadowed `overlap_s` is
    /// excluded — those seconds already ran under someone else's compute).
    pub fn total(&self) -> f64 {
        self.wait_weights_s
            + self.wait_act_s
            + self.wait_kv_s
            + self.recompute_s
            + self.attn_ffn_s
            + self.other_s
            + self.stall_s
    }

    pub fn add(&mut self, other: &Breakdown) {
        self.wait_weights_s += other.wait_weights_s;
        self.wait_act_s += other.wait_act_s;
        self.wait_kv_s += other.wait_kv_s;
        self.recompute_s += other.recompute_s;
        self.attn_ffn_s += other.attn_ffn_s;
        self.other_s += other.other_s;
        self.overlap_s += other.overlap_s;
        self.stall_s += other.stall_s;
    }

    /// Fraction of the step the "GPU" (compute thread) was doing useful
    /// work rather than waiting on the link — Fig 8's utilization line.
    pub fn compute_utilization(&self) -> f64 {
        let busy = self.recompute_s + self.attn_ffn_s + self.other_s;
        let total = self.total();
        if total == 0.0 {
            0.0
        } else {
            busy / total
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_transposes_and_pads() {
        // 2 rows, batch 2, hidden 2 → pad to 3 rows/batch
        // seq-major rows: row0 = [b0: 1,2 | b1: 3,4], row1 = [b0: 5,6 | b1: 7,8]
        let rows = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let mut out = Vec::new();
        stage_padded(&rows, 2, 2, 2, 3, &mut out);
        assert_eq!(
            out,
            vec![
                1.0, 2.0, 5.0, 6.0, 0.0, 0.0, // batch 0: row0, row1, pad
                3.0, 4.0, 7.0, 8.0, 0.0, 0.0, // batch 1
            ]
        );
    }

    #[test]
    fn stage_zero_rows_is_all_padding() {
        let mut out = vec![9.0; 4];
        stage_padded(&[], 0, 1, 2, 2, &mut out);
        assert_eq!(out, vec![0.0; 4]);
    }

    #[test]
    fn stage_reuses_buffer_capacity() {
        let rows = vec![1.0; 8];
        let mut out = Vec::with_capacity(64);
        let cap = out.capacity();
        stage_padded(&rows, 2, 2, 2, 4, &mut out);
        assert_eq!(out.len(), 16);
        assert_eq!(out.capacity(), cap, "no reallocation");
    }

    #[test]
    fn stage2_matches_concatenated_single_stage() {
        // 3 rows split 2+1 must stage exactly like the 3 rows in one piece
        let rows: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 3 rows, b=2, h=2
        let mut want = Vec::new();
        stage_padded(&rows, 3, 2, 2, 4, &mut want);
        let mut got = Vec::new();
        stage_padded2(&rows[0..8], &rows[8..12], 2, 2, 4, &mut got);
        assert_eq!(got, want);
    }

    #[test]
    fn stage2_empty_segments() {
        let rows: Vec<f32> = (0..8).map(|i| i as f32).collect(); // 2 rows, b=2, h=2
        let mut want = Vec::new();
        stage_padded(&rows, 2, 2, 2, 3, &mut want);
        let mut got = Vec::new();
        stage_padded2(&rows, &[], 2, 2, 3, &mut got);
        assert_eq!(got, want, "empty resident suffix");
        stage_padded2(&[], &rows, 2, 2, 3, &mut got);
        assert_eq!(got, want, "everything resident");
        stage_padded2(&[], &[], 2, 2, 3, &mut got);
        assert_eq!(got, vec![0.0; 12], "all padding");
    }

    #[test]
    fn breakdown_utilization() {
        let b = Breakdown {
            wait_weights_s: 0.0,
            wait_act_s: 0.1,
            wait_kv_s: 0.3,
            recompute_s: 0.2,
            attn_ffn_s: 0.3,
            other_s: 0.1,
            overlap_s: 0.0,
            stall_s: 0.0,
        };
        assert!((b.total() - 1.0).abs() < 1e-12);
        assert!((b.compute_utilization() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn breakdown_overlap_is_shadow_time_stall_is_wall_time() {
        // overlap_s is time hidden under another group's compute: it must
        // not inflate total(); stall_s is real blocked wall time: it must.
        let mut b = Breakdown { attn_ffn_s: 0.8, other_s: 0.2, ..Breakdown::default() };
        assert!((b.total() - 1.0).abs() < 1e-12);
        b.overlap_s = 0.5;
        assert!((b.total() - 1.0).abs() < 1e-12, "overlap is already covered");
        b.stall_s = 0.25;
        assert!((b.total() - 1.25).abs() < 1e-12, "stalls extend the wall");
        // utilization degrades with stalls, is untouched by overlap
        assert!((b.compute_utilization() - 0.8).abs() < 1e-12);
        let mut sum = Breakdown::default();
        sum.add(&b);
        sum.add(&b);
        assert!((sum.overlap_s - 1.0).abs() < 1e-12);
        assert!((sum.stall_s - 0.5).abs() < 1e-12);
    }
}
