//! The decode engine: row-by-row and column-by-column generation with
//! overlapped transfer/compute per Algorithm 1.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::pipeline::{StageSlots, StagedInput, StepHandoff};
use super::stage::{stage_padded, stage_padded2, Breakdown};
use crate::kvcache::HostKvCache;
use crate::memory::{MemPool, PoolGuard};
use crate::model::{ModelWeights, RefModel};
use crate::profiler::SystemProfile;
use crate::runtime::{ArgValue, Runtime};
use crate::scheduler::{CostModel, Planner, SchedulePolicy};
use crate::transfer::{Link, LinkConfig, PinnedPool, Priority, TransferHandle};

/// Which schedule structure the engine executes (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EnginePolicy {
    /// Synchronous full KV transfer, no prefetch (HF-Accelerate-like).
    FullTransferSync,
    /// Full KV transfer + next-layer prefetch (FlexGen-like).
    FullTransferOverlap,
    /// KVPR: split schedule, recompute ∥ remainder transfer + prefetch.
    Kvpr,
    /// KVPR via the fused artifact: same transfer volume, but recompute
    /// cannot start before the remainder lands (intra-layer ablation).
    KvprFused,
    /// Recompute first, *then* transfer the remainder (ALISA-style, no
    /// overlap between the two).
    AlisaSequential,
}

impl EnginePolicy {
    pub fn is_partial(&self) -> bool {
        matches!(self, Self::Kvpr | Self::KvprFused | Self::AlisaSequential)
    }

    pub fn prefetches(&self) -> bool {
        !matches!(self, Self::FullTransferSync)
    }
}

/// Engine construction parameters.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub policy: EnginePolicy,
    /// Model weights offloaded to host (throughput regime): weight traffic
    /// is charged per layer per step.
    pub weights_offloaded: bool,
    /// Fine-grained MHA pipeline: W_K/W_V transferred at high priority so
    /// recompute starts early (paper Fig 5b).  Only meaningful when
    /// `weights_offloaded`.
    pub fine_grained_weights: bool,
    /// H2D link shaping.
    pub link: LinkConfig,
    /// Paper's `l ≤ s` cap (prompt-only activations); `usize::MAX` = free.
    pub l_cap: usize,
    /// Emulated device memory capacity.
    pub gpu_mem_bytes: u64,
    /// Weight-generation seed (identical seeds → identical tokens).
    pub seed: u64,
}

impl EngineConfig {
    pub fn new(policy: EnginePolicy) -> Self {
        EngineConfig {
            policy,
            weights_offloaded: false,
            fine_grained_weights: false,
            link: LinkConfig::with_bandwidth(30e6),
            l_cap: usize::MAX,
            gpu_mem_bytes: 2 << 30,
            seed: 42,
        }
    }
}

/// Result of one generation call.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// Generated token ids per sequence (greedy).
    pub tokens: Vec<Vec<i32>>,
    pub metrics: GenMetrics,
}

/// Timing + accounting for one generation.
#[derive(Debug, Clone, Default)]
pub struct GenMetrics {
    pub prefill_s: f64,
    pub decode_s: f64,
    pub tokens_generated: u64,
    /// Split point chosen at layer 0 of each decode step (Fig 12 trace).
    pub splits: Vec<usize>,
    pub breakdown: Breakdown,
    pub gpu_peak_bytes: u64,
    pub h2d_bytes: u64,
    pub h2d_busy_s: f64,
}

impl GenMetrics {
    /// Decode throughput in generated tokens per second.
    pub fn decode_tok_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.tokens_generated as f64 / self.decode_s
        } else {
            0.0
        }
    }
}

/// A running batch mid-decode: prefill is done, one token per lane is
/// sampled per [`Engine::decode_step`] call.
///
/// This is the unit the continuous-batching coordinator schedules: sessions
/// are admitted (prefilled) and retired per decode step, and each step's
/// split point can be re-planned from outside via
/// [`Engine::decode_step_with_plan`].  Sessions are engine-affine — step a
/// session only on the engine (and thread) that created it.
pub struct DecodeSession {
    cache: HostKvCache,
    /// Last sampled token per lane, the next step's input.
    last: Vec<i32>,
    /// Sampled tokens per lane (first entry comes from prefill).
    tokens: Vec<Vec<i32>>,
    /// Batch bucket (lanes incl. padding replicas).
    b: usize,
    /// Real sequences (≤ `b`).
    n_seqs: usize,
    planner: Option<Planner>,
    metrics: GenMetrics,
    store_handles: Vec<TransferHandle>,
    /// Device-resident KV suffix (tiered kvstore gpu tier); off by default.
    resident: Option<GpuResident>,
    /// Mandatory recompute floor: rows `[0, kv_floor)` had their K/V host
    /// storage physically reclaimed ([`Engine::truncate_dropped_kv`]), so
    /// every later step must plan a split covering them.
    kv_floor: usize,
}

/// Device-resident KV suffix of a session — the engine-side landing of the
/// kvstore's gpu-hbm tier.  The newest `len` tokens of every layer's K/V
/// stay on the emulated device between steps (rows `[kv_len − len, kv_len)`,
/// seq-major), so each step's H2D submission covers only
/// `[l, kv_len − len)`.  The window grows one token per step for free (the
/// appended K/V is computed on the GPU), slides under gpu-pool pressure,
/// and is aligned to the store's placement by
/// [`Engine::set_resident_target`].  Capacity is charged to the engine's
/// gpu pool one `block_tokens` block at a time.
struct GpuResident {
    /// Resident tokens (suffix of every layer).
    len: usize,
    /// Token granularity of pool charges.
    block_tokens: usize,
    /// Per-layer seq-major K rows, `len * batch * hidden` elements each.
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    /// One gpu-pool charge per resident block (all layers, K+V).
    guards: Vec<PoolGuard>,
}

impl GpuResident {
    /// Bytes one residency block charges: K+V rows across every layer.
    fn block_bytes(n_layers: usize, block_tokens: usize, row: usize) -> u64 {
        (n_layers * 2 * block_tokens * row * 4) as u64
    }

    /// Drop the oldest `tokens` resident rows (the suffix start moves up)
    /// and release the charges they no longer need.  No writeback: the
    /// host cache always holds the canonical copy.
    fn drop_head(&mut self, tokens: usize, row: usize) {
        let t = tokens.min(self.len);
        for k in self.k.iter_mut() {
            k.drain(..t * row);
        }
        for v in self.v.iter_mut() {
            v.drain(..t * row);
        }
        self.len -= t;
        self.guards.truncate(self.len.div_ceil(self.block_tokens));
    }

    fn clear(&mut self) {
        self.len = 0;
        for k in self.k.iter_mut() {
            k.clear();
        }
        for v in self.v.iter_mut() {
            v.clear();
        }
        self.guards.clear();
    }
}

impl DecodeSession {
    /// Batch bucket the session decodes at (including padding lanes).
    pub fn batch_bucket(&self) -> usize {
        self.b
    }

    /// Number of real sequences in the session.
    pub fn n_seqs(&self) -> usize {
        self.n_seqs
    }

    /// Valid cached tokens (the paper's s'): prompt bucket + steps taken.
    pub fn kv_len(&self) -> usize {
        self.cache.seq_len()
    }

    /// Row capacity of the session's KV cache.
    pub fn seq_cap(&self) -> usize {
        self.cache.layer(0).capacity()
    }

    /// Tokens sampled so far per lane (identical count across lanes).
    pub fn tokens_per_lane(&self) -> usize {
        self.tokens.first().map_or(0, |t| t.len())
    }

    /// The sampled tokens of one lane.
    pub fn lane_tokens(&self, lane: usize) -> &[i32] {
        &self.tokens[lane]
    }

    /// Host bytes this session's cache reserves (full capacity).
    pub fn kv_capacity_bytes(&self) -> u64 {
        self.cache.capacity_bytes()
    }

    /// Tokens of the device-resident KV suffix (0 when residency is off).
    pub fn resident_tokens(&self) -> usize {
        self.resident.as_ref().map_or(0, |g| g.len)
    }

    /// Tokens of the mandatory recompute floor — the physically truncated
    /// dropped-KV prefix ([`Engine::truncate_dropped_kv`]).
    pub fn kv_floor(&self) -> usize {
        self.kv_floor
    }

    /// Host bytes currently held by the session's cache (valid rows only;
    /// a truncated dropped prefix has already left the K/V side).
    pub fn host_bytes(&self) -> u64 {
        self.cache.host_bytes()
    }

    /// Whether the device-resident suffix is enabled (it may be enabled
    /// yet momentarily empty under pool pressure) — the pipelined serve
    /// loop uses this to project next step's residency for plan prestage.
    pub fn residency_enabled(&self) -> bool {
        self.resident.is_some()
    }

    /// Timing and split-point accounting accumulated so far.
    pub fn metrics(&self) -> &GenMetrics {
        &self.metrics
    }

    /// Fold pipeline accounting into the session's breakdown: `overlap_s`
    /// host work hidden under compute, `stall_s` wall time blocked on a
    /// stage handoff (the serving loop's worker recv).
    pub(crate) fn note_pipeline(&mut self, overlap_s: f64, stall_s: f64) {
        self.metrics.breakdown.overlap_s += overlap_s;
        self.metrics.breakdown.stall_s += stall_s;
    }
}

/// Per-layer in-flight transfers (issued ahead of compute).
pub(super) struct LayerTransfers {
    plan_l: usize,
    act: Option<TransferHandle>,
    k: Option<TransferHandle>,
    v: Option<TransferHandle>,
    w_kv: Option<TransferHandle>,
    w_rest: Option<TransferHandle>,
}

/// The decode engine.  Owns the PJRT runtime (single-threaded) plus the
/// emulated H2D/D2H links (their worker threads provide the overlap).
pub struct Engine {
    runtime: Runtime,
    h2d: Link,
    d2h: Link,
    pub weights: ModelWeights,
    profile: SystemProfile,
    gpu_pool: MemPool,
    staging: PinnedPool,
    cfg: EngineConfig,
}

impl Engine {
    /// Load artifacts, generate weights, calibrate the profiler.  When
    /// `artifact_dir` has no `manifest.json` the engine falls back to the
    /// interpreter runtime over a synthetic manifest ([`Runtime::synthetic`])
    /// so the full serving stack works without `make artifacts`.
    pub fn new(artifact_dir: &Path, cfg: EngineConfig) -> Result<Self> {
        let runtime = Runtime::load_or_synthetic(artifact_dir)?;
        let model = runtime.manifest().model.clone();
        let weights = ModelWeights::generate(&model, cfg.seed);
        let h2d = Link::new(cfg.link.clone());
        let d2h = Link::new(cfg.link.clone());
        // profile at the largest batch bucket (most representative) on the
        // compiled backend; the interpreter's marginal costs are exactly
        // linear in batch, so the cheapest bucket profiles just as well and
        // keeps startup fast (the planner rescales linearly either way)
        let b = if runtime.is_compiled() {
            *runtime
                .manifest()
                .batch_buckets
                .iter()
                .max()
                .context("no batch buckets")?
        } else {
            *runtime
                .manifest()
                .batch_buckets
                .iter()
                .min()
                .context("no batch buckets")?
        };
        let profile = SystemProfile::measure(&h2d, &runtime, b)?;
        let gpu_pool = MemPool::new("gpu-hbm", cfg.gpu_mem_bytes);
        Ok(Engine {
            runtime,
            h2d,
            d2h,
            weights,
            profile,
            gpu_pool,
            staging: PinnedPool::new(),
            cfg,
        })
    }

    pub fn profile(&self) -> &SystemProfile {
        &self.profile
    }

    pub fn runtime(&self) -> &Runtime {
        &self.runtime
    }

    pub fn gpu_pool(&self) -> &MemPool {
        &self.gpu_pool
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// A reference model sharing this engine's weights (tests/debug).
    pub fn ref_model(&self) -> RefModel {
        RefModel::new(self.weights.clone())
    }

    /// Build an adaptive [`Planner`] for batch bucket `batch`: the measured
    /// cost model is rescaled from the profiled bucket (marginal costs are
    /// linear in batch, see `CostModel` tests) and constrained to the
    /// artifact L buckets.  The planner is rooted on the profile's
    /// measured device⊃host topology
    /// ([`SystemProfile::topology`](crate::profiler::SystemProfile::topology)),
    /// so its [`StepPlan`](crate::scheduler::StepPlan)s predict link slack
    /// out of the box; the tiered serving loop swaps in its deeper
    /// calibrated chain via
    /// [`Planner::with_topology`](crate::scheduler::Planner::with_topology).
    /// The coordinator uses this to re-solve Eq. (11) per formed batch;
    /// [`Engine::decode_step`] uses it internally when no externally
    /// planned split is supplied.
    pub fn planner(&self, batch: usize, policy: SchedulePolicy) -> Planner {
        let mut cost: CostModel = self.profile.cost_model(&self.runtime.manifest().model);
        // profile was taken at profile.batch; rescale marginals linearly
        let scale = batch as f64 / self.profile.batch as f64;
        cost.recompute_per_token_s *= scale;
        cost.transfer_kv_per_token_s *= scale;
        cost.transfer_act_per_token_s *= scale;
        Planner::new(
            cost,
            policy,
            self.runtime.manifest().l_buckets.clone(),
            self.cfg.l_cap,
        )
        .with_topology(self.profile.topology(self.cfg.gpu_mem_bytes))
    }

    fn layer_weight_args<'a>(&'a self, layer: usize) -> Vec<ArgValue<'a>> {
        self.weights
            .layer(layer)
            .iter()
            .map(|(_, data, _)| ArgValue::F32(data.as_slice()))
            .collect()
    }

    // ---------------------------------------------------------------------
    // prefill
    // ---------------------------------------------------------------------

    /// Run whole-model prefill; returns (first tokens, per-layer host cache).
    fn prefill(
        &self,
        ids: &[i32],
        b: usize,
        sp: usize,
        cache: &mut HostKvCache,
    ) -> Result<Vec<i32>> {
        let m = self.runtime.manifest();
        let model = m.model.clone();
        let art = self.runtime.artifact(&m.prefill_name(b, sp))?;
        let mut args: Vec<ArgValue> = vec![
            ArgValue::I32Slice(ids),
            ArgValue::F32(&self.weights.tok_table),
            ArgValue::F32(&self.weights.pos_table),
            ArgValue::F32(&self.weights.lnf_g),
            ArgValue::F32(&self.weights.lnf_b),
        ];
        for i in 0..model.n_layers {
            args.extend(self.layer_weight_args(i));
        }
        let out = art.call(&args)?;
        let (logits, k_stack, v_stack, x_stack) = (&out[0], &out[1], &out[2], &out[3]);
        let per_layer = b * sp * model.hidden;
        for i in 0..model.n_layers {
            let lo = i * per_layer;
            cache.layer_mut(i).load_prefill(
                &k_stack[lo..lo + per_layer],
                &v_stack[lo..lo + per_layer],
                &x_stack[lo..lo + per_layer],
                sp,
            )?;
        }
        Ok(RefModel::argmax(logits, model.vocab))
    }

    // ---------------------------------------------------------------------
    // transfer issue / wait
    // ---------------------------------------------------------------------

    /// Issue all of layer `i`'s transfers for this step (Algorithm 1's
    /// load_* calls).  `l` is the planned split (0 = full path);
    /// `resident` is the device-resident suffix length — those rows never
    /// cross the link, so only `KV[l, kv_len − resident)` is submitted.
    /// The caller guarantees `l + resident ≤ kv_len`.
    fn issue_layer(
        &self,
        cache: &HostKvCache,
        layer: usize,
        l: usize,
        resident: usize,
    ) -> LayerTransfers {
        let st = cache.layer(layer);
        let kv_len = st.len() - resident;
        let mut t = LayerTransfers { plan_l: l, act: None, k: None, v: None, w_kv: None, w_rest: None };

        if self.cfg.weights_offloaded {
            let lw = self.weights.layer(layer);
            let total = (lw.bytes() / 4) as usize;
            let kvp = (lw.kv_proj_bytes() / 4) as usize;
            if self.cfg.fine_grained_weights {
                t.w_kv = Some(self.h2d.submit_timing(kvp, Priority::High));
                t.w_rest = Some(self.h2d.submit_timing(total - kvp, Priority::Normal));
            } else {
                t.w_rest = Some(self.h2d.submit_timing(total, Priority::Normal));
            }
        }

        if l > 0 {
            // activations first, at high priority (the recompute feedstock);
            // K/V views go through kv_rows — a truncated dropped prefix has
            // physically left the k/v arcs, X keeps every row
            t.act = Some(self.h2d.submit(st.x_arc(), st.rows(0, l), Priority::High));
            t.k = Some(self.h2d.submit(st.k_arc(), st.kv_rows(l, kv_len), Priority::Normal));
            t.v = Some(self.h2d.submit(st.v_arc(), st.kv_rows(l, kv_len), Priority::Normal));
        } else {
            t.k = Some(self.h2d.submit(st.k_arc(), st.kv_rows(0, kv_len), Priority::Normal));
            t.v = Some(self.h2d.submit(st.v_arc(), st.kv_rows(0, kv_len), Priority::Normal));
        }
        t
    }

    // ---------------------------------------------------------------------
    // one decode step of one layer
    // ---------------------------------------------------------------------

    /// Consume `t`, run the layer, return (y, k_new, v_new).  `res_k` /
    /// `res_v` are the device-resident suffix rows (empty when residency
    /// is off): they join the staged K/V after the transferred remainder,
    /// reproducing the exact layout a full transfer would have staged.
    #[allow(clippy::too_many_arguments)]
    fn run_layer(
        &self,
        layer: usize,
        b: usize,
        x: &[f32],
        kv_len: usize,
        t: LayerTransfers,
        res_k: &[f32],
        res_v: &[f32],
        bd: &mut Breakdown,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = self.runtime.manifest();
        let h = m.model.hidden;
        let cap = m.seq_cap;
        let l = t.plan_l;
        let _guard = self
            .gpu_pool
            .alloc((2 * cap * b * h * 4) as u64)
            .context("device pool for staged KV")?;

        let out = if l == 0 {
            // ---- full-transfer path ----
            if let Some(w) = t.w_kv {
                let t0 = Instant::now();
                w.wait();
                bd.wait_weights_s += t0.elapsed().as_secs_f64();
            }
            if let Some(w) = t.w_rest {
                let t0 = Instant::now();
                w.wait();
                bd.wait_weights_s += t0.elapsed().as_secs_f64();
            }
            let t0 = Instant::now();
            let k_rows = t.k.unwrap().wait();
            let v_rows = t.v.unwrap().wait();
            bd.wait_kv_s += t0.elapsed().as_secs_f64();

            let t0 = Instant::now();
            let mut k_buf = self.staging.get(b * cap * h);
            let mut v_buf = self.staging.get(b * cap * h);
            stage_padded2(&k_rows, res_k, b, h, cap, &mut k_buf);
            stage_padded2(&v_rows, res_v, b, h, cap, &mut v_buf);
            bd.other_s += t0.elapsed().as_secs_f64();

            let art = self.runtime.artifact(&m.decode_full_name(b))?;
            let mut args: Vec<ArgValue> = vec![
                ArgValue::F32(x),
                ArgValue::F32(&k_buf),
                ArgValue::F32(&v_buf),
                ArgValue::I32(kv_len as i32),
            ];
            args.extend(self.layer_weight_args(layer));
            let t0 = Instant::now();
            let out = art.call(&args)?;
            bd.attn_ffn_s += t0.elapsed().as_secs_f64();
            self.staging.put(k_buf);
            self.staging.put(v_buf);
            out
        } else {
            // ---- partial-recompute paths ----
            let w = self.weights.layer(layer);

            let fused = matches!(self.cfg.policy, EnginePolicy::KvprFused);
            if fused {
                // wait everything, call the fused artifact
                if let Some(wh) = t.w_kv {
                    let t0 = Instant::now();
                    wh.wait();
                    bd.wait_weights_s += t0.elapsed().as_secs_f64();
                }
                if let Some(wh) = t.w_rest {
                    let t0 = Instant::now();
                    wh.wait();
                    bd.wait_weights_s += t0.elapsed().as_secs_f64();
                }
                let t0 = Instant::now();
                let act_rows = t.act.unwrap().wait();
                bd.wait_act_s += t0.elapsed().as_secs_f64();
                let t0 = Instant::now();
                let k_rows = t.k.unwrap().wait();
                let v_rows = t.v.unwrap().wait();
                bd.wait_kv_s += t0.elapsed().as_secs_f64();

                let t0 = Instant::now();
                let mut x_buf = self.staging.get(b * l * h);
                let mut k_buf = self.staging.get(b * (cap - l) * h);
                let mut v_buf = self.staging.get(b * (cap - l) * h);
                stage_padded(&act_rows, l, b, h, l, &mut x_buf);
                stage_padded2(&k_rows, res_k, b, h, cap - l, &mut k_buf);
                stage_padded2(&v_rows, res_v, b, h, cap - l, &mut v_buf);
                bd.other_s += t0.elapsed().as_secs_f64();

                let art = self.runtime.artifact(&m.decode_partial_name(b, l))?;
                let mut args: Vec<ArgValue> = vec![
                    ArgValue::F32(x),
                    ArgValue::F32(&x_buf),
                    ArgValue::F32(&k_buf),
                    ArgValue::F32(&v_buf),
                    ArgValue::I32(kv_len as i32),
                ];
                args.extend(self.layer_weight_args(layer));
                let t0 = Instant::now();
                let out = art.call(&args)?;
                bd.attn_ffn_s += t0.elapsed().as_secs_f64();
                self.staging.put(x_buf);
                self.staging.put(k_buf);
                self.staging.put(v_buf);
                out
            } else {
                // split schedule: recompute overlaps the remainder transfer
                if let Some(wh) = t.w_kv {
                    // fine-grained: only W_K/W_V gate the recompute
                    let t0 = Instant::now();
                    wh.wait();
                    bd.wait_weights_s += t0.elapsed().as_secs_f64();
                }
                let t0 = Instant::now();
                let act_rows = t.act.unwrap().wait();
                bd.wait_act_s += t0.elapsed().as_secs_f64();

                let t0 = Instant::now();
                let mut x_buf = self.staging.get(b * l * h);
                stage_padded(&act_rows, l, b, h, l, &mut x_buf);
                bd.other_s += t0.elapsed().as_secs_f64();

                let recompute = self.runtime.artifact(&m.recompute_name(b, l))?;
                let t0 = Instant::now();
                let re = recompute.call(&[
                    ArgValue::F32(&x_buf),
                    ArgValue::F32(w.get("ln1_g")),
                    ArgValue::F32(w.get("ln1_b")),
                    ArgValue::F32(w.get("wk")),
                    ArgValue::F32(w.get("bk")),
                    ArgValue::F32(w.get("wv")),
                    ArgValue::F32(w.get("bv")),
                ])?;
                bd.recompute_s += t0.elapsed().as_secs_f64();
                self.staging.put(x_buf);

                // now join the remainder stream (ALISA issues it only here;
                // for Kvpr it has been streaming since issue_layer)
                if let Some(wh) = t.w_rest {
                    let t0 = Instant::now();
                    wh.wait();
                    bd.wait_weights_s += t0.elapsed().as_secs_f64();
                }
                let t0 = Instant::now();
                let k_rows = t.k.unwrap().wait();
                let v_rows = t.v.unwrap().wait();
                bd.wait_kv_s += t0.elapsed().as_secs_f64();

                let t0 = Instant::now();
                let mut k_buf = self.staging.get(b * (cap - l) * h);
                let mut v_buf = self.staging.get(b * (cap - l) * h);
                stage_padded2(&k_rows, res_k, b, h, cap - l, &mut k_buf);
                stage_padded2(&v_rows, res_v, b, h, cap - l, &mut v_buf);
                bd.other_s += t0.elapsed().as_secs_f64();

                let merge = self.runtime.artifact(&m.decode_merge_name(b, l))?;
                let mut args: Vec<ArgValue> = vec![
                    ArgValue::F32(x),
                    ArgValue::F32(&re[0]),
                    ArgValue::F32(&re[1]),
                    ArgValue::F32(&k_buf),
                    ArgValue::F32(&v_buf),
                    ArgValue::I32(kv_len as i32),
                ];
                args.extend(self.layer_weight_args(layer));
                let t0 = Instant::now();
                let out = merge.call(&args)?;
                bd.attn_ffn_s += t0.elapsed().as_secs_f64();
                self.staging.put(k_buf);
                self.staging.put(v_buf);
                out
            }
        };
        Ok((out[0].clone(), out[1].clone(), out[2].clone()))
    }

    // ---------------------------------------------------------------------
    // step-wise decode API (continuous batching) and row-by-row generation
    // ---------------------------------------------------------------------

    /// Host KV+X bytes a new session for `n_seqs` sequences will reserve
    /// (full capacity, the admission-control number), without building it.
    pub fn session_kv_bytes(&self, n_seqs: usize) -> Result<u64> {
        let m = self.runtime.manifest();
        let b = m
            .batch_bucket_for(n_seqs)
            .with_context(|| format!("no batch bucket for {n_seqs} sequences"))?;
        let model = &m.model;
        Ok(HostKvCache::capacity_bytes_for(
            model.n_layers,
            b,
            model.hidden,
            m.seq_cap,
        ))
    }

    /// Headroom residency charges must always leave free in the gpu pool:
    /// one layer's transient staged-KV allocation at the largest batch
    /// bucket, doubled for the next-layer prefetch — `run_layer` fails
    /// hard without it, so the resident window must never squeeze it out.
    fn residency_headroom(&self) -> u64 {
        let m = self.runtime.manifest();
        let b = m.batch_buckets.iter().max().copied().unwrap_or(1);
        (2 * 2 * m.seq_cap * b * m.model.hidden * 4) as u64
    }

    /// Charge one residency block, refusing when it would eat into the
    /// staging headroom (a refused charge shrinks or stops the window —
    /// always safe — while a squeezed-out staging alloc is a decode error).
    fn try_charge_resident_block(&self, block_bytes: u64) -> Option<PoolGuard> {
        if self.gpu_pool.available() < block_bytes + self.residency_headroom() {
            return None;
        }
        self.gpu_pool.alloc(block_bytes).ok()
    }

    /// Turn on the device-resident KV suffix for a session (the engine
    /// side of the kvstore's gpu tier).  Newly generated tokens then stay
    /// on the emulated device — the window grows one token per step for
    /// free and slides under gpu-pool pressure — and
    /// [`Engine::set_resident_target`] aligns it with the store's
    /// placement decisions.  All policies produce identical tokens with or
    /// without residency: it moves bytes, never math.
    pub fn enable_residency(&self, sess: &mut DecodeSession, block_tokens: usize) {
        assert!(block_tokens > 0, "block_tokens must be positive");
        if sess.resident.is_none() {
            let n_layers = self.runtime.manifest().model.n_layers;
            sess.resident = Some(GpuResident {
                len: 0,
                block_tokens,
                k: vec![Vec::new(); n_layers],
                v: vec![Vec::new(); n_layers],
                guards: Vec::new(),
            });
        }
    }

    /// Align a session's device-resident KV suffix to `target_tokens` (the
    /// kvstore's gpu-tier decision): promote by copying host rows up, or
    /// demote by dropping the oldest resident rows (no writeback — the
    /// host cache holds the canonical copy).  Promotion does not ride the
    /// engine's H2D link: the store already paid for the migration on its
    /// own link, this is the data landing.  Promotion stops early if the
    /// gpu pool cannot charge the blocks.  Returns (promoted, demoted)
    /// token counts; (0, 0) when residency is off.
    pub fn set_resident_target(
        &self,
        sess: &mut DecodeSession,
        target_tokens: usize,
    ) -> (usize, usize) {
        let m = self.runtime.manifest();
        let kv_len = sess.cache.seq_len();
        let row = sess.b * m.model.hidden;
        // the window can never extend into a physically truncated prefix —
        // those K/V rows no longer exist on the host to promote from
        let kv_avail = kv_len - sess.cache.kv_trunc();
        let cache = &sess.cache;
        let Some(g) = sess.resident.as_mut() else { return (0, 0) };
        let target = target_tokens.min(kv_avail);
        if target < g.len {
            let demoted = g.len - target;
            g.drop_head(demoted, row);
            return (0, demoted);
        }
        // promote: charge the extra blocks, then extend the suffix downward
        let bb = GpuResident::block_bytes(m.model.n_layers, g.block_tokens, row);
        let mut new_len = target;
        while g.guards.len() * g.block_tokens < new_len {
            match self.try_charge_resident_block(bb) {
                Some(guard) => g.guards.push(guard),
                None => {
                    new_len = (g.guards.len() * g.block_tokens).max(g.len).min(new_len);
                    break;
                }
            }
        }
        let add = new_len - g.len;
        if add == 0 {
            return (0, 0);
        }
        let start = kv_len - new_len;
        for layer in 0..m.model.n_layers {
            let st = cache.layer(layer);
            let range = st.kv_rows(start, start + add);
            let mut nk: Vec<f32> = Vec::with_capacity(new_len * row);
            nk.extend_from_slice(&st.k_arc()[range.clone()]);
            nk.extend_from_slice(&g.k[layer]);
            g.k[layer] = nk;
            let mut nv: Vec<f32> = Vec::with_capacity(new_len * row);
            nv.extend_from_slice(&st.v_arc()[range]);
            nv.extend_from_slice(&g.v[layer]);
            g.v[layer] = nv;
        }
        g.len = new_len;
        (add, 0)
    }

    /// Align the session's device window to the store's *settled* resident
    /// suffix, with slack tuned to the store's asynchronous migrations:
    ///
    /// * normally the engine may run up to one residency block ahead of
    ///   `backed` (the window grows a token per step for free; the store's
    ///   accounting catches up on the next sync) — forcing exact alignment
    ///   every step would thrash the window against in-flight growth;
    /// * but when `demotion_inflight` is set, the store has already
    ///   *released* gpu bytes under part of this window (an eviction's
    ///   async writeback is still on the link), so the engine must shed
    ///   the unbacked rows **this** step — keeping them would double-count
    ///   the gpu budget against whichever promotion reused those bytes.
    ///
    /// Returns the (promoted, demoted) token counts of the alignment, or
    /// (0, 0) when the window was already within slack.
    pub fn sync_residency(
        &self,
        sess: &mut DecodeSession,
        backed: usize,
        demotion_inflight: bool,
    ) -> (usize, usize) {
        let cur = sess.resident_tokens();
        let slack = match (&sess.resident, demotion_inflight) {
            (Some(g), false) => g.block_tokens,
            _ => 0,
        };
        if backed > cur || cur > backed + slack {
            self.set_resident_target(sess, backed)
        } else {
            (0, 0)
        }
    }

    /// Prefill `ids` (row-major `[n_seqs][prompt]`, padded per request) and
    /// return a [`DecodeSession`] ready for step-wise decoding.  This is the
    /// admission half of the continuous-batching loop; whole-batch
    /// [`Engine::generate`] is a thin wrapper over it.
    pub fn start_batch(&self, ids: &[Vec<i32>]) -> Result<DecodeSession> {
        let m = self.runtime.manifest();
        let model = m.model.clone();
        let n_seqs = ids.len();
        if n_seqs == 0 {
            bail!("cannot start an empty batch");
        }
        let b = m
            .batch_bucket_for(n_seqs)
            .with_context(|| format!("no batch bucket for {n_seqs} sequences"))?;
        let max_prompt = ids.iter().map(|p| p.len()).max().unwrap_or(0);
        let sp = m
            .prompt_bucket_for(max_prompt)
            .with_context(|| format!("no prompt bucket for length {max_prompt}"))?;

        // pad ids to [b, sp] (PAD token + replicate last row for slack seqs)
        let mut flat = Vec::with_capacity(b * sp);
        for i in 0..b {
            let src = ids.get(i.min(n_seqs - 1)).unwrap();
            for j in 0..sp {
                flat.push(*src.get(j).unwrap_or(&258));
            }
        }

        let planner = self
            .cfg
            .policy
            .is_partial()
            .then(|| self.planner(b, SchedulePolicy::RowByRow));

        let mut cache = HostKvCache::new(model.n_layers, b, model.hidden, m.seq_cap);
        let mut metrics = GenMetrics::default();

        let t0 = Instant::now();
        let last = self.prefill(&flat, b, sp, &mut cache)?;
        metrics.prefill_s = t0.elapsed().as_secs_f64();

        let mut tokens: Vec<Vec<i32>> = vec![Vec::new(); b];
        for (i, tk) in tokens.iter_mut().enumerate() {
            tk.push(last[i]);
        }

        Ok(DecodeSession {
            cache,
            last,
            tokens,
            b,
            n_seqs,
            planner,
            metrics,
            store_handles: Vec::new(),
            resident: None,
            kv_floor: 0,
        })
    }

    /// Physically reclaim the K/V host storage of a session's dropped
    /// prefix (the tiered store's `kv_dropped_tokens` decision): every
    /// layer's K/V `Vec`s shrink while the X activations survive for
    /// recompute, and the floor becomes **mandatory** — every later step
    /// must plan `l` at or above it, which
    /// [`build_step`](Self::build_step) enforces by raising an uncovering
    /// split to the smallest artifact L bucket over the hole.  To keep
    /// that raise always executable, the truncation itself never goes past
    /// what an artifact bucket within the current length can cover.
    /// No-op for full-transfer policies (they can never recompute over the
    /// hole).  Returns the host bytes freed.
    pub fn truncate_dropped_kv(&self, sess: &mut DecodeSession, tokens: usize) -> u64 {
        if !self.cfg.policy.is_partial() || tokens <= sess.kv_floor {
            return 0;
        }
        let m = self.runtime.manifest();
        let kv_len = sess.cache.seq_len();
        let covered = m
            .l_buckets
            .iter()
            .copied()
            .any(|lb| lb >= tokens && lb <= kv_len);
        let target = if covered {
            tokens
        } else {
            // no bucket covers the full request within the current length:
            // truncate up to the largest bucket at or below it — the floor
            // then covers itself
            m.l_buckets
                .iter()
                .copied()
                .filter(|&lb| lb <= tokens.min(kv_len))
                .max()
                .unwrap_or(0)
        };
        if target <= sess.kv_floor {
            return 0;
        }
        let freed = sess.cache.drop_prefix_kv(target);
        sess.kv_floor = sess.cache.kv_trunc();
        freed
    }

    /// One decode step with the split chosen by the session's own planner.
    pub fn decode_step(&self, sess: &mut DecodeSession) -> Result<Vec<i32>> {
        self.decode_step_with_plan(sess, None)
    }

    /// One decode step of every layer: embed the last sampled tokens, run
    /// the planned transfer/recompute schedule per layer, sample the next
    /// token per lane.  `plan_override` supplies an externally solved split
    /// point (the coordinator re-solves Eq. 11 over the whole formed batch);
    /// `None` lets the session's planner decide.  Returns the tokens
    /// sampled this step (one per batch lane).
    ///
    /// The step is the serial composition of the four pipeline stages —
    /// [`build_step`](Self::build_step) → [`stage_step`](Self::stage_step)
    /// → [`submit_step`](Self::submit_step) →
    /// [`collect_step`](Self::collect_step) (see
    /// [`pipeline`](super::pipeline)); the pipelined serving loop drives
    /// the same stages with a shared [`StageSlots`] double buffer so one
    /// group's staging overlaps another's compute.
    pub fn decode_step_with_plan(
        &self,
        sess: &mut DecodeSession,
        plan_override: Option<usize>,
    ) -> Result<Vec<i32>> {
        let mut slots = StageSlots::new();
        let mut h = self.build_step(sess, plan_override)?;
        self.stage_step(sess, &mut h, &mut slots)?;
        let hidden = self.submit_step(sess, &mut h, &mut slots)?;
        self.collect_step(sess, h, hidden)
    }

    // ---------------------------------------------------------------------
    // pipeline stages (see `engine::pipeline` for the handoff contract)
    // ---------------------------------------------------------------------

    /// **build**: plan-driven input selection.  Resolve the split point
    /// this step executes, charge the residency block the appended token
    /// needs (sliding the window under gpu-pool pressure), and bound the
    /// resident suffix against the recompute prefix.  Produces the
    /// [`StepHandoff`] the remaining stages carry.
    pub fn build_step(
        &self,
        sess: &mut DecodeSession,
        plan_override: Option<usize>,
    ) -> Result<StepHandoff> {
        let m = self.runtime.manifest();
        let model = &m.model;
        let kv_len = sess.cache.seq_len();
        if kv_len >= m.seq_cap {
            bail!("kv cache full ({kv_len} rows): session must be retired");
        }

        let plan_l = match plan_override {
            // an override must be an artifact L bucket (plan_batch only
            // emits those); an infeasible prefix degrades to full transfer
            // rather than to a bucket no artifact exists for
            Some(l) if l <= kv_len => l,
            Some(_) => 0,
            None => sess
                .planner
                .as_ref()
                .map(|p| p.plan_step(kv_len).l())
                .unwrap_or(0),
        };
        // a physically truncated dropped prefix makes the floor mandatory:
        // rows below it no longer exist to transfer, so an uncovering plan
        // is raised to the smallest artifact bucket over the hole
        // (truncate_dropped_kv guarantees one exists within kv_len)
        let plan_l = if plan_l < sess.kv_floor {
            m.l_buckets
                .iter()
                .copied()
                .filter(|&lb| lb >= sess.kv_floor)
                .min()
                .with_context(|| {
                    format!("no L bucket covers the dropped-KV floor {}", sess.kv_floor)
                })?
        } else {
            plan_l
        };
        sess.metrics.splits.push(plan_l);

        // -- tiered-residency bookkeeping ---------------------------------
        // the token appended this step stays on device (its K/V is computed
        // there): charge the crossing into a new residency block up front,
        // sliding the window when the gpu pool is contended so the resident
        // region stays a suffix
        let row = sess.b * model.hidden;
        if let Some(g) = sess.resident.as_mut() {
            if g.guards.len() * g.block_tokens < g.len + 1 {
                let bb = GpuResident::block_bytes(model.n_layers, g.block_tokens, row);
                match self.try_charge_resident_block(bb) {
                    Some(guard) => g.guards.push(guard),
                    None if g.len >= g.block_tokens => {
                        g.drop_head(g.block_tokens, row);
                        match self.try_charge_resident_block(bb) {
                            Some(guard) => g.guards.push(guard),
                            None => g.clear(),
                        }
                    }
                    None => {} // empty window and no room: stay empty
                }
            }
        }
        let grow_resident = sess
            .resident
            .as_ref()
            .is_some_and(|g| g.guards.len() * g.block_tokens >= g.len + 1);
        // the resident suffix yields to the recompute prefix when they meet
        let r_used = sess.resident_tokens().min(kv_len - plan_l);
        Ok(StepHandoff::new(plan_l, r_used, kv_len, grow_resident))
    }

    /// **stage**: embed the last sampled tokens and issue layer 0's
    /// transfers (activation prefix + KV remainder) into a free staging
    /// slot.  Once staged, the transfers stream on the link's worker
    /// threads — a pipelined caller stages the *next* step here while the
    /// current one is still in [`submit_step`](Self::submit_step).
    pub fn stage_step(
        &self,
        sess: &mut DecodeSession,
        h: &mut StepHandoff,
        slots: &mut StageSlots,
    ) -> Result<()> {
        let t_stage = Instant::now();
        let m = self.runtime.manifest();
        let embed = self.runtime.artifact(&m.embed_decode_name(sess.b))?;

        let t0 = Instant::now();
        let x0 = embed.call(&[
            ArgValue::I32Slice(&sess.last),
            ArgValue::I32(h.kv_len() as i32),
            ArgValue::F32(&self.weights.tok_table),
            ArgValue::F32(&self.weights.pos_table),
        ])?;
        sess.metrics.breakdown.other_s += t0.elapsed().as_secs_f64();
        let x = x0.into_iter().next().unwrap();

        // ALISA defers the remainder: issue only at the top of each layer
        let alisa = matches!(self.cfg.policy, EnginePolicy::AlisaSequential);
        let first = (!alisa).then(|| self.issue_layer(&sess.cache, 0, h.plan_l(), h.r_used()));
        h.slot = Some(slots.store(StagedInput { x, first })?);
        h.staged_s += t_stage.elapsed().as_secs_f64();
        Ok(())
    }

    /// **submit**: drain the staged slot through every layer's planned
    /// transfer/recompute schedule (Algorithm 1's compute half), appending
    /// K/V as it goes.  Returns the final hidden state for
    /// [`collect_step`](Self::collect_step).
    pub fn submit_step(
        &self,
        sess: &mut DecodeSession,
        h: &mut StepHandoff,
        slots: &mut StageSlots,
    ) -> Result<Vec<f32>> {
        let t_submit = Instant::now();
        let m = self.runtime.manifest();
        let model = &m.model;
        let b = sess.b;
        let (plan_l, r_used, kv_len) = (h.plan_l(), h.r_used(), h.kv_len());
        let slot = h
            .slot
            .take()
            .context("submit_step needs a staged handoff (call stage_step first)")?;
        let StagedInput { mut x, first } = slots.take(slot)?;
        let row = b * model.hidden;
        let alisa = matches!(self.cfg.policy, EnginePolicy::AlisaSequential);

        let mut pending: Option<LayerTransfers> = first;
        for layer in 0..model.n_layers {
            let t = if alisa {
                // sequential: ALISA issues a layer's transfers only when
                // it reaches the layer (no cross-layer prefetch); the
                // recompute-then-transfer serialisation inside the layer
                // is modelled faithfully in the simulator (sim::policies)
                // while the engine covers the no-intra-overlap ablation
                // via KvprFused.
                self.issue_layer(&sess.cache, layer, plan_l, r_used)
            } else {
                // prefetching policies filled this one layer ahead; the
                // synchronous baseline issues at the top of the layer
                pending
                    .take()
                    .unwrap_or_else(|| self.issue_layer(&sess.cache, layer, plan_l, r_used))
            };
            // prefetch next layer (Algorithm 1: load(i+1) before compute(i))
            if !alisa && self.cfg.policy.prefetches() && layer + 1 < model.n_layers {
                pending = Some(self.issue_layer(&sess.cache, layer + 1, plan_l, r_used));
            }

            // the resident suffix rows join staging without link traffic
            let (res_k, res_v): (&[f32], &[f32]) = match sess.resident.as_ref() {
                Some(g) if r_used > 0 => {
                    let skip = (g.len - r_used) * row;
                    (&g.k[layer][skip..], &g.v[layer][skip..])
                }
                _ => (&[], &[]),
            };
            let (y, k_new, v_new) = self.run_layer(
                layer,
                b,
                &x,
                kv_len,
                t,
                res_k,
                res_v,
                &mut sess.metrics.breakdown,
            )?;

            // store streams (Algorithm 1 store_*): host append + D2H timing
            sess.store_handles
                .push(self.d2h.submit_timing(3 * b * model.hidden, Priority::Normal));
            if h.grow_resident {
                if let Some(g) = sess.resident.as_mut() {
                    g.k[layer].extend_from_slice(&k_new);
                    g.v[layer].extend_from_slice(&v_new);
                }
            }
            sess.cache.layer_mut(layer).append(&k_new, &v_new, &x)?;
            x = y;
        }
        h.submit_s += t_submit.elapsed().as_secs_f64();
        Ok(x)
    }

    /// **collect**: token landing + residency sync.  Runs lm_head over the
    /// submitted hidden state, samples one token per lane, grows the
    /// device-resident window over the appended K/V, and books the step's
    /// timing — staging time counts as decode wall time in serial mode but
    /// as hidden [`Breakdown::overlap_s`](super::Breakdown) when the
    /// handoff was [marked overlapped](StepHandoff::mark_overlapped).
    pub fn collect_step(
        &self,
        sess: &mut DecodeSession,
        h: StepHandoff,
        hidden: Vec<f32>,
    ) -> Result<Vec<i32>> {
        let t_collect = Instant::now();
        let m = self.runtime.manifest();
        let model = &m.model;
        if h.grow_resident {
            if let Some(g) = sess.resident.as_mut() {
                g.len += 1;
            }
        }
        let head = self.runtime.artifact(&m.lm_head_name(sess.b))?;
        let t0 = Instant::now();
        let logits = head.call(&[
            ArgValue::F32(&hidden),
            ArgValue::F32(&self.weights.tok_table),
            ArgValue::F32(&self.weights.lnf_g),
            ArgValue::F32(&self.weights.lnf_b),
        ])?;
        sess.metrics.breakdown.other_s += t0.elapsed().as_secs_f64();
        sess.last = RefModel::argmax(&logits[0], model.vocab);
        for (i, tk) in sess.tokens.iter_mut().enumerate() {
            tk.push(sess.last[i]);
        }
        // staging time is decode wall time unless the pipeline hid it
        // under another step's compute, in which case it is shadow time
        let exec_s = h.submit_s + t_collect.elapsed().as_secs_f64();
        if h.overlapped() {
            sess.metrics.decode_s += exec_s;
            sess.metrics.breakdown.overlap_s += h.staged_s;
        } else {
            sess.metrics.decode_s += h.staged_s + exec_s;
        }

        // opportunistically retire landed store timings so a long-running
        // session's handle list stays bounded
        while sess.store_handles.first().is_some_and(|h| h.is_done()) {
            sess.store_handles.remove(0).wait();
        }
        Ok(sess.last.clone())
    }

    /// Retire a session: drain outstanding store streams, finalise metrics,
    /// and hand back the generated tokens (truncated to the real sequences).
    pub fn finish_batch(&self, mut sess: DecodeSession) -> GenResult {
        for h in sess.store_handles.drain(..) {
            h.wait();
        }
        let mut metrics = sess.metrics;
        let per_lane = sess.tokens.first().map_or(0, |t| t.len());
        metrics.tokens_generated = (sess.n_seqs * per_lane.saturating_sub(1)) as u64;
        metrics.gpu_peak_bytes = self.gpu_pool.peak();
        metrics.h2d_bytes = self.h2d.stats().total_bytes();
        metrics.h2d_busy_s = self.h2d.stats().busy_secs();
        let mut tokens = sess.tokens;
        tokens.truncate(sess.n_seqs);
        GenResult { tokens, metrics }
    }

    // ---------------------------------------------------------------------
    // row-by-row generation (paper §3.2, latency objective)
    // ---------------------------------------------------------------------

    /// Generate `gen_len` tokens for up to `batch_bucket` sequences.
    /// `ids` is row-major `[n_seqs][prompt_bucket]`, already padded.
    pub fn generate(
        &self,
        ids: &[Vec<i32>],
        gen_len: usize,
    ) -> Result<GenResult> {
        let m = self.runtime.manifest();
        let max_prompt = ids.iter().map(|p| p.len()).max().unwrap_or(0);
        let sp = m
            .prompt_bucket_for(max_prompt)
            .with_context(|| format!("no prompt bucket for length {max_prompt}"))?;
        if sp + gen_len >= m.seq_cap {
            bail!("prompt {sp} + gen {gen_len} exceeds cache capacity {}", m.seq_cap);
        }

        self.gpu_pool.reset_peak();
        // weights resident on device when not offloaded (latency regime)
        let _resident = if !self.cfg.weights_offloaded {
            Some(
                self.gpu_pool
                    .alloc(self.weights.total_bytes())
                    .context("resident weights exceed device memory")?,
            )
        } else {
            None
        };

        let mut sess = self.start_batch(ids)?;
        for _step in 1..gen_len {
            self.decode_step(&mut sess)?;
        }
        Ok(self.finish_batch(sess))
    }

    // ---------------------------------------------------------------------
    // column-by-column generation (paper §3.2, throughput objective)
    // ---------------------------------------------------------------------

    /// Generate for `groups` batches, reusing each layer's weights across
    /// the whole group before moving on (weights offloaded).  Every batch
    /// must fit the same bucket.
    pub fn generate_column(
        &self,
        groups: &[Vec<Vec<i32>>],
        gen_len: usize,
    ) -> Result<Vec<GenResult>> {
        let m = self.runtime.manifest().clone();
        let model = m.model.clone();
        if groups.is_empty() {
            return Ok(Vec::new());
        }
        let n_seqs = groups[0].len();
        let b = m
            .batch_bucket_for(n_seqs)
            .context("no batch bucket for group size")?;
        let max_prompt = groups
            .iter()
            .flat_map(|g| g.iter().map(|p| p.len()))
            .max()
            .unwrap_or(0);
        let sp = m.prompt_bucket_for(max_prompt).context("no prompt bucket")?;
        if sp + gen_len >= m.seq_cap {
            bail!("prompt + gen exceeds capacity");
        }

        let planner = self
            .cfg
            .policy
            .is_partial()
            .then(|| self.planner(b, SchedulePolicy::ColumnByColumn));

        // per-batch state
        let n_batches = groups.len();
        let mut caches: Vec<HostKvCache> = (0..n_batches)
            .map(|_| HostKvCache::new(model.n_layers, b, model.hidden, m.seq_cap))
            .collect();
        let mut lasts: Vec<Vec<i32>> = Vec::with_capacity(n_batches);
        let mut tokens: Vec<Vec<Vec<i32>>> =
            vec![vec![Vec::with_capacity(gen_len); b]; n_batches];
        let mut all_metrics: Vec<GenMetrics> = vec![GenMetrics::default(); n_batches];

        let t0 = Instant::now();
        for (g, group) in groups.iter().enumerate() {
            let mut flat = Vec::with_capacity(b * sp);
            for i in 0..b {
                let src = group.get(i.min(group.len() - 1)).unwrap();
                for j in 0..sp {
                    flat.push(*src.get(j).unwrap_or(&258));
                }
            }
            let first = self.prefill(&flat, b, sp, &mut caches[g])?;
            for (i, tk) in tokens[g].iter_mut().enumerate() {
                tk.push(first[i]);
            }
            lasts.push(first);
        }
        let prefill_s = t0.elapsed().as_secs_f64();
        for gm in all_metrics.iter_mut() {
            gm.prefill_s = prefill_s / n_batches as f64;
        }

        let embed = self.runtime.artifact(&m.embed_decode_name(b))?;
        let head = self.runtime.artifact(&m.lm_head_name(b))?;

        let t_dec = Instant::now();
        for _step in 1..gen_len {
            let kv_len = caches[0].seq_len();
            let plan_l = planner
                .as_ref()
                .map(|p| p.plan_step(kv_len).l())
                .unwrap_or(0);

            // embed all batches for this step
            let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n_batches);
            for g in 0..n_batches {
                let x0 = embed.call(&[
                    ArgValue::I32Slice(&lasts[g]),
                    ArgValue::I32(kv_len as i32),
                    ArgValue::F32(&self.weights.tok_table),
                    ArgValue::F32(&self.weights.pos_table),
                ])?;
                xs.push(x0.into_iter().next().unwrap());
            }

            for layer in 0..model.n_layers {
                // weights move once per layer (the column schedule's point)
                if self.cfg.weights_offloaded {
                    let lw = self.weights.layer(layer);
                    let wh = self
                        .h2d
                        .submit_timing((lw.bytes() / 4) as usize, Priority::High);
                    let t0 = Instant::now();
                    wh.wait();
                    all_metrics[0].breakdown.wait_weights_s += t0.elapsed().as_secs_f64();
                }
                // pipeline batches through this layer
                let mut pending = Some(self.issue_layer(&caches[0], layer, plan_l, 0));
                for g in 0..n_batches {
                    let t = pending.take().unwrap();
                    if self.cfg.policy.prefetches() && g + 1 < n_batches {
                        pending = Some(self.issue_layer(&caches[g + 1], layer, plan_l, 0));
                    }
                    let (y, k_new, v_new) = self.run_layer(
                        layer,
                        b,
                        &xs[g],
                        kv_len,
                        t,
                        &[],
                        &[],
                        &mut all_metrics[g].breakdown,
                    )?;
                    self.d2h
                        .submit_timing(3 * b * model.hidden, Priority::Normal);
                    caches[g].layer_mut(layer).append(&k_new, &v_new, &xs[g])?;
                    xs[g] = y;
                    if pending.is_none() && g + 1 < n_batches {
                        pending = Some(self.issue_layer(&caches[g + 1], layer, plan_l, 0));
                    }
                }
            }

            for g in 0..n_batches {
                let logits = head.call(&[
                    ArgValue::F32(&xs[g]),
                    ArgValue::F32(&self.weights.tok_table),
                    ArgValue::F32(&self.weights.lnf_g),
                    ArgValue::F32(&self.weights.lnf_b),
                ])?;
                lasts[g] = RefModel::argmax(&logits[0], model.vocab);
                for (i, tk) in tokens[g].iter_mut().enumerate() {
                    tk.push(lasts[g][i]);
                }
                all_metrics[g].splits.push(plan_l);
            }
        }
        self.d2h.drain();
        let decode_s = t_dec.elapsed().as_secs_f64();

        let mut out = Vec::with_capacity(n_batches);
        for (g, mut toks) in tokens.into_iter().enumerate() {
            toks.truncate(groups[g].len());
            let mut gm = std::mem::take(&mut all_metrics[g]);
            gm.decode_s = decode_s; // group decodes are interleaved; report wall
            gm.tokens_generated = (groups[g].len() * gen_len.saturating_sub(1)) as u64;
            gm.gpu_peak_bytes = self.gpu_pool.peak();
            out.push(GenResult { tokens: toks, metrics: gm });
        }
        Ok(out)
    }
}
