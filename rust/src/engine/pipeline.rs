//! The pipelined step runtime: one decode step split into explicit
//! **build → stage → submit → collect** stages with a typed handoff.
//!
//! [`Engine::decode_step_with_plan`](super::Engine::decode_step_with_plan)
//! is the serial composition of four stage methods (same bytes, same
//! tokens — the split is pure structure):
//!
//! ```text
//!   build    plan-driven input selection: resolve the split l, charge the
//!            residency block, bound the resident suffix      → StepHandoff
//!   stage    embed the last tokens and issue layer 0's KV-remainder /
//!            activation transfers into a staging slot        → slot filled
//!   submit   per-layer transfer/recompute/merge compute       → slot drained
//!   collect  lm_head + token landing + residency growth + timings
//! ```
//!
//! [`StageSlots`] is the double buffer between `stage` and `submit`: two
//! slots, so **stage(N+1) fills slot B while submit(N) drains slot A** —
//! the staged transfers stream on the link's worker threads underneath
//! slot A's compute.  Sessions are engine-affine (the staging touches the
//! engine's links and pinned pool), so the slots pipeline *groups* on the
//! serving thread; the cross-step half of the overlap — next step's
//! [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch) solve
//! and the migration pump — runs on the coordinator's stage worker thread
//! (see `coordinator::continuous`), with
//! [`PlanHandoff`](crate::scheduler::PlanHandoff) validity tokens
//! guaranteeing every adopted plan equals the inline solve it replaced.
//! Either way the stages move bytes earlier, never math: serial and
//! overlapped execution produce bit-identical tokens.
//!
//! Driving the stages by hand:
//!
//! ```no_run
//! use kvpr::engine::{Engine, EngineConfig, EnginePolicy, StageSlots};
//!
//! fn main() -> anyhow::Result<()> {
//!     let cfg = EngineConfig::new(EnginePolicy::Kvpr);
//!     let engine = Engine::new(std::path::Path::new("artifacts"), cfg)?;
//!     let mut sess = engine.start_batch(&[vec![104, 105]])?;
//!     let mut slots = StageSlots::new();
//!
//!     // one decode step, stages spelled out (== engine.decode_step(&mut sess))
//!     let mut h = engine.build_step(&mut sess, None)?;
//!     engine.stage_step(&mut sess, &mut h, &mut slots)?;
//!     let hidden = engine.submit_step(&mut sess, &mut h, &mut slots)?;
//!     let tokens = engine.collect_step(&mut sess, h, hidden)?;
//!     assert_eq!(tokens.len(), sess.batch_bucket());
//!     Ok(())
//! }
//! ```

use anyhow::{bail, Context, Result};

use super::decode::LayerTransfers;

/// The typed handoff carried through one step's build → stage → submit →
/// collect stages: the plan the step executes, the staging slot holding
/// its in-flight inputs, and the per-stage timing that lets `collect`
/// account hidden (overlapped) staging time separately from wall time.
#[derive(Debug)]
pub struct StepHandoff {
    /// The split point this step executes (0 = full transfer).
    plan_l: usize,
    /// Device-resident suffix rows the step keeps off the link.
    r_used: usize,
    /// Cached tokens (the paper's s') at build time.
    kv_len: usize,
    /// Whether the appended token's K/V stays device-resident.
    pub(super) grow_resident: bool,
    /// Index of the staging slot holding this step's staged inputs
    /// (`None` before `stage` and after `submit` consumed it).
    pub(super) slot: Option<usize>,
    /// Host seconds `stage` spent (embed + transfer issue).
    pub(super) staged_s: f64,
    /// Seconds `submit` spent in the per-layer loop.
    pub(super) submit_s: f64,
    /// Set by the pipelined caller when `stage` ran in another step's
    /// compute shadow: `collect` then books `staged_s` as
    /// [`Breakdown::overlap_s`](super::Breakdown) instead of decode wall
    /// time.
    overlapped: bool,
}

impl StepHandoff {
    pub(super) fn new(plan_l: usize, r_used: usize, kv_len: usize, grow_resident: bool) -> Self {
        StepHandoff {
            plan_l,
            r_used,
            kv_len,
            grow_resident,
            slot: None,
            staged_s: 0.0,
            submit_s: 0.0,
            overlapped: false,
        }
    }

    /// The split point the step will execute (an artifact L bucket).
    pub fn plan_l(&self) -> usize {
        self.plan_l
    }

    /// Resident-suffix rows staged without link traffic.
    pub fn r_used(&self) -> usize {
        self.r_used
    }

    /// Cached tokens at build time (the s' the plan was solved for).
    pub fn kv_len(&self) -> usize {
        self.kv_len
    }

    /// Whether `stage` has filled a slot that `submit` has not drained.
    pub fn is_staged(&self) -> bool {
        self.slot.is_some()
    }

    /// Host seconds the stage phase spent (embed + transfer issue).
    pub fn staged_s(&self) -> f64 {
        self.staged_s
    }

    /// Mark this step's staging as pipelined — it ran while another step
    /// computed, so its host time was hidden, not spent.
    pub fn mark_overlapped(&mut self) {
        self.overlapped = true;
    }

    pub(super) fn overlapped(&self) -> bool {
        self.overlapped
    }
}

/// One staged step's inputs, parked between `stage` and `submit`.
pub(super) struct StagedInput {
    /// Embedded input activations for every lane.
    pub(super) x: Vec<f32>,
    /// Layer 0's issued transfers (`None` under `AlisaSequential`, which
    /// defers all issue to the layer loop).
    pub(super) first: Option<LayerTransfers>,
}

/// The double buffer between `stage` and `submit`: two slots, so the next
/// step's staging can fill one while the current step's compute drains the
/// other.  A third in-flight stage is a caller bug and fails loudly.
#[derive(Default)]
pub struct StageSlots {
    slots: [Option<StagedInput>; 2],
}

impl StageSlots {
    pub fn new() -> Self {
        Self::default()
    }

    /// Slots currently holding a staged step.
    pub fn in_flight(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Park a staged input in a free slot, returning its index.
    pub(super) fn store(&mut self, staged: StagedInput) -> Result<usize> {
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.is_none() {
                *s = Some(staged);
                return Ok(i);
            }
        }
        bail!("both staging slots in flight: submit a staged step before staging a third")
    }

    /// Drain slot `i` for submission.
    pub(super) fn take(&mut self, i: usize) -> Result<StagedInput> {
        self.slots
            .get_mut(i)
            .and_then(Option::take)
            .with_context(|| format!("staging slot {i} is empty"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn staged() -> StagedInput {
        StagedInput { x: vec![0.0; 4], first: None }
    }

    #[test]
    fn slots_double_buffer_and_reject_a_third() {
        let mut s = StageSlots::new();
        let a = s.store(staged()).unwrap();
        let b = s.store(staged()).unwrap();
        assert_ne!(a, b);
        assert_eq!(s.in_flight(), 2);
        assert!(s.store(staged()).is_err(), "two slots only");
        s.take(a).unwrap();
        assert_eq!(s.in_flight(), 1);
        let c = s.store(staged()).unwrap();
        assert_eq!(c, a, "freed slot is reused");
        assert!(s.take(c).is_ok());
        assert!(s.take(c).is_err(), "a slot drains once");
    }

    #[test]
    fn handoff_carries_the_plan_and_overlap_marking() {
        let mut h = StepHandoff::new(16, 4, 64, true);
        assert_eq!((h.plan_l(), h.r_used(), h.kv_len()), (16, 4, 64));
        assert!(!h.is_staged());
        assert!(!h.overlapped());
        h.mark_overlapped();
        assert!(h.overlapped());
    }
}
