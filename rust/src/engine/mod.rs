//! The runtime module (paper §3.3): overlapped decode execution.
//!
//! The engine drives the AOT artifacts layer-by-layer so that KV-cache /
//! activation / weight transfers interleave with compute exactly like the
//! paper's six-stream pipeline (Algorithm 1):
//!
//! * **within a layer** (KVPR): the activation prefix `X[0:l]` is submitted
//!   at high priority; as soon as it lands, the `recompute_*` artifact runs
//!   on the compute thread *while the link is still streaming* `KV[l:s']`;
//!   the `decode_merge_*` artifact then consumes both.
//! * **across layers**: transfers for layer i+1 are issued before layer i's
//!   compute (double buffering / prefetch).
//! * **weights** (offloaded mode): per-layer weight traffic, optionally
//!   fine-grained — W_K/W_V jump the queue so recomputation is not blocked
//!   behind W_Q/W_O (paper Fig 5b, "hiding KV cache partial recomputation").
//!
//! Five policies make the paper's baselines runnable on the same engine:
//! `FullTransferSync` (HF-Accelerate-like), `FullTransferOverlap`
//! (FlexGen-like), `Kvpr` (split schedule), `KvprFused` (single fused
//! artifact — no intra-layer overlap; ablation), and `AlisaSequential`
//! (recompute **then** transfer, the ALISA §5 comparison).
//!
//! All policies produce **identical tokens** — the schedules move bytes and
//! kernels around, never the math.
//!
//! The decode step itself is split into explicit **build → stage → submit →
//! collect** stages with a typed [`StepHandoff`] and a [`StageSlots`]
//! double buffer (see [`pipeline`]), so the continuous serving loop can
//! overlap one step's staging with another's compute.

mod decode;
mod pipeline;
mod stage;

pub use decode::{DecodeSession, Engine, EngineConfig, EnginePolicy, GenMetrics, GenResult};
pub use pipeline::{StageSlots, StepHandoff};
pub use stage::Breakdown;

#[doc(hidden)]
pub use stage::stage_padded as stage_padded_for_bench;
