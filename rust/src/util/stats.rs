//! Summary statistics over latency/throughput samples.

/// Online mean/variance (Welford) plus retained samples for percentiles.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
    mean: f64,
    m2: f64,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
        let n = self.samples.len() as f64;
        let delta = x - self.mean;
        self.mean += delta / n;
        self.m2 += delta * (x - self.mean);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn std(&self) -> f64 {
        if self.samples.len() < 2 {
            0.0
        } else {
            (self.m2 / (self.samples.len() - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Linear-interpolated percentile, p in [0, 100].
    pub fn percentile(&self, p: f64) -> f64 {
        assert!(!self.samples.is_empty(), "percentile of empty summary");
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
        }
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Least-squares line fit y = a + b·x; returns (intercept, slope).
/// Used by the profiler to split link time into latency + bytes/bandwidth.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2, "need at least two points");
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        den += (x - mx) * (x - mx);
    }
    let slope = if den == 0.0 { 0.0 } else { num / den };
    (my - slope * mx, slope)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let mut s = Summary::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.add(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.138089935299395).abs() < 1e-9);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let mut s = Summary::new();
        for x in 1..=100 {
            s.add(x as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.p95() - 95.05).abs() < 1e-9);
        assert!((s.percentile(0.0) - 1.0).abs() < 1e-9);
        assert!((s.percentile(100.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn single_sample() {
        let mut s = Summary::new();
        s.add(3.0);
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.p50(), 3.0);
    }

    #[test]
    fn fit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 0.5 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 0.5).abs() < 1e-9);
    }

    #[test]
    fn fit_latency_bandwidth_model() {
        // t = 100µs + bytes / (2 GB/s): profiler-style recovery
        let sizes = [1e6, 4e6, 16e6, 64e6];
        let times: Vec<f64> = sizes.iter().map(|b| 100e-6 + b / 2e9).collect();
        let (lat, inv_bw) = linear_fit(&sizes, &times);
        assert!((lat - 100e-6).abs() < 1e-9);
        assert!((1.0 / inv_bw - 2e9).abs() / 2e9 < 1e-9);
    }
}
