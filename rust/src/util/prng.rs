//! Deterministic PRNG (xoshiro256** seeded via SplitMix64).
//!
//! Used for weight generation, synthetic workloads and the property-test
//! harness.  No `rand` crate in the vendored set; determinism across runs is
//! a feature here anyway — the E2E example checks that KVPR and the full
//! transfer baseline emit *identical* tokens, which requires reproducible
//! weights and prompts.

/// xoshiro256** — fast, high-quality, 64-bit.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the full state
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [lo, hi) — hi exclusive, requires hi > lo.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range");
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        self.range(0, n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of scaled normals (weights init).
    pub fn normal_vec_f32(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Case-count knob for the heavier property tests (the proptest
/// `PROPTEST_CASES` convention): `KVPR_PROPTEST_CASES` in the environment
/// overrides the test's default, so the nightly-scheduled extended CI job
/// can run the same properties at high case counts without dragging the
/// PR-latency path.  Unset or unparsable values keep the default.
pub fn prop_cases(default_cases: usize) -> usize {
    std::env::var("KVPR_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default_cases)
}

/// Tiny property-test harness: run `f` on `n` PRNG-derived cases and report
/// the seed of the first failure so it can be replayed.  A stand-in for
/// proptest (not in the vendored crate set) — shrinkless but reproducible.
pub fn check_property<F: FnMut(&mut Prng) -> Result<(), String>>(
    name: &str,
    cases: usize,
    mut f: F,
) {
    for case in 0..cases {
        let seed = 0xc0ffee ^ (case as u64).wrapping_mul(0x9e3779b97f4a7c15);
        let mut rng = Prng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn uniform_range() {
        let mut r = Prng::new(7);
        for _ in 0..1000 {
            let x = r.range(10, 20);
            assert!((10..20).contains(&x));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Prng::new(8);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Prng::new(9);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Prng::new(10);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn property_harness_reports_failure() {
        check_property("always_fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn property_harness_passes() {
        check_property("range_bounds", 20, |rng| {
            let x = rng.range(0, 5);
            if x < 5 { Ok(()) } else { Err(format!("{x} out of range")) }
        });
    }

    #[test]
    fn prop_cases_defaults_without_the_env_knob() {
        // the knob is read per call; tests must not set the variable (that
        // would race other tests in the same process), so only the default
        // path is pinned here — the nightly CI job exercises the override
        if std::env::var("KVPR_PROPTEST_CASES").is_err() {
            assert_eq!(prop_cases(123), 123);
        }
    }
}
