//! Small self-contained substrates: a mini JSON parser/writer (the vendored
//! crate set has no serde facade), a deterministic PRNG (no `rand`), basic
//! statistics, a fixed-width table printer used by the bench harnesses, the
//! bench-regression gate CI runs over their JSON output, and the wall /
//! deterministic-step [`clock::Clock`] the serving loop stamps latencies
//! through.

pub mod benchgate;
pub mod clock;
pub mod json;
pub mod prng;
pub mod stats;
pub mod table;

/// Global lock serialising wall-clock-sensitive tests.  `cargo test` runs
/// tests concurrently; on a 2-core box a spinning link worker plus a busy
/// caller plus an unrelated test is oversubscribed and timing asserts turn
/// flaky.  Timing tests take this lock first.
#[cfg(test)]
pub(crate) fn timing_lock() -> std::sync::MutexGuard<'static, ()> {
    use std::sync::{Mutex, OnceLock};
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

/// Format a byte count human-readably (MiB with 1 decimal below 1 GiB).
pub fn fmt_bytes(bytes: u64) -> String {
    const GIB: f64 = (1u64 << 30) as f64;
    const MIB: f64 = (1u64 << 20) as f64;
    const KIB: f64 = 1024.0;
    let b = bytes as f64;
    if b >= GIB {
        format!("{:.2} GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.1} MiB", b / MIB)
    } else if b >= KIB {
        format!("{:.1} KiB", b / KIB)
    } else {
        format!("{bytes} B")
    }
}

/// Format seconds with an adaptive unit (s / ms / µs).
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert_eq!(fmt_bytes(5 << 20), "5.0 MiB");
        assert_eq!(fmt_bytes(3 << 30), "3.00 GiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(fmt_secs(2.5), "2.500 s");
        assert_eq!(fmt_secs(0.0156), "15.600 ms");
        assert_eq!(fmt_secs(3.5e-6), "3.5 µs");
    }
}
