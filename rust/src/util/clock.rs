//! Serving-loop clock: wall time or a deterministic decode-step clock.
//!
//! The continuous-batching loop stamps every latency-bearing moment
//! (arrival, admission, first token, retirement, step duration) through one
//! shared [`Clock`].  In [`ClockMode::Wall`] those stamps are real elapsed
//! seconds, exactly as before.  In [`ClockMode::Step`] the clock is
//! *virtual*: time only moves when the serving loop finishes a decode step
//! ([`Clock::advance`]), and each step contributes a fixed `step_s`
//! seconds.  Under the deterministic interpreter runtime that makes every
//! trace, TTFT/TPOT percentile and plan-vs-actual residual bit-reproducible
//! across replays — no sleeps, no scheduler jitter — which is what the
//! observability e2e tests and `examples/trace_dump.rs` rely on.
//!
//! The handle is cheap to clone (an `Arc` around an atomic step counter)
//! and is shared between the submitting thread (arrival stamps) and the
//! serving thread (everything else).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// How a [`Clock`] produces time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClockMode {
    /// Real wall time (seconds since the clock was created).
    Wall,
    /// Virtual step time: `now() = step * step_s`, advanced explicitly by
    /// the serving loop once per decode step.
    Step {
        /// Seconds one decode step is defined to take.
        step_s: f64,
    },
}

struct Inner {
    mode: ClockMode,
    origin: Instant,
    step: AtomicU64,
}

/// Shared wall/virtual clock (see the [module docs](self)).
#[derive(Clone)]
pub struct Clock {
    inner: Arc<Inner>,
}

impl Clock {
    /// A real-time clock; `now()` is seconds since this call.
    pub fn wall() -> Self {
        Self::new(ClockMode::Wall)
    }

    /// A deterministic step clock: `now()` is `step() * step_s`.
    pub fn deterministic(step_s: f64) -> Self {
        Self::new(ClockMode::Step { step_s })
    }

    /// Build a clock in the given mode.
    pub fn new(mode: ClockMode) -> Self {
        Clock {
            inner: Arc::new(Inner {
                mode,
                origin: Instant::now(),
                step: AtomicU64::new(0),
            }),
        }
    }

    /// Current time in seconds (wall-elapsed or virtual, per mode).
    pub fn now(&self) -> f64 {
        match self.inner.mode {
            ClockMode::Wall => self.inner.origin.elapsed().as_secs_f64(),
            ClockMode::Step { step_s } => self.inner.step.load(Ordering::Relaxed) as f64 * step_s,
        }
    }

    /// The decode-step counter (advanced in both modes; only [`ClockMode::Step`]
    /// derives `now()` from it).
    pub fn step(&self) -> u64 {
        self.inner.step.load(Ordering::Relaxed)
    }

    /// Advance the step counter by one (the serving loop calls this once
    /// per completed decode step).
    pub fn advance(&self) {
        self.inner.step.fetch_add(1, Ordering::Relaxed);
    }

    /// Jump the step counter forward (idle fast-forward to the next trace
    /// arrival).  Never moves backwards.
    pub fn set_step(&self, step: u64) {
        self.inner.step.fetch_max(step, Ordering::Relaxed);
    }

    /// `true` when time is virtual ([`ClockMode::Step`]).
    pub fn is_deterministic(&self) -> bool {
        matches!(self.inner.mode, ClockMode::Step { .. })
    }

    /// The per-step duration in [`ClockMode::Step`]; `None` for wall time.
    pub fn step_seconds(&self) -> Option<f64> {
        match self.inner.mode {
            ClockMode::Wall => None,
            ClockMode::Step { step_s } => Some(step_s),
        }
    }
}

impl std::fmt::Debug for Clock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Clock")
            .field("mode", &self.inner.mode)
            .field("step", &self.step())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_moves_forward() {
        let c = Clock::wall();
        let a = c.now();
        let b = c.now();
        assert!(b >= a && a >= 0.0);
        assert!(!c.is_deterministic());
        assert_eq!(c.step_seconds(), None);
        // the step counter still ticks in wall mode
        c.advance();
        assert_eq!(c.step(), 1);
    }

    #[test]
    fn step_clock_is_virtual_and_exact() {
        let c = Clock::deterministic(0.25);
        assert!(c.is_deterministic());
        assert_eq!(c.now(), 0.0);
        c.advance();
        c.advance();
        assert_eq!(c.now(), 0.5);
        assert_eq!(c.step(), 2);
        assert_eq!(c.step_seconds(), Some(0.25));
        // identical across clones (shared counter)
        let d = c.clone();
        d.advance();
        assert_eq!(c.now(), 0.75);
    }

    #[test]
    fn set_step_never_rewinds() {
        let c = Clock::deterministic(1.0);
        c.set_step(7);
        assert_eq!(c.step(), 7);
        c.set_step(3);
        assert_eq!(c.step(), 7);
    }
}
