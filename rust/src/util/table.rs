//! Fixed-width text table printer for the bench harnesses — every paper
//! table/figure reproduction prints through this so the reports have one
//! consistent look and can be diffed run-to-run.

use std::fmt::Write as _;

#[derive(Debug, Clone)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_display(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let total: usize = widths.iter().sum::<usize>() + 3 * ncol + 1;
        let _ = writeln!(out, "{}", self.title);
        let _ = writeln!(out, "{}", "=".repeat(total.min(100)));
        let mut line = String::from("|");
        for (h, w) in self.headers.iter().zip(&widths) {
            let _ = write!(line, " {h:<w$} |");
        }
        let _ = writeln!(out, "{line}");
        let _ = writeln!(out, "{}", "-".repeat(line.len()));
        for row in &self.rows {
            let mut line = String::from("|");
            for (c, w) in row.iter().zip(&widths) {
                let _ = write!(line, " {c:>w$} |");
            }
            let _ = writeln!(out, "{line}");
        }
        out
    }

    /// Print to stdout and append to `reports/<slug>.txt` when the reports
    /// directory exists (bench harness convention).
    pub fn emit(&self, slug: &str) {
        let rendered = self.render();
        println!("{rendered}");
        let dir = std::path::Path::new("reports");
        if dir.is_dir() {
            let path = dir.join(format!("{slug}.txt"));
            let _ = std::fs::write(path, &rendered);
        }
    }
}

/// Shorthand for formatting a float cell.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["model", "latency (ms)"]);
        t.row(&["opt-6.7b".into(), "15.6".into()]);
        t.row(&["opt-30b".into(), "27.3".into()]);
        let s = t.render();
        assert!(s.contains("| model    | latency (ms) |"));
        assert!(s.contains("| opt-6.7b |         15.6 |"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn float_helper() {
        assert_eq!(f(1.23456, 2), "1.23");
        assert_eq!(f(10.0, 3), "10.000");
    }
}
