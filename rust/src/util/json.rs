//! Minimal JSON parser + writer.
//!
//! The vendored crate set has no `serde`/`serde_json`, and the only JSON we
//! must read is the artifact manifest emitted by `python/compile/aot.py`
//! (machine-generated, well-formed), so a small recursive-descent parser is
//! the right tool.  The writer is used by the bench harnesses to dump
//! machine-readable reports next to the human-readable tables.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"][2]`-style traversal helper used all over the manifest
    /// loader: returns Null on any miss instead of panicking.
    pub fn at(&self, path: &[&str]) -> &Json {
        let mut cur = self;
        for key in path {
            cur = match cur.get(key) {
                Some(v) => v,
                None => return &Json::Null,
            };
        }
        cur
    }

    // -- construction helpers ------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}

impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}

impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}

/// Parse failure with the byte offset it occurred at.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // reassemble UTF-8 multibyte sequence
                    let len = if c >= 0xf0 { 4 } else if c >= 0xe0 { 3 } else { 2 };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("bad utf8"))?;
                    out.push_str(s);
                }
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.at(&["a"]).as_arr().unwrap().len(), 3);
        assert_eq!(v.at(&["a"]).as_arr().unwrap()[2].at(&["b"]).as_str(), Some("c"));
        assert_eq!(v.at(&["d"]), &Json::Null);
        assert_eq!(v.at(&["missing", "x"]), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"µs\"").unwrap(), Json::Str("µs".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"artifacts":[{"b":1,"name":"x","shape":[2,128,256]}],"v":1.25}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn writer_escapes() {
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(s, r#""a\"b\\c\nd""#);
    }

    #[test]
    fn integral_floats_render_as_ints() {
        assert_eq!(Json::Num(128.0).to_string(), "128");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "model": {"name": "kvpr-tiny", "hidden": 256},
          "artifacts": [
            {"name": "decode_full_b1_s128", "file": "decode_full_b1_s128.hlo.txt",
             "inputs": [{"name": "x", "shape": [1, 1, 256], "dtype": "float32"}]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["model", "hidden"]).as_usize(), Some(256));
        let a = &v.at(&["artifacts"]).as_arr().unwrap()[0];
        assert_eq!(a.at(&["inputs"]).as_arr().unwrap()[0].at(&["dtype"]).as_str(),
                   Some("float32"));
    }
}
