//! Bench-regression gate over the JSON trajectories the bench harnesses
//! emit (`BENCH_kvstore.json`): compare a fresh run against the committed
//! `BENCH_baseline.json` and fail when any policy's throughput regressed
//! beyond the allowed fraction.  CI runs this through the thin
//! `examples/bench_gate.rs` wrapper after `cargo bench --bench
//! perf_hotpath`.
//!
//! Two modes, decided by the baseline file itself:
//!
//! * **Regression mode** (the normal state): the baseline mirrors the
//!   bench output's shape.  Every object in the baseline carrying a
//!   `steps_per_s` number is located at the same path in the fresh run
//!   and must not have dropped by more than `max_drop_frac`.
//! * **Provisional mode** (`"provisional": true` in the baseline): the
//!   baseline carries no trusted numbers yet — only an `"expect"` list of
//!   dotted paths that must exist in the fresh run with a positive
//!   throughput metric.  The gate passes on structure alone and prints
//!   the refresh recipe, so the first machine to run the bench can
//!   promote its output to the real baseline.
//!
//! Orthogonally to both modes, a `"ratio_gates"` list in the baseline
//! pins **machine-independent relative claims**: each
//! `{"num": path, "den": path, "min_frac": f}` entry requires the fresh
//! run's `num` throughput to be at least `min_frac` of its `den`
//! throughput.  Both metrics come from the *same* fresh run, so the gate
//! holds on any machine — it is how the tracing-overhead claim
//! (`obs_overhead.enabled` within 5 % of `obs_overhead.disabled`) is
//! enforced even while the absolute baseline is provisional.

use super::json::Json;

/// Throughput metrics the gate compares at every pinned path: eviction
/// policies report `steps_per_s`, the planner's topology-fold section
/// reports `plans_per_s`, and the prefix-sharing admission section
/// reports `admitted_tokens_per_s`.  Higher is better for every listed
/// metric.
const METRICS: [&str; 3] = ["steps_per_s", "plans_per_s", "admitted_tokens_per_s"];

/// Default allowed fractional drop before the gate fails (10 %).
pub const DEFAULT_MAX_DROP: f64 = 0.10;

/// Outcome of one gate run.
#[derive(Debug)]
pub struct GateReport {
    /// Metric paths compared (regression mode) or structurally verified
    /// (provisional mode).
    pub checked: usize,
    /// Human-readable failures; empty means the gate passed.
    pub failures: Vec<String>,
    /// The baseline was provisional: only structure was enforced.
    pub provisional: bool,
}

impl GateReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Compare `fresh` against `baseline`, allowing `max_drop_frac` relative
/// regression on every `steps_per_s` metric the baseline pins.
pub fn compare(baseline: &Json, fresh: &Json, max_drop_frac: f64) -> GateReport {
    let provisional = matches!(baseline.get("provisional"), Some(Json::Bool(true)));
    let mut report = GateReport { checked: 0, failures: Vec::new(), provisional };
    ratio_gates(baseline, fresh, &mut report);
    if provisional {
        match baseline.get("expect").and_then(|e| e.as_arr()) {
            Some(paths) if !paths.is_empty() => {
                for p in paths {
                    let Some(path) = p.as_str() else {
                        report.failures.push("non-string entry in \"expect\"".to_string());
                        continue;
                    };
                    let parts: Vec<&str> = path.split('.').collect();
                    let node = fresh.at(&parts);
                    let ok = METRICS.iter().any(|m| {
                        node.get(m).and_then(|v| v.as_f64()).is_some_and(|v| v > 0.0)
                    });
                    if ok {
                        report.checked += 1;
                    } else {
                        report.failures.push(format!(
                            "{path}: missing or non-positive throughput metric \
                             ({}) in the fresh run",
                            METRICS.join("/")
                        ));
                    }
                }
            }
            _ => report
                .failures
                .push("provisional baseline carries no \"expect\" path list".to_string()),
        }
        return report;
    }
    walk(baseline, fresh, "", max_drop_frac, &mut report);
    if report.checked == 0 {
        report.failures.push(format!(
            "baseline pins no throughput metrics ({}) — nothing gated",
            METRICS.join("/")
        ));
    }
    report
}

/// Enforce the baseline's `ratio_gates` against the fresh run alone (both
/// metrics from the same machine, so no trusted absolute numbers needed).
fn ratio_gates(baseline: &Json, fresh: &Json, report: &mut GateReport) {
    let Some(gates) = baseline.get("ratio_gates").and_then(|g| g.as_arr()) else {
        return;
    };
    let lookup = |path: &str| {
        let parts: Vec<&str> = path.split('.').collect();
        let node = fresh.at(&parts);
        METRICS
            .iter()
            .find_map(|m| node.get(m).and_then(|v| v.as_f64()).filter(|v| *v > 0.0))
    };
    for g in gates {
        let (Some(num), Some(den), Some(min_frac)) = (
            g.get("num").and_then(|v| v.as_str()),
            g.get("den").and_then(|v| v.as_str()),
            g.get("min_frac").and_then(|v| v.as_f64()),
        ) else {
            report
                .failures
                .push("malformed ratio_gates entry (need num/den/min_frac)".to_string());
            continue;
        };
        match (lookup(num), lookup(den)) {
            (Some(n), Some(d)) => {
                report.checked += 1;
                let frac = n / d;
                if frac + 1e-12 < min_frac {
                    report.failures.push(format!(
                        "ratio {num}/{den} = {frac:.4} fell below the {min_frac:.2} floor"
                    ));
                }
            }
            _ => report.failures.push(format!(
                "ratio gate {num}/{den}: missing or non-positive throughput in the fresh run"
            )),
        }
    }
}

fn walk(base: &Json, fresh: &Json, path: &str, max_drop: f64, report: &mut GateReport) {
    let Json::Obj(map) = base else { return };
    for metric in METRICS {
        if let Some(bv) = map.get(metric).and_then(|v| v.as_f64()) {
            report.checked += 1;
            match fresh.get(metric).and_then(|v| v.as_f64()) {
                Some(fv) if fv + 1e-12 >= bv * (1.0 - max_drop) => {}
                Some(fv) => report.failures.push(format!(
                    "{path}: {metric} regressed {bv:.3} → {fv:.3} (allowed drop {:.0}%)",
                    max_drop * 100.0
                )),
                None => report
                    .failures
                    .push(format!("{path}: {metric} missing from the fresh run")),
            }
        }
    }
    for (k, v) in map {
        if matches!(v, Json::Obj(_)) {
            let child = fresh.get(k).unwrap_or(&Json::Null);
            let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
            walk(v, child, &p, max_drop, report);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn j(text: &str) -> Json {
        Json::parse(text).expect("test json")
    }

    #[test]
    fn equal_runs_pass() {
        let b = j(r#"{"policies": {"lru": {"steps_per_s": 100.0, "evictions": 3}}}"#);
        let r = compare(&b, &b.clone(), DEFAULT_MAX_DROP);
        assert!(r.passed());
        assert_eq!(r.checked, 1);
        assert!(!r.provisional);
    }

    #[test]
    fn small_drop_passes_large_drop_fails() {
        let b = j(r#"{"policies": {"lru": {"steps_per_s": 100.0}}}"#);
        let ok = j(r#"{"policies": {"lru": {"steps_per_s": 91.0}}}"#);
        assert!(compare(&b, &ok, 0.10).passed());
        let bad = j(r#"{"policies": {"lru": {"steps_per_s": 89.0}}}"#);
        let r = compare(&b, &bad, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("policies.lru"), "{}", r.failures[0]);
    }

    #[test]
    fn improvements_always_pass() {
        let b = j(r#"{"a": {"steps_per_s": 50.0}, "b": {"steps_per_s": 70.0}}"#);
        let f = j(r#"{"a": {"steps_per_s": 500.0}, "b": {"steps_per_s": 70.0}}"#);
        let r = compare(&b, &f, 0.10);
        assert!(r.passed());
        assert_eq!(r.checked, 2);
    }

    #[test]
    fn missing_policy_in_fresh_run_fails() {
        let b = j(r#"{"four_tier": {"lru": {"steps_per_s": 10.0}}}"#);
        let f = j(r#"{"four_tier": {}}"#);
        let r = compare(&b, &f, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("missing"));
    }

    #[test]
    fn nested_sections_are_all_gated() {
        let b = j(
            r#"{"policies": {"lru": {"steps_per_s": 10.0}},
                "tiered": {"ra": {"steps_per_s": 20.0}},
                "four_tier": {"ra": {"steps_per_s": 30.0}}}"#,
        );
        let f = j(
            r#"{"policies": {"lru": {"steps_per_s": 10.0}},
                "tiered": {"ra": {"steps_per_s": 20.0}},
                "four_tier": {"ra": {"steps_per_s": 1.0}}}"#,
        );
        let r = compare(&b, &f, 0.10);
        assert_eq!(r.checked, 3);
        assert_eq!(r.failures.len(), 1);
        assert!(r.failures[0].contains("four_tier.ra"));
    }

    #[test]
    fn empty_baseline_is_a_failure_not_a_silent_pass() {
        let b = j(r#"{"bench": "kvstore"}"#);
        let r = compare(&b, &b.clone(), 0.10);
        assert!(!r.passed(), "a baseline pinning nothing must not pass silently");
    }

    #[test]
    fn provisional_baseline_checks_structure_only() {
        let b = j(
            r#"{"provisional": true,
                "expect": ["policies.lru", "four_tier.recompute_aware"]}"#,
        );
        let good = j(
            r#"{"policies": {"lru": {"steps_per_s": 12.5}},
                "four_tier": {"recompute_aware": {"steps_per_s": 40.0}}}"#,
        );
        let r = compare(&b, &good, 0.10);
        assert!(r.passed());
        assert!(r.provisional);
        assert_eq!(r.checked, 2);
        // a fresh run missing an expected section still fails the gate
        let bad = j(r#"{"policies": {"lru": {"steps_per_s": 12.5}}}"#);
        let r = compare(&b, &bad, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("four_tier.recompute_aware"));
    }

    #[test]
    fn provisional_without_expectations_fails() {
        let b = j(r#"{"provisional": true}"#);
        let r = compare(&b, &j("{}"), 0.10);
        assert!(!r.passed());
    }

    #[test]
    fn plans_per_s_is_gated_like_steps_per_s() {
        // the planner's topology_plan section reports plans_per_s; the
        // gate must regress-check it with the same rule
        let b = j(r#"{"topology_plan": {"four_tier": {"plans_per_s": 1000.0}}}"#);
        let ok = j(r#"{"topology_plan": {"four_tier": {"plans_per_s": 950.0}}}"#);
        let r = compare(&b, &ok, 0.10);
        assert!(r.passed());
        assert_eq!(r.checked, 1);
        let bad = j(r#"{"topology_plan": {"four_tier": {"plans_per_s": 500.0}}}"#);
        let r = compare(&b, &bad, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("plans_per_s"), "{}", r.failures[0]);
        // provisional expect entries accept either throughput metric
        let prov = j(r#"{"provisional": true, "expect": ["topology_plan.four_tier"]}"#);
        assert!(compare(&prov, &ok, 0.10).passed());
        assert!(!compare(&prov, &j("{}"), 0.10).passed());
    }

    #[test]
    fn admitted_tokens_per_s_is_gated_like_steps_per_s() {
        // the prefix-sharing admission section reports admitted_tokens_per_s;
        // both the absolute pin and the shared/unshared ratio gate ride it
        let b = j(
            r#"{"prefix_share": {"unshared": {"admitted_tokens_per_s": 1000.0}},
                "ratio_gates": [{"num": "prefix_share.shared",
                                 "den": "prefix_share.unshared",
                                 "min_frac": 1.0}]}"#,
        );
        let ok = j(
            r#"{"prefix_share": {"unshared": {"admitted_tokens_per_s": 990.0},
                                 "shared": {"admitted_tokens_per_s": 2500.0}}}"#,
        );
        let r = compare(&b, &ok, 0.10);
        assert!(r.passed(), "{:?}", r.failures);
        assert_eq!(r.checked, 2, "one absolute pin + one ratio gate");
        let slow = j(
            r#"{"prefix_share": {"unshared": {"admitted_tokens_per_s": 990.0},
                                 "shared": {"admitted_tokens_per_s": 900.0}}}"#,
        );
        let r = compare(&b, &slow, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("prefix_share.shared"), "{}", r.failures[0]);
    }

    #[test]
    fn ratio_gate_holds_fresh_run_to_the_floor() {
        let b = j(
            r#"{"obs_overhead": {"disabled": {"steps_per_s": 100.0}},
                "ratio_gates": [{"num": "obs_overhead.enabled",
                                 "den": "obs_overhead.disabled",
                                 "min_frac": 0.95}]}"#,
        );
        let ok = j(
            r#"{"obs_overhead": {"disabled": {"steps_per_s": 100.0},
                                 "enabled": {"steps_per_s": 96.0}}}"#,
        );
        let r = compare(&b, &ok, 0.10);
        assert!(r.passed(), "{:?}", r.failures);
        assert_eq!(r.checked, 2, "one absolute pin + one ratio gate");
        let slow = j(
            r#"{"obs_overhead": {"disabled": {"steps_per_s": 100.0},
                                 "enabled": {"steps_per_s": 80.0}}}"#,
        );
        let r = compare(&b, &slow, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("obs_overhead.enabled"), "{}", r.failures[0]);
    }

    #[test]
    fn ratio_gate_applies_in_provisional_mode_too() {
        // the overhead claim is machine-independent, so it must bite even
        // while the absolute baseline is still provisional
        let b = j(
            r#"{"provisional": true,
                "expect": ["obs_overhead.disabled"],
                "ratio_gates": [{"num": "obs_overhead.enabled",
                                 "den": "obs_overhead.disabled",
                                 "min_frac": 0.95}]}"#,
        );
        let ok = j(
            r#"{"obs_overhead": {"disabled": {"steps_per_s": 50.0},
                                 "enabled": {"steps_per_s": 49.0}}}"#,
        );
        let r = compare(&b, &ok, 0.10);
        assert!(r.passed(), "{:?}", r.failures);
        assert_eq!(r.checked, 2);
        let slow = j(
            r#"{"obs_overhead": {"disabled": {"steps_per_s": 50.0},
                                 "enabled": {"steps_per_s": 40.0}}}"#,
        );
        assert!(!compare(&b, &slow, 0.10).passed());
    }

    #[test]
    fn ratio_gate_fails_on_missing_or_malformed_inputs() {
        let b = j(
            r#"{"provisional": true, "expect": ["a"],
                "ratio_gates": [{"num": "a", "den": "missing", "min_frac": 0.9}]}"#,
        );
        let f = j(r#"{"a": {"steps_per_s": 10.0}}"#);
        let r = compare(&b, &f, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("missing"), "{}", r.failures[0]);
        let malformed = j(
            r#"{"provisional": true, "expect": ["a"],
                "ratio_gates": [{"num": "a"}]}"#,
        );
        let r = compare(&malformed, &f, 0.10);
        assert!(!r.passed());
        assert!(r.failures[0].contains("malformed"), "{}", r.failures[0]);
    }
}
