// quick smoke: generate 6 tokens with Kvpr vs FullTransferOverlap, compare tokens
use kvpr::engine::{Engine, EngineConfig, EnginePolicy};
use kvpr::transfer::LinkConfig;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts");
    let mk = |p| {
        let mut c = EngineConfig::new(p);
        c.link = LinkConfig { bytes_per_sec: 30e6, latency_s: 100e-6, chunk_bytes: 16 << 10 };
        c
    };
    let prompts: Vec<Vec<i32>> = vec![
        kvpr::model::ByteTokenizer::new().encode("the quick brown fox", 32),
        kvpr::model::ByteTokenizer::new().encode("kv cache partial recomputation", 32),
    ];
    let t0 = std::time::Instant::now();
    let e1 = Engine::new(dir, mk(EnginePolicy::Kvpr))?;
    println!("engine init {:.2}s, profile {:?}", t0.elapsed().as_secs_f64(), e1.profile());
    let t0 = std::time::Instant::now();
    let r1 = e1.generate(&prompts, 8)?;
    println!("kvpr gen {:.2}s decode {:.3}s splits {:?}", t0.elapsed().as_secs_f64(), r1.metrics.decode_s, r1.metrics.splits);
    let e2 = Engine::new(dir, mk(EnginePolicy::FullTransferOverlap))?;
    let r2 = e2.generate(&prompts, 8)?;
    println!("full decode {:.3}s", r2.metrics.decode_s);
    assert_eq!(r1.tokens, r2.tokens, "tokens must be identical");
    println!("tokens identical: {:?}", r1.tokens[0]);
    println!("breakdown kvpr {:?}", r1.metrics.breakdown);
    Ok(())
}
