//! Deterministic trace-driven workload generation (the serving harness's
//! load side).
//!
//! Every e2e before this module drove the serving loop with hand-rolled
//! uniform request lists; the offloading-bottleneck analysis in PAPERS.md
//! shows the CPU–GPU transfer regime flips with arrival burstiness and
//! context-length tails, so watermarks, cooldowns and spill floors tuned
//! against uniform load are tuned against the wrong regime.  A
//! [`WorkloadSpec`] declares a mix — an arrival process
//! ([`Arrival`]: uniform / bursty / diurnal), traffic classes with
//! heavy-tailed context lengths ([`LenDist::HeavyTail`], a bounded
//! Pareto) and chat think-time gaps — and [`WorkloadSpec::generate`]
//! lowers it with a seeded [`Prng`] into a [`Trace`]: a flat,
//! step-indexed request list.
//!
//! The same trace drives both sides of the validation story:
//!
//! * **served** — [`Submit::dispatch`](crate::coordinator::Submit::dispatch)
//!   replays it against the real engine (admission honours each request's
//!   arrival step), and [`ServeMetrics`](crate::coordinator::ServeMetrics)
//!   reports TTFT/TPOT percentiles and attainment against the spec's
//!   [`SloTargets`];
//! * **analytic** — [`EvictionSimConfig::from_trace`](crate::kvstore::EvictionSimConfig::from_trace)
//!   replays it through the closed-form eviction/spill model, and a tier-1
//!   e2e asserts the two agree on step counts, concurrency and KV traffic.
//!
//! Generation is bit-deterministic: the same spec + seed yields a
//! byte-identical serialized trace (the JSON writer's `BTreeMap` key order
//! does the rest), and traces round-trip losslessly through
//! [`Trace::to_json`] / [`Trace::from_json`].
//!
//! ```
//! use kvpr::workload::{Arrival, LenDist, SloTargets, Trace, TrafficClass, WorkloadSpec};
//!
//! // a small bursty chat mix: pairs of arrivals, then a 3-step lull
//! let spec = WorkloadSpec {
//!     name: "doc_bursty".into(),
//!     seed: 7,
//!     requests: 6,
//!     arrivals: Arrival::Bursty { burst: 2, gap: 3 },
//!     classes: vec![TrafficClass {
//!         name: "chat".into(),
//!         weight: 1.0,
//!         prompt: LenDist::HeavyTail { floor: 16, alpha: 1.5, cap: 64 },
//!         gen: LenDist::Uniform { lo: 4, hi: 8 },
//!         think: LenDist::Fixed { steps: 0 },
//!         shared_prefix: 0,
//!     }],
//!     slo: SloTargets::default(),
//! };
//! let trace = spec.generate();
//! assert_eq!(trace.requests.len(), 6);
//! assert!(trace.requests.windows(2).all(|w| w[0].step <= w[1].step));
//! // byte-identical regeneration + lossless JSON round-trip
//! assert_eq!(spec.generate().to_json().to_string(), trace.to_json().to_string());
//! let back = Trace::from_json_str(&trace.to_json().to_string()).unwrap();
//! assert_eq!(back, trace);
//! ```

use crate::util::json::Json;
use crate::util::prng::Prng;

/// Arrival process of a workload mix, in event-loop **steps** (the serving
/// loop's decode-step clock, not wall time — the analytic sim shares the
/// same clock, which is what makes sim-vs-served agreement assertable).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// One request every `every` steps.
    Uniform { every: usize },
    /// `burst` back-to-back arrivals, then `gap` idle steps.
    Bursty { burst: usize, gap: usize },
    /// Sinusoidal rate modulation over a `period`-step "day": the
    /// inter-arrival gap swings from `min_gap` at the peak to `max_gap`
    /// in the trough.
    Diurnal { period: usize, min_gap: usize, max_gap: usize },
}

/// Token-length (or think-step) distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LenDist {
    /// Always `steps` (named for the think-time use; it is a token count
    /// in the prompt/gen positions).
    Fixed { steps: usize },
    /// Uniform over the inclusive range `[lo, hi]`.
    Uniform { lo: usize, hi: usize },
    /// Bounded Pareto: floor / (1 − u)^(1/alpha), capped at `cap` — the
    /// heavy-tailed context-length shape of production chat/RAG traffic.
    HeavyTail { floor: usize, alpha: f64, cap: usize },
}

impl LenDist {
    fn sample(&self, rng: &mut Prng) -> usize {
        match *self {
            LenDist::Fixed { steps } => steps,
            LenDist::Uniform { lo, hi } => {
                if hi <= lo {
                    lo
                } else {
                    lo + rng.index(hi - lo + 1)
                }
            }
            LenDist::HeavyTail { floor, alpha, cap } => {
                let u = rng.next_f64();
                let x = floor.max(1) as f64 / (1.0 - u).powf(1.0 / alpha.max(1e-9));
                (x as usize).clamp(floor, cap.max(floor))
            }
        }
    }
}

/// One component of a mix: a weighted traffic class with its own length
/// distributions and a chat think-time gap (extra idle steps the user
/// "types" before the next arrival).
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficClass {
    pub name: String,
    /// Relative sampling weight within the mix (need not sum to 1).
    pub weight: f64,
    /// Prompt (context) length in tokens.
    pub prompt: LenDist,
    /// Generation length in tokens.
    pub gen: LenDist,
    /// Think-time steps appended to the arrival cursor after a request of
    /// this class.
    pub think: LenDist,
    /// Tokens of a class-wide shared preamble (system prompt / retrieval
    /// template) at the head of every prompt this class samples — the
    /// content cross-request prefix sharing deduplicates.  0 means fully
    /// private prompts.  Clamped per request to its sampled prompt length.
    pub shared_prefix: usize,
}

/// Per-mix service-level objectives the SLO table is scored against.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    /// Time-to-first-token target, seconds.
    pub ttft_s: f64,
    /// Time-per-output-token target, seconds.
    pub tpot_s: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets { ttft_s: 0.5, tpot_s: 0.1 }
    }
}

/// Declarative workload mix: arrival process + traffic classes + SLOs.
/// [`generate`](WorkloadSpec::generate) lowers it deterministically into a
/// [`Trace`].
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    pub name: String,
    pub seed: u64,
    /// Requests in the trace.
    pub requests: usize,
    pub arrivals: Arrival,
    pub classes: Vec<TrafficClass>,
    pub slo: SloTargets,
}

impl WorkloadSpec {
    /// Chat traffic arriving in bursts (the "everyone hits enter at once"
    /// shape), with a small long-context RAG admixture.
    pub fn bursty_chat() -> Self {
        WorkloadSpec {
            name: "bursty_chat".into(),
            seed: 0xb0c1,
            requests: 32,
            arrivals: Arrival::Bursty { burst: 4, gap: 6 },
            classes: vec![
                TrafficClass {
                    name: "chat".into(),
                    weight: 0.85,
                    prompt: LenDist::HeavyTail { floor: 24, alpha: 1.5, cap: 96 },
                    gen: LenDist::Uniform { lo: 4, hi: 16 },
                    think: LenDist::Uniform { lo: 0, hi: 2 },
                    shared_prefix: 0,
                },
                TrafficClass {
                    name: "rag".into(),
                    weight: 0.15,
                    prompt: LenDist::HeavyTail { floor: 64, alpha: 1.1, cap: 120 },
                    gen: LenDist::Uniform { lo: 2, hi: 8 },
                    think: LenDist::Fixed { steps: 0 },
                    shared_prefix: 0,
                },
            ],
            slo: SloTargets { ttft_s: 0.5, tpot_s: 0.1 },
        }
    }

    /// Mixed chat/RAG traffic under a sinusoidal "day": dense arrivals at
    /// the peak, long lulls in the trough.
    pub fn diurnal_mixed() -> Self {
        WorkloadSpec {
            name: "diurnal_mixed".into(),
            seed: 0xd1c2,
            requests: 32,
            arrivals: Arrival::Diurnal { period: 64, min_gap: 1, max_gap: 8 },
            classes: vec![
                TrafficClass {
                    name: "chat".into(),
                    weight: 0.7,
                    prompt: LenDist::HeavyTail { floor: 24, alpha: 1.4, cap: 96 },
                    gen: LenDist::Uniform { lo: 4, hi: 12 },
                    think: LenDist::Uniform { lo: 0, hi: 3 },
                    shared_prefix: 0,
                },
                TrafficClass {
                    name: "rag".into(),
                    weight: 0.3,
                    prompt: LenDist::HeavyTail { floor: 48, alpha: 1.2, cap: 120 },
                    gen: LenDist::Uniform { lo: 2, hi: 8 },
                    think: LenDist::Fixed { steps: 0 },
                    shared_prefix: 0,
                },
            ],
            slo: SloTargets { ttft_s: 0.8, tpot_s: 0.1 },
        }
    }

    /// Long-context retrieval traffic: steady arrivals, fat prompt tail,
    /// short generations — the KV-capacity stressor.
    pub fn rag_long_context() -> Self {
        WorkloadSpec {
            name: "rag_long_context".into(),
            seed: 0x4a63,
            requests: 24,
            arrivals: Arrival::Uniform { every: 2 },
            classes: vec![TrafficClass {
                name: "rag".into(),
                weight: 1.0,
                prompt: LenDist::HeavyTail { floor: 64, alpha: 1.05, cap: 480 },
                gen: LenDist::Uniform { lo: 2, hi: 6 },
                think: LenDist::Fixed { steps: 0 },
                shared_prefix: 0,
            }],
            slo: SloTargets { ttft_s: 1.0, tpot_s: 0.15 },
        }
    }

    /// Multi-turn assistant traffic over a handful of shared system
    /// prompts: most requests open with the same class-wide preamble, so
    /// cross-request prefix sharing can adopt the head blocks in place.
    /// The `private` admixture never shares — it pins the hit-rate
    /// frontier's floor.
    pub fn shared_chat() -> Self {
        WorkloadSpec {
            name: "shared_chat".into(),
            seed: 0x5a7e,
            requests: 32,
            arrivals: Arrival::Bursty { burst: 4, gap: 5 },
            classes: vec![
                TrafficClass {
                    name: "assistant".into(),
                    weight: 0.8,
                    prompt: LenDist::HeavyTail { floor: 48, alpha: 1.4, cap: 120 },
                    gen: LenDist::Uniform { lo: 4, hi: 12 },
                    think: LenDist::Uniform { lo: 0, hi: 1 },
                    shared_prefix: 64,
                },
                TrafficClass {
                    name: "private".into(),
                    weight: 0.2,
                    prompt: LenDist::HeavyTail { floor: 24, alpha: 1.5, cap: 96 },
                    gen: LenDist::Uniform { lo: 2, hi: 8 },
                    think: LenDist::Fixed { steps: 0 },
                    shared_prefix: 0,
                },
            ],
            slo: SloTargets { ttft_s: 0.5, tpot_s: 0.1 },
        }
    }

    /// The named mixes the bench and example binaries iterate over.
    pub fn mix_names() -> &'static [&'static str] {
        &["bursty_chat", "diurnal_mixed", "rag_long_context", "shared_chat"]
    }

    /// Look up a named mix ([`mix_names`](WorkloadSpec::mix_names)).
    pub fn named(name: &str) -> Option<Self> {
        match name {
            "bursty_chat" => Some(Self::bursty_chat()),
            "diurnal_mixed" => Some(Self::diurnal_mixed()),
            "rag_long_context" => Some(Self::rag_long_context()),
            "shared_chat" => Some(Self::shared_chat()),
            _ => None,
        }
    }

    /// Lower the spec into a concrete trace.  Deterministic: the same spec
    /// (same seed included) always produces the same trace, byte for byte
    /// once serialized.
    pub fn generate(&self) -> Trace {
        assert!(!self.classes.is_empty(), "a workload mix needs at least one class");
        let total_w: f64 = self.classes.iter().map(|c| c.weight.max(0.0)).sum();
        assert!(total_w > 0.0, "class weights must not all be zero");
        let mut rng = Prng::new(self.seed);
        let mut step = 0usize;
        let mut burst_pos = 0usize;
        let mut requests = Vec::with_capacity(self.requests);
        for id in 0..self.requests {
            // weighted class pick
            let mut x = rng.next_f64() * total_w;
            let mut ci = self.classes.len() - 1;
            for (i, c) in self.classes.iter().enumerate() {
                x -= c.weight.max(0.0);
                if x < 0.0 {
                    ci = i;
                    break;
                }
            }
            let c = &self.classes[ci];
            let prompt_tokens = c.prompt.sample(&mut rng).max(1);
            requests.push(TraceRequest {
                id: id as u64,
                step,
                class: c.name.clone(),
                prompt_tokens,
                gen_tokens: c.gen.sample(&mut rng).max(1),
                shared_prefix_tokens: c.shared_prefix.min(prompt_tokens),
            });
            // advance the arrival cursor for the next request
            let gap = match self.arrivals {
                Arrival::Uniform { every } => every,
                Arrival::Bursty { burst, gap } => {
                    burst_pos += 1;
                    if burst_pos >= burst.max(1) {
                        burst_pos = 0;
                        gap
                    } else {
                        0
                    }
                }
                Arrival::Diurnal { period, min_gap, max_gap } => {
                    let p = period.max(1) as f64;
                    let phase = (step % period.max(1)) as f64 / p;
                    // load peaks mid-period: 0 in the trough, 1 at the peak
                    let load = 0.5 - 0.5 * (2.0 * std::f64::consts::PI * phase).cos();
                    let (lo, hi) = (min_gap as f64, max_gap.max(min_gap) as f64);
                    (hi - (hi - lo) * load).round() as usize
                }
            };
            step += gap + c.think.sample(&mut rng);
        }
        Trace { name: self.name.clone(), seed: self.seed, requests }
    }
}

/// One request of a trace: a step-indexed arrival with sampled lengths.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival step (the serving loop's decode-step clock).
    pub step: usize,
    /// Name of the traffic class that sampled this request.
    pub class: String,
    pub prompt_tokens: usize,
    pub gen_tokens: usize,
    /// Leading prompt tokens drawn from the class-wide shared preamble
    /// ([`TrafficClass::shared_prefix`], clamped to the sampled length);
    /// the rest of the prompt mixes the id in and stays private.
    pub shared_prefix_tokens: usize,
}

impl TraceRequest {
    /// Deterministic synthetic prompt of exactly `prompt_tokens` bytes
    /// (the serving tokenizer is byte-level, so bytes are tokens).  The
    /// first [`shared_prefix_tokens`](Self::shared_prefix_tokens) bytes
    /// cycle a class-deterministic preamble — byte-identical across every
    /// request of the class, the content prefix sharing content-hashes —
    /// and the remainder mixes the id in so lanes diverge past it.
    pub fn prompt_text(&self) -> String {
        let total = self.prompt_tokens.max(1);
        let shared = self.shared_prefix_tokens.min(total);
        let preamble = format!("sys[{}] shared retrieval preamble ", self.class);
        let seedling = format!("req{} kv partial recompute trace ", self.id);
        preamble
            .bytes()
            .cycle()
            .take(shared)
            .chain(seedling.bytes().cycle().take(total - shared))
            .map(|b| b as char)
            .collect()
    }
}

/// A generated trace: the flat, serializable request list both the serving
/// loop and the analytic sim replay.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub name: String,
    pub seed: u64,
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    /// Last arrival step in the trace (0 for an empty trace).
    pub fn max_step(&self) -> usize {
        self.requests.iter().map(|r| r.step).max().unwrap_or(0)
    }

    /// Total generation budget across requests, in tokens — equal to the
    /// decode-step count a lossless replay must take (one token per
    /// request per step).
    pub fn total_gen_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.gen_tokens as u64).sum()
    }

    /// Serialize to the JSON trace format.  Key order is `BTreeMap`-fixed,
    /// so equal traces serialize byte-identically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("seed", Json::Num(self.seed as f64)),
            (
                "requests",
                Json::Arr(
                    self.requests
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("id", Json::Num(r.id as f64)),
                                ("step", Json::from(r.step)),
                                ("class", Json::from(r.class.as_str())),
                                ("prompt", Json::from(r.prompt_tokens)),
                                ("gen", Json::from(r.gen_tokens)),
                                ("shared", Json::from(r.shared_prefix_tokens)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Parse the JSON trace format back into a trace (lossless inverse of
    /// [`to_json`](Trace::to_json)).
    pub fn from_json(v: &Json) -> Result<Trace, String> {
        let name = v
            .at(&["name"])
            .as_str()
            .ok_or("trace: missing string field 'name'")?
            .to_string();
        let seed = v.at(&["seed"]).as_f64().ok_or("trace: missing numeric field 'seed'")? as u64;
        let reqs = v.at(&["requests"]).as_arr().ok_or("trace: missing array field 'requests'")?;
        let mut requests = Vec::with_capacity(reqs.len());
        for (i, r) in reqs.iter().enumerate() {
            let field = |k: &str| {
                r.at(&[k])
                    .as_f64()
                    .ok_or_else(|| format!("trace request {i}: missing numeric field '{k}'"))
            };
            requests.push(TraceRequest {
                id: field("id")? as u64,
                step: field("step")? as usize,
                class: r
                    .at(&["class"])
                    .as_str()
                    .ok_or_else(|| format!("trace request {i}: missing string field 'class'"))?
                    .to_string(),
                prompt_tokens: field("prompt")? as usize,
                gen_tokens: field("gen")? as usize,
                // absent in pre-sharing traces — decode as fully private
                shared_prefix_tokens: r
                    .at(&["shared"])
                    .as_f64()
                    .map_or(0, |v| v as usize),
            });
        }
        Ok(Trace { name, seed, requests })
    }

    /// [`from_json`](Trace::from_json) over raw text.
    pub fn from_json_str(text: &str) -> Result<Trace, String> {
        let v = Json::parse(text).map_err(|e| e.to_string())?;
        Self::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> WorkloadSpec {
        WorkloadSpec {
            name: "tiny".into(),
            seed: 11,
            requests: 8,
            arrivals: Arrival::Bursty { burst: 2, gap: 4 },
            classes: vec![
                TrafficClass {
                    name: "chat".into(),
                    weight: 0.75,
                    prompt: LenDist::HeavyTail { floor: 8, alpha: 1.3, cap: 64 },
                    gen: LenDist::Uniform { lo: 2, hi: 6 },
                    think: LenDist::Uniform { lo: 0, hi: 1 },
                    shared_prefix: 0,
                },
                TrafficClass {
                    name: "rag".into(),
                    weight: 0.25,
                    prompt: LenDist::Fixed { steps: 48 },
                    gen: LenDist::Fixed { steps: 3 },
                    think: LenDist::Fixed { steps: 0 },
                    shared_prefix: 0,
                },
            ],
            slo: SloTargets::default(),
        }
    }

    #[test]
    fn same_spec_and_seed_is_byte_identical() {
        // satellite: determinism down to the serialized bytes
        let a = tiny_spec().generate().to_json().to_string();
        let b = tiny_spec().generate().to_json().to_string();
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn different_seed_changes_the_trace() {
        let mut other = tiny_spec();
        other.seed = 12;
        assert_ne!(
            tiny_spec().generate().to_json().to_string(),
            other.generate().to_json().to_string()
        );
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let trace = tiny_spec().generate();
        let text = trace.to_json().to_string();
        let back = Trace::from_json_str(&text).unwrap();
        assert_eq!(back, trace);
        // and re-serialization is stable (BTreeMap key order)
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn from_json_reports_missing_fields() {
        assert!(Trace::from_json_str("{}").is_err());
        assert!(Trace::from_json_str(r#"{"name":"x","seed":1}"#).is_err());
        let bad_req = r#"{"name":"x","seed":1,"requests":[{"id":0}]}"#;
        let err = Trace::from_json_str(bad_req).unwrap_err();
        assert!(err.contains("request 0"), "{err}");
    }

    #[test]
    fn arrival_steps_are_monotone_and_lengths_positive() {
        for name in WorkloadSpec::mix_names() {
            let trace = WorkloadSpec::named(name).unwrap().generate();
            assert_eq!(trace.requests.len(), WorkloadSpec::named(name).unwrap().requests);
            assert!(trace.requests.windows(2).all(|w| w[0].step <= w[1].step), "{name}");
            assert!(trace.requests.iter().all(|r| r.prompt_tokens >= 1 && r.gen_tokens >= 1));
            assert_eq!(trace.total_gen_tokens(), trace.requests.iter().map(|r| r.gen_tokens as u64).sum::<u64>());
        }
    }

    #[test]
    fn bursty_arrivals_come_in_bursts() {
        let mut spec = tiny_spec();
        spec.classes.truncate(1);
        spec.classes[0].think = LenDist::Fixed { steps: 0 };
        spec.arrivals = Arrival::Bursty { burst: 2, gap: 5 };
        let t = spec.generate();
        // pairs share a step, then a 5-step gap
        assert_eq!(t.requests[0].step, t.requests[1].step);
        assert_eq!(t.requests[2].step, t.requests[1].step + 5);
        assert_eq!(t.requests[2].step, t.requests[3].step);
    }

    #[test]
    fn diurnal_gaps_swing_between_the_bounds() {
        let spec = WorkloadSpec {
            arrivals: Arrival::Diurnal { period: 16, min_gap: 1, max_gap: 9 },
            classes: vec![TrafficClass {
                name: "c".into(),
                weight: 1.0,
                prompt: LenDist::Fixed { steps: 8 },
                gen: LenDist::Fixed { steps: 2 },
                think: LenDist::Fixed { steps: 0 },
                shared_prefix: 0,
            }],
            requests: 24,
            name: "d".into(),
            seed: 3,
            slo: SloTargets::default(),
        };
        let t = spec.generate();
        let gaps: Vec<usize> =
            t.requests.windows(2).map(|w| w[1].step - w[0].step).collect();
        assert!(gaps.iter().all(|&g| (1..=9).contains(&g)), "{gaps:?}");
        assert!(gaps.iter().any(|&g| g <= 2), "peak gaps present: {gaps:?}");
        assert!(gaps.iter().any(|&g| g >= 8), "trough gaps present: {gaps:?}");
    }

    #[test]
    fn heavy_tail_respects_floor_and_cap() {
        let d = LenDist::HeavyTail { floor: 16, alpha: 1.1, cap: 128 };
        let mut rng = Prng::new(5);
        let xs: Vec<usize> = (0..2000).map(|_| d.sample(&mut rng)).collect();
        assert!(xs.iter().all(|&x| (16..=128).contains(&x)));
        // heavy tail: the cap is actually hit, and the median hugs the floor
        assert!(xs.iter().any(|&x| x == 128));
        let mut sorted = xs.clone();
        sorted.sort();
        assert!(sorted[xs.len() / 2] < 48, "median {}", sorted[xs.len() / 2]);
    }

    #[test]
    fn prompt_text_is_exact_length_and_deterministic() {
        let r = TraceRequest {
            id: 3,
            step: 0,
            class: "chat".into(),
            prompt_tokens: 37,
            gen_tokens: 4,
            shared_prefix_tokens: 0,
        };
        assert_eq!(r.prompt_text().len(), 37);
        assert_eq!(r.prompt_text(), r.prompt_text());
        let other = TraceRequest { id: 4, ..r.clone() };
        assert_ne!(other.prompt_text(), r.prompt_text());
    }

    #[test]
    fn shared_prefix_prompts_share_exactly_the_preamble() {
        let mk = |id: u64, total: usize, shared: usize| TraceRequest {
            id,
            step: 0,
            class: "assistant".into(),
            prompt_tokens: total,
            gen_tokens: 2,
            shared_prefix_tokens: shared,
        };
        let a = mk(1, 96, 64).prompt_text();
        let b = mk(2, 96, 64).prompt_text();
        // byte-identical through the preamble, divergent right after it
        assert_eq!(a.as_bytes()[..64], b.as_bytes()[..64]);
        assert_ne!(a.as_bytes()[64], b.as_bytes()[64]);
        // a different class cycles a different preamble
        let mut c = mk(3, 96, 64);
        c.class = "other".into();
        assert_ne!(c.prompt_text().as_bytes()[..64], a.as_bytes()[..64]);
        // shared clamps to the prompt: an all-shared prompt is pure preamble
        let d = mk(4, 32, 64).prompt_text();
        assert_eq!(d.as_bytes(), &a.as_bytes()[..32]);
    }

    #[test]
    fn shared_chat_mix_generates_and_round_trips_shared_tokens() {
        let spec = WorkloadSpec::shared_chat();
        let t = spec.generate();
        assert_eq!(t.requests.len(), spec.requests);
        // the assistant class actually shares; the private class never does
        assert!(t
            .requests
            .iter()
            .any(|r| r.class == "assistant" && r.shared_prefix_tokens > 0));
        assert!(t
            .requests
            .iter()
            .all(|r| r.class != "private" || r.shared_prefix_tokens == 0));
        assert!(t.requests.iter().all(|r| r.shared_prefix_tokens <= r.prompt_tokens));
        // shared tokens survive the JSON round trip…
        let back = Trace::from_json_str(&t.to_json().to_string()).unwrap();
        assert_eq!(back, t);
        // …and a pre-sharing trace (no "shared" key) decodes as private
        let legacy = r#"{"name":"x","seed":1,"requests":[
            {"id":0,"step":0,"class":"chat","prompt":8,"gen":2}]}"#;
        let old = Trace::from_json_str(legacy).unwrap();
        assert_eq!(old.requests[0].shared_prefix_tokens, 0);
    }
}
