//! Paper reproduction suite: one function per table/figure of the
//! evaluation (DESIGN.md §6 maps each to its bench target).
//!
//! Every function returns a [`Table`] whose rows mirror the paper's
//! rows/series; the bench binaries (`rust/benches/*`) print them and write
//! `reports/*.txt`.  Absolute numbers come from the timeline simulator at
//! the paper's hardware scale — the *shape* (who wins, by roughly what
//! factor, where crossovers fall) is the reproduction target.

use crate::config::{HardwareConfig, ModelConfig, WorkloadConfig};
use crate::scheduler::{CostModel, Planner, SchedulePolicy};
use crate::sim::{simulate_decode, Policy, RunConfig, Sim, StepCtx, TaskKind};
use crate::util::table::{f, Table};

/// Paper Table 1: KV-cache size, PCIe latency and KV computation latency
/// (FP16, batch 32, sequence 1024, A100 + PCIe 4.0 x16).
pub fn table1() -> Table {
    let hw = HardwareConfig::a100_x16();
    let mut t = Table::new(
        "Table 1 — PCIe vs compute latency (b=32, s=1024, fp16)",
        &["model", "hidden", "KV cache (MB)", "PCIe lat (ms)", "comp lat (ms)", "ratio"],
    );
    for m in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b(), ModelConfig::opt_30b()] {
        let kv = m.kv_bytes_per_layer(32, 1024);
        let pcie_ms = hw.link_time(kv) * 1e3;
        // Table 1's comp column: computing the KV pair for the decode step
        let comp_ms = hw.gpu_time(m.recompute_flops(32, 1)) * 1e3;
        t.row(&[
            m.name.clone(),
            m.hidden.to_string(),
            (kv >> 20).to_string(),
            f(pcie_ms, 1),
            f(comp_ms, 4),
            f(pcie_ms / comp_ms, 0),
        ]);
    }
    t
}

fn thr(policy: Policy, model: &ModelConfig, hw: &HardwareConfig, prompt: usize, gen: usize) -> f64 {
    let wl = WorkloadConfig::throughput_oriented(prompt, gen);
    simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl, policy)).tok_per_s
}

/// Paper Fig 6 (row 1): decoding throughput, KVPR vs FlexGen, three OPT
/// models × six (prompt, gen) settings, effective batch 32×8.
pub fn fig6_seq_sweep() -> Table {
    let hw = HardwareConfig::a100_x16();
    let mut t = Table::new(
        "Fig 6 (row 1) — decode throughput (tok/s), effective batch 32x8",
        &["model", "seq (prompt/gen)", "FlexGen", "KVPR", "speedup"],
    );
    for model in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b(), ModelConfig::opt_30b()] {
        for (p, g) in [(256, 32), (256, 128), (512, 32), (512, 128), (1024, 32), (1024, 128)] {
            let flex = thr(Policy::FlexGen, &model, &hw, p, g);
            let kvpr = thr(Policy::Kvpr, &model, &hw, p, g);
            t.row(&[
                model.name.clone(),
                format!("{p}/{g}"),
                f(flex, 1),
                f(kvpr, 1),
                format!("{:.1}%", (kvpr / flex - 1.0) * 100.0),
            ]);
        }
    }
    t
}

/// Paper Fig 6 (row 2): throughput vs batch size 1–48, prompt 1024, gen 32.
pub fn fig6_batch_sweep() -> Table {
    let hw = HardwareConfig::a100_x16();
    let mut t = Table::new(
        "Fig 6 (row 2) — throughput vs batch size (prompt 1024, gen 32)",
        &["model", "batch", "FlexGen", "KVPR", "speedup"],
    );
    for model in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b(), ModelConfig::opt_30b()] {
        for batch in [1usize, 4, 8, 16, 32, 48] {
            let mut wl = WorkloadConfig::throughput_oriented(1024, 32);
            wl.batch = batch;
            let flex = simulate_decode(&RunConfig::new(
                model.clone(), hw.clone(), wl.clone(), Policy::FlexGen)).tok_per_s;
            let kvpr = simulate_decode(&RunConfig::new(
                model.clone(), hw.clone(), wl, Policy::Kvpr)).tok_per_s;
            t.row(&[
                model.name.clone(),
                batch.to_string(),
                f(flex, 1),
                f(kvpr, 1),
                format!("{:.1}%", (kvpr / flex - 1.0) * 100.0),
            ]);
        }
    }
    t
}

/// Paper Fig 7: decode latency for a single batch of 64, latency workload,
/// KVPR vs Accelerate vs DeepSpeed (weights resident on GPU).
pub fn fig7_latency() -> Table {
    let hw = HardwareConfig::a100_x16();
    let mut t = Table::new(
        "Fig 7 — decode latency (s), single batch of 64, weights on GPU",
        &["model", "prompt/gen", "Accelerate", "DeepSpeed", "KVPR", "cut vs Accel"],
    );
    for model in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b()] {
        for (p, g) in [(128, 32), (128, 128), (256, 32), (256, 128), (512, 32), (512, 128)] {
            let wl = WorkloadConfig::latency_oriented(p, g);
            let run = |policy| {
                simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), policy))
                    .decode_s
            };
            let acc = run(Policy::Accelerate);
            let ds = run(Policy::DeepSpeed);
            let kv = run(Policy::Kvpr);
            t.row(&[
                model.name.clone(),
                format!("{p}/{g}"),
                f(acc, 3),
                f(ds, 3),
                f(kv, 3),
                format!("{:.1}%", (1.0 - kv / acc) * 100.0),
            ]);
        }
    }
    t
}

/// Paper Fig 8: GPU utilization during decode, KVPR vs FlexGen (85%→99%),
/// plus the binned utilization timeline.
pub fn fig8_utilization() -> (Table, Table) {
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_6_7b();
    let wl = WorkloadConfig::throughput_oriented(512, 16);
    let flex = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), Policy::FlexGen));
    let kvpr = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl, Policy::Kvpr));

    let mut t = Table::new(
        "Fig 8 — decode-stage resource utilization (OPT-6.7B, 32x8)",
        &["method", "GPU util", "link util", "peak mem"],
    );
    for r in [&flex, &kvpr] {
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.1}%", r.gpu_util * 100.0),
            format!("{:.1}%", r.link_util * 100.0),
            crate::util::fmt_bytes(r.peak_gpu_bytes),
        ]);
    }

    let mut tl = Table::new(
        "Fig 8 — GPU utilization timeline (decode, 10 bins)",
        &["bin", "FlexGen", "KVPR"],
    );
    let bins = 10;
    let sample = |r: &crate::sim::RunReport, i: usize| {
        let n = r.util_series.len();
        let lo = i * n / bins;
        let hi = (((i + 1) * n) / bins).max(lo + 1);
        let s: f64 = r.util_series[lo..hi.min(n)].iter().map(|u| u.gpu_util).sum();
        s / (hi.min(n) - lo) as f64
    };
    for i in 0..bins {
        tl.row(&[
            i.to_string(),
            format!("{:.1}%", sample(&flex, i) * 100.0),
            format!("{:.1}%", sample(&kvpr, i) * 100.0),
        ]);
    }
    (t, tl)
}

/// Paper Fig 9: decoding throughput with group-wise 4-bit KV quantization
/// (OPT-13B).
pub fn fig9_compression() -> Table {
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_13b();
    let mut t = Table::new(
        "Fig 9 — KVPR + 4-bit KV compression (OPT-13B, tok/s)",
        &["seq (prompt/gen)", "KVPR", "KVPR+4bit", "gain"],
    );
    for (p, g) in [(256, 32), (512, 32), (1024, 32)] {
        let wl = WorkloadConfig::throughput_oriented(p, g);
        let plain = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), Policy::Kvpr));
        let mut wlq = wl;
        wlq.kv_quant_4bit = true;
        let quant = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wlq, Policy::Kvpr));
        t.row(&[
            format!("{p}/{g}"),
            f(plain.tok_per_s, 1),
            f(quant.tok_per_s, 1),
            format!("{:.1}%", (quant.tok_per_s / plain.tok_per_s - 1.0) * 100.0),
        ]);
    }
    t
}

/// Paper Fig 10: runtime breakdown of an MHA block during decode,
/// KVPR vs FlexGen (KV xfer 58%→38%, act 8%, GPU 2.3%→13.3%).
pub fn fig10_breakdown() -> Table {
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_6_7b();
    let wl = WorkloadConfig::throughput_oriented(1024, 16);
    let mut t = Table::new(
        "Fig 10 — runtime breakdown (% of step time)",
        &["method", "weights", "KV xfer", "act xfer", "recompute", "attn+ffn", "store"],
    );
    for policy in [Policy::FlexGen, Policy::Kvpr] {
        let r = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), policy));
        let pct = r.breakdown_pct();
        let get = |k: TaskKind| {
            pct.iter()
                .find(|(kk, _)| *kk == k)
                .map(|(_, v)| v.max(0.0))
                .unwrap_or(0.0)
        };
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.1}%", get(TaskKind::WeightXfer)),
            format!("{:.1}%", get(TaskKind::KvXfer)),
            format!("{:.1}%", get(TaskKind::ActXfer)),
            format!("{:.1}%", get(TaskKind::Recompute)),
            format!("{:.1}%", get(TaskKind::AttnFfn)),
            format!("{:.1}%", get(TaskKind::Store)),
        ]);
    }
    t
}

/// Paper Table 2: hiding ablation — small KV cache, weights offloaded,
/// batch 1–32, prompt 256, gen 64.
pub fn table2_hiding() -> Table {
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_6_7b();
    let mut t = Table::new(
        "Table 2 — hiding KV recomputation under weight loading (decode s)",
        &["batch", "KV (MB)", "FlexGen", "KVPR w/o hiding", "KVPR w/ hiding"],
    );
    for batch in [1usize, 2, 4, 8, 16, 32] {
        let mut wl = WorkloadConfig::throughput_oriented(256, 64);
        wl.batch = batch;
        wl.n_batches = 1;
        let run = |policy| {
            simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), policy)).decode_s
        };
        let kv_mb = model.kv_bytes_per_layer(batch, 256 + 64) >> 20;
        t.row(&[
            batch.to_string(),
            kv_mb.to_string(),
            f(run(Policy::FlexGen), 3),
            f(run(Policy::KvprNoHide), 3),
            f(run(Policy::Kvpr), 3),
        ]);
    }
    t
}

/// Paper Fig 12: optimal split point l* over the generation process
/// (prompt 128, gen 32, batch 64) — uncapped and with the l ≤ s cap.
pub fn fig12_splits() -> Table {
    let hw = HardwareConfig::a100_x16();
    let model = ModelConfig::opt_6_7b();
    let cost = CostModel::from_hardware(&hw, &model, 64);
    let free = Planner::new(cost.clone(), SchedulePolicy::RowByRow, vec![], usize::MAX);
    let capped = Planner::new(cost, SchedulePolicy::RowByRow, vec![], 128);
    let t_free = free.split_trajectory(128, 32);
    let t_cap = capped.split_trajectory(128, 32);
    let mut t = Table::new(
        "Fig 12 — optimal KV split point l* over generation (prompt 128, b=64)",
        &["gen step", "s'", "l* (uncapped)", "l* (l ≤ s cap)"],
    );
    for (i, (a, b)) in t_free.iter().zip(&t_cap).enumerate() {
        if i % 4 == 0 || i == t_free.len() - 1 {
            t.row(&[
                (i + 1).to_string(),
                (128 + i).to_string(),
                a.to_string(),
                b.to_string(),
            ]);
        }
    }
    t
}

/// Paper Tables 3–4: detailed latency-oriented results (Accelerate vs KVPR).
pub fn table34_detailed() -> Table {
    let hw = HardwareConfig::a100_x16();
    let mut t = Table::new(
        "Tables 3-4 — detailed latency-oriented results (batch 64)",
        &["model", "method", "prompt", "gen", "cache (GB)", "peak mem (GB)", "decode (s)", "tok/s"],
    );
    for model in [ModelConfig::opt_6_7b(), ModelConfig::opt_13b()] {
        for (p, g) in [(128, 32), (128, 128), (256, 32), (256, 128), (512, 32), (512, 128)] {
            let wl = WorkloadConfig::latency_oriented(p, g);
            for policy in [Policy::Accelerate, Policy::Kvpr] {
                let r = simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), policy));
                let cache_gb =
                    model.kv_bytes_total(64, p + g) as f64 / (1u64 << 30) as f64;
                t.row(&[
                    model.name.clone(),
                    r.policy.name().to_string(),
                    p.to_string(),
                    g.to_string(),
                    f(cache_gb, 1),
                    f(r.peak_gpu_bytes as f64 / (1u64 << 30) as f64, 2),
                    f(r.decode_s, 3),
                    f(r.tok_per_s, 1),
                ]);
            }
        }
    }
    t
}

/// Paper Table 5 (Appendix A.5): low-end system (RTX 5000, PCIe 4.0 x8).
pub fn table5_lowend() -> Table {
    let hw = HardwareConfig::rtx5000_x8();
    let model = ModelConfig::opt_6_7b();
    let mut t = Table::new(
        "Table 5 — low-end system throughput (OPT-6.7B, tok/s)",
        &["seq (prompt/gen)", "FlexGen", "KVPR", "speedup"],
    );
    for (p, g) in [(256, 32), (256, 128), (512, 32), (512, 128), (1024, 32), (1024, 128)] {
        let flex = thr(Policy::FlexGen, &model, &hw, p, g);
        let kvpr = thr(Policy::Kvpr, &model, &hw, p, g);
        t.row(&[
            format!("{p}/{g}"),
            f(flex, 1),
            f(kvpr, 1),
            format!("{:.1}%", (kvpr / flex - 1.0) * 100.0),
        ]);
    }
    t
}

/// Paper Fig 13 (Appendix A.6): LLaMa2 models, single batch of 64.
pub fn fig13_llama() -> Table {
    let hw = HardwareConfig::a100_x16();
    let mut t = Table::new(
        "Fig 13 — LLaMa2 decode throughput (tok/s), batch 64",
        &["model", "prompt/gen", "Accelerate", "DeepSpeed", "KVPR"],
    );
    for model in [ModelConfig::llama2_7b(), ModelConfig::llama2_13b()] {
        for (p, g) in [(128, 32), (256, 32), (512, 32), (512, 128)] {
            let wl = WorkloadConfig::latency_oriented(p, g);
            let run = |policy| {
                simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl.clone(), policy))
                    .tok_per_s
            };
            t.row(&[
                model.name.clone(),
                format!("{p}/{g}"),
                f(run(Policy::Accelerate), 1),
                f(run(Policy::DeepSpeed), 1),
                f(run(Policy::Kvpr), 1),
            ]);
        }
    }
    t
}

/// Paper Fig 14 (Appendix A.7): data-parallel scaling — N GPU workers
/// behind one CPU.  FastDecode's CPU attention saturates the shared host;
/// KVPR scales linearly.
pub fn fig14_multigpu() -> Table {
    let model = ModelConfig::opt_6_7b();
    let hw = HardwareConfig::a100_x16();
    let prompt = 512;
    let gen = 8;
    let batch = 32;

    let mut t = Table::new(
        "Fig 14 — aggregate throughput vs #GPU processes (one shared CPU)",
        &["processes", "FastDecode (tok/s)", "KVPR (tok/s)", "KVPR/FD"],
    );

    // KVPR per-process throughput (no shared resource → linear scaling)
    let mut wl = WorkloadConfig::throughput_oriented(prompt, gen);
    wl.batch = batch;
    wl.n_batches = 1;
    let kvpr_single =
        simulate_decode(&RunConfig::new(model.clone(), hw.clone(), wl, Policy::Kvpr)).tok_per_s;

    for n in [1usize, 2, 4, 8] {
        // FastDecode: N process chains share ONE cpu resource
        let mut sim = Sim::new();
        let cpu = sim.resource("cpu-shared");
        let mut ends = Vec::new();
        for p in 0..n {
            let gpu = sim.resource(&format!("gpu{p}"));
            let h2d = sim.resource(&format!("h2d{p}"));
            let d2h = sim.resource(&format!("d2h{p}"));
            let mut prev = None;
            for step in 0..gen {
                let ctx = StepCtx {
                    model: model.clone(),
                    hw: hw.clone(),
                    batch,
                    kv_len: prompt + step,
                    weights_offloaded: false,
                    kv_quant: false,
                    l: 0,
                    gpu,
                    h2d,
                    d2h,
                    cpu,
                };
                for _layer in 0..model.n_layers {
                    prev = Some(crate::sim::build_layer_pub(
                        &mut sim,
                        Policy::FastDecode,
                        &ctx,
                        prev,
                        None,
                    ));
                }
            }
            ends.push(prev.unwrap());
        }
        let makespan = ends.iter().map(|e| sim.finish(*e)).fold(0.0, f64::max);
        let fd_tput = (n * batch * gen) as f64 / makespan;
        let kvpr_tput = kvpr_single * n as f64;
        t.row(&[
            n.to_string(),
            f(fd_tput, 1),
            f(kvpr_tput, 1),
            f(kvpr_tput / fd_tput, 2),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reproduces_ratio() {
        let t = table1();
        let s = t.render();
        assert!(s.contains("opt-6.7b") && s.contains("512"));
        assert!(s.contains("opt-30b") && s.contains("896"));
    }

    #[test]
    fn fig12_trajectory_capped_at_prompt() {
        let t = fig12_splits();
        let s = t.render();
        assert!(s.contains("l ≤ s cap") || s.contains("128"));
    }

    #[test]
    fn fig14_kvpr_scales_better() {
        let t = fig14_multigpu();
        let s = t.render();
        // last row's ratio must exceed the first row's
        let rows: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).skip(1).collect();
        assert!(rows.len() >= 4);
        let ratio = |row: &str| -> f64 {
            row.split('|').filter(|c| !c.trim().is_empty()).last().unwrap().trim().parse().unwrap()
        };
        assert!(ratio(rows.last().unwrap()) > ratio(&rows[1]) * 1.5,
                "scaling advantage must grow: {s}");
    }
}
