//! Pure-Rust reference implementation of the tiny model.
//!
//! Mirrors `python/compile/model.py` operation-for-operation (layernorm eps,
//! head split, contiguous-prefix cache update, causal prefill) so the
//! integration tests can check that what the PJRT artifacts compute is what
//! the math says — Rust↔JAX parity with no Python on the judging side.
//!
//! All tensors are flat `Vec<f32>` in `[batch, seq, hidden]` layout, exactly
//! the artifact I/O layout.

use crate::model::weights::ModelWeights;

const LN_EPS: f32 = 1e-5;
const NEG_INF: f32 = -1e30;

/// Reference executor over a weight set.
#[derive(Debug, Clone)]
pub struct RefModel {
    pub weights: ModelWeights,
}

impl RefModel {
    pub fn new(weights: ModelWeights) -> Self {
        RefModel { weights }
    }

    fn h(&self) -> usize {
        self.weights.config.hidden
    }

    // -- primitive ops -------------------------------------------------------

    /// Row-wise layernorm over the last dim.
    pub fn layernorm(x: &[f32], g: &[f32], b: &[f32], h: usize) -> Vec<f32> {
        let mut out = vec![0.0; x.len()];
        for (row_i, row) in x.chunks(h).enumerate() {
            let mu = row.iter().sum::<f32>() / h as f32;
            let var = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / h as f32;
            let inv = 1.0 / (var + LN_EPS).sqrt();
            let o = &mut out[row_i * h..(row_i + 1) * h];
            for i in 0..h {
                o[i] = (row[i] - mu) * inv * g[i] + b[i];
            }
        }
        out
    }

    /// `x[rows, in] @ w[in, out] + b[out]`.
    pub fn linear(x: &[f32], w: &[f32], b: &[f32], rows: usize, d_in: usize, d_out: usize) -> Vec<f32> {
        assert_eq!(x.len(), rows * d_in);
        assert_eq!(w.len(), d_in * d_out);
        let mut out = vec![0.0; rows * d_out];
        for r in 0..rows {
            let xr = &x[r * d_in..(r + 1) * d_in];
            let or = &mut out[r * d_out..(r + 1) * d_out];
            or.copy_from_slice(&b[..d_out]);
            for (i, &xv) in xr.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let wr = &w[i * d_out..(i + 1) * d_out];
                for j in 0..d_out {
                    or[j] += xv * wr[j];
                }
            }
        }
        out
    }

    fn softmax_inplace(scores: &mut [f32]) {
        let m = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0;
        for s in scores.iter_mut() {
            *s = (*s - m).exp();
            sum += *s;
        }
        for s in scores.iter_mut() {
            *s /= sum;
        }
    }

    // -- model steps (artifact-equivalent) ------------------------------------

    /// `embed_decode` artifact: ids[b] + position → x [b, 1, h].
    pub fn embed_decode(&self, ids: &[i32], pos: usize) -> Vec<f32> {
        let h = self.h();
        let mut out = Vec::with_capacity(ids.len() * h);
        for &id in ids {
            let t = &self.weights.tok_table[id as usize * h..(id as usize + 1) * h];
            let p = &self.weights.pos_table[pos * h..(pos + 1) * h];
            out.extend(t.iter().zip(p).map(|(a, b)| a + b));
        }
        out
    }

    /// `lm_head` artifact: x [b, 1, h] → logits [b, vocab].
    pub fn lm_head(&self, x: &[f32]) -> Vec<f32> {
        let h = self.h();
        let v = self.weights.config.vocab;
        let ln = Self::layernorm(x, &self.weights.lnf_g, &self.weights.lnf_b, h);
        let b = x.len() / h;
        let mut out = vec![0.0; b * v];
        for r in 0..b {
            let xr = &ln[r * h..(r + 1) * h];
            for t in 0..v {
                let row = &self.weights.tok_table[t * h..(t + 1) * h];
                out[r * v + t] = xr.iter().zip(row).map(|(a, b)| a * b).sum();
            }
        }
        out
    }

    /// Greedy sampling over `lm_head` logits → one token per sequence.
    pub fn argmax(logits: &[f32], vocab: usize) -> Vec<i32> {
        logits
            .chunks(vocab)
            .map(|row| {
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0 as i32
            })
            .collect()
    }

    /// `decode_full` artifact: one layer, one token, padded cache with
    /// `kv_len` valid rows (kv_len < cap).  Returns (y, k_new, v_new);
    /// the caller owns appending k_new/v_new to its cache.
    #[allow(clippy::too_many_arguments)]
    pub fn decode_layer_full(
        &self,
        layer: usize,
        x: &[f32],
        k_cache: &[f32],
        v_cache: &[f32],
        cap: usize,
        kv_len: usize,
        batch: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let h = self.h();
        let nh = self.weights.config.n_heads;
        let d = h / nh;
        let w = self.weights.layer(layer);
        assert!(kv_len < cap, "cache must have room for the new token");
        assert_eq!(x.len(), batch * h);
        assert_eq!(k_cache.len(), batch * cap * h);

        let ln1 = Self::layernorm(x, w.get("ln1_g"), w.get("ln1_b"), h);
        let q = Self::linear(&ln1, w.get("wq"), w.get("bq"), batch, h, h);
        let k_new = Self::linear(&ln1, w.get("wk"), w.get("bk"), batch, h, h);
        let v_new = Self::linear(&ln1, w.get("wv"), w.get("bv"), batch, h, h);

        // attention over valid prefix + the new token (logical position kv_len)
        let n_valid = kv_len + 1;
        let mut attn = vec![0.0; batch * h];
        let scale = 1.0 / (d as f32).sqrt();
        let mut scores = vec![0.0f32; n_valid];
        for b in 0..batch {
            for head in 0..nh {
                let qo = b * h + head * d;
                let qh = &q[qo..qo + d];
                for (s, score) in scores.iter_mut().enumerate() {
                    let krow: &[f32] = if s < kv_len {
                        let off = (b * cap + s) * h + head * d;
                        &k_cache[off..off + d]
                    } else {
                        // the new token's key (k_new is [batch, h])
                        &k_new[qo..qo + d]
                    };
                    *score = qh.iter().zip(krow).map(|(a, c)| a * c).sum::<f32>() * scale;
                    if *score < NEG_INF {
                        *score = NEG_INF;
                    }
                }
                Self::softmax_inplace(&mut scores);
                let out = &mut attn[qo..qo + d];
                for (s, &p) in scores.iter().enumerate() {
                    let vrow: &[f32] = if s < kv_len {
                        let off = (b * cap + s) * h + head * d;
                        &v_cache[off..off + d]
                    } else {
                        &v_new[b * h + head * d..b * h + head * d + d]
                    };
                    for j in 0..d {
                        out[j] += p * vrow[j];
                    }
                }
            }
        }

        let proj = Self::linear(&attn, w.get("wo"), w.get("bo"), batch, h, h);
        let mut xr: Vec<f32> = x.iter().zip(&proj).map(|(a, b)| a + b).collect();

        // FFN
        let f = self.weights.config.ffn;
        let ln2 = Self::layernorm(&xr, w.get("ln2_g"), w.get("ln2_b"), h);
        let mut mid = Self::linear(&ln2, w.get("w1"), w.get("b1"), batch, h, f);
        for m in mid.iter_mut() {
            *m = m.max(0.0);
        }
        let down = Self::linear(&mid, w.get("w2"), w.get("b2"), batch, f, h);
        for (a, b) in xr.iter_mut().zip(&down) {
            *a += b;
        }
        (xr, k_new, v_new)
    }

    /// Causal prefill of one layer over [batch, s_p, h] activations.
    /// Returns (y, k, v) each [batch, s_p, h].
    pub fn prefill_layer(
        &self,
        layer: usize,
        x: &[f32],
        batch: usize,
        s_p: usize,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let h = self.h();
        let nh = self.weights.config.n_heads;
        let d = h / nh;
        let rows = batch * s_p;
        let w = self.weights.layer(layer);

        let ln1 = Self::layernorm(x, w.get("ln1_g"), w.get("ln1_b"), h);
        let q = Self::linear(&ln1, w.get("wq"), w.get("bq"), rows, h, h);
        let k = Self::linear(&ln1, w.get("wk"), w.get("bk"), rows, h, h);
        let v = Self::linear(&ln1, w.get("wv"), w.get("bv"), rows, h, h);

        let mut attn = vec![0.0; rows * h];
        let scale = 1.0 / (d as f32).sqrt();
        for b in 0..batch {
            for head in 0..nh {
                for qi in 0..s_p {
                    let qo = (b * s_p + qi) * h + head * d;
                    let qh = &q[qo..qo + d];
                    let mut scores = vec![0.0f32; qi + 1];
                    for (s, score) in scores.iter_mut().enumerate() {
                        let ko = (b * s_p + s) * h + head * d;
                        *score =
                            qh.iter().zip(&k[ko..ko + d]).map(|(a, c)| a * c).sum::<f32>() * scale;
                    }
                    Self::softmax_inplace(&mut scores);
                    let out_off = qo;
                    for (s, &p) in scores.iter().enumerate() {
                        let vo = (b * s_p + s) * h + head * d;
                        for j in 0..d {
                            attn[out_off + j] += p * v[vo + j];
                        }
                    }
                }
            }
        }

        let proj = Self::linear(&attn, w.get("wo"), w.get("bo"), rows, h, h);
        let mut xr: Vec<f32> = x.iter().zip(&proj).map(|(a, b)| a + b).collect();
        let f = self.weights.config.ffn;
        let ln2 = Self::layernorm(&xr, w.get("ln2_g"), w.get("ln2_b"), h);
        let mut mid = Self::linear(&ln2, w.get("w1"), w.get("b1"), rows, h, f);
        for m in mid.iter_mut() {
            *m = m.max(0.0);
        }
        let down = Self::linear(&mid, w.get("w2"), w.get("b2"), rows, f, h);
        for (a, b) in xr.iter_mut().zip(&down) {
            *a += b;
        }
        (xr, k, v)
    }

    /// Whole-model prefill: ids [batch, s_p] → (logits [b, vocab], per-layer
    /// (k, v, x) each [batch, s_p, h]).
    #[allow(clippy::type_complexity)]
    pub fn prefill(
        &self,
        ids: &[i32],
        batch: usize,
        s_p: usize,
    ) -> (Vec<f32>, Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>) {
        let h = self.h();
        let mut x = Vec::with_capacity(batch * s_p * h);
        for b in 0..batch {
            for s in 0..s_p {
                let id = ids[b * s_p + s] as usize;
                let tok = &self.weights.tok_table[id * h..(id + 1) * h];
                let pos = &self.weights.pos_table[s * h..(s + 1) * h];
                x.extend(tok.iter().zip(pos).map(|(a, b)| a + b));
            }
        }
        let mut per_layer = Vec::with_capacity(self.weights.config.n_layers);
        for i in 0..self.weights.config.n_layers {
            let x_in = x.clone();
            let (y, k, v) = self.prefill_layer(i, &x, batch, s_p);
            per_layer.push((k, v, x_in));
            x = y;
        }
        // last position's hidden → logits
        let mut last = Vec::with_capacity(batch * h);
        for b in 0..batch {
            let off = (b * s_p + s_p - 1) * h;
            last.extend_from_slice(&x[off..off + h]);
        }
        (self.lm_head(&last), per_layer)
    }

    /// Reference end-to-end greedy generation (slow; tests/parity only).
    pub fn generate(&self, prompt_ids: &[i32], batch: usize, s_p: usize, gen: usize, cap: usize) -> Vec<Vec<i32>> {
        let h = self.h();
        let n_layers = self.weights.config.n_layers;
        let (logits, per_layer) = self.prefill(prompt_ids, batch, s_p);
        // padded caches [batch, cap, h]
        let mut kc = vec![vec![0.0f32; batch * cap * h]; n_layers];
        let mut vc = vec![vec![0.0f32; batch * cap * h]; n_layers];
        for (i, (k, v, _)) in per_layer.iter().enumerate() {
            for b in 0..batch {
                for s in 0..s_p {
                    let src = (b * s_p + s) * h;
                    let dst = (b * cap + s) * h;
                    kc[i][dst..dst + h].copy_from_slice(&k[src..src + h]);
                    vc[i][dst..dst + h].copy_from_slice(&v[src..src + h]);
                }
            }
        }
        let vocab = self.weights.config.vocab;
        let mut out: Vec<Vec<i32>> = vec![Vec::new(); batch];
        let mut next = Self::argmax(&logits, vocab);
        for (b, t) in next.iter().enumerate() {
            out[b].push(*t);
        }
        let mut kv_len = s_p;
        for step in 1..gen {
            let _ = step;
            let mut x = self.embed_decode(&next, kv_len);
            for i in 0..n_layers {
                let (y, k_new, v_new) =
                    self.decode_layer_full(i, &x, &kc[i], &vc[i], cap, kv_len, batch);
                for b in 0..batch {
                    let dst = (b * cap + kv_len) * h;
                    kc[i][dst..dst + h].copy_from_slice(&k_new[b * h..(b + 1) * h]);
                    vc[i][dst..dst + h].copy_from_slice(&v_new[b * h..(b + 1) * h]);
                }
                x = y;
            }
            kv_len += 1;
            next = Self::argmax(&self.lm_head(&x), vocab);
            for (b, t) in next.iter().enumerate() {
                out[b].push(*t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn tiny_model() -> RefModel {
        RefModel::new(ModelWeights::generate(&ModelConfig::tiny(), 3))
    }

    #[test]
    fn layernorm_normalizes() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        let y = RefModel::layernorm(&x, &g, &b, 4);
        let mu: f32 = y.iter().sum::<f32>() / 4.0;
        let var: f32 = y.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
        assert!(mu.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn linear_identity() {
        // identity weight, zero bias
        let mut w = vec![0.0; 9];
        for i in 0..3 {
            w[i * 3 + i] = 1.0;
        }
        let x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let y = RefModel::linear(&x, &w, &[0.0; 3], 2, 3, 3);
        assert_eq!(y, x);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut s = vec![1.0, 2.0, 3.0];
        RefModel::softmax_inplace(&mut s);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn decode_ignores_padding_rows() {
        let m = tiny_model();
        let h = 256;
        let batch = 1;
        let cap = 32;
        let kv_len = 10;
        let x = vec![0.1; batch * h];
        let mut kc = vec![0.05; batch * cap * h];
        let mut vc = vec![-0.05; batch * cap * h];
        let (y1, _, _) = m.decode_layer_full(0, &x, &kc, &vc, cap, kv_len, batch);
        // poison rows beyond kv_len+1
        for row in (kv_len + 1)..cap {
            for j in 0..h {
                kc[row * h + j] = 50.0;
                vc[row * h + j] = -50.0;
            }
        }
        let (y2, _, _) = m.decode_layer_full(0, &x, &kc, &vc, cap, kv_len, batch);
        for (a, b) in y1.iter().zip(&y2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn generate_is_deterministic() {
        let m = tiny_model();
        let ids: Vec<i32> = (0..16).collect();
        let a = m.generate(&ids, 1, 16, 4, 64);
        let b = m.generate(&ids, 1, 16, 4, 64);
        assert_eq!(a, b);
        assert_eq!(a[0].len(), 4);
        assert!(a[0].iter().all(|&t| (0..512).contains(&t)));
    }

    #[test]
    fn prefill_matches_decode_chain() {
        // KV rows from prefill(s_p) must match prefill(s_p-1) + one decode step
        let m = tiny_model();
        let s_p = 8;
        let ids: Vec<i32> = (10..10 + s_p as i32).collect();
        let (_, full) = m.prefill(&ids, 1, s_p);

        let (_, part) = m.prefill(&ids[..s_p - 1], 1, s_p - 1);
        let h = 256;
        let cap = 32;
        let mut x = m.embed_decode(&ids[s_p - 1..], s_p - 1);
        for i in 0..m.weights.config.n_layers {
            let (k, v, _) = &part[i];
            let mut kc = vec![0.0; cap * h];
            let mut vcache = vec![0.0; cap * h];
            for s in 0..s_p - 1 {
                kc[s * h..(s + 1) * h].copy_from_slice(&k[s * h..(s + 1) * h]);
                vcache[s * h..(s + 1) * h].copy_from_slice(&v[s * h..(s + 1) * h]);
            }
            let (y, k_new, _v_new) = m.decode_layer_full(i, &x, &kc, &vcache, cap, s_p - 1, 1);
            let (k_full, _, _) = &full[i];
            let want = &k_full[(s_p - 1) * h..s_p * h];
            for (a, b) in k_new.iter().zip(want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
            x = y;
        }
    }

    #[test]
    fn argmax_picks_max() {
        let logits = vec![0.1, 0.9, 0.3, /* row 2 */ 5.0, -1.0, 2.0];
        assert_eq!(RefModel::argmax(&logits, 3), vec![1, 0]);
    }
}
