//! Model-side substrates: deterministic weight generation, a byte-level
//! tokenizer, and a pure-Rust reference implementation of the decoder-layer
//! math used to cross-check the PJRT artifacts (Rust↔JAX parity).

mod reference;
mod tokenizer;
mod weights;

pub use reference::RefModel;
pub use tokenizer::ByteTokenizer;
pub use weights::{LayerWeights, ModelWeights, LAYER_WEIGHT_NAMES};
