//! Deterministic host-side weight store.
//!
//! The artifacts take weights as runtime inputs (the offloading regime moves
//! them over the link every layer in throughput mode), so Rust owns weight
//! generation.  Generation is seeded and reproducible: the E2E example
//! verifies KVPR and the baseline produce *identical* tokens, which needs
//! identical weights across engine instances.
//!
//! Weight order per layer is pinned to `python/compile/model.py`'s
//! `LAYER_WEIGHT_NAMES` — the manifest loader cross-checks this at startup.

use std::sync::Arc;

use crate::config::ModelConfig;
use crate::util::prng::Prng;

/// Canonical per-layer weight order (must match the python side).
pub const LAYER_WEIGHT_NAMES: [&str; 16] = [
    "ln1_g", "ln1_b", "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
];

/// One decoder layer's weights, in canonical order.
#[derive(Debug, Clone)]
pub struct LayerWeights {
    /// (name, flat data, shape) in canonical order.
    tensors: Vec<(String, Arc<Vec<f32>>, Vec<usize>)>,
}

impl LayerWeights {
    /// Assemble a layer from externally supplied tensors (the interpreter
    /// runtime rebuilds layer weights from artifact call arguments).
    pub(crate) fn from_tensors(tensors: Vec<(String, Arc<Vec<f32>>, Vec<usize>)>) -> Self {
        LayerWeights { tensors }
    }

    pub fn get(&self, name: &str) -> &Arc<Vec<f32>> {
        &self
            .tensors
            .iter()
            .find(|(n, _, _)| n == name)
            .unwrap_or_else(|| panic!("no weight {name}"))
            .1
    }

    pub fn shape(&self, name: &str) -> &[usize] {
        &self
            .tensors
            .iter()
            .find(|(n, _, _)| n == name)
            .unwrap_or_else(|| panic!("no weight {name}"))
            .2
    }

    /// Iterate in canonical order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, &Arc<Vec<f32>>, &[usize])> {
        self.tensors.iter().map(|(n, d, s)| (n.as_str(), d, s.as_slice()))
    }

    /// Total bytes (for transfer accounting).
    pub fn bytes(&self) -> u64 {
        self.tensors.iter().map(|(_, d, _)| (d.len() * 4) as u64).sum()
    }

    /// Bytes of W_K + W_V + their biases — the fine-grained pipeline's
    /// front-loaded subset (paper Fig 5b).
    pub fn kv_proj_bytes(&self) -> u64 {
        ["wk", "bk", "wv", "bv"]
            .iter()
            .map(|n| (self.get(n).len() * 4) as u64)
            .sum()
    }
}

/// All weights of the model.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub config: ModelConfig,
    pub tok_table: Arc<Vec<f32>>,
    pub pos_table: Arc<Vec<f32>>,
    pub lnf_g: Arc<Vec<f32>>,
    pub lnf_b: Arc<Vec<f32>>,
    pub layers: Vec<LayerWeights>,
}

impl ModelWeights {
    /// Deterministically generate small-magnitude weights (activations stay
    /// O(1) through all layers so f32 artifacts are well-conditioned).
    pub fn generate(config: &ModelConfig, seed: u64) -> Self {
        let mut rng = Prng::new(seed);
        let h = config.hidden;
        let f = config.ffn;
        let xavier = |rng: &mut Prng, rows: usize, cols: usize| {
            let scale = (2.0 / (rows + cols) as f64).sqrt() as f32;
            Arc::new(rng.normal_vec_f32(rows * cols, scale))
        };
        let gamma = |rng: &mut Prng, n: usize| {
            Arc::new((0..n).map(|_| 1.0 + rng.normal() as f32 * 0.02).collect::<Vec<_>>())
        };
        let beta = |rng: &mut Prng, n: usize| Arc::new(rng.normal_vec_f32(n, 0.02));

        let tok_table = Arc::new(rng.normal_vec_f32(config.vocab * h, 0.05));
        let pos_table = Arc::new(rng.normal_vec_f32(config.max_pos * h, 0.05));
        let lnf_g = gamma(&mut rng, h);
        let lnf_b = beta(&mut rng, h);

        let layers = (0..config.n_layers)
            .map(|_| {
                let tensors = vec![
                    ("ln1_g".into(), gamma(&mut rng, h), vec![h]),
                    ("ln1_b".into(), beta(&mut rng, h), vec![h]),
                    ("wq".into(), xavier(&mut rng, h, h), vec![h, h]),
                    ("bq".into(), beta(&mut rng, h), vec![h]),
                    ("wk".into(), xavier(&mut rng, h, h), vec![h, h]),
                    ("bk".into(), beta(&mut rng, h), vec![h]),
                    ("wv".into(), xavier(&mut rng, h, h), vec![h, h]),
                    ("bv".into(), beta(&mut rng, h), vec![h]),
                    ("wo".into(), xavier(&mut rng, h, h), vec![h, h]),
                    ("bo".into(), beta(&mut rng, h), vec![h]),
                    ("ln2_g".into(), gamma(&mut rng, h), vec![h]),
                    ("ln2_b".into(), beta(&mut rng, h), vec![h]),
                    ("w1".into(), xavier(&mut rng, h, f), vec![h, f]),
                    ("b1".into(), beta(&mut rng, f), vec![f]),
                    ("w2".into(), xavier(&mut rng, f, h), vec![f, h]),
                    ("b2".into(), beta(&mut rng, h), vec![h]),
                ];
                LayerWeights { tensors }
            })
            .collect();

        ModelWeights { config: config.clone(), tok_table, pos_table, lnf_g, lnf_b, layers }
    }

    pub fn layer(&self, i: usize) -> &LayerWeights {
        &self.layers[i]
    }

    pub fn total_bytes(&self) -> u64 {
        let head = (self.tok_table.len() + self.pos_table.len() + self.lnf_g.len()
            + self.lnf_b.len()) as u64
            * 4;
        head + self.layers.iter().map(|l| l.bytes()).sum::<u64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let cfg = ModelConfig::tiny();
        let a = ModelWeights::generate(&cfg, 7);
        let b = ModelWeights::generate(&cfg, 7);
        assert_eq!(a.layer(0).get("wq")[..10], b.layer(0).get("wq")[..10]);
        assert_eq!(a.tok_table[100], b.tok_table[100]);
        let c = ModelWeights::generate(&cfg, 8);
        assert_ne!(a.layer(0).get("wq")[0], c.layer(0).get("wq")[0]);
    }

    #[test]
    fn canonical_order_matches_names() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::generate(&cfg, 1);
        let names: Vec<&str> = w.layer(0).iter().map(|(n, _, _)| n).collect();
        assert_eq!(names, LAYER_WEIGHT_NAMES);
    }

    #[test]
    fn shapes_are_consistent() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::generate(&cfg, 1);
        let l = w.layer(0);
        assert_eq!(l.shape("wq"), &[cfg.hidden, cfg.hidden]);
        assert_eq!(l.shape("w1"), &[cfg.hidden, cfg.ffn]);
        assert_eq!(l.get("w1").len(), cfg.hidden * cfg.ffn);
        assert_eq!(l.get("b1").len(), cfg.ffn);
    }

    #[test]
    fn byte_accounting() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::generate(&cfg, 1);
        let l = w.layer(0);
        // 4 h² + 2 h·ffn mats dominate
        let h = cfg.hidden as u64;
        let f = cfg.ffn as u64;
        let mats = (4 * h * h + 2 * h * f) * 4;
        assert!(l.bytes() > mats);
        assert!(l.bytes() < mats + 100 * h * 4);
        assert_eq!(l.kv_proj_bytes(), (2 * h * h + 2 * h) * 4);
    }

    #[test]
    fn layernorm_gammas_near_one() {
        let cfg = ModelConfig::tiny();
        let w = ModelWeights::generate(&cfg, 1);
        let g = w.layer(0).get("ln1_g");
        let mean: f32 = g.iter().sum::<f32>() / g.len() as f32;
        assert!((mean - 1.0).abs() < 0.05);
    }
}
