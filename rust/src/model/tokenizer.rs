//! Byte-level tokenizer for the tiny model.
//!
//! Token ids 0–255 are raw bytes; 256 = BOS, 257 = EOS, 258 = PAD.  Vocab
//! 512 leaves headroom.  This is deliberately trivial — tokenization is not
//! the paper's subject, but the serving examples need a real text→ids→text
//! path so requests are actual strings.

pub const BOS: i32 = 256;
#[allow(dead_code)]
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;

#[derive(Debug, Clone, Copy, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> Self {
        ByteTokenizer
    }

    /// Encode text, prepend BOS, right-pad with PAD to `pad_to` (0 = none).
    /// Texts longer than `pad_to` − 1 are truncated from the left (keep the
    /// most recent context), mirroring the paper's uniform prompt padding.
    pub fn encode(&self, text: &str, pad_to: usize) -> Vec<i32> {
        let bytes = text.as_bytes();
        let mut ids = Vec::with_capacity(pad_to.max(bytes.len() + 1));
        ids.push(BOS);
        if pad_to > 0 && bytes.len() > pad_to - 1 {
            let start = bytes.len() - (pad_to - 1);
            ids.extend(bytes[start..].iter().map(|&b| b as i32));
        } else {
            ids.extend(bytes.iter().map(|&b| b as i32));
        }
        while pad_to > 0 && ids.len() < pad_to {
            ids.push(PAD);
        }
        ids
    }

    /// Decode ids back to text, dropping specials and invalid UTF-8.
    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&t| (0..256).contains(&t))
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        512
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hello kvpr", 0);
        assert_eq!(ids[0], BOS);
        assert_eq!(t.decode(&ids), "hello kvpr");
    }

    #[test]
    fn padding_to_bucket() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hi", 16);
        assert_eq!(ids.len(), 16);
        assert_eq!(ids[0], BOS);
        assert_eq!(&ids[1..3], &[104, 105]);
        assert!(ids[3..].iter().all(|&i| i == PAD));
    }

    #[test]
    fn truncates_from_left() {
        let t = ByteTokenizer::new();
        let long = "abcdefghijklmnop"; // 16 bytes
        let ids = t.encode(long, 8);
        assert_eq!(ids.len(), 8);
        // keeps the last 7 bytes
        assert_eq!(t.decode(&ids), "jklmnop");
    }

    #[test]
    fn decode_skips_specials() {
        let t = ByteTokenizer::new();
        assert_eq!(t.decode(&[BOS, 104, 105, EOS, PAD]), "hi");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = ByteTokenizer::new();
        let ids = t.encode("µs → fast", 0);
        assert_eq!(t.decode(&ids), "µs → fast");
    }
}
