//! Executable registry + argument marshalling.
//!
//! Two interchangeable backends sit behind [`Artifact::call`]:
//!
//! * **Compiled** (`--features pjrt`): the artifact's HLO text is compiled
//!   through the PJRT CPU client and executed natively.
//! * **Interpreted** (default): the artifact is evaluated by the pure-Rust
//!   [`RefModel`](crate::model::RefModel) interpreter (`interp` module) —
//!   identical math, no XLA, no files needed.
//!
//! Argument/output validation against the manifest signature is shared, so a
//! shape bug fails identically on either backend.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifacts::{ArtifactDType, ArtifactMeta, Manifest};
use super::interp::{self, InterpCtx};

/// An argument to an artifact call.
#[derive(Debug, Clone, Copy)]
pub enum ArgValue<'a> {
    /// Flat f32 tensor (shape comes from the signature).
    F32(&'a [f32]),
    /// Flat i32 tensor.
    I32Slice(&'a [i32]),
    /// Scalar i32 (e.g. `kv_len`, `pos`).
    I32(i32),
}

enum Backend {
    /// PJRT-compiled executable.
    #[cfg(feature = "pjrt")]
    Compiled(xla::PjRtLoadedExecutable),
    /// Reference-model interpreter.
    Interp(InterpCtx),
}

/// A callable artifact bound to one backend.
pub struct Artifact {
    pub meta: ArtifactMeta,
    backend: Backend,
}

impl Artifact {
    /// Whether this artifact executes on the interpreter backend.
    pub fn is_interpreted(&self) -> bool {
        matches!(self.backend, Backend::Interp(_))
    }

    /// Check positional args against the manifest signature.
    fn validate_args(&self, args: &[ArgValue]) -> Result<()> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        for (arg, sig) in args.iter().zip(&self.meta.inputs) {
            match (arg, sig.dtype) {
                (ArgValue::F32(data), ArtifactDType::F32) => {
                    if data.len() != sig.numel() {
                        bail!(
                            "{}: input '{}' numel {} != {}",
                            self.meta.name,
                            sig.name,
                            data.len(),
                            sig.numel()
                        );
                    }
                }
                (ArgValue::I32Slice(data), ArtifactDType::I32) => {
                    if data.len() != sig.numel() {
                        bail!(
                            "{}: input '{}' numel {} != {}",
                            self.meta.name,
                            sig.name,
                            data.len(),
                            sig.numel()
                        );
                    }
                }
                (ArgValue::I32(_), ArtifactDType::I32) => {
                    if !sig.shape.is_empty() {
                        bail!(
                            "{}: '{}' expects shape {:?}",
                            self.meta.name,
                            sig.name,
                            sig.shape
                        );
                    }
                }
                _ => bail!("{}: input '{}' dtype mismatch", self.meta.name, sig.name),
            }
        }
        Ok(())
    }

    /// Execute with positional args checked against the manifest signature.
    /// Returns one flat `Vec<f32>` per output (i32 outputs are unsupported —
    /// the tiny model has none).
    pub fn call(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        self.validate_args(args)?;
        let out = match &self.backend {
            #[cfg(feature = "pjrt")]
            Backend::Compiled(exe) => call_compiled(&self.meta, exe, args)?,
            Backend::Interp(ctx) => interp::execute(&self.meta, ctx, args)?,
        };
        if out.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                out.len()
            );
        }
        for (v, sig) in out.iter().zip(&self.meta.outputs) {
            if v.len() != sig.numel() {
                bail!("{}: output '{}' numel mismatch", self.meta.name, sig.name);
            }
        }
        Ok(out)
    }
}

/// Marshal args into XLA literals, execute, unpack the result tuple.
#[cfg(feature = "pjrt")]
fn call_compiled(
    meta: &ArtifactMeta,
    exe: &xla::PjRtLoadedExecutable,
    args: &[ArgValue],
) -> Result<Vec<Vec<f32>>> {
    let mut literals = Vec::with_capacity(args.len());
    for (arg, sig) in args.iter().zip(&meta.inputs) {
        let lit = match (arg, sig.dtype) {
            (ArgValue::F32(data), ArtifactDType::F32) => {
                let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            (ArgValue::I32Slice(data), ArtifactDType::I32) => {
                let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(data).reshape(&dims)?
            }
            (ArgValue::I32(v), ArtifactDType::I32) => xla::Literal::scalar(*v),
            _ => bail!("{}: input '{}' dtype mismatch", meta.name, sig.name),
        };
        literals.push(lit);
    }
    let result = exe.execute::<xla::Literal>(&literals)?;
    let tuple = result[0][0].to_literal_sync()?;
    // aot.py lowers with return_tuple=True → always a tuple
    let parts = tuple.to_tuple()?;
    let mut out = Vec::with_capacity(parts.len());
    for lit in parts.iter() {
        out.push(lit.to_vec::<f32>()?);
    }
    Ok(out)
}

/// Executable registry: lazily instantiated, cached artifacts over one
/// manifest.  `!Send`: lives on the engine's compute thread (PJRT handles
/// are thread-pinned; the interpreter simply inherits the constraint).
pub struct Runtime {
    #[cfg(feature = "pjrt")]
    client: Option<xla::PjRtClient>,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
    compile_count: std::cell::Cell<usize>,
}

impl Runtime {
    /// Load the manifest from `dir`.  With the `pjrt` feature a CPU PJRT
    /// client is created and artifacts whose HLO files exist are compiled;
    /// otherwise everything runs on the interpreter.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        Self::from_manifest(manifest, true)
    }

    /// A runtime over an in-memory [`Manifest::synthetic`] manifest for the
    /// tiny model: everything executes on the interpreter, no files needed.
    pub fn synthetic() -> Self {
        let manifest = Manifest::synthetic(crate::config::ModelConfig::tiny());
        Self::from_manifest(manifest, false).expect("synthetic runtime construction is infallible")
    }

    /// [`Runtime::load`] when `dir/manifest.json` exists, otherwise
    /// [`Runtime::synthetic`] — the constructor the serving path uses so the
    /// whole stack runs with or without `make artifacts`.
    pub fn load_or_synthetic(dir: &Path) -> Result<Self> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::synthetic())
        }
    }

    fn from_manifest(manifest: Manifest, compiled: bool) -> Result<Self> {
        #[cfg(feature = "pjrt")]
        {
            let client = if compiled { Some(xla::PjRtClient::cpu()?) } else { None };
            Ok(Runtime {
                client,
                manifest,
                cache: RefCell::new(HashMap::new()),
                compile_count: std::cell::Cell::new(0),
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = compiled;
            Ok(Runtime {
                manifest,
                cache: RefCell::new(HashMap::new()),
                compile_count: std::cell::Cell::new(0),
            })
        }
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// How many artifacts have been instantiated so far (startup metric).
    pub fn compiled(&self) -> usize {
        self.compile_count.get()
    }

    /// Whether a PJRT client is active (artifacts may compile natively);
    /// `false` means every call runs on the interpreter.
    pub fn is_compiled(&self) -> bool {
        #[cfg(feature = "pjrt")]
        {
            self.client.is_some()
        }
        #[cfg(not(feature = "pjrt"))]
        {
            false
        }
    }

    fn make_backend(&self, meta: &ArtifactMeta) -> Result<Backend> {
        #[cfg(feature = "pjrt")]
        if let Some(client) = &self.client {
            let path = self.manifest.dir.join(&meta.file);
            if path.exists() {
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp)?;
                return Ok(Backend::Compiled(exe));
            }
        }
        let _ = meta;
        Ok(Backend::Interp(InterpCtx {
            model: self.manifest.model.clone(),
            seq_cap: self.manifest.seq_cap,
        }))
    }

    /// Fetch (instantiating on first use) the named artifact.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("no artifact '{name}' in manifest"))?
            .clone();
        let backend = self.make_backend(&meta)?;
        self.compile_count.set(self.compile_count.get() + 1);
        let artifact = Rc::new(Artifact { meta, backend });
        self.cache.borrow_mut().insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Pre-instantiate every artifact needed for decode at batch bucket `b`
    /// (keeps first-token latency off the serving path).
    pub fn warmup_decode(&self, b: usize) -> Result<()> {
        let m = &self.manifest;
        self.artifact(&m.embed_decode_name(b))?;
        self.artifact(&m.lm_head_name(b))?;
        self.artifact(&m.decode_full_name(b))?;
        for &l in &m.l_buckets.clone() {
            self.artifact(&m.recompute_name(b, l))?;
            self.artifact(&m.decode_merge_name(b, l))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(&dir).expect("runtime loads"))
        } else {
            None
        }
    }

    #[test]
    fn embed_decode_executes() {
        let Some(rt) = runtime() else { return };
        let w = crate::model::ModelWeights::generate(&rt.manifest().model, 1);
        let a = rt.artifact(&rt.manifest().embed_decode_name(1)).unwrap();
        let ids = [42i32];
        let out = a
            .call(&[
                ArgValue::I32Slice(&ids),
                ArgValue::I32(3),
                ArgValue::F32(&w.tok_table),
                ArgValue::F32(&w.pos_table),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 256);
        // parity with the Rust reference
        let rm = crate::model::RefModel::new(w);
        let want = rm.embed_decode(&ids, 3);
        for (a, b) in out[0].iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn arity_and_shape_validated() {
        let Some(rt) = runtime() else { return };
        let a = rt.artifact("embed_decode_b1").unwrap();
        assert!(a.call(&[]).is_err());
        let ids = [1i32, 2];
        let junk = [0f32; 4];
        assert!(a
            .call(&[
                ArgValue::I32Slice(&ids), // wrong numel (2 vs 1)
                ArgValue::I32(0),
                ArgValue::F32(&junk),
                ArgValue::F32(&junk),
            ])
            .is_err());
    }

    #[test]
    fn cache_compiles_once() {
        let Some(rt) = runtime() else { return };
        let _ = rt.artifact("lm_head_b1").unwrap();
        let n = rt.compiled();
        let _ = rt.artifact("lm_head_b1").unwrap();
        assert_eq!(rt.compiled(), n);
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.artifact("nope_b9").is_err());
    }

    // ---- interpreter backend (always runnable, no artifacts needed) ------

    #[test]
    fn synthetic_runtime_embed_matches_reference() {
        let rt = Runtime::synthetic();
        let w = crate::model::ModelWeights::generate(&rt.manifest().model, 1);
        let a = rt.artifact(&rt.manifest().embed_decode_name(1)).unwrap();
        assert!(a.is_interpreted());
        let ids = [42i32];
        let out = a
            .call(&[
                ArgValue::I32Slice(&ids),
                ArgValue::I32(3),
                ArgValue::F32(&w.tok_table),
                ArgValue::F32(&w.pos_table),
            ])
            .unwrap();
        let rm = crate::model::RefModel::new(w);
        assert_eq!(out[0], rm.embed_decode(&ids, 3));
    }

    #[test]
    fn synthetic_runtime_validates_arity() {
        let rt = Runtime::synthetic();
        let a = rt.artifact("embed_decode_b1").unwrap();
        assert!(a.call(&[]).is_err());
    }

    #[test]
    fn synthetic_decode_paths_agree() {
        // decode_full over a spliced cache == decode_merge over its parts:
        // the same consistency contract `parity.rs` pins for compiled HLO.
        let rt = Runtime::synthetic();
        let m = rt.manifest().clone();
        let h = m.model.hidden;
        let cap = m.seq_cap;
        let w = crate::model::ModelWeights::generate(&m.model, 13);
        let (b, l, kv_len) = (1usize, 32usize, 50usize);

        let mut rng = crate::util::prng::Prng::new(9);
        let x: Vec<f32> = rng.normal_vec_f32(b * h, 0.1);
        let x_pre: Vec<f32> = rng.normal_vec_f32(b * l * h, 0.1);
        let k_rest: Vec<f32> = rng.normal_vec_f32(b * (cap - l) * h, 0.1);
        let v_rest: Vec<f32> = rng.normal_vec_f32(b * (cap - l) * h, 0.1);

        let lw = w.layer(0);
        let rec = rt.artifact(&m.recompute_name(b, l)).unwrap();
        let re = rec
            .call(&[
                ArgValue::F32(&x_pre),
                ArgValue::F32(lw.get("ln1_g")),
                ArgValue::F32(lw.get("ln1_b")),
                ArgValue::F32(lw.get("wk")),
                ArgValue::F32(lw.get("bk")),
                ArgValue::F32(lw.get("wv")),
                ArgValue::F32(lw.get("bv")),
            ])
            .unwrap();

        let mut kc = re[0].clone();
        kc.extend_from_slice(&k_rest);
        let mut vc = re[1].clone();
        vc.extend_from_slice(&v_rest);
        let full = rt.artifact(&m.decode_full_name(b)).unwrap();
        let mut args = vec![
            ArgValue::F32(&x),
            ArgValue::F32(&kc),
            ArgValue::F32(&vc),
            ArgValue::I32(kv_len as i32),
        ];
        for (_, d, _) in w.layer(0).iter() {
            args.push(ArgValue::F32(d.as_slice()));
        }
        let out_full = full.call(&args).unwrap();

        let merge = rt.artifact(&m.decode_merge_name(b, l)).unwrap();
        let mut args = vec![
            ArgValue::F32(&x),
            ArgValue::F32(&re[0]),
            ArgValue::F32(&re[1]),
            ArgValue::F32(&k_rest),
            ArgValue::F32(&v_rest),
            ArgValue::I32(kv_len as i32),
        ];
        for (_, d, _) in w.layer(0).iter() {
            args.push(ArgValue::F32(d.as_slice()));
        }
        let out_split = merge.call(&args).unwrap();

        for i in 0..3 {
            for (a, b) in out_full[i].iter().zip(&out_split[i]) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }
}
