//! Executable registry + literal marshalling.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::artifacts::{ArtifactDType, ArtifactMeta, Manifest};

/// An argument to an artifact call.
#[derive(Debug, Clone, Copy)]
pub enum ArgValue<'a> {
    /// Flat f32 tensor (shape comes from the signature).
    F32(&'a [f32]),
    /// Flat i32 tensor.
    I32Slice(&'a [i32]),
    /// Scalar i32 (e.g. `kv_len`, `pos`).
    I32(i32),
}

/// A compiled artifact bound to the PJRT client.
pub struct Artifact {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with positional args checked against the manifest signature.
    /// Returns one flat `Vec<f32>` per output (i32 outputs are unsupported —
    /// the tiny model has none).
    pub fn call(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        if args.len() != self.meta.inputs.len() {
            bail!(
                "{}: expected {} args, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                args.len()
            );
        }
        let mut literals = Vec::with_capacity(args.len());
        for (arg, sig) in args.iter().zip(&self.meta.inputs) {
            let lit = match (arg, sig.dtype) {
                (ArgValue::F32(data), ArtifactDType::F32) => {
                    if data.len() != sig.numel() {
                        bail!(
                            "{}: input '{}' numel {} != {}",
                            self.meta.name,
                            sig.name,
                            data.len(),
                            sig.numel()
                        );
                    }
                    let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                (ArgValue::I32Slice(data), ArtifactDType::I32) => {
                    if data.len() != sig.numel() {
                        bail!(
                            "{}: input '{}' numel {} != {}",
                            self.meta.name,
                            sig.name,
                            data.len(),
                            sig.numel()
                        );
                    }
                    let dims: Vec<i64> = sig.shape.iter().map(|&d| d as i64).collect();
                    xla::Literal::vec1(data).reshape(&dims)?
                }
                (ArgValue::I32(v), ArtifactDType::I32) => {
                    if !sig.shape.is_empty() {
                        bail!("{}: '{}' expects shape {:?}", self.meta.name, sig.name, sig.shape);
                    }
                    xla::Literal::scalar(*v)
                }
                _ => bail!(
                    "{}: input '{}' dtype mismatch",
                    self.meta.name,
                    sig.name
                ),
            };
            literals.push(lit);
        }

        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → always a tuple
        let parts = tuple.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            );
        }
        let mut out = Vec::with_capacity(parts.len());
        for (lit, sig) in parts.iter().zip(&self.meta.outputs) {
            let v = lit.to_vec::<f32>()?;
            if v.len() != sig.numel() {
                bail!("{}: output '{}' numel mismatch", self.meta.name, sig.name);
            }
            out.push(v);
        }
        Ok(out)
    }
}

/// PJRT client + lazily compiled executable cache.  `!Send`: lives on the
/// engine's compute thread.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Artifact>>>,
    compile_count: std::cell::Cell<usize>,
}

impl Runtime {
    /// Load the manifest from `dir` and create a CPU PJRT client.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            compile_count: std::cell::Cell::new(0),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// How many artifacts have been XLA-compiled so far (startup metric).
    pub fn compiled(&self) -> usize {
        self.compile_count.get()
    }

    /// Fetch (compiling on first use) the named artifact.
    pub fn artifact(&self, name: &str) -> Result<Rc<Artifact>> {
        if let Some(a) = self.cache.borrow().get(name) {
            return Ok(a.clone());
        }
        let meta = self
            .manifest
            .find(name)
            .with_context(|| format!("no artifact '{name}' in manifest"))?
            .clone();
        let path = self.manifest.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compile_count.set(self.compile_count.get() + 1);
        let artifact = Rc::new(Artifact { meta, exe });
        self.cache.borrow_mut().insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Pre-compile every artifact needed for decode at batch bucket `b`
    /// (keeps first-token latency off the serving path).
    pub fn warmup_decode(&self, b: usize) -> Result<()> {
        let m = &self.manifest;
        self.artifact(&m.embed_decode_name(b))?;
        self.artifact(&m.lm_head_name(b))?;
        self.artifact(&m.decode_full_name(b))?;
        for &l in &m.l_buckets.clone() {
            self.artifact(&m.recompute_name(b, l))?;
            self.artifact(&m.decode_merge_name(b, l))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn runtime() -> Option<Runtime> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Runtime::load(&dir).expect("runtime loads"))
        } else {
            None
        }
    }

    #[test]
    fn embed_decode_executes() {
        let Some(rt) = runtime() else { return };
        let w = crate::model::ModelWeights::generate(&rt.manifest().model, 1);
        let a = rt.artifact(&rt.manifest().embed_decode_name(1)).unwrap();
        let ids = [42i32];
        let out = a
            .call(&[
                ArgValue::I32Slice(&ids),
                ArgValue::I32(3),
                ArgValue::F32(&w.tok_table),
                ArgValue::F32(&w.pos_table),
            ])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 256);
        // parity with the Rust reference
        let rm = crate::model::RefModel::new(w);
        let want = rm.embed_decode(&ids, 3);
        for (a, b) in out[0].iter().zip(&want) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn arity_and_shape_validated() {
        let Some(rt) = runtime() else { return };
        let a = rt.artifact("embed_decode_b1").unwrap();
        assert!(a.call(&[]).is_err());
        let ids = [1i32, 2];
        let junk = [0f32; 4];
        assert!(a
            .call(&[
                ArgValue::I32Slice(&ids), // wrong numel (2 vs 1)
                ArgValue::I32(0),
                ArgValue::F32(&junk),
                ArgValue::F32(&junk),
            ])
            .is_err());
    }

    #[test]
    fn cache_compiles_once() {
        let Some(rt) = runtime() else { return };
        let _ = rt.artifact("lm_head_b1").unwrap();
        let n = rt.compiled();
        let _ = rt.artifact("lm_head_b1").unwrap();
        assert_eq!(rt.compiled(), n);
    }

    #[test]
    fn missing_artifact_errors() {
        let Some(rt) = runtime() else { return };
        assert!(rt.artifact("nope_b9").is_err());
    }
}
