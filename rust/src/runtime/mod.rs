//! The artifact runtime: load the AOT step functions and execute them on
//! the request path (paper §3.3's "runtime module" substrate).
//!
//! Two backends sit behind one [`Runtime`] API:
//!
//! * **PJRT** (`--features pjrt`): `PjRtClient::cpu()` →
//!   `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//!   `client.compile` → `execute`.  HLO *text* is the interchange format —
//!   `python/compile/aot.py` explains why.  PJRT handles are not
//!   `Send`/`Sync`; a [`Runtime`] therefore lives on the engine's compute
//!   thread.  Executables are compiled lazily on first use and cached.
//! * **Interpreter** (default): every artifact is evaluated with the
//!   pure-Rust [`RefModel`](crate::model::RefModel) math over the call's
//!   argument tensors — exactly what the HLO computes (`rust/tests/parity.rs`
//!   pins them against each other when artifacts are present).  With
//!   [`Manifest::synthetic`] this backend needs **no files at all**, which is
//!   what lets the serving stack and its tests run in a container that never
//!   ran `make artifacts`.
//!
//! The manifest (`manifest.json`) is the contract between the two worlds:
//! bucket grids, tensor signatures and the canonical per-layer weight order.

mod artifacts;
mod exec;
mod interp;

pub use artifacts::{ArtifactMeta, Manifest, TensorSig};
pub use exec::{ArgValue, Artifact, Runtime};
