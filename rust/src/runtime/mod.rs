//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`) and execute
//! them on the request path.
//!
//! Flow (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`.  HLO *text* is the interchange format —
//! `python/compile/aot.py` explains why.
//!
//! PJRT handles are not `Send`/`Sync`; a [`Runtime`] therefore lives on the
//! engine's compute thread.  Executables are compiled lazily on first use
//! and cached for the lifetime of the runtime.

mod artifacts;
mod exec;

pub use artifacts::{ArtifactMeta, Manifest, TensorSig};
pub use exec::{ArgValue, Artifact, Runtime};
