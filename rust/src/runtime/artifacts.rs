//! Manifest parsing: the signature registry emitted by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::util::json::Json;

/// Dtype of an artifact input/output (the tiny model only uses these two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

/// One tensor in an artifact signature.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSig {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let name = j.at(&["name"]).as_str().context("tensor name")?.to_string();
        let shape = j
            .at(&["shape"])
            .as_arr()
            .context("tensor shape")?
            .iter()
            .map(|d| d.as_usize().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = match j.at(&["dtype"]).as_str() {
            Some("float32") => DType::F32,
            Some("int32") => DType::I32,
            other => bail!("unsupported dtype {other:?}"),
        };
        Ok(TensorSig { name, shape, dtype })
    }
}

/// Metadata for one AOT-compiled step function.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    /// Function kind: embed_decode | lm_head | decode_full | decode_partial
    /// | recompute | decode_merge | prefill.
    pub kind: String,
    pub b: usize,
    pub s: usize,
    pub l: usize,
    pub sp: usize,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub model: ModelConfig,
    pub batch_buckets: Vec<usize>,
    pub seq_cap: usize,
    pub l_buckets: Vec<usize>,
    pub prompt_buckets: Vec<usize>,
    pub layer_weight_names: Vec<String>,
    pub artifacts: Vec<ArtifactMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let m = j.at(&["model"]);
        let mut model = ModelConfig::tiny();
        model.name = m.at(&["name"]).as_str().context("model.name")?.to_string();
        model.hidden = m.at(&["hidden"]).as_usize().context("hidden")?;
        model.n_heads = m.at(&["n_heads"]).as_usize().context("n_heads")?;
        model.n_layers = m.at(&["n_layers"]).as_usize().context("n_layers")?;
        model.ffn = m.at(&["ffn"]).as_usize().context("ffn")?;
        model.vocab = m.at(&["vocab"]).as_usize().context("vocab")?;
        model.max_pos = m.at(&["max_pos"]).as_usize().context("max_pos")?;

        let get_buckets = |key: &str| -> Result<Vec<usize>> {
            j.at(&["buckets", key])
                .as_arr()
                .with_context(|| format!("buckets.{key}"))?
                .iter()
                .map(|v| v.as_usize().context("bucket"))
                .collect()
        };

        let artifacts = j
            .at(&["artifacts"])
            .as_arr()
            .context("artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: a.at(&["name"]).as_str().context("name")?.to_string(),
                    file: a.at(&["file"]).as_str().context("file")?.to_string(),
                    kind: a.at(&["fn"]).as_str().context("fn")?.to_string(),
                    b: a.at(&["b"]).as_usize().unwrap_or(0),
                    s: a.at(&["s"]).as_usize().unwrap_or(0),
                    l: a.at(&["l"]).as_usize().unwrap_or(0),
                    sp: a.at(&["sp"]).as_usize().unwrap_or(0),
                    inputs: a
                        .at(&["inputs"])
                        .as_arr()
                        .context("inputs")?
                        .iter()
                        .map(TensorSig::from_json)
                        .collect::<Result<Vec<_>>>()?,
                    outputs: a
                        .at(&["outputs"])
                        .as_arr()
                        .context("outputs")?
                        .iter()
                        .map(TensorSig::from_json)
                        .collect::<Result<Vec<_>>>()?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let layer_weight_names = j
            .at(&["layer_weight_names"])
            .as_arr()
            .context("layer_weight_names")?
            .iter()
            .map(|v| Ok(v.as_str().context("weight name")?.to_string()))
            .collect::<Result<Vec<_>>>()?;

        // cross-check the canonical weight order against the Rust constant —
        // a silent mismatch here would mis-wire every weight matrix
        if layer_weight_names != crate::model::LAYER_WEIGHT_NAMES {
            bail!("manifest layer_weight_names diverge from rust LAYER_WEIGHT_NAMES");
        }

        Ok(Manifest {
            model,
            batch_buckets: get_buckets("batch")?,
            seq_cap: j.at(&["buckets", "seq_cap"]).as_usize().context("seq_cap")?,
            l_buckets: get_buckets("l")?,
            prompt_buckets: get_buckets("prompt")?,
            layer_weight_names,
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    /// Build a manifest **in memory**, with the same bucket grid the AOT
    /// pipeline emits for the tiny model, so the interpreter runtime can
    /// execute without `make artifacts` ever having run.  Signatures match
    /// `python/compile/aot.py` exactly; only the `.hlo.txt` files (which the
    /// interpreter never reads) are absent.
    pub fn synthetic(model: ModelConfig) -> Self {
        Self::synthetic_with(model, vec![1, 2, 4, 8], 128, vec![32, 64, 96], vec![16, 32, 64])
    }

    /// [`Manifest::synthetic`] with explicit bucket grids.
    pub fn synthetic_with(
        model: ModelConfig,
        batch_buckets: Vec<usize>,
        seq_cap: usize,
        l_buckets: Vec<usize>,
        prompt_buckets: Vec<usize>,
    ) -> Self {
        let h = model.hidden;
        let f32s = |name: &str, shape: Vec<usize>| TensorSig {
            name: name.to_string(),
            shape,
            dtype: DType::F32,
        };
        let i32s = |name: &str, shape: Vec<usize>| TensorSig {
            name: name.to_string(),
            shape,
            dtype: DType::I32,
        };
        let weight_sigs = || -> Vec<TensorSig> {
            crate::model::LAYER_WEIGHT_NAMES
                .iter()
                .map(|&n| {
                    let shape = match n {
                        "wq" | "wk" | "wv" | "wo" => vec![h, h],
                        "w1" => vec![h, model.ffn],
                        "w2" => vec![model.ffn, h],
                        "b1" => vec![model.ffn],
                        _ => vec![h],
                    };
                    f32s(n, shape)
                })
                .collect()
        };
        let tok_table = || f32s("tok_table", vec![model.vocab, h]);
        let pos_table = || f32s("pos_table", vec![model.max_pos, h]);

        let mut artifacts = Vec::new();
        let mut push = |name: String, kind: &str, b: usize, s: usize, l: usize, sp: usize,
                        inputs: Vec<TensorSig>, outputs: Vec<TensorSig>| {
            artifacts.push(ArtifactMeta {
                file: format!("{name}.hlo.txt"),
                name,
                kind: kind.to_string(),
                b,
                s,
                l,
                sp,
                inputs,
                outputs,
            });
        };

        for &b in &batch_buckets {
            push(
                format!("embed_decode_b{b}"),
                "embed_decode",
                b, 0, 0, 0,
                vec![i32s("ids", vec![b]), i32s("pos", vec![]), tok_table(), pos_table()],
                vec![f32s("x", vec![b, 1, h])],
            );
            push(
                format!("lm_head_b{b}"),
                "lm_head",
                b, 0, 0, 0,
                vec![
                    f32s("x", vec![b, 1, h]),
                    tok_table(),
                    f32s("lnf_g", vec![h]),
                    f32s("lnf_b", vec![h]),
                ],
                vec![f32s("logits", vec![b, model.vocab])],
            );
            push(
                format!("decode_full_b{b}_s{seq_cap}"),
                "decode_full",
                b, seq_cap, 0, 0,
                [
                    vec![
                        f32s("x", vec![b, 1, h]),
                        f32s("k_cache", vec![b, seq_cap, h]),
                        f32s("v_cache", vec![b, seq_cap, h]),
                        i32s("kv_len", vec![]),
                    ],
                    weight_sigs(),
                ]
                .concat(),
                vec![
                    f32s("y", vec![b, 1, h]),
                    f32s("k_new", vec![b, 1, h]),
                    f32s("v_new", vec![b, 1, h]),
                ],
            );
            for &sp in &prompt_buckets {
                let mut inputs = vec![
                    i32s("ids", vec![b, sp]),
                    tok_table(),
                    pos_table(),
                    f32s("lnf_g", vec![h]),
                    f32s("lnf_b", vec![h]),
                ];
                for _ in 0..model.n_layers {
                    inputs.extend(weight_sigs());
                }
                push(
                    format!("prefill_b{b}_p{sp}"),
                    "prefill",
                    b, 0, 0, sp,
                    inputs,
                    vec![
                        f32s("logits", vec![b, model.vocab]),
                        f32s("k_stack", vec![model.n_layers, b, sp, h]),
                        f32s("v_stack", vec![model.n_layers, b, sp, h]),
                        f32s("x_stack", vec![model.n_layers, b, sp, h]),
                    ],
                );
            }
            for &l in &l_buckets {
                push(
                    format!("recompute_b{b}_l{l}"),
                    "recompute",
                    b, 0, l, 0,
                    vec![
                        f32s("x_pre", vec![b, l, h]),
                        f32s("ln1_g", vec![h]),
                        f32s("ln1_b", vec![h]),
                        f32s("wk", vec![h, h]),
                        f32s("bk", vec![h]),
                        f32s("wv", vec![h, h]),
                        f32s("bv", vec![h]),
                    ],
                    vec![f32s("k_pre", vec![b, l, h]), f32s("v_pre", vec![b, l, h])],
                );
                push(
                    format!("decode_merge_b{b}_s{seq_cap}_l{l}"),
                    "decode_merge",
                    b, seq_cap, l, 0,
                    [
                        vec![
                            f32s("x", vec![b, 1, h]),
                            f32s("k_pre", vec![b, l, h]),
                            f32s("v_pre", vec![b, l, h]),
                            f32s("k_rest", vec![b, seq_cap - l, h]),
                            f32s("v_rest", vec![b, seq_cap - l, h]),
                            i32s("kv_len", vec![]),
                        ],
                        weight_sigs(),
                    ]
                    .concat(),
                    vec![
                        f32s("y", vec![b, 1, h]),
                        f32s("k_new", vec![b, 1, h]),
                        f32s("v_new", vec![b, 1, h]),
                    ],
                );
                push(
                    format!("decode_partial_b{b}_s{seq_cap}_l{l}"),
                    "decode_partial",
                    b, seq_cap, l, 0,
                    [
                        vec![
                            f32s("x", vec![b, 1, h]),
                            f32s("x_pre", vec![b, l, h]),
                            f32s("k_rest", vec![b, seq_cap - l, h]),
                            f32s("v_rest", vec![b, seq_cap - l, h]),
                            i32s("kv_len", vec![]),
                        ],
                        weight_sigs(),
                    ]
                    .concat(),
                    vec![
                        f32s("y", vec![b, 1, h]),
                        f32s("k_new", vec![b, 1, h]),
                        f32s("v_new", vec![b, 1, h]),
                    ],
                );
            }
        }

        let layer_weight_names = crate::model::LAYER_WEIGHT_NAMES
            .iter()
            .map(|s| s.to_string())
            .collect();
        Manifest {
            model,
            batch_buckets,
            seq_cap,
            l_buckets,
            prompt_buckets,
            layer_weight_names,
            artifacts,
            dir: PathBuf::new(),
        }
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    // -- canonical artifact names ------------------------------------------

    pub fn embed_decode_name(&self, b: usize) -> String {
        format!("embed_decode_b{b}")
    }

    pub fn lm_head_name(&self, b: usize) -> String {
        format!("lm_head_b{b}")
    }

    pub fn decode_full_name(&self, b: usize) -> String {
        format!("decode_full_b{b}_s{}", self.seq_cap)
    }

    pub fn decode_partial_name(&self, b: usize, l: usize) -> String {
        format!("decode_partial_b{b}_s{}_l{l}", self.seq_cap)
    }

    pub fn recompute_name(&self, b: usize, l: usize) -> String {
        format!("recompute_b{b}_l{l}")
    }

    pub fn decode_merge_name(&self, b: usize, l: usize) -> String {
        format!("decode_merge_b{b}_s{}_l{l}", self.seq_cap)
    }

    pub fn prefill_name(&self, b: usize, sp: usize) -> String {
        format!("prefill_b{b}_p{sp}")
    }

    /// Smallest batch bucket that fits `n` sequences.
    pub fn batch_bucket_for(&self, n: usize) -> Option<usize> {
        self.batch_buckets.iter().copied().filter(|&b| b >= n).min()
    }

    /// Smallest prompt bucket that fits `len` tokens.
    pub fn prompt_bucket_for(&self, len: usize) -> Option<usize> {
        self.prompt_buckets.iter().copied().filter(|&p| p >= len).min()
    }
}

pub(crate) use DType as ArtifactDType;

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn manifest() -> Option<Manifest> {
        let dir = art_dir();
        if dir.join("manifest.json").exists() {
            Some(Manifest::load(&dir).expect("manifest parses"))
        } else {
            None
        }
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.model.name, "kvpr-tiny");
        assert_eq!(m.model.hidden, 256);
        assert_eq!(m.seq_cap, 128);
        assert!(!m.l_buckets.is_empty());
        assert!(m.artifacts.len() >= 16);
    }

    #[test]
    fn canonical_names_resolve() {
        let Some(m) = manifest() else { return };
        for &b in &m.batch_buckets.clone() {
            assert!(m.find(&m.embed_decode_name(b)).is_some());
            assert!(m.find(&m.lm_head_name(b)).is_some());
            assert!(m.find(&m.decode_full_name(b)).is_some());
            for &l in &m.l_buckets.clone() {
                assert!(m.find(&m.decode_partial_name(b, l)).is_some());
                assert!(m.find(&m.recompute_name(b, l)).is_some());
                assert!(m.find(&m.decode_merge_name(b, l)).is_some());
            }
            for &sp in &m.prompt_buckets.clone() {
                assert!(m.find(&m.prefill_name(b, sp)).is_some());
            }
        }
    }

    #[test]
    fn signatures_have_weights_in_canonical_order() {
        let Some(m) = manifest() else { return };
        let a = m.find(&m.decode_full_name(1)).unwrap();
        let tail: Vec<&str> = a.inputs[4..].iter().map(|t| t.name.as_str()).collect();
        assert_eq!(tail, crate::model::LAYER_WEIGHT_NAMES);
    }

    #[test]
    fn bucket_selection() {
        let Some(m) = manifest() else { return };
        assert_eq!(m.batch_bucket_for(1), Some(1));
        assert_eq!(m.batch_bucket_for(3), Some(4));
        assert_eq!(m.batch_bucket_for(100), None);
        assert_eq!(m.prompt_bucket_for(10), Some(16));
        assert_eq!(m.prompt_bucket_for(17), Some(32));
    }

    #[test]
    fn synthetic_manifest_resolves_canonical_names() {
        let m = Manifest::synthetic(ModelConfig::tiny());
        assert_eq!(m.seq_cap, 128);
        for &b in &m.batch_buckets.clone() {
            assert!(m.find(&m.embed_decode_name(b)).is_some());
            assert!(m.find(&m.lm_head_name(b)).is_some());
            assert!(m.find(&m.decode_full_name(b)).is_some());
            for &l in &m.l_buckets.clone() {
                assert!(m.find(&m.decode_partial_name(b, l)).is_some());
                assert!(m.find(&m.recompute_name(b, l)).is_some());
                assert!(m.find(&m.decode_merge_name(b, l)).is_some());
            }
            for &sp in &m.prompt_buckets.clone() {
                assert!(m.find(&m.prefill_name(b, sp)).is_some());
            }
        }
        // weight tail in canonical order, exactly like the AOT manifest
        let a = m.find(&m.decode_full_name(1)).unwrap();
        let tail: Vec<&str> = a.inputs[4..].iter().map(|t| t.name.as_str()).collect();
        assert_eq!(tail, crate::model::LAYER_WEIGHT_NAMES);
    }

    #[test]
    fn hlo_files_exist() {
        let Some(m) = manifest() else { return };
        for a in &m.artifacts {
            assert!(m.dir.join(&a.file).exists(), "{}", a.file);
        }
    }
}
