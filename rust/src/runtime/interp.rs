//! Pure-Rust artifact interpreter — the runtime backend used when PJRT is
//! unavailable (no `pjrt` feature, or no compiled `.hlo.txt` on disk).
//!
//! Each artifact kind is executed with [`RefModel`] math over the *argument*
//! tensors, so the interpreter computes exactly what the compiled HLO
//! computes (the parity tests in `rust/tests/parity.rs` pin the two against
//! each other whenever real artifacts are present).  Weights always arrive
//! as call arguments — never from engine state — mirroring the offloading
//! regime where weights stream over the link every layer.
//!
//! Performance note: this path re-wraps argument weight slices into
//! [`LayerWeights`] per call (one copy per layer per step).  That is fine
//! for the tiny model the interpreter serves; the PJRT path keeps weights
//! device-resident and pays nothing.

use std::sync::Arc;

use anyhow::{bail, Result};

use super::artifacts::ArtifactMeta;
use super::exec::ArgValue;
use crate::config::ModelConfig;
use crate::model::{LayerWeights, ModelWeights, RefModel, LAYER_WEIGHT_NAMES};

/// What the interpreter needs beyond the artifact metadata.
pub(crate) struct InterpCtx {
    pub model: ModelConfig,
    pub seq_cap: usize,
}

fn f32_arg<'a>(meta: &ArtifactMeta, args: &'a [ArgValue], i: usize) -> Result<&'a [f32]> {
    match args.get(i) {
        Some(ArgValue::F32(d)) => Ok(d),
        _ => bail!("{}: arg {i} must be an f32 tensor", meta.name),
    }
}

fn i32_slice_arg<'a>(meta: &ArtifactMeta, args: &'a [ArgValue], i: usize) -> Result<&'a [i32]> {
    match args.get(i) {
        Some(ArgValue::I32Slice(d)) => Ok(d),
        _ => bail!("{}: arg {i} must be an i32 tensor", meta.name),
    }
}

fn i32_scalar_arg(meta: &ArtifactMeta, args: &[ArgValue], i: usize) -> Result<i32> {
    match args.get(i) {
        Some(ArgValue::I32(v)) => Ok(*v),
        _ => bail!("{}: arg {i} must be a scalar i32", meta.name),
    }
}

fn weight_shape(name: &str, h: usize, f: usize) -> Vec<usize> {
    match name {
        "wq" | "wk" | "wv" | "wo" => vec![h, h],
        "w1" => vec![h, f],
        "w2" => vec![f, h],
        "b1" => vec![f],
        _ => vec![h],
    }
}

/// Rebuild one layer's [`LayerWeights`] from 16 consecutive f32 args.
fn layer_weights(
    meta: &ArtifactMeta,
    model: &ModelConfig,
    args: &[ArgValue],
    off: usize,
) -> Result<LayerWeights> {
    let mut tensors = Vec::with_capacity(LAYER_WEIGHT_NAMES.len());
    for (j, &name) in LAYER_WEIGHT_NAMES.iter().enumerate() {
        let data = f32_arg(meta, args, off + j)?;
        tensors.push((
            name.to_string(),
            Arc::new(data.to_vec()),
            weight_shape(name, model.hidden, model.ffn),
        ));
    }
    Ok(LayerWeights::from_tensors(tensors))
}

/// A [`ModelWeights`] carrying only one decoder layer (head tables empty):
/// enough for [`RefModel::decode_layer_full`].
fn single_layer_model(model: &ModelConfig, lw: LayerWeights) -> RefModel {
    RefModel::new(ModelWeights {
        config: model.clone(),
        tok_table: Arc::new(Vec::new()),
        pos_table: Arc::new(Vec::new()),
        lnf_g: Arc::new(Vec::new()),
        lnf_b: Arc::new(Vec::new()),
        layers: vec![lw],
    })
}

/// Splice a recomputed `[b, l, h]` prefix and a transferred `[b, cap-l, h]`
/// remainder into one padded `[b, cap, h]` cache.
fn splice_cache(pre: &[f32], rest: &[f32], b: usize, l: usize, cap: usize, h: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; b * cap * h];
    let rest_rows = cap - l;
    for bi in 0..b {
        let dst = bi * cap * h;
        out[dst..dst + l * h].copy_from_slice(&pre[bi * l * h..(bi + 1) * l * h]);
        out[dst + l * h..dst + cap * h]
            .copy_from_slice(&rest[bi * rest_rows * h..(bi + 1) * rest_rows * h]);
    }
    out
}

/// Execute `meta` over `args`; returns one flat f32 vector per output.
pub(crate) fn execute(
    meta: &ArtifactMeta,
    ctx: &InterpCtx,
    args: &[ArgValue],
) -> Result<Vec<Vec<f32>>> {
    let model = &ctx.model;
    let h = model.hidden;
    match meta.kind.as_str() {
        "embed_decode" => {
            let ids = i32_slice_arg(meta, args, 0)?;
            let pos = i32_scalar_arg(meta, args, 1)? as usize;
            let tok = f32_arg(meta, args, 2)?;
            let pt = f32_arg(meta, args, 3)?;
            let mut out = Vec::with_capacity(ids.len() * h);
            for &id in ids {
                let t = &tok[id as usize * h..(id as usize + 1) * h];
                let p = &pt[pos * h..(pos + 1) * h];
                out.extend(t.iter().zip(p).map(|(a, b)| a + b));
            }
            Ok(vec![out])
        }
        "lm_head" => {
            let x = f32_arg(meta, args, 0)?;
            let tok = f32_arg(meta, args, 1)?;
            let g = f32_arg(meta, args, 2)?;
            let bb = f32_arg(meta, args, 3)?;
            let v = model.vocab;
            let ln = RefModel::layernorm(x, g, bb, h);
            let b = x.len() / h;
            let mut out = vec![0.0f32; b * v];
            for r in 0..b {
                let xr = &ln[r * h..(r + 1) * h];
                for t in 0..v {
                    let row = &tok[t * h..(t + 1) * h];
                    out[r * v + t] = xr.iter().zip(row).map(|(a, b)| a * b).sum();
                }
            }
            Ok(vec![out])
        }
        "prefill" => {
            let ids = i32_slice_arg(meta, args, 0)?;
            let (b, sp) = (meta.b, meta.sp);
            let mut layers = Vec::with_capacity(model.n_layers);
            for i in 0..model.n_layers {
                layers.push(layer_weights(meta, model, args, 5 + i * LAYER_WEIGHT_NAMES.len())?);
            }
            let rm = RefModel::new(ModelWeights {
                config: model.clone(),
                tok_table: Arc::new(f32_arg(meta, args, 1)?.to_vec()),
                pos_table: Arc::new(f32_arg(meta, args, 2)?.to_vec()),
                lnf_g: Arc::new(f32_arg(meta, args, 3)?.to_vec()),
                lnf_b: Arc::new(f32_arg(meta, args, 4)?.to_vec()),
                layers,
            });
            let (logits, per_layer) = rm.prefill(ids, b, sp);
            let chunk = b * sp * h;
            let mut k_stack = Vec::with_capacity(model.n_layers * chunk);
            let mut v_stack = Vec::with_capacity(model.n_layers * chunk);
            let mut x_stack = Vec::with_capacity(model.n_layers * chunk);
            for (k, v, x) in per_layer {
                k_stack.extend_from_slice(&k);
                v_stack.extend_from_slice(&v);
                x_stack.extend_from_slice(&x);
            }
            Ok(vec![logits, k_stack, v_stack, x_stack])
        }
        "decode_full" => {
            let x = f32_arg(meta, args, 0)?;
            let kc = f32_arg(meta, args, 1)?;
            let vc = f32_arg(meta, args, 2)?;
            let kv_len = i32_scalar_arg(meta, args, 3)? as usize;
            let lw = layer_weights(meta, model, args, 4)?;
            let rm = single_layer_model(model, lw);
            let (y, kn, vn) = rm.decode_layer_full(0, x, kc, vc, ctx.seq_cap, kv_len, meta.b);
            Ok(vec![y, kn, vn])
        }
        "recompute" => {
            let x_pre = f32_arg(meta, args, 0)?;
            let rows = meta.b * meta.l;
            let ln = RefModel::layernorm(x_pre, f32_arg(meta, args, 1)?, f32_arg(meta, args, 2)?, h);
            let k = RefModel::linear(&ln, f32_arg(meta, args, 3)?, f32_arg(meta, args, 4)?, rows, h, h);
            let v = RefModel::linear(&ln, f32_arg(meta, args, 5)?, f32_arg(meta, args, 6)?, rows, h, h);
            Ok(vec![k, v])
        }
        "decode_merge" => {
            let x = f32_arg(meta, args, 0)?;
            let k_pre = f32_arg(meta, args, 1)?;
            let v_pre = f32_arg(meta, args, 2)?;
            let k_rest = f32_arg(meta, args, 3)?;
            let v_rest = f32_arg(meta, args, 4)?;
            let kv_len = i32_scalar_arg(meta, args, 5)? as usize;
            let (b, l, cap) = (meta.b, meta.l, ctx.seq_cap);
            let kc = splice_cache(k_pre, k_rest, b, l, cap, h);
            let vc = splice_cache(v_pre, v_rest, b, l, cap, h);
            let lw = layer_weights(meta, model, args, 6)?;
            let rm = single_layer_model(model, lw);
            let (y, kn, vn) = rm.decode_layer_full(0, x, &kc, &vc, cap, kv_len, b);
            Ok(vec![y, kn, vn])
        }
        "decode_partial" => {
            let x = f32_arg(meta, args, 0)?;
            let x_pre = f32_arg(meta, args, 1)?;
            let k_rest = f32_arg(meta, args, 2)?;
            let v_rest = f32_arg(meta, args, 3)?;
            let kv_len = i32_scalar_arg(meta, args, 4)? as usize;
            let (b, l, cap) = (meta.b, meta.l, ctx.seq_cap);
            let lw = layer_weights(meta, model, args, 5)?;
            // fused = recompute + merge in one call
            let rows = b * l;
            let ln = RefModel::layernorm(x_pre, lw.get("ln1_g"), lw.get("ln1_b"), h);
            let k_pre = RefModel::linear(&ln, lw.get("wk"), lw.get("bk"), rows, h, h);
            let v_pre = RefModel::linear(&ln, lw.get("wv"), lw.get("bv"), rows, h, h);
            let kc = splice_cache(&k_pre, k_rest, b, l, cap, h);
            let vc = splice_cache(&v_pre, v_rest, b, l, cap, h);
            let rm = single_layer_model(model, lw);
            let (y, kn, vn) = rm.decode_layer_full(0, x, &kc, &vc, cap, kv_len, b);
            Ok(vec![y, kn, vn])
        }
        other => bail!("{}: interpreter has no kernel for kind '{other}'", meta.name),
    }
}
