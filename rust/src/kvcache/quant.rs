//! Group-wise 4-bit KV-cache quantization (paper §4.4, following FlexGen).
//!
//! Values are grouped along the hidden dimension (`group` elements per
//! group); each group stores an f32 scale + zero-point and packs two 4-bit
//! codes per byte.  On the wire this is what the link transfers; the engine
//! dequantizes on the "device" side before handing the artifact its f32
//! inputs — the same place the paper's CUDA kernel dequantizes.
//!
//! Wire size per group: 8 bytes header + group/2 bytes payload.  At the
//! paper's group size 64 that is 0.625 bytes/element vs 2 (fp16) → a 3.2×
//! transfer reduction; at our f32 host width it is a 6.4× reduction.

use anyhow::{bail, Result};

pub const DEFAULT_GROUP: usize = 64;

/// A quantized tensor (flat, grouped along the last axis).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantBlock {
    pub n: usize,
    pub group: usize,
    /// per-group (min, scale) pairs
    pub headers: Vec<(f32, f32)>,
    /// two 4-bit codes per byte, low nibble first
    pub packed: Vec<u8>,
}

impl QuantBlock {
    /// Wire bytes this block occupies (what the link is charged).
    pub fn wire_bytes(&self) -> u64 {
        (self.headers.len() * 8 + self.packed.len()) as u64
    }

    /// Compression ratio vs f32.
    pub fn ratio_vs_f32(&self) -> f64 {
        (self.n * 4) as f64 / self.wire_bytes() as f64
    }
}

/// Quantize `data` group-wise to 4 bits (asymmetric min/max).
pub fn quantize(data: &[f32], group: usize) -> Result<QuantBlock> {
    if group == 0 || group % 2 != 0 {
        bail!("group size must be even and nonzero");
    }
    let n = data.len();
    let n_groups = n.div_ceil(group);
    let mut headers = Vec::with_capacity(n_groups);
    let mut packed = vec![0u8; n.div_ceil(2)];

    for g in 0..n_groups {
        let lo = g * group;
        let hi = (lo + group).min(n);
        let chunk = &data[lo..hi];
        let mut min = f32::INFINITY;
        let mut max = f32::NEG_INFINITY;
        for &x in chunk {
            if !x.is_finite() {
                bail!("non-finite input to quantizer");
            }
            min = min.min(x);
            max = max.max(x);
        }
        let scale = if max > min { (max - min) / 15.0 } else { 1.0 };
        headers.push((min, scale));
        for (i, &x) in chunk.iter().enumerate() {
            let q = (((x - min) / scale).round() as i32).clamp(0, 15) as u8;
            let idx = lo + i;
            if idx % 2 == 0 {
                packed[idx / 2] |= q;
            } else {
                packed[idx / 2] |= q << 4;
            }
        }
    }
    Ok(QuantBlock { n, group, headers, packed })
}

/// Dequantize into `out` (cleared and refilled).
pub fn dequantize(block: &QuantBlock, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(block.n);
    for idx in 0..block.n {
        let byte = block.packed[idx / 2];
        let q = if idx % 2 == 0 { byte & 0x0f } else { byte >> 4 };
        let (min, scale) = block.headers[idx / block.group];
        out.push(min + q as f32 * scale);
    }
}

/// Max absolute reconstruction error bound for a group with range r:
/// scale/2 = r/30.
pub fn error_bound(data: &[f32], group: usize) -> f32 {
    data.chunks(group)
        .map(|c| {
            let min = c.iter().copied().fold(f32::INFINITY, f32::min);
            let max = c.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            (max - min) / 30.0 + 1e-7
        })
        .fold(0.0, f32::max)
}

/// Wire bytes for quantizing `n` f32 elements at `group` (without building
/// the block) — used by the scheduler/simulator for transfer-volume math.
pub fn wire_bytes_for(n: usize, group: usize) -> u64 {
    (n.div_ceil(group) * 8 + n.div_ceil(2)) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{check_property, Prng};

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Prng::new(1);
        let data = rng.normal_vec_f32(1024, 1.0);
        let block = quantize(&data, 64).unwrap();
        let mut out = Vec::new();
        dequantize(&block, &mut out);
        assert_eq!(out.len(), data.len());
        let bound = error_bound(&data, 64);
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() <= bound, "{a} vs {b} (bound {bound})");
        }
    }

    #[test]
    fn constant_group_is_exact() {
        let data = vec![3.25f32; 128];
        let block = quantize(&data, 64).unwrap();
        let mut out = Vec::new();
        dequantize(&block, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn extremes_are_preserved() {
        // min and max of each group are exactly representable (codes 0, 15)
        let mut data = vec![0.5f32; 64];
        data[0] = -2.0;
        data[63] = 4.0;
        let block = quantize(&data, 64).unwrap();
        let mut out = Vec::new();
        dequantize(&block, &mut out);
        assert_eq!(out[0], -2.0);
        assert_eq!(out[63], 4.0);
    }

    #[test]
    fn wire_size_math() {
        let data = vec![0.0f32; 4096];
        let block = quantize(&data, 64).unwrap();
        assert_eq!(block.wire_bytes(), wire_bytes_for(4096, 64));
        assert_eq!(block.wire_bytes(), (4096 / 64 * 8 + 2048) as u64);
        // 6.4× smaller than f32 (0.625 bytes/element)
        assert!(block.ratio_vs_f32() > 6.0);
    }

    #[test]
    fn odd_length_and_tail_group() {
        let data: Vec<f32> = (0..101).map(|i| i as f32 * 0.1).collect();
        let block = quantize(&data, 64).unwrap();
        let mut out = Vec::new();
        dequantize(&block, &mut out);
        assert_eq!(out.len(), 101);
        let bound = error_bound(&data, 64);
        for (a, b) in data.iter().zip(&out) {
            assert!((a - b).abs() <= bound);
        }
    }

    #[test]
    fn rejects_bad_group() {
        assert!(quantize(&[1.0], 0).is_err());
        assert!(quantize(&[1.0], 3).is_err());
    }

    #[test]
    fn rejects_nan() {
        assert!(quantize(&[f32::NAN, 0.0], 2).is_err());
    }

    #[test]
    fn property_roundtrip_any_distribution() {
        check_property("quant_roundtrip", 25, |rng| {
            let n = 1 + rng.index(500);
            let scale = 10f32.powi(rng.index(6) as i32 - 3);
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * scale).collect();
            let block = quantize(&data, DEFAULT_GROUP).map_err(|e| e.to_string())?;
            let mut out = Vec::new();
            dequantize(&block, &mut out);
            let bound = error_bound(&data, DEFAULT_GROUP);
            for (i, (a, b)) in data.iter().zip(&out).enumerate() {
                if (a - b).abs() > bound {
                    return Err(format!("elem {i}: {a} vs {b}, bound {bound}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn property_wire_bytes_smaller_than_f32() {
        check_property("quant_compresses", 10, |rng| {
            let n = 64 + rng.index(4000);
            if wire_bytes_for(n, 64) * 4 < (n * 4) as u64 * 3 {
                Ok(())
            } else {
                Err(format!("n={n} not compressed"))
            }
        });
    }
}
