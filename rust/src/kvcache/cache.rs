//! Per-sequence-batch host store: K, V and input activations X per layer.
//!
//! Layout per layer: row-major `[seq, batch*hidden]`-style flattening —
//! concretely each of K/V/X is a `Vec<f32>` of capacity `cap * row` where
//! `row = batch * hidden` and rows `[0, len)` are valid.  Row granularity is
//! what the engine's split views hand to the link: `X[0:l]` (activations to
//! recompute from) and `KV[l:len]` (the transferred remainder).
//!
//! NOTE the artifact expects `[batch, seq, hidden]`; the engine transposes
//! at staging time via [`LayerState::rows_to_bsh`].  Keeping the host layout
//! seq-major makes the split views contiguous, which is what lets the link
//! stream them without gather overhead — the Rust analogue of the paper
//! storing the KV cache contiguously per token.

use std::sync::Arc;

use anyhow::{bail, Result};

/// Element width of the f32 host store.
pub const ELEM_BYTES_F32: f64 = 4.0;

/// Wire bytes per element under group-wise 4-bit quantization at the
/// default group size 64: 8-byte header per group + ½ byte payload.
pub const ELEM_BYTES_INT4_G64: f64 = 0.625;

/// K/V/X store for one layer of one running batch.
///
/// A dropped-KV prefix (the tiered store's last-resort pressure valve)
/// physically truncates the K/V buffers: rows `[0, kv_trunc)` hold no
/// stored KV — only the X activations survive there, and the planner's
/// `l_floor` guarantees recompute always covers the hole.  K/V element
/// views therefore go through [`LayerState::kv_rows`], which subtracts the
/// truncation offset; X views keep using [`LayerState::rows`].
#[derive(Debug, Clone)]
pub struct LayerState {
    batch: usize,
    hidden: usize,
    cap: usize,
    len: usize,
    /// Rows `[0, kv_trunc)` have been drained from the K/V buffers.
    kv_trunc: usize,
    k: Arc<Vec<f32>>,
    v: Arc<Vec<f32>>,
    x: Arc<Vec<f32>>,
}

impl LayerState {
    fn row(&self) -> usize {
        self.batch * self.hidden
    }

    /// Valid sequence length (the paper's s').
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Bytes a full-KV transfer would move (2 segments × len rows) at
    /// `elem_bytes` per element.  The host store is f32
    /// ([`ELEM_BYTES_F32`]), but the *wire* width differs under
    /// [`quant`](crate::kvcache::quant) compression (0.625 B/elem at group
    /// size 64), so byte accounting takes the width instead of hardcoding
    /// it.
    pub fn kv_bytes(&self, elem_bytes: f64) -> u64 {
        (2.0 * (self.len * self.row()) as f64 * elem_bytes).ceil() as u64
    }

    /// Number of `block_tokens`-sized blocks the valid rows span — the
    /// granularity the tiered [`kvstore`](crate::kvstore) places and
    /// migrates.
    pub fn n_blocks(&self, block_tokens: usize) -> usize {
        assert!(block_tokens > 0, "block_tokens must be positive");
        self.len.div_ceil(block_tokens)
    }

    /// Element range (into the k/v/x arcs) covering block `i`: rows
    /// `[i·block_tokens, (i+1)·block_tokens)` clamped to the valid length.
    /// Together with [`LayerState::rows`] this makes the layer a view over
    /// blocks: the kvstore migrates block ranges, the engine transfers
    /// split ranges, both over the same seq-major rows.
    pub fn block_rows(&self, i: usize, block_tokens: usize) -> std::ops::Range<usize> {
        assert!(block_tokens > 0, "block_tokens must be positive");
        let lo = (i * block_tokens).min(self.len);
        let hi = ((i + 1) * block_tokens).min(self.len);
        self.rows(lo, hi)
    }

    /// Shared handles for zero-copy link submission.
    pub fn k_arc(&self) -> Arc<Vec<f32>> {
        self.k.clone()
    }

    pub fn v_arc(&self) -> Arc<Vec<f32>> {
        self.v.clone()
    }

    pub fn x_arc(&self) -> Arc<Vec<f32>> {
        self.x.clone()
    }

    /// Element range (into the x arc — and into k/v only while no prefix
    /// has been dropped) covering rows [lo, hi).
    pub fn rows(&self, lo: usize, hi: usize) -> std::ops::Range<usize> {
        assert!(lo <= hi && hi <= self.len, "rows {lo}..{hi} of {}", self.len);
        lo * self.row()..hi * self.row()
    }

    /// Rows `[0, kv_trunc)` whose K/V storage has been reclaimed by
    /// [`LayerState::drop_prefix_kv`]; their X activations remain.
    pub fn kv_trunc(&self) -> usize {
        self.kv_trunc
    }

    /// Element range *into the truncated k/v arcs* covering rows
    /// [lo, hi).  Panics when `lo` reaches into the dropped prefix — the
    /// planner's floor must keep every K/V read above the hole.
    pub fn kv_rows(&self, lo: usize, hi: usize) -> std::ops::Range<usize> {
        assert!(
            lo >= self.kv_trunc,
            "kv rows {lo}..{hi} reach into the dropped prefix [0, {})",
            self.kv_trunc
        );
        assert!(lo <= hi && hi <= self.len, "rows {lo}..{hi} of {}", self.len);
        let row = self.row();
        (lo - self.kv_trunc) * row..(hi - self.kv_trunc) * row
    }

    /// Physically reclaim the K/V storage of rows `[0, tokens)`: the host
    /// `Vec`s shrink by `2 × tokens × row` f32 elements (X is untouched —
    /// recompute needs it).  Monotone: dropping fewer tokens than already
    /// dropped is a no-op; `tokens` clamps to the valid length.  Returns
    /// the host bytes freed.
    pub fn drop_prefix_kv(&mut self, tokens: usize) -> u64 {
        let target = tokens.min(self.len);
        let delta = target.saturating_sub(self.kv_trunc);
        if delta == 0 {
            return 0;
        }
        let row = self.row();
        let kd = Arc::make_mut(&mut self.k);
        kd.drain(0..delta * row);
        kd.shrink_to_fit();
        let vd = Arc::make_mut(&mut self.v);
        vd.drain(0..delta * row);
        vd.shrink_to_fit();
        self.kv_trunc = target;
        (2 * delta * row * 4) as u64
    }

    /// Transpose seq-major rows `[rows, batch, hidden]` → `[batch, seq, hidden]`
    /// into `out` (artifact input layout). `rows_data` must hold `n_rows`
    /// contiguous rows as returned by a link transfer of [`Self::rows`].
    pub fn rows_to_bsh(&self, rows_data: &[f32], n_rows: usize, out: &mut Vec<f32>) {
        assert_eq!(rows_data.len(), n_rows * self.row());
        out.clear();
        out.reserve(n_rows * self.row());
        for b in 0..self.batch {
            for s in 0..n_rows {
                let base = s * self.row() + b * self.hidden;
                out.extend_from_slice(&rows_data[base..base + self.hidden]);
            }
        }
    }

    /// Append one token row per sequence. `k_new`/`v_new`/`x_new` are
    /// `[batch, 1, hidden]` (artifact output layout == one seq-major row).
    pub fn append(&mut self, k_new: &[f32], v_new: &[f32], x_new: &[f32]) -> Result<()> {
        let row = self.row();
        if k_new.len() != row || v_new.len() != row || x_new.len() != row {
            bail!("append row size mismatch: {} vs {}", k_new.len(), row);
        }
        if self.len >= self.cap {
            bail!("layer cache full: len {} == cap {}", self.len, self.cap);
        }
        let kv_off = (self.len - self.kv_trunc) * row;
        Arc::make_mut(&mut self.k)[kv_off..kv_off + row].copy_from_slice(k_new);
        Arc::make_mut(&mut self.v)[kv_off..kv_off + row].copy_from_slice(v_new);
        let x_off = self.len * row;
        Arc::make_mut(&mut self.x)[x_off..x_off + row].copy_from_slice(x_new);
        self.len += 1;
        Ok(())
    }

    /// Bulk-load prefill results. `k`/`v`/`x` are `[batch, s_p, hidden]`
    /// (artifact output layout); stored transposed to seq-major rows.
    pub fn load_prefill(&mut self, k: &[f32], v: &[f32], x: &[f32], s_p: usize) -> Result<()> {
        let row = self.row();
        if k.len() != s_p * row {
            bail!("prefill size mismatch: {} vs {}", k.len(), s_p * row);
        }
        if s_p > self.cap {
            bail!("prefill longer than capacity");
        }
        debug_assert_eq!(self.kv_trunc, 0, "prefill into a truncated layer");
        let kd = Arc::make_mut(&mut self.k);
        let vd = Arc::make_mut(&mut self.v);
        let xd = Arc::make_mut(&mut self.x);
        for b in 0..self.batch {
            for s in 0..s_p {
                let src = (b * s_p + s) * self.hidden;
                let dst = s * row + b * self.hidden;
                kd[dst..dst + self.hidden].copy_from_slice(&k[src..src + self.hidden]);
                vd[dst..dst + self.hidden].copy_from_slice(&v[src..src + self.hidden]);
                xd[dst..dst + self.hidden].copy_from_slice(&x[src..src + self.hidden]);
            }
        }
        self.len = s_p;
        Ok(())
    }
}

/// All layers of one running batch.
#[derive(Debug, Clone)]
pub struct HostKvCache {
    layers: Vec<LayerState>,
}

impl HostKvCache {
    /// Allocate a cache of `n_layers`, each with row capacity `cap`.
    pub fn new(n_layers: usize, batch: usize, hidden: usize, cap: usize) -> Self {
        let mk = || LayerState {
            batch,
            hidden,
            cap,
            len: 0,
            kv_trunc: 0,
            k: Arc::new(vec![0.0; cap * batch * hidden]),
            v: Arc::new(vec![0.0; cap * batch * hidden]),
            x: Arc::new(vec![0.0; cap * batch * hidden]),
        };
        HostKvCache { layers: (0..n_layers).map(|_| mk()).collect() }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    pub fn layer(&self, i: usize) -> &LayerState {
        &self.layers[i]
    }

    pub fn layer_mut(&mut self, i: usize) -> &mut LayerState {
        &mut self.layers[i]
    }

    /// Current sequence length (identical across layers by construction).
    pub fn seq_len(&self) -> usize {
        self.layers.first().map_or(0, |l| l.len())
    }

    /// Host bytes a cache with these dimensions reserves (K + V + X f32
    /// buffers at full row capacity).  The single source of truth shared by
    /// the allocation here and by admission control
    /// ([`Engine::session_kv_bytes`](crate::engine::Engine::session_kv_bytes)),
    /// so budgeting can never drift from what a session actually holds.
    pub fn capacity_bytes_for(n_layers: usize, batch: usize, hidden: usize, cap: usize) -> u64 {
        (n_layers * 3 * cap * batch * hidden * 4) as u64
    }

    /// Total host bytes *reserved* (K + V + X across layers at full row
    /// capacity) — what admission control must budget for when a new batch
    /// is allocated, independent of how far it has filled.
    pub fn capacity_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| Self::capacity_bytes_for(1, l.batch, l.hidden, l.capacity()))
            .sum()
    }

    /// Total host bytes held (K + V + X across layers, valid rows only).
    /// A dropped-KV prefix shrinks the K/V side — those rows were
    /// physically reclaimed by [`HostKvCache::drop_prefix_kv`] — while the
    /// X side still spans every valid row.
    pub fn host_bytes(&self) -> u64 {
        self.layers
            .iter()
            .map(|l| {
                let row = l.batch * l.hidden;
                ((2 * (l.len() - l.kv_trunc()) + l.len()) * row * 4) as u64
            })
            .sum()
    }

    /// Rows whose K/V storage has been reclaimed (identical across layers
    /// by construction).
    pub fn kv_trunc(&self) -> usize {
        self.layers.first().map_or(0, |l| l.kv_trunc())
    }

    /// Physically reclaim the K/V storage of rows `[0, tokens)` on every
    /// layer.  Returns the total host bytes freed; monotone and clamped
    /// like [`LayerState::drop_prefix_kv`].
    pub fn drop_prefix_kv(&mut self, tokens: usize) -> u64 {
        self.layers.iter_mut().map(|l| l.drop_prefix_kv(tokens)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poke(cache: &mut HostKvCache, layer: usize, val: f32) {
        let l = cache.layer(layer);
        let row = l.batch * l.hidden;
        let k: Vec<f32> = (0..row).map(|i| val + i as f32).collect();
        let v: Vec<f32> = (0..row).map(|i| -val - i as f32).collect();
        let x: Vec<f32> = (0..row).map(|i| val * 2.0 + i as f32).collect();
        cache.layer_mut(layer).append(&k, &v, &x).unwrap();
    }

    #[test]
    fn append_and_views() {
        let mut c = HostKvCache::new(2, 2, 4, 8);
        poke(&mut c, 0, 1.0);
        poke(&mut c, 0, 100.0);
        let l = c.layer(0);
        assert_eq!(l.len(), 2);
        let r = l.rows(0, 2);
        assert_eq!(r, 0..16);
        assert_eq!(l.k_arc()[0], 1.0);
        assert_eq!(l.k_arc()[8], 100.0); // second row
        assert_eq!(l.kv_bytes(ELEM_BYTES_F32), 2 * 2 * 8 * 4);
    }

    #[test]
    fn kv_bytes_tracks_element_width() {
        let mut c = HostKvCache::new(1, 2, 4, 8);
        poke(&mut c, 0, 0.0);
        poke(&mut c, 0, 0.0);
        let l = c.layer(0);
        assert_eq!(l.kv_bytes(ELEM_BYTES_F32), 2 * 2 * 8 * 4);
        // int4 wire width: 0.625 B/elem → 2 segments × 2 rows × 8 elems
        assert_eq!(l.kv_bytes(ELEM_BYTES_INT4_G64), (2.0 * 16.0 * 0.625_f64).ceil() as u64);
        // fp16 host stores would halve the f32 number
        assert_eq!(l.kv_bytes(2.0), 2 * 2 * 8 * 2);
    }

    #[test]
    fn block_views_tile_the_valid_rows() {
        let mut c = HostKvCache::new(1, 1, 4, 16);
        for i in 0..10 {
            poke(&mut c, 0, i as f32);
        }
        let l = c.layer(0);
        assert_eq!(l.n_blocks(4), 3, "10 rows → 2 full + 1 partial block");
        assert_eq!(l.block_rows(0, 4), l.rows(0, 4));
        assert_eq!(l.block_rows(1, 4), l.rows(4, 8));
        assert_eq!(l.block_rows(2, 4), l.rows(8, 10), "last block clamps to len");
        assert_eq!(l.block_rows(3, 4).len(), 0, "past the end is empty");
        // blocks partition exactly
        let total: usize = (0..l.n_blocks(4)).map(|i| l.block_rows(i, 4).len()).sum();
        assert_eq!(total, 10 * 4);
    }

    #[test]
    fn capacity_enforced() {
        let mut c = HostKvCache::new(1, 1, 2, 2);
        poke(&mut c, 0, 0.0);
        poke(&mut c, 0, 0.0);
        let l = c.layer(0);
        assert_eq!(l.len(), 2);
        let row = vec![0.0; 2];
        assert!(c.layer_mut(0).append(&row, &row, &row).is_err());
    }

    #[test]
    fn row_size_checked() {
        let mut c = HostKvCache::new(1, 2, 4, 4);
        let bad = vec![0.0; 3];
        let good = vec![0.0; 8];
        assert!(c.layer_mut(0).append(&bad, &good, &good).is_err());
    }

    #[test]
    fn prefill_roundtrip_transpose() {
        // load [batch, s_p, hidden] then read back seq-major rows and convert
        let mut c = HostKvCache::new(1, 2, 3, 8);
        let s_p = 2;
        // batch-major input: b0s0=[0,1,2] b0s1=[3,4,5] b1s0=[6,7,8] b1s1=[9,10,11]
        let data: Vec<f32> = (0..12).map(|i| i as f32).collect();
        c.layer_mut(0).load_prefill(&data, &data, &data, s_p).unwrap();
        let l = c.layer(0);
        assert_eq!(l.len(), 2);
        // seq-major row 0 = [b0s0, b1s0] = [0,1,2, 6,7,8]
        let k = l.k_arc();
        assert_eq!(&k[0..6], &[0.0, 1.0, 2.0, 6.0, 7.0, 8.0]);
        // convert back to [batch, seq, hidden]
        let mut out = Vec::new();
        l.rows_to_bsh(&k[l.rows(0, 2)], 2, &mut out);
        assert_eq!(out, data);
    }

    #[test]
    fn split_views_partition_the_cache() {
        let mut c = HostKvCache::new(1, 1, 4, 16);
        for i in 0..10 {
            poke(&mut c, 0, i as f32);
        }
        let l = c.layer(0);
        let a = l.rows(0, 4);
        let b = l.rows(4, 10);
        assert_eq!(a.end, b.start);
        assert_eq!(b.end, 10 * 4);
    }

    #[test]
    #[should_panic(expected = "rows")]
    fn view_beyond_len_panics() {
        let c = HostKvCache::new(1, 1, 4, 16);
        let _ = c.layer(0).rows(0, 1); // len == 0
    }

    #[test]
    fn host_bytes_counts_kvx() {
        let mut c = HostKvCache::new(2, 1, 4, 8);
        poke(&mut c, 0, 0.0);
        poke(&mut c, 1, 0.0);
        // 2 layers × 1 row × (3 tensors × 4 f32 × 4 bytes)
        assert_eq!(c.host_bytes(), 2 * 3 * 4 * 4);
    }

    #[test]
    fn drop_prefix_kv_physically_reclaims_host_bytes() {
        // the regression this pins: a dropped prefix must shrink the K/V
        // host `Vec`s by exactly 2 × delta × row × 4 bytes per layer, not
        // just mark rows stale
        let (n_layers, batch, hidden, cap) = (3, 2, 4, 16);
        let row = batch * hidden;
        let mut c = HostKvCache::new(n_layers, batch, hidden, cap);
        for layer in 0..n_layers {
            for i in 0..10 {
                poke(&mut c, layer, i as f32);
            }
        }
        let before = c.host_bytes();
        let delta = 4;
        let freed = c.drop_prefix_kv(delta);
        assert_eq!(freed, (2 * delta * row * 4 * n_layers) as u64);
        assert_eq!(c.host_bytes(), before - freed);
        assert_eq!(c.kv_trunc(), delta);
        let l = c.layer(0);
        // the buffers really shrank — capacity, not just a length marker
        assert_eq!(l.k_arc().len(), (cap - delta) * row);
        assert!(l.k_arc().capacity() < cap * row);
        // X keeps every valid row; K/V views shift by the truncation
        assert_eq!(l.rows(0, 10), 0..10 * row);
        assert_eq!(l.kv_rows(4, 10), 0..6 * row);
        assert_eq!(l.kv_rows(6, 8), 2 * row..4 * row);
    }

    #[test]
    fn drop_prefix_kv_is_monotone_and_survives_appends() {
        let mut c = HostKvCache::new(1, 1, 2, 8);
        for i in 0..4 {
            poke(&mut c, 0, 10.0 * i as f32);
        }
        assert_eq!(c.drop_prefix_kv(2), 2 * 2 * 2 * 4);
        // re-dropping the same (or a smaller) prefix frees nothing more
        assert_eq!(c.drop_prefix_kv(2), 0);
        assert_eq!(c.drop_prefix_kv(1), 0);
        assert_eq!(c.kv_trunc(), 2);
        // surviving rows kept their contents across the drain
        let l = c.layer(0);
        assert_eq!(l.k_arc()[l.kv_rows(2, 3)][0], 20.0);
        assert_eq!(l.k_arc()[l.kv_rows(3, 4)][0], 30.0);
        // appends after truncation land in the right (shifted) slots
        poke(&mut c, 0, 40.0);
        let l = c.layer(0);
        assert_eq!(l.len(), 5);
        assert_eq!(l.k_arc()[l.kv_rows(4, 5)][0], 40.0);
        assert_eq!(l.x_arc()[l.rows(4, 5)][0], 80.0);
        // reaching into the hole panics via the kv_rows guard (checked in
        // kv_view_into_dropped_prefix_panics); clamping past len is safe
        assert_eq!(c.drop_prefix_kv(100), 3 * 2 * 2 * 4, "clamps to len 5");
        assert_eq!(c.kv_trunc(), 5);
    }

    #[test]
    #[should_panic(expected = "dropped prefix")]
    fn kv_view_into_dropped_prefix_panics() {
        let mut c = HostKvCache::new(1, 1, 2, 8);
        for _ in 0..4 {
            poke(&mut c, 0, 0.0);
        }
        c.drop_prefix_kv(2);
        let _ = c.layer(0).kv_rows(1, 3);
    }
}
