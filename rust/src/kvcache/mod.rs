//! Host-side KV-cache and activation management.
//!
//! In the offloaded regime the KV cache (and, for KVPR, the per-layer input
//! activations it is recomputed from) live in CPU DRAM; the engine requests
//! split views of them for transfer.  Group-wise 4-bit quantization (paper
//! §4.4) compresses the transferred remainder on the wire.

mod cache;
pub mod quant;

pub use cache::{HostKvCache, LayerState};
