//! Host-side KV-cache and activation management.
//!
//! In the offloaded regime the KV cache (and, for KVPR, the per-layer input
//! activations it is recomputed from) live in CPU DRAM; the engine requests
//! split views of them for transfer, and the tiered
//! [`kvstore`](crate::kvstore) requests *block* views
//! ([`LayerState::block_rows`]) for placement and migration — both are
//! ranges over the same seq-major rows.  Group-wise 4-bit quantization
//! (paper §4.4) compresses the transferred remainder on the wire; byte
//! accounting takes the element width explicitly
//! ([`LayerState::kv_bytes`]) so it stays correct across widths.

mod cache;
pub mod quant;

pub use cache::{HostKvCache, LayerState, ELEM_BYTES_F32, ELEM_BYTES_INT4_G64};
