//! Hardware descriptions — the paper's two testbeds plus the locally
//! emulated link the real engine runs against.
//!
//! The simulator consumes these directly; the engine's profiler *measures*
//! the local values instead (paper §3.1: "the profiler module gathers system
//! statistics"), so `local_emulated` only seeds the emulation knobs.

/// A CPU–GPU system: one GPU behind a PCIe link plus host CPU/DRAM.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    pub name: String,
    /// H2D/D2H link bandwidth, bytes/s (paper: PCIe 4.0 x16 = 32 GB/s).
    pub pcie_bytes_per_sec: f64,
    /// Per-transfer fixed latency, seconds (DMA setup + driver).
    pub pcie_latency_s: f64,
    /// GPU peak fp16 throughput, FLOP/s.
    pub gpu_peak_flops: f64,
    /// Fraction of peak the decode-step GEMMs actually achieve (memory-bound
    /// small-batch GEMMs sit well below peak; calibrated so Table 1's
    /// compute column lands in the paper's range).
    pub gpu_efficiency: f64,
    /// Fixed per-kernel launch overhead, seconds.
    pub gpu_launch_overhead_s: f64,
    /// GPU HBM capacity, bytes.
    pub gpu_mem_bytes: u64,
    /// Host CPU throughput for attention-style math, FLOP/s (FastDecode).
    pub cpu_flops: f64,
    /// Host DRAM capacity, bytes.
    pub cpu_mem_bytes: u64,
}

impl HardwareConfig {
    /// Paper §4: A100-40GB, PCIe 4.0 x16 (32 GB/s), EPYC 64-core @ 2.6 GHz.
    pub fn a100_x16() -> Self {
        HardwareConfig {
            name: "a100-pcie4-x16".into(),
            pcie_bytes_per_sec: 32e9,
            pcie_latency_s: 10e-6,
            gpu_peak_flops: 312e12, // A100 fp16 tensor core peak
            gpu_efficiency: 0.35,
            gpu_launch_overhead_s: 25e-6,
            gpu_mem_bytes: 40 << 30,
            // 64 cores × 2.6 GHz × ~16 f32 FLOP/cycle (AVX2 FMA)
            cpu_flops: 2.6e9 * 64.0 * 16.0,
            cpu_mem_bytes: 512 << 30,
        }
    }

    /// Appendix A.5: Quadro RTX 5000 16 GB (89.2 TFLOPS fp16), PCIe 4.0 x8
    /// (16 GB/s), EPYC 32-core.
    pub fn rtx5000_x8() -> Self {
        HardwareConfig {
            name: "rtx5000-pcie4-x8".into(),
            pcie_bytes_per_sec: 16e9,
            pcie_latency_s: 10e-6,
            gpu_peak_flops: 89.2e12,
            gpu_efficiency: 0.35,
            gpu_launch_overhead_s: 25e-6,
            gpu_mem_bytes: 16 << 30,
            cpu_flops: 2.6e9 * 32.0 * 16.0,
            cpu_mem_bytes: 256 << 30,
        }
    }

    /// Knobs for the locally *emulated* link (`transfer::Link`): bandwidth is
    /// deliberately throttled so that, for the tiny model, KV transfer
    /// dominates decode compute exactly as PCIe does at paper scale.
    pub fn local_emulated() -> Self {
        HardwareConfig {
            name: "local-emulated".into(),
            pcie_bytes_per_sec: 1.5e9,
            pcie_latency_s: 30e-6,
            gpu_peak_flops: 5e9, // placeholder; the profiler measures reality
            gpu_efficiency: 1.0,
            gpu_launch_overhead_s: 50e-6,
            gpu_mem_bytes: 2 << 30,
            cpu_flops: 5e9,
            cpu_mem_bytes: 8 << 30,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "a100" | "a100-pcie4-x16" => Some(Self::a100_x16()),
            "rtx5000" | "rtx5000-pcie4-x8" => Some(Self::rtx5000_x8()),
            "local" | "local-emulated" => Some(Self::local_emulated()),
            _ => None,
        }
    }

    /// Effective GPU FLOP/s the simulator charges for GEMM work.
    pub fn gpu_effective_flops(&self) -> f64 {
        self.gpu_peak_flops * self.gpu_efficiency
    }

    /// Time to move `bytes` over the link (latency + size/bandwidth).
    pub fn link_time(&self, bytes: u64) -> f64 {
        self.pcie_latency_s + bytes as f64 / self.pcie_bytes_per_sec
    }

    /// Time to run `flops` of GEMM-like work on the GPU.
    pub fn gpu_time(&self, flops: f64) -> f64 {
        self.gpu_launch_overhead_s + flops / self.gpu_effective_flops()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn paper_table1_pcie_latency() {
        // 512 MB over 32 GB/s ≈ 15.6–16.8 ms (paper: 15.6 ms)
        let hw = HardwareConfig::a100_x16();
        let m = ModelConfig::opt_6_7b();
        let t = hw.link_time(m.kv_bytes_per_layer(32, 1024));
        assert!((0.0145..0.018).contains(&t), "{t}");
        let t13 = hw.link_time(ModelConfig::opt_13b().kv_bytes_per_layer(32, 1024));
        assert!((0.018..0.022).contains(&t13), "{t13}"); // paper: 19.5 ms
        let t30 = hw.link_time(ModelConfig::opt_30b().kv_bytes_per_layer(32, 1024));
        assert!((0.026..0.031).contains(&t30), "{t30}"); // paper: 27.3 ms
    }

    #[test]
    fn transfer_dwarfs_recompute_at_paper_scale() {
        // The premise of the whole paper (Table 1): PCIe latency for the KV
        // cache exceeds the decode step's KV computation latency by over an
        // order of magnitude (paper: 15.6 ms vs 0.35 ms for OPT-6.7B).
        let hw = HardwareConfig::a100_x16();
        let m = ModelConfig::opt_6_7b();
        let t_link = hw.link_time(m.kv_bytes_per_layer(32, 1024));
        // Table 1's comp column: the new token's KV pair computation
        let t_comp = hw.gpu_time(m.recompute_flops(32, 1));
        assert!(t_link / t_comp > 10.0, "link {t_link} vs comp {t_comp}");

        // And per-token costs must still favour a *mixed* split: recompute
        // of one token is the same order as transferring its KV pair, so the
        // LP lands strictly inside (0, s) rather than at a corner.
        let a = hw.gpu_time(m.recompute_flops(32, 1024)) / 1024.0;
        let c = hw.link_time(m.kv_bytes_per_layer(32, 1024)) / 1024.0;
        let ratio = a / c;
        assert!((0.2..5.0).contains(&ratio), "per-token ratio {ratio}");
    }

    #[test]
    fn lowend_is_slower_everywhere() {
        let a = HardwareConfig::a100_x16();
        let r = HardwareConfig::rtx5000_x8();
        assert!(r.pcie_bytes_per_sec < a.pcie_bytes_per_sec);
        assert!(r.gpu_peak_flops < a.gpu_peak_flops);
        assert!(r.gpu_mem_bytes < a.gpu_mem_bytes);
    }

    #[test]
    fn lookup() {
        assert!(HardwareConfig::by_name("a100").is_some());
        assert!(HardwareConfig::by_name("rtx5000").is_some());
        assert!(HardwareConfig::by_name("local").is_some());
        assert!(HardwareConfig::by_name("h100").is_none());
    }

    #[test]
    fn link_time_monotone_in_bytes() {
        let hw = HardwareConfig::a100_x16();
        assert!(hw.link_time(2 << 20) > hw.link_time(1 << 20));
        // latency floor
        assert!(hw.link_time(0) >= hw.pcie_latency_s);
    }
}
