//! Configuration: model geometries, hardware descriptions and workloads.
//!
//! The paper's evaluation is fully characterised by a triple
//! (ModelConfig, HardwareConfig, WorkloadConfig); every bench harness and
//! the simulator take exactly these.

mod hardware;
mod model;
mod workload;

pub use hardware::HardwareConfig;
pub use model::{ArchKind, ModelConfig};
pub use workload::{Objective, WorkloadConfig};
