//! Model geometries — the paper's OPT family (§4), the LLaMa2 pair from
//! Appendix A.6, and the tiny model the real PJRT path executes.

/// Attention/FFN flavour. OPT uses plain MHA + 2-layer ReLU FFN; LLaMa2 uses
/// MHA (no GQA at 7B/13B) + SwiGLU (three FFN matrices).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArchKind {
    Opt,
    Llama,
}

/// Transformer geometry + element size. All byte/flop formulas the paper
/// relies on (Eq. 6 and 8) live here so scheduler, simulator and benches
/// agree on them by construction.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub arch: ArchKind,
    pub hidden: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub max_pos: usize,
    /// bytes per element (2 = fp16 at paper scale, 4 = f32 on the CPU path)
    pub dtype_bytes: usize,
}

impl ModelConfig {
    fn new(
        name: &str,
        arch: ArchKind,
        hidden: usize,
        n_heads: usize,
        n_layers: usize,
        ffn: usize,
        dtype_bytes: usize,
    ) -> Self {
        ModelConfig {
            name: name.to_string(),
            arch,
            hidden,
            n_heads,
            n_layers,
            ffn,
            vocab: 50272,
            max_pos: 2048,
            dtype_bytes,
        }
    }

    // -- paper model zoo ------------------------------------------------------

    /// OPT-6.7B: h=4096, 32 layers, 32 heads (paper Table 1: hidden dim 4096).
    pub fn opt_6_7b() -> Self {
        Self::new("opt-6.7b", ArchKind::Opt, 4096, 32, 32, 16384, 2)
    }

    /// OPT-13B: h=5120, 40 layers (paper Table 1: hidden dim 5120).
    pub fn opt_13b() -> Self {
        Self::new("opt-13b", ArchKind::Opt, 5120, 40, 40, 20480, 2)
    }

    /// OPT-30B: h=7168, 48 layers (paper Table 1: hidden dim 7168).
    pub fn opt_30b() -> Self {
        Self::new("opt-30b", ArchKind::Opt, 7168, 56, 48, 28672, 2)
    }

    /// LLaMa2-7B (Appendix A.6): h=4096, 32 layers, SwiGLU ffn 11008.
    pub fn llama2_7b() -> Self {
        let mut m = Self::new("llama2-7b", ArchKind::Llama, 4096, 32, 32, 11008, 2);
        m.vocab = 32000;
        m.max_pos = 4096;
        m
    }

    /// LLaMa2-13B (Appendix A.6): h=5120, 40 layers, SwiGLU ffn 13824.
    pub fn llama2_13b() -> Self {
        let mut m = Self::new("llama2-13b", ArchKind::Llama, 5120, 40, 40, 13824, 2);
        m.vocab = 32000;
        m.max_pos = 4096;
        m
    }

    /// The tiny model the real PJRT path executes (matches
    /// `python/compile/model.py::TINY` and the artifact manifest).
    pub fn tiny() -> Self {
        let mut m = Self::new("kvpr-tiny", ArchKind::Opt, 256, 4, 4, 1024, 4);
        m.vocab = 512;
        m.max_pos = 512;
        m
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "opt-6.7b" => Some(Self::opt_6_7b()),
            "opt-13b" => Some(Self::opt_13b()),
            "opt-30b" => Some(Self::opt_30b()),
            "llama2-7b" => Some(Self::llama2_7b()),
            "llama2-13b" => Some(Self::llama2_13b()),
            "kvpr-tiny" | "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    // -- byte/flop formulas (paper Eq. 6 & 8) ---------------------------------

    /// KV-cache bytes for one layer: 2 · b · s · h · p  (Eq. 6, M_KV).
    pub fn kv_bytes_per_layer(&self, batch: usize, seq: usize) -> u64 {
        2 * (batch * seq * self.hidden * self.dtype_bytes) as u64
    }

    /// KV-cache bytes across all layers.
    pub fn kv_bytes_total(&self, batch: usize, seq: usize) -> u64 {
        self.kv_bytes_per_layer(batch, seq) * self.n_layers as u64
    }

    /// Activation bytes for an l-token prefix of one layer: b · l · h · p
    /// (Eq. 6, M_X) — half the KV bytes for the same tokens.
    pub fn act_bytes_per_layer(&self, batch: usize, l: usize) -> u64 {
        (batch * l * self.hidden * self.dtype_bytes) as u64
    }

    /// FLOPs to recompute KV for an l-token prefix of one layer:
    /// 4 · b · l · h²  (Eq. 8, N_KV).
    pub fn recompute_flops(&self, batch: usize, l: usize) -> f64 {
        4.0 * batch as f64 * l as f64 * (self.hidden as f64) * (self.hidden as f64)
    }

    /// MHA weight bytes for one layer (W_Q, W_K, W_V, W_O): 4 h² p.
    pub fn mha_weight_bytes_per_layer(&self) -> u64 {
        4 * (self.hidden * self.hidden * self.dtype_bytes) as u64
    }

    /// W_K + W_V only — what the fine-grained pipeline front-loads.
    pub fn kv_proj_weight_bytes(&self) -> u64 {
        2 * (self.hidden * self.hidden * self.dtype_bytes) as u64
    }

    /// FFN weight bytes for one layer (2 mats for OPT, 3 for SwiGLU).
    pub fn ffn_weight_bytes_per_layer(&self) -> u64 {
        let mats = match self.arch {
            ArchKind::Opt => 2,
            ArchKind::Llama => 3,
        };
        (mats * self.hidden * self.ffn * self.dtype_bytes) as u64
    }

    /// Total per-layer weight bytes (MHA + FFN; norms are negligible).
    pub fn weight_bytes_per_layer(&self) -> u64 {
        self.mha_weight_bytes_per_layer() + self.ffn_weight_bytes_per_layer()
    }

    /// Decode-step FLOPs for one layer at batch b over a kv_len-long cache:
    /// projections (8bh² incl. output proj) + attention (4·b·kv·h) + FFN.
    pub fn decode_flops_per_layer(&self, batch: usize, kv_len: usize) -> f64 {
        let b = batch as f64;
        let h = self.hidden as f64;
        let f = self.ffn as f64;
        let ffn_mats = match self.arch {
            ArchKind::Opt => 2.0,
            ArchKind::Llama => 3.0,
        };
        let proj = 8.0 * b * h * h;
        let attn = 4.0 * b * kv_len as f64 * h;
        let ffn = 2.0 * ffn_mats * b * h * f;
        proj + attn + ffn
    }

    /// Rough total parameter count (for display).
    pub fn approx_params(&self) -> u64 {
        let per_layer = self.mha_weight_bytes_per_layer() / self.dtype_bytes as u64
            + self.ffn_weight_bytes_per_layer() / self.dtype_bytes as u64;
        per_layer * self.n_layers as u64
            + (self.vocab * self.hidden + self.max_pos * self.hidden) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table1_kv_sizes() {
        // Table 1: FP16, batch 32, seq 1024 → 512 MB / 640 MB / 896 MB per
        // layer (the paper counts MB as 2^20)
        let mib = |b: u64| b / (1 << 20);
        assert_eq!(mib(ModelConfig::opt_6_7b().kv_bytes_per_layer(32, 1024)), 512);
        assert_eq!(mib(ModelConfig::opt_13b().kv_bytes_per_layer(32, 1024)), 640);
        assert_eq!(mib(ModelConfig::opt_30b().kv_bytes_per_layer(32, 1024)), 896);
    }

    #[test]
    fn activations_are_half_the_kv_bytes() {
        let m = ModelConfig::opt_13b();
        assert_eq!(
            2 * m.act_bytes_per_layer(8, 300),
            m.kv_bytes_per_layer(8, 300)
        );
    }

    #[test]
    fn recompute_flops_formula() {
        let m = ModelConfig::opt_6_7b();
        // 4 · b · l · h²
        assert_eq!(m.recompute_flops(2, 10), 4.0 * 2.0 * 10.0 * 4096.0 * 4096.0);
    }

    #[test]
    fn table2_mha_weight_bytes() {
        // Table 2 caption: OPT-6.7B MHA block (W_Q,W_K,W_V,W_O) = 128 MB
        let m = ModelConfig::opt_6_7b();
        assert_eq!(m.mha_weight_bytes_per_layer() >> 20, 128);
        assert_eq!(m.kv_proj_weight_bytes() >> 20, 64);
    }

    #[test]
    fn zoo_lookup() {
        for name in ["opt-6.7b", "opt-13b", "opt-30b", "llama2-7b", "llama2-13b", "tiny"] {
            assert!(ModelConfig::by_name(name).is_some(), "{name}");
        }
        assert!(ModelConfig::by_name("gpt-5").is_none());
    }

    #[test]
    fn llama_ffn_has_three_mats() {
        let l = ModelConfig::llama2_7b();
        assert_eq!(
            l.ffn_weight_bytes_per_layer(),
            (3 * l.hidden * l.ffn * l.dtype_bytes) as u64
        );
    }

    #[test]
    fn head_dim_divides() {
        for m in [
            ModelConfig::opt_6_7b(),
            ModelConfig::opt_13b(),
            ModelConfig::opt_30b(),
            ModelConfig::llama2_7b(),
            ModelConfig::tiny(),
        ] {
            assert_eq!(m.head_dim() * m.n_heads, m.hidden, "{}", m.name);
        }
    }

    #[test]
    fn param_counts_in_right_ballpark() {
        let p67 = ModelConfig::opt_6_7b().approx_params() as f64 / 1e9;
        assert!((6.0..7.5).contains(&p67), "{p67}");
        let p13 = ModelConfig::opt_13b().approx_params() as f64 / 1e9;
        assert!((12.0..14.0).contains(&p13), "{p13}");
        let p30 = ModelConfig::opt_30b().approx_params() as f64 / 1e9;
        assert!((28.0..33.0).contains(&p30), "{p30}");
    }
}
