//! Flight recorder: anomaly triggers and the JSON dump format.
//!
//! The tracer keeps a bounded ring of the most recent events (the *flight
//! window*).  When an anomaly trigger fires — a retired request blowing
//! through the TTFT SLO, a backpressure streak, or a zero-slack streak —
//! the ring is snapshotted into a [`FlightDump`]: the postmortem record of
//! exactly what the loop was doing in the steps leading up to the anomaly.
//! Dump count is capped ([`AnomalyConfig::max_dumps`]) so a persistent
//! pathology cannot grow memory without bound.

use crate::obs::event::Event;
use crate::util::json::Json;

/// Flight-recorder trigger thresholds.  A threshold of `0` (or `None` for
/// the SLO) disables that trigger; the [`Default`] config never fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnomalyConfig {
    /// Dump when a retired request's TTFT exceeds this many seconds.
    pub ttft_slo_s: Option<f64>,
    /// Dump after this many *consecutive* steps that saw backpressure.
    pub backpressure_streak: usize,
    /// Dump after this many consecutive steps whose plans predicted zero
    /// link slack (the GPU-never-idles claim has no headroom left).
    pub zero_slack_streak: usize,
    /// Dump after this many consecutive steps that forced at least one
    /// fallback re-solve in the pipelined loop (the prestage worker's
    /// predictions are persistently stale — the overlap is buying nothing).
    pub replan_streak: usize,
    /// Maximum dumps retained per run.
    pub max_dumps: usize,
}

impl Default for AnomalyConfig {
    fn default() -> Self {
        AnomalyConfig {
            ttft_slo_s: None,
            backpressure_streak: 0,
            zero_slack_streak: 0,
            replan_streak: 0,
            max_dumps: 4,
        }
    }
}

/// One snapshot of the flight window at trigger time.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightDump {
    /// Which trigger fired: `"slo_violation"`, `"backpressure_streak"`,
    /// `"zero_slack_streak"` or `"replan_streak"`.
    pub reason: String,
    /// Decode-step clock at trigger time.
    pub step: u64,
    /// The ring contents, oldest first (ends with the `Anomaly` marker).
    pub events: Vec<Event>,
}

impl FlightDump {
    /// Encode as JSON (the postmortem artifact).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("reason", self.reason.as_str().into()),
            ("step", Json::from(self.step as f64)),
            (
                "events",
                Json::Arr(self.events.iter().map(Event::to_json).collect()),
            ),
        ])
    }

    /// Decode a dump encoded by [`FlightDump::to_json`].
    pub fn from_json(j: &Json) -> Option<FlightDump> {
        let reason = j.get("reason")?.as_str()?.to_string();
        let step = j.get("step")?.as_f64()? as u64;
        let events = j
            .get("events")?
            .as_arr()?
            .iter()
            .map(Event::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(FlightDump {
            reason,
            step,
            events,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::EventKind;

    #[test]
    fn dump_round_trips_through_json() {
        let dump = FlightDump {
            reason: "backpressure_streak".into(),
            step: 12,
            events: vec![
                Event {
                    step: 11,
                    seq: 40,
                    kind: EventKind::Backpressure,
                },
                Event {
                    step: 12,
                    seq: 41,
                    kind: EventKind::Anomaly {
                        reason: "backpressure_streak".into(),
                    },
                },
            ],
        };
        let text = dump.to_json().to_string();
        let back = FlightDump::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, dump);
    }

    #[test]
    fn default_config_never_fires() {
        let c = AnomalyConfig::default();
        assert!(c.ttft_slo_s.is_none());
        assert_eq!(c.backpressure_streak, 0);
        assert_eq!(c.zero_slack_streak, 0);
        assert_eq!(c.replan_streak, 0);
    }
}
