//! Plan-vs-actual accounting: per-step predicted time and link slack from
//! [`StepPlan`](crate::scheduler::StepPlan) against what the step actually
//! measured and launched.
//!
//! The serving loop records one [`StepRecord`] per completed decode step;
//! [`PlanVsActual::from_records`] folds them into residual summaries
//! (`measured − predicted`, via [`crate::util::stats::Summary`]) and a
//! log₂-ratio **drift histogram** — the profiler→scheduler feedback signal
//! the ROADMAP's auto-tuning item needs: a systematic residual means the
//! cost model under- or over-prices the step and every slack grant inherits
//! the bias.

use std::collections::VecDeque;

use crate::util::json::Json;
use crate::util::stats::Summary;
use crate::util::table::{f, Table};

/// One decode step's plan-vs-actual ledger entry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepRecord {
    /// Decode-step clock value.
    pub step: u64,
    /// Sum of the step's group plans' `predicted_s` (groups decode serially).
    pub predicted_s: f64,
    /// Sum of the plans' `link_slack_bytes`.
    pub slack_bytes: u64,
    /// The migration grant actually issued (`max(slack, 1)` or the A/B pin).
    pub granted_bytes: u64,
    /// Measured step duration on the serving clock.
    pub measured_s: f64,
    /// Migration launches this step.
    pub launched: usize,
    /// Wire bytes those launches put on the link.
    pub launched_wire_bytes: u64,
    /// Migration completions polled this step.
    pub landed: usize,
}

/// Bounded FIFO of step records (the tracer keeps the most recent window).
#[derive(Debug)]
pub(crate) struct Ledger {
    records: VecDeque<StepRecord>,
    cap: usize,
}

impl Ledger {
    pub(crate) fn new(cap: usize) -> Self {
        Ledger {
            records: VecDeque::new(),
            cap: cap.max(1),
        }
    }

    pub(crate) fn push(&mut self, rec: StepRecord) {
        if self.records.len() == self.cap {
            self.records.pop_front();
        }
        self.records.push_back(rec);
    }

    pub(crate) fn snapshot(&self) -> Vec<StepRecord> {
        self.records.iter().copied().collect()
    }
}

/// Bucket edges (log₂ of measured/predicted) for the drift histogram.
const DRIFT_EDGES: [f64; 6] = [-1.0, -0.5, -0.1, 0.1, 0.5, 1.0];

/// Human label for drift bucket `i` (`i in 0..=DRIFT_EDGES.len()`).
fn drift_label(i: usize) -> String {
    if i == 0 {
        format!("log2<{}", DRIFT_EDGES[0])
    } else if i == DRIFT_EDGES.len() {
        format!("log2>={}", DRIFT_EDGES[DRIFT_EDGES.len() - 1])
    } else {
        format!("log2[{},{})", DRIFT_EDGES[i - 1], DRIFT_EDGES[i])
    }
}

/// Folded plan-vs-actual report (see the [module docs](self)).
#[derive(Debug)]
pub struct PlanVsActual {
    /// Steps folded in.
    pub steps: usize,
    /// `measured_s − predicted_s` per step.
    pub residual_s: Summary,
    /// `measured_s / predicted_s` per step (only steps with a positive
    /// prediction — untiered idle steps predict 0).
    pub ratio: Summary,
    /// Count per log₂-ratio bucket; same indexing as [`PlanVsActual::drift_labels`].
    pub drift_hist: Vec<usize>,
    /// Total predicted slack bytes across steps.
    pub slack_bytes: u64,
    /// Total granted bytes across steps.
    pub granted_bytes: u64,
    /// Total launched wire bytes across steps.
    pub launched_wire_bytes: u64,
    /// Total migration launches / landings.
    pub launched: usize,
    /// Total migration landings.
    pub landed: usize,
}

impl PlanVsActual {
    /// Fold a record window into the report.
    pub fn from_records(records: &[StepRecord]) -> Self {
        let mut residual_s = Summary::new();
        let mut ratio = Summary::new();
        let mut drift_hist = vec![0usize; DRIFT_EDGES.len() + 1];
        let (mut slack, mut granted, mut lw) = (0u64, 0u64, 0u64);
        let (mut launched, mut landed) = (0usize, 0usize);
        for r in records {
            residual_s.add(r.measured_s - r.predicted_s);
            if r.predicted_s > 0.0 && r.measured_s > 0.0 {
                let q = r.measured_s / r.predicted_s;
                ratio.add(q);
                let d = q.log2();
                let bucket = DRIFT_EDGES.iter().position(|&e| d < e).unwrap_or(DRIFT_EDGES.len());
                drift_hist[bucket] += 1;
            }
            slack += r.slack_bytes;
            granted += r.granted_bytes;
            lw += r.launched_wire_bytes;
            launched += r.launched;
            landed += r.landed;
        }
        PlanVsActual {
            steps: records.len(),
            residual_s,
            ratio,
            drift_hist,
            slack_bytes: slack,
            granted_bytes: granted,
            launched_wire_bytes: lw,
            launched,
            landed,
        }
    }

    /// Bucket labels aligned with [`PlanVsActual::drift_hist`].
    pub fn drift_labels(&self) -> Vec<String> {
        (0..self.drift_hist.len()).map(drift_label).collect()
    }

    /// Render as a two-column text table (`util::table`).
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new("plan vs actual", &["metric", "value"]);
        t.row(&["steps".into(), self.steps.to_string()]);
        if self.residual_s.count() > 0 {
            t.row(&["residual_mean_s".into(), f(self.residual_s.mean(), 6)]);
            t.row(&["residual_p50_s".into(), f(self.residual_s.p50(), 6)]);
            t.row(&["residual_p95_s".into(), f(self.residual_s.p95(), 6)]);
        }
        if self.ratio.count() > 0 {
            t.row(&["ratio_mean".into(), f(self.ratio.mean(), 4)]);
            t.row(&["ratio_p95".into(), f(self.ratio.p95(), 4)]);
        }
        t.row(&["slack_bytes".into(), self.slack_bytes.to_string()]);
        t.row(&["granted_bytes".into(), self.granted_bytes.to_string()]);
        t.row(&["launched_wire_bytes".into(), self.launched_wire_bytes.to_string()]);
        t.row(&["migrations_launched".into(), self.launched.to_string()]);
        t.row(&["migrations_landed".into(), self.landed.to_string()]);
        for (i, &n) in self.drift_hist.iter().enumerate() {
            if n > 0 {
                t.row(&[format!("drift {}", drift_label(i)), n.to_string()]);
            }
        }
        t
    }

    /// Encode for artifacts (`TRACE_*.json` sidecars, tests).
    pub fn to_json(&self) -> Json {
        fn summary_json(s: &Summary) -> Json {
            if s.count() == 0 {
                return Json::Null;
            }
            Json::obj(vec![
                ("count", Json::from(s.count())),
                ("mean", Json::from(s.mean())),
                ("p50", Json::from(s.p50())),
                ("p95", Json::from(s.p95())),
                ("min", Json::from(s.min())),
                ("max", Json::from(s.max())),
            ])
        }
        let drift = self
            .drift_hist
            .iter()
            .enumerate()
            .map(|(i, &n)| (drift_label(i), Json::from(n)))
            .collect::<Vec<_>>();
        Json::obj(vec![
            ("steps", Json::from(self.steps)),
            ("residual_s", summary_json(&self.residual_s)),
            ("ratio", summary_json(&self.ratio)),
            (
                "drift_hist",
                Json::obj(drift.iter().map(|(k, v)| (k.as_str(), v.clone())).collect()),
            ),
            ("slack_bytes", Json::from(self.slack_bytes as f64)),
            ("granted_bytes", Json::from(self.granted_bytes as f64)),
            ("launched_wire_bytes", Json::from(self.launched_wire_bytes as f64)),
            ("migrations_launched", Json::from(self.launched)),
            ("migrations_landed", Json::from(self.landed)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, predicted_s: f64, measured_s: f64) -> StepRecord {
        StepRecord {
            step,
            predicted_s,
            slack_bytes: 100,
            granted_bytes: 100,
            measured_s,
            launched: 1,
            launched_wire_bytes: 64,
            landed: 1,
        }
    }

    #[test]
    fn ledger_is_bounded_fifo() {
        let mut l = Ledger::new(3);
        for i in 0..5 {
            l.push(rec(i, 1.0, 1.0));
        }
        let snap = l.snapshot();
        assert_eq!(snap.len(), 3);
        assert_eq!(snap[0].step, 2);
        assert_eq!(snap[2].step, 4);
    }

    #[test]
    fn residuals_and_drift_buckets() {
        // measured exactly 2x predicted → log2 ratio = 1 → top bucket
        let report = PlanVsActual::from_records(&[rec(0, 0.5, 1.0), rec(1, 1.0, 1.0)]);
        assert_eq!(report.steps, 2);
        assert_eq!(report.residual_s.count(), 2);
        assert!((report.residual_s.mean() - 0.25).abs() < 1e-12);
        assert_eq!(report.ratio.count(), 2);
        assert_eq!(report.drift_hist.iter().sum::<usize>(), 2);
        // ratio 2.0 lands in the >= 1.0 overflow bucket, ratio 1.0 in the
        // centred [-0.1, 0.1) bucket
        assert_eq!(report.drift_hist[DRIFT_EDGES.len()], 1);
        let centre = DRIFT_EDGES.iter().position(|&e| 0.0 < e).unwrap();
        assert_eq!(report.drift_hist[centre], 1);
        assert_eq!(report.slack_bytes, 200);
        assert_eq!(report.launched, 2);
    }

    #[test]
    fn zero_prediction_steps_skip_ratio_but_keep_residual() {
        let report = PlanVsActual::from_records(&[rec(0, 0.0, 0.25)]);
        assert_eq!(report.residual_s.count(), 1);
        assert_eq!(report.ratio.count(), 0);
        assert_eq!(report.drift_hist.iter().sum::<usize>(), 0);
        // json encodes the empty ratio as null, and the table still renders
        let j = report.to_json();
        assert_eq!(j.get("ratio"), Some(&Json::Null));
        assert!(!report.summary_table().is_empty());
    }

    #[test]
    fn json_report_parses_back() {
        let report = PlanVsActual::from_records(&[rec(0, 0.5, 1.0)]);
        let parsed = Json::parse(&report.to_json().to_string()).expect("parses");
        assert_eq!(parsed.at(&["steps"]).as_usize(), Some(1));
        assert!(parsed.at(&["residual_s", "mean"]).as_f64().is_some());
    }
}
