//! The tracer handle: a cloneable, thread-safe event sink that costs
//! nothing when disabled.
//!
//! [`Tracer::disabled`] carries no allocation at all — `emit` takes the
//! event as a *closure* and never calls it on the no-op sink, so a traced
//! hot path pays one branch on a `None` when tracing is off (the
//! `perf_hotpath` `obs_overhead` section gates this at ≤ 5 %).  When
//! enabled, the tracer owns the flight-recorder ring, the optional full
//! event retention used by the exporters, the plan-vs-actual ledger, and
//! the anomaly triggers (see [`crate::obs::recorder`]).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::obs::event::{Event, EventKind};
use crate::obs::ledger::{Ledger, PlanVsActual, StepRecord};
use crate::obs::recorder::{AnomalyConfig, FlightDump};

/// Tracer construction knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TracerConfig {
    /// Flight-recorder window: how many recent events a dump snapshots.
    pub ring_capacity: usize,
    /// Keep the *full* event stream for export (Chrome trace, e2e
    /// assertions).  Turn off for long-running servers where only the
    /// flight window and the ledger matter.
    pub retain_all: bool,
    /// How many step records the plan-vs-actual ledger retains.
    pub ledger_capacity: usize,
    /// Flight-recorder triggers.
    pub anomaly: AnomalyConfig,
}

impl Default for TracerConfig {
    fn default() -> Self {
        TracerConfig {
            ring_capacity: 512,
            retain_all: true,
            ledger_capacity: 4096,
            anomaly: AnomalyConfig::default(),
        }
    }
}

struct Inner {
    cfg: TracerConfig,
    ring: VecDeque<Event>,
    all: Vec<Event>,
    seq: u64,
    step: u64,
    ledger: Ledger,
    dumps: Vec<FlightDump>,
    backpressure_this_step: bool,
    backpressure_streak: usize,
    zero_slack_streak: usize,
    replan_this_step: bool,
    replan_streak: usize,
}

impl Inner {
    fn push(&mut self, kind: EventKind) {
        // trigger checks read the payload before it is moved into the ring
        let slo_breach = match (&kind, self.cfg.anomaly.ttft_slo_s) {
            (EventKind::ReqRetire { ttft_s, .. }, Some(slo)) => *ttft_s > slo,
            _ => false,
        };
        if matches!(kind, EventKind::Backpressure) {
            self.backpressure_this_step = true;
        }
        if matches!(kind, EventKind::ReplanFallback { .. }) {
            self.replan_this_step = true;
        }
        self.push_raw(kind);
        if slo_breach {
            self.dump("slo_violation");
        }
    }

    fn push_raw(&mut self, kind: EventKind) {
        let ev = Event {
            step: self.step,
            seq: self.seq,
            kind,
        };
        self.seq += 1;
        if self.ring.len() == self.cfg.ring_capacity.max(1) {
            self.ring.pop_front();
        }
        self.ring.push_back(ev.clone());
        if self.cfg.retain_all {
            self.all.push(ev);
        }
    }

    fn dump(&mut self, reason: &'static str) {
        if self.dumps.len() >= self.cfg.anomaly.max_dumps {
            return;
        }
        self.push_raw(EventKind::Anomaly {
            reason: reason.to_string(),
        });
        self.dumps.push(FlightDump {
            reason: reason.to_string(),
            step: self.step,
            events: self.ring.iter().cloned().collect(),
        });
    }

    fn record_step(&mut self, rec: StepRecord) {
        self.ledger.push(rec);
        // streak triggers advance on step boundaries
        if std::mem::take(&mut self.backpressure_this_step) {
            self.backpressure_streak += 1;
        } else {
            self.backpressure_streak = 0;
        }
        if rec.slack_bytes == 0 {
            self.zero_slack_streak += 1;
        } else {
            self.zero_slack_streak = 0;
        }
        if std::mem::take(&mut self.replan_this_step) {
            self.replan_streak += 1;
        } else {
            self.replan_streak = 0;
        }
        let a = self.cfg.anomaly;
        if a.backpressure_streak > 0 && self.backpressure_streak >= a.backpressure_streak {
            self.backpressure_streak = 0;
            self.dump("backpressure_streak");
        }
        if a.zero_slack_streak > 0 && self.zero_slack_streak >= a.zero_slack_streak {
            self.zero_slack_streak = 0;
            self.dump("zero_slack_streak");
        }
        if a.replan_streak > 0 && self.replan_streak >= a.replan_streak {
            self.replan_streak = 0;
            self.dump("replan_streak");
        }
    }
}

/// Cloneable tracing handle (see the [module docs](self)).
#[derive(Clone)]
pub struct Tracer {
    inner: Option<Arc<Mutex<Inner>>>,
}

impl Tracer {
    /// The no-op sink: every operation is a branch on `None`.
    pub fn disabled() -> Self {
        Tracer { inner: None }
    }

    /// An enabled tracer with the given configuration.
    pub fn new(cfg: TracerConfig) -> Self {
        Tracer {
            inner: Some(Arc::new(Mutex::new(Inner {
                ring: VecDeque::with_capacity(cfg.ring_capacity.max(1)),
                all: Vec::new(),
                seq: 0,
                step: 0,
                ledger: Ledger::new(cfg.ledger_capacity),
                dumps: Vec::new(),
                backpressure_this_step: false,
                backpressure_streak: 0,
                zero_slack_streak: 0,
                replan_this_step: false,
                replan_streak: 0,
                cfg,
            }))),
        }
    }

    /// `true` when events are being recorded.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Emit one event.  `build` is only invoked when the tracer is enabled,
    /// so payload construction (strings, field reads) costs nothing on the
    /// disabled path.
    #[inline]
    pub fn emit(&self, build: impl FnOnce() -> EventKind) {
        if let Some(m) = &self.inner {
            let mut g = m.lock().unwrap_or_else(|p| p.into_inner());
            let kind = build();
            g.push(kind);
        }
    }

    /// Stamp subsequent events with this decode-step clock value.
    pub fn set_step(&self, step: u64) {
        if let Some(m) = &self.inner {
            m.lock().unwrap_or_else(|p| p.into_inner()).step = step;
        }
    }

    /// Append one step's plan-vs-actual record and advance the streak
    /// triggers (called once per completed decode step).
    pub fn record_step(&self, rec: StepRecord) {
        if let Some(m) = &self.inner {
            m.lock().unwrap_or_else(|p| p.into_inner()).record_step(rec);
        }
    }

    /// The full retained event stream (empty when disabled or when
    /// [`TracerConfig::retain_all`] is off).
    pub fn events(&self) -> Vec<Event> {
        match &self.inner {
            Some(m) => m.lock().unwrap_or_else(|p| p.into_inner()).all.clone(),
            None => Vec::new(),
        }
    }

    /// The current flight-recorder window, oldest first.
    pub fn ring_snapshot(&self) -> Vec<Event> {
        match &self.inner {
            Some(m) => m
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .ring
                .iter()
                .cloned()
                .collect(),
            None => Vec::new(),
        }
    }

    /// Flight dumps captured so far.
    pub fn dumps(&self) -> Vec<FlightDump> {
        match &self.inner {
            Some(m) => m.lock().unwrap_or_else(|p| p.into_inner()).dumps.clone(),
            None => Vec::new(),
        }
    }

    /// The retained plan-vs-actual step records, oldest first.
    pub fn step_records(&self) -> Vec<StepRecord> {
        match &self.inner {
            Some(m) => m.lock().unwrap_or_else(|p| p.into_inner()).ledger.snapshot(),
            None => Vec::new(),
        }
    }

    /// Fold the retained step records into a [`PlanVsActual`] report
    /// (`None` when the tracer is disabled).
    pub fn plan_vs_actual(&self) -> Option<PlanVsActual> {
        self.inner
            .as_ref()
            .map(|m| PlanVsActual::from_records(&m.lock().unwrap_or_else(|p| p.into_inner()).ledger.snapshot()))
    }
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tracer({})", if self.enabled() { "enabled" } else { "disabled" })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: u64, slack: u64) -> StepRecord {
        StepRecord {
            step,
            predicted_s: 0.001,
            slack_bytes: slack,
            granted_bytes: slack.max(1),
            measured_s: 0.001,
            launched: 0,
            launched_wire_bytes: 0,
            landed: 0,
        }
    }

    #[test]
    fn disabled_sink_never_builds_the_event() {
        let t = Tracer::disabled();
        t.emit(|| unreachable!("no-op sink must not construct payloads"));
        t.set_step(9);
        t.record_step(rec(9, 0));
        assert!(!t.enabled());
        assert!(t.events().is_empty() && t.dumps().is_empty());
        assert!(t.plan_vs_actual().is_none());
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest_window() {
        let t = Tracer::new(TracerConfig {
            ring_capacity: 4,
            ..TracerConfig::default()
        });
        for i in 0..10u64 {
            t.set_step(i);
            t.emit(|| EventKind::ReqArrive { id: i });
        }
        let ring = t.ring_snapshot();
        assert_eq!(ring.len(), 4);
        assert!(matches!(ring[0].kind, EventKind::ReqArrive { id: 6 }));
        assert!(matches!(ring[3].kind, EventKind::ReqArrive { id: 9 }));
        // full retention still has all ten, with dense seq numbers
        let all = t.events();
        assert_eq!(all.len(), 10);
        assert!(all.windows(2).all(|w| w[1].seq == w[0].seq + 1));
    }

    #[test]
    fn slo_breach_dumps_immediately() {
        let t = Tracer::new(TracerConfig {
            anomaly: AnomalyConfig {
                ttft_slo_s: Some(0.5),
                ..AnomalyConfig::default()
            },
            ..TracerConfig::default()
        });
        t.emit(|| EventKind::ReqRetire {
            id: 1,
            tokens: 4,
            ttft_s: 0.1,
        });
        assert!(t.dumps().is_empty());
        t.emit(|| EventKind::ReqRetire {
            id: 2,
            tokens: 4,
            ttft_s: 0.9,
        });
        let dumps = t.dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].reason, "slo_violation");
        // the dump window ends with the anomaly marker
        assert!(matches!(
            dumps[0].events.last().unwrap().kind,
            EventKind::Anomaly { .. }
        ));
    }

    #[test]
    fn streak_triggers_fire_on_consecutive_steps_only() {
        let t = Tracer::new(TracerConfig {
            anomaly: AnomalyConfig {
                backpressure_streak: 2,
                zero_slack_streak: 3,
                ..AnomalyConfig::default()
            },
            ..TracerConfig::default()
        });
        // backpressure on steps 0 and 2 — not consecutive, no dump
        t.emit(|| EventKind::Backpressure);
        t.record_step(rec(0, 1));
        t.record_step(rec(1, 1));
        t.emit(|| EventKind::Backpressure);
        t.record_step(rec(2, 1));
        assert!(t.dumps().is_empty());
        // two in a row fires
        t.emit(|| EventKind::Backpressure);
        t.record_step(rec(3, 1));
        t.emit(|| EventKind::Backpressure);
        t.record_step(rec(4, 1));
        assert_eq!(t.dumps().len(), 1);
        assert_eq!(t.dumps()[0].reason, "backpressure_streak");
        // zero-slack streak: three consecutive zero-slack steps
        t.record_step(rec(5, 0));
        t.record_step(rec(6, 0));
        assert_eq!(t.dumps().len(), 1);
        t.record_step(rec(7, 0));
        assert_eq!(t.dumps().len(), 2);
        assert_eq!(t.dumps()[1].reason, "zero_slack_streak");
    }

    #[test]
    fn replan_fallback_streak_trips_the_flight_recorder() {
        let t = Tracer::new(TracerConfig {
            anomaly: AnomalyConfig {
                replan_streak: 2,
                ..AnomalyConfig::default()
            },
            ..TracerConfig::default()
        });
        // fallbacks on steps 0 and 2 — not consecutive, no dump
        t.emit(|| EventKind::ReplanFallback { group: 0 });
        t.record_step(rec(0, 1));
        t.record_step(rec(1, 1));
        t.emit(|| EventKind::ReplanFallback { group: 0 });
        t.record_step(rec(2, 1));
        assert!(t.dumps().is_empty());
        // two consecutive fallback steps fire (several in one step count once)
        t.emit(|| EventKind::ReplanFallback { group: 0 });
        t.emit(|| EventKind::ReplanFallback { group: 1 });
        t.record_step(rec(3, 1));
        t.emit(|| EventKind::ReplanFallback { group: 0 });
        t.record_step(rec(4, 1));
        assert_eq!(t.dumps().len(), 1);
        assert_eq!(t.dumps()[0].reason, "replan_streak");
    }

    #[test]
    fn dump_count_is_capped() {
        let t = Tracer::new(TracerConfig {
            anomaly: AnomalyConfig {
                ttft_slo_s: Some(0.0),
                max_dumps: 2,
                ..AnomalyConfig::default()
            },
            ..TracerConfig::default()
        });
        for i in 0..5 {
            t.emit(|| EventKind::ReqRetire {
                id: i,
                tokens: 1,
                ttft_s: 1.0,
            });
        }
        assert_eq!(t.dumps().len(), 2);
    }
}
