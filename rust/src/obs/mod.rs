//! Observability: step-level tracing, plan-vs-actual telemetry, and a
//! flight recorder for the serving loop.
//!
//! KVPR's scheduler derives an analytic execution plan every step — split
//! point, predicted step time, predicted idle-link slack — and the serving
//! loop *acts* on those predictions (the migration grant **is** the plan's
//! slack).  This module measures how good the predictions are at runtime
//! and records what the loop was doing when they weren't:
//!
//! * [`Tracer`] — a cloneable, thread-safe event sink.  The serving loop,
//!   the [`KvStore`](crate::kvstore::KvStore) /
//!   [`MigrationEngine`](crate::kvstore::MigrationEngine) and the planner
//!   path emit typed [`Event`]s: request lifecycle (arrive → admit →
//!   first-token → retire), step phases (stage / migration-poll / plan /
//!   compute, nested in a per-step span; the pipelined loop adds a
//!   prestage span wrapping compute and a handoff span, exported on their
//!   own Chrome-trace thread track so the overlap is visible, plus
//!   [`EventKind::ReplanFallback`] instants for every stale-prestage
//!   inline re-solve), per-group [`EventKind::Plan`]s,
//!   the slack→grant derivation, and every migration lifecycle transition
//!   (queued → staged → in-flight → landed, tagged with tier hop, class
//!   and bytes).  Events are stamped with the decode-step virtual clock
//!   ([`crate::util::clock::Clock`]), so traces are deterministic under
//!   the interpreter runtime.  [`Tracer::disabled`] is a no-op sink:
//!   `emit` takes a closure it never calls, so tracing off costs one
//!   branch (gated ≤ 5 % in `perf_hotpath`'s `obs_overhead` section).
//! * [`PlanVsActual`] / [`StepRecord`] — the plan-vs-actual ledger:
//!   per-step predicted vs measured step time and predicted slack vs
//!   launched link bytes, folded into residual summaries and a log₂-ratio
//!   drift histogram (`util::stats`) — the profiler→scheduler feedback
//!   signal.
//! * [`FlightDump`] / [`AnomalyConfig`] — the flight recorder: a bounded
//!   ring of recent events snapshotted to JSON when an anomaly trigger
//!   fires (TTFT SLO violation, backpressure streak, zero-slack streak,
//!   replan-fallback streak).
//! * [`chrome_trace`] — Chrome `trace_event` export (Perfetto /
//!   `chrome://tracing`), plus [`PlanVsActual::summary_table`] for the
//!   text view.  `examples/trace_dump.rs` and `examples/workload_slo.rs`
//!   wire both to files.  [`chrome_trace_sharded`] merges several serving
//!   loops — the [`Router`](crate::coordinator::Router)'s worker shards —
//!   into one document, each shard on its own named process track
//!   (`examples/shard_trace.rs`).
//!
//! # Tracer API
//!
//! ```
//! use kvpr::obs::{EventKind, Tracer, TracerConfig};
//!
//! let t = Tracer::new(TracerConfig::default());
//! t.set_step(3);
//! t.emit(|| EventKind::ReqArrive { id: 41 });
//! let events = t.events();
//! assert_eq!(events.len(), 1);
//! assert_eq!(events[0].step, 3);
//!
//! // the disabled sink never even constructs the payload
//! let off = Tracer::disabled();
//! off.emit(|| unreachable!("not called on the no-op sink"));
//! assert!(off.events().is_empty());
//! ```

mod chrome;
mod event;
mod ledger;
mod recorder;
mod tracer;

pub use chrome::{chrome_trace, chrome_trace_sharded};
pub use event::{Event, EventKind, MigPhase, Phase};
pub use ledger::{PlanVsActual, StepRecord};
pub use recorder::{AnomalyConfig, FlightDump};
pub use tracer::{Tracer, TracerConfig};
