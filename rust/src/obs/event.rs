//! Typed trace events and their lossless JSON encoding.
//!
//! One [`Event`] is a `(step, seq, kind)` triple: `step` is the serving
//! loop's decode-step clock at emission time, `seq` a global monotonically
//! increasing ordinal (total order over the whole trace), and
//! [`EventKind`] the payload.  Events serialise to flat, tag-discriminated
//! JSON objects through [`crate::util::json::Json`] — the writer's ordered
//! keys make encoded traces byte-stable, and [`Event::from_json`] round-trips
//! them back for postmortem tooling and the flight-recorder tests.

use crate::util::json::Json;

/// Serving-loop phase a span event brackets (one B/E pair per phase per
/// step in the Chrome export; `Step` encloses the others).  The pipelined
/// loop adds `Prestage` (the worker's plan-solve + pump window, wrapping
/// `Compute` so the overlap is visible) and `Handoff` (adopting the
/// worker's results); both render on their own Chrome-trace thread track.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// The whole decode step (admission through retirement).
    Step,
    /// Admission / prefill staging (§2 of the loop).
    Stage,
    /// Tier sync + migration completion polling (§2b).
    MigrationPoll,
    /// Per-group Eq. (11) re-planning and the slack→grant derivation (§3).
    Plan,
    /// The engine decode step itself (§4).
    Compute,
    /// Pipelined mode: the stage worker's overlap window — next step's
    /// plan solve and the migration pump running under this step's
    /// compute.  Encloses `Compute`; the tail past `Compute`'s end is the
    /// serve thread stalled on the handoff.
    Prestage,
    /// Pipelined mode: adopting the worker's results on the serve thread
    /// (step-budget accounting, migration deltas, next step's tickets).
    Handoff,
}

impl Phase {
    /// Stable lower-case label used in JSON and the Chrome export.
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Step => "step",
            Phase::Stage => "stage",
            Phase::MigrationPoll => "migration_poll",
            Phase::Plan => "plan",
            Phase::Compute => "compute",
            Phase::Prestage => "prestage",
            Phase::Handoff => "handoff",
        }
    }

    fn parse(s: &str) -> Option<Phase> {
        Some(match s {
            "step" => Phase::Step,
            "stage" => Phase::Stage,
            "migration_poll" => Phase::MigrationPoll,
            "plan" => Phase::Plan,
            "compute" => Phase::Compute,
            "prestage" => Phase::Prestage,
            "handoff" => Phase::Handoff,
            _ => return None,
        })
    }
}

/// Where in the queued → staged → in-flight → landed lifecycle a
/// migration event was emitted (plus cancellation on sequence release).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigPhase {
    /// Destination reserved, waiting for link-budget grant.
    Queued,
    /// Copied into the pinned staging buffer at launch.
    Staged,
    /// Riding the wire.
    InFlight,
    /// Completion observed by `poll()`.
    Landed,
    /// Released before landing; parked on the drain list.
    Canceled,
}

impl MigPhase {
    /// Stable lower-case label used in JSON and the Chrome export.
    pub fn name(&self) -> &'static str {
        match self {
            MigPhase::Queued => "queued",
            MigPhase::Staged => "staged",
            MigPhase::InFlight => "in_flight",
            MigPhase::Landed => "landed",
            MigPhase::Canceled => "canceled",
        }
    }

    fn parse(s: &str) -> Option<MigPhase> {
        Some(match s {
            "queued" => MigPhase::Queued,
            "staged" => MigPhase::Staged,
            "in_flight" => MigPhase::InFlight,
            "landed" => MigPhase::Landed,
            "canceled" => MigPhase::Canceled,
            _ => return None,
        })
    }
}

/// The typed payload of one trace event.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Request entered the serving queue.
    ReqArrive { id: u64 },
    /// Request admitted into a decode group on `lane`.
    ReqAdmit { id: u64, lane: usize },
    /// First generated token produced.
    ReqFirstToken { id: u64 },
    /// Request finished and left the loop.
    ReqRetire { id: u64, tokens: usize, ttft_s: f64 },
    /// A serving-loop phase opened.
    PhaseBegin { phase: Phase },
    /// A serving-loop phase closed.
    PhaseEnd { phase: Phase },
    /// One group's step plan (Eq. 11 output) for this step.
    Plan {
        group: usize,
        l: usize,
        predicted_s: f64,
        slack_bytes: u64,
    },
    /// The step's slack→grant derivation and what the grant bought.
    StepBudget {
        slack: u64,
        granted: u64,
        launched: usize,
        launched_bytes: u64,
    },
    /// Migration lifecycle transition, tagged with the tier hop.
    Migration {
        id: u64,
        phase: MigPhase,
        class: String,
        from: String,
        to: String,
        bytes: u64,
    },
    /// Admission hit backpressure this step.
    Backpressure,
    /// Admission adopted `blocks` registered shared-prefix blocks covering
    /// `tokens` prompt tokens (cross-request prefix sharing); `id` is the
    /// first admitted request of the group.
    ShareHit { id: u64, blocks: usize, tokens: usize },
    /// Pipelined mode: a group's prestaged plan went stale (or was never
    /// solved) and the serve thread re-solved it inline.
    ReplanFallback { group: usize },
    /// Flight-recorder trigger fired (`reason` matches the dump's).
    Anomaly { reason: String },
}

/// One trace event (see the [module docs](self)).
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Decode-step clock at emission.
    pub step: u64,
    /// Global emission ordinal (total order).
    pub seq: u64,
    /// Payload.
    pub kind: EventKind,
}

impl Event {
    /// Encode as a flat JSON object with a `"kind"` tag.
    pub fn to_json(&self) -> Json {
        let mut kv: Vec<(&str, Json)> = vec![
            ("step", Json::from(self.step as f64)),
            ("seq", Json::from(self.seq as f64)),
        ];
        match &self.kind {
            EventKind::ReqArrive { id } => {
                kv.push(("kind", "req_arrive".into()));
                kv.push(("id", Json::from(*id as f64)));
            }
            EventKind::ReqAdmit { id, lane } => {
                kv.push(("kind", "req_admit".into()));
                kv.push(("id", Json::from(*id as f64)));
                kv.push(("lane", Json::from(*lane)));
            }
            EventKind::ReqFirstToken { id } => {
                kv.push(("kind", "req_first_token".into()));
                kv.push(("id", Json::from(*id as f64)));
            }
            EventKind::ReqRetire { id, tokens, ttft_s } => {
                kv.push(("kind", "req_retire".into()));
                kv.push(("id", Json::from(*id as f64)));
                kv.push(("tokens", Json::from(*tokens)));
                kv.push(("ttft_s", Json::from(*ttft_s)));
            }
            EventKind::PhaseBegin { phase } => {
                kv.push(("kind", "phase_begin".into()));
                kv.push(("phase", phase.name().into()));
            }
            EventKind::PhaseEnd { phase } => {
                kv.push(("kind", "phase_end".into()));
                kv.push(("phase", phase.name().into()));
            }
            EventKind::Plan {
                group,
                l,
                predicted_s,
                slack_bytes,
            } => {
                kv.push(("kind", "plan".into()));
                kv.push(("group", Json::from(*group)));
                kv.push(("l", Json::from(*l)));
                kv.push(("predicted_s", Json::from(*predicted_s)));
                kv.push(("slack_bytes", Json::from(*slack_bytes as f64)));
            }
            EventKind::StepBudget {
                slack,
                granted,
                launched,
                launched_bytes,
            } => {
                kv.push(("kind", "step_budget".into()));
                kv.push(("slack", Json::from(*slack as f64)));
                kv.push(("granted", Json::from(*granted as f64)));
                kv.push(("launched", Json::from(*launched)));
                kv.push(("launched_bytes", Json::from(*launched_bytes as f64)));
            }
            EventKind::Migration {
                id,
                phase,
                class,
                from,
                to,
                bytes,
            } => {
                kv.push(("kind", "migration".into()));
                kv.push(("id", Json::from(*id as f64)));
                kv.push(("phase", phase.name().into()));
                kv.push(("class", class.as_str().into()));
                kv.push(("from", from.as_str().into()));
                kv.push(("to", to.as_str().into()));
                kv.push(("bytes", Json::from(*bytes as f64)));
            }
            EventKind::Backpressure => kv.push(("kind", "backpressure".into())),
            EventKind::ShareHit { id, blocks, tokens } => {
                kv.push(("kind", "share_hit".into()));
                kv.push(("id", Json::from(*id as f64)));
                kv.push(("blocks", Json::from(*blocks)));
                kv.push(("tokens", Json::from(*tokens)));
            }
            EventKind::ReplanFallback { group } => {
                kv.push(("kind", "replan_fallback".into()));
                kv.push(("group", Json::from(*group)));
            }
            EventKind::Anomaly { reason } => {
                kv.push(("kind", "anomaly".into()));
                kv.push(("reason", reason.as_str().into()));
            }
        }
        Json::obj(kv)
    }

    /// Decode an event encoded by [`Event::to_json`].
    pub fn from_json(j: &Json) -> Option<Event> {
        let step = j.get("step")?.as_f64()? as u64;
        let seq = j.get("seq")?.as_f64()? as u64;
        let u = |key: &str| j.get(key).and_then(Json::as_f64).map(|v| v as u64);
        let us = |key: &str| j.get(key).and_then(Json::as_usize);
        let s = |key: &str| j.get(key).and_then(Json::as_str).map(str::to_string);
        let kind = match j.get("kind")?.as_str()? {
            "req_arrive" => EventKind::ReqArrive { id: u("id")? },
            "req_admit" => EventKind::ReqAdmit {
                id: u("id")?,
                lane: us("lane")?,
            },
            "req_first_token" => EventKind::ReqFirstToken { id: u("id")? },
            "req_retire" => EventKind::ReqRetire {
                id: u("id")?,
                tokens: us("tokens")?,
                ttft_s: j.get("ttft_s")?.as_f64()?,
            },
            "phase_begin" => EventKind::PhaseBegin {
                phase: Phase::parse(j.get("phase")?.as_str()?)?,
            },
            "phase_end" => EventKind::PhaseEnd {
                phase: Phase::parse(j.get("phase")?.as_str()?)?,
            },
            "plan" => EventKind::Plan {
                group: us("group")?,
                l: us("l")?,
                predicted_s: j.get("predicted_s")?.as_f64()?,
                slack_bytes: u("slack_bytes")?,
            },
            "step_budget" => EventKind::StepBudget {
                slack: u("slack")?,
                granted: u("granted")?,
                launched: us("launched")?,
                launched_bytes: u("launched_bytes")?,
            },
            "migration" => EventKind::Migration {
                id: u("id")?,
                phase: MigPhase::parse(j.get("phase")?.as_str()?)?,
                class: s("class")?,
                from: s("from")?,
                to: s("to")?,
                bytes: u("bytes")?,
            },
            "backpressure" => EventKind::Backpressure,
            "share_hit" => EventKind::ShareHit {
                id: u("id")?,
                blocks: us("blocks")?,
                tokens: us("tokens")?,
            },
            "replan_fallback" => EventKind::ReplanFallback { group: us("group")? },
            "anomaly" => EventKind::Anomaly { reason: s("reason")? },
            _ => return None,
        };
        Some(Event { step, seq, kind })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(e: Event) {
        let j = e.to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).expect("encoded event parses");
        let back = Event::from_json(&parsed).expect("decodes");
        assert_eq!(back, e);
    }

    #[test]
    fn every_kind_round_trips_through_json() {
        let kinds = vec![
            EventKind::ReqArrive { id: 3 },
            EventKind::ReqAdmit { id: 3, lane: 1 },
            EventKind::ReqFirstToken { id: 3 },
            EventKind::ReqRetire {
                id: 3,
                tokens: 17,
                ttft_s: 0.125,
            },
            EventKind::PhaseBegin { phase: Phase::Plan },
            EventKind::PhaseEnd {
                phase: Phase::MigrationPoll,
            },
            EventKind::Plan {
                group: 0,
                l: 48,
                predicted_s: 0.01,
                slack_bytes: 1 << 20,
            },
            EventKind::StepBudget {
                slack: 4096,
                granted: 4096,
                launched: 2,
                launched_bytes: 2048,
            },
            EventKind::Migration {
                id: 9,
                phase: MigPhase::InFlight,
                class: "promote".into(),
                from: "cpu-dram".into(),
                to: "gpu-hbm".into(),
                bytes: 65536,
            },
            EventKind::Backpressure,
            EventKind::ShareHit {
                id: 4,
                blocks: 3,
                tokens: 96,
            },
            EventKind::ReplanFallback { group: 1 },
            EventKind::PhaseBegin {
                phase: Phase::Prestage,
            },
            EventKind::PhaseEnd {
                phase: Phase::Handoff,
            },
            EventKind::Anomaly {
                reason: "slo_violation".into(),
            },
        ];
        for (i, kind) in kinds.into_iter().enumerate() {
            roundtrip(Event {
                step: i as u64,
                seq: 100 + i as u64,
                kind,
            });
        }
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let j = crate::util::json::Json::parse(r#"{"step":0,"seq":0,"kind":"martian"}"#).unwrap();
        assert!(Event::from_json(&j).is_none());
    }
}
