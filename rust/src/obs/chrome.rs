//! Chrome `trace_event` exporter: loads in Perfetto / `chrome://tracing`.
//!
//! The serving loop's virtual clock is the decode step, so the export maps
//! one step to 1 ms of trace time (`ts = step·1000 + ordinal` µs, the
//! within-step emission ordinal breaking ties) — wall time never enters,
//! which is what makes two seeded replays byte-identical.  Phases become
//! synchronous `B`/`E` spans (the `step` span encloses the sub-phase
//! spans), requests become async `b`/`n`/`e` spans keyed by request id,
//! migrations and plans are instants, and the per-step link budget is a
//! counter track (`C`).  The pipelined loop's `prestage`/`handoff` spans
//! render on their own thread track (`tid` 2), so the stage worker's
//! overlap with the `compute` span on the serve track is directly visible
//! in Perfetto.

use crate::obs::event::{Event, EventKind, Phase};
use crate::util::json::Json;

fn base(ph: &str, name: &str, cat: &str, ts: u64) -> Vec<(&'static str, Json)> {
    base_tid(ph, name, cat, ts, 1)
}

fn base_tid(ph: &str, name: &str, cat: &str, ts: u64, tid: usize) -> Vec<(&'static str, Json)> {
    vec![
        ("ph", ph.into()),
        ("name", name.into()),
        ("cat", cat.into()),
        ("ts", Json::from(ts as f64)),
        ("pid", Json::from(1usize)),
        ("tid", Json::from(tid)),
    ]
}

/// Pipeline phases get their own thread track so their spans draw beside —
/// not inside — the serve track's `compute` span.
fn phase_tid(phase: &Phase) -> usize {
    match phase {
        Phase::Prestage | Phase::Handoff => 2,
        _ => 1,
    }
}

/// Convert an event stream (as produced by
/// [`Tracer::events`](crate::obs::Tracer::events)) into a Chrome
/// `trace_event` JSON document.
pub fn chrome_trace(events: &[Event]) -> Json {
    Json::obj(vec![
        ("traceEvents", Json::Arr(convert(events, 1))),
        ("displayTimeUnit", "ms".into()),
    ])
}

/// Merge several serving loops' event streams — one per worker shard —
/// into a single Chrome trace document, each shard on its own *process*
/// track (`pid` = shard index + 1, named `shard-<i>` via `process_name`
/// metadata), so Perfetto renders the shards' step spans side by side.
/// The single-loop [`chrome_trace`] is the `pid` 1 special case.
pub fn chrome_trace_sharded(shards: &[Vec<Event>]) -> Json {
    let mut out: Vec<Json> = Vec::with_capacity(shards.iter().map(Vec::len).sum::<usize>() + shards.len());
    for (i, events) in shards.iter().enumerate() {
        let pid = i + 1;
        let name = format!("shard-{i}");
        out.push(Json::obj(vec![
            ("ph", "M".into()),
            ("name", "process_name".into()),
            ("pid", Json::from(pid)),
            ("tid", Json::from(0usize)),
            ("args", Json::obj(vec![("name", name.as_str().into())])),
        ]));
        out.extend(convert(events, pid));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", "ms".into()),
    ])
}

fn convert(events: &[Event], pid: usize) -> Vec<Json> {
    let mut out: Vec<Json> = Vec::with_capacity(events.len());
    let (mut cur_step, mut ordinal) = (u64::MAX, 0u64);
    for ev in events {
        if ev.step != cur_step {
            cur_step = ev.step;
            ordinal = 0;
        }
        let ts = ev.step * 1000 + ordinal.min(999);
        ordinal += 1;
        let mut kv = match &ev.kind {
            EventKind::PhaseBegin { phase } => {
                base_tid("B", phase.name(), "step", ts, phase_tid(phase))
            }
            EventKind::PhaseEnd { phase } => {
                base_tid("E", phase.name(), "step", ts, phase_tid(phase))
            }
            EventKind::ReqArrive { id } => {
                let mut kv = base("b", "req", "request", ts);
                kv.push(("id", Json::from(*id as f64)));
                kv
            }
            EventKind::ReqAdmit { id, lane } => {
                let mut kv = base("n", "req", "request", ts);
                kv.push(("id", Json::from(*id as f64)));
                kv.push((
                    "args",
                    Json::obj(vec![
                        ("milestone", "admit".into()),
                        ("lane", Json::from(*lane)),
                    ]),
                ));
                kv
            }
            EventKind::ReqFirstToken { id } => {
                let mut kv = base("n", "req", "request", ts);
                kv.push(("id", Json::from(*id as f64)));
                kv.push(("args", Json::obj(vec![("milestone", "first_token".into())])));
                kv
            }
            EventKind::ReqRetire { id, tokens, ttft_s } => {
                let mut kv = base("e", "req", "request", ts);
                kv.push(("id", Json::from(*id as f64)));
                kv.push((
                    "args",
                    Json::obj(vec![
                        ("tokens", Json::from(*tokens)),
                        ("ttft_s", Json::from(*ttft_s)),
                    ]),
                ));
                kv
            }
            EventKind::Plan {
                group,
                l,
                predicted_s,
                slack_bytes,
            } => {
                let mut kv = base("i", "plan", "plan", ts);
                kv.push(("s", "t".into()));
                kv.push((
                    "args",
                    Json::obj(vec![
                        ("group", Json::from(*group)),
                        ("l", Json::from(*l)),
                        ("predicted_s", Json::from(*predicted_s)),
                        ("slack_bytes", Json::from(*slack_bytes as f64)),
                    ]),
                ));
                kv
            }
            EventKind::StepBudget {
                slack,
                granted,
                launched,
                launched_bytes,
            } => {
                let mut kv = base("C", "link_budget", "step", ts);
                kv.push((
                    "args",
                    Json::obj(vec![
                        ("slack", Json::from(*slack as f64)),
                        ("granted", Json::from(*granted as f64)),
                        ("launched", Json::from(*launched)),
                        ("launched_bytes", Json::from(*launched_bytes as f64)),
                    ]),
                ));
                kv
            }
            EventKind::Migration {
                id,
                phase,
                class,
                from,
                to,
                bytes,
            } => {
                let mut kv = base("i", phase.name(), "migration", ts);
                kv.push(("s", "t".into()));
                kv.push((
                    "args",
                    Json::obj(vec![
                        ("id", Json::from(*id as f64)),
                        ("class", class.as_str().into()),
                        ("from", from.as_str().into()),
                        ("to", to.as_str().into()),
                        ("bytes", Json::from(*bytes as f64)),
                    ]),
                ));
                kv
            }
            EventKind::Backpressure => {
                let mut kv = base("i", "backpressure", "step", ts);
                kv.push(("s", "t".into()));
                kv
            }
            EventKind::ShareHit { id, blocks, tokens } => {
                let mut kv = base("i", "share_hit", "step", ts);
                kv.push(("s", "t".into()));
                kv.push((
                    "args",
                    Json::obj(vec![
                        ("id", Json::from(*id as f64)),
                        ("blocks", Json::from(*blocks)),
                        ("tokens", Json::from(*tokens)),
                    ]),
                ));
                kv
            }
            EventKind::ReplanFallback { group } => {
                let mut kv = base_tid("i", "replan_fallback", "step", ts, 2);
                kv.push(("s", "t".into()));
                kv.push(("args", Json::obj(vec![("group", Json::from(*group))])));
                kv
            }
            EventKind::Anomaly { reason } => {
                let mut kv = base("i", "anomaly", "anomaly", ts);
                kv.push(("s", "g".into()));
                kv.push(("args", Json::obj(vec![("reason", reason.as_str().into())])));
                kv
            }
        };
        kv.push(("seq", Json::from(ev.seq as f64)));
        if pid != 1 {
            for slot in kv.iter_mut() {
                if slot.0 == "pid" {
                    slot.1 = Json::from(pid);
                }
            }
        }
        out.push(Json::obj(kv));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::Phase;

    fn ev(step: u64, seq: u64, kind: EventKind) -> Event {
        Event { step, seq, kind }
    }

    fn sample() -> Vec<Event> {
        vec![
            ev(0, 0, EventKind::ReqArrive { id: 7 }),
            ev(0, 1, EventKind::PhaseBegin { phase: Phase::Step }),
            ev(0, 2, EventKind::PhaseBegin { phase: Phase::Stage }),
            ev(0, 3, EventKind::ReqAdmit { id: 7, lane: 0 }),
            ev(0, 4, EventKind::PhaseEnd { phase: Phase::Stage }),
            ev(
                0,
                5,
                EventKind::PhaseBegin {
                    phase: Phase::Compute,
                },
            ),
            ev(0, 6, EventKind::PhaseEnd { phase: Phase::Compute }),
            ev(0, 7, EventKind::ReqFirstToken { id: 7 }),
            ev(0, 8, EventKind::PhaseEnd { phase: Phase::Step }),
            ev(
                1,
                9,
                EventKind::ReqRetire {
                    id: 7,
                    tokens: 2,
                    ttft_s: 0.25,
                },
            ),
        ]
    }

    #[test]
    fn timestamps_are_monotone_and_step_scaled() {
        let doc = chrome_trace(&sample());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 10);
        let ts: Vec<f64> = evs.iter().map(|e| e.at(&["ts"]).as_f64().unwrap()).collect();
        assert!(ts.windows(2).all(|w| w[0] <= w[1]), "ts must be ordered: {ts:?}");
        // step 1 events start at the 1 ms boundary
        assert_eq!(ts[9], 1000.0);
    }

    #[test]
    fn spans_nest_properly() {
        let doc = chrome_trace(&sample());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let mut stack: Vec<String> = Vec::new();
        for e in evs {
            match e.at(&["ph"]).as_str().unwrap() {
                "B" => stack.push(e.at(&["name"]).as_str().unwrap().to_string()),
                "E" => {
                    let open = stack.pop().expect("E without open span");
                    assert_eq!(open, e.at(&["name"]).as_str().unwrap(), "mismatched span close");
                }
                _ => {}
            }
        }
        assert!(stack.is_empty(), "unclosed spans: {stack:?}");
    }

    #[test]
    fn request_async_span_is_keyed_by_request_id() {
        let doc = chrome_trace(&sample());
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let req: Vec<&Json> = evs
            .iter()
            .filter(|e| e.at(&["cat"]).as_str() == Some("request"))
            .collect();
        let phs: Vec<&str> = req.iter().map(|e| e.at(&["ph"]).as_str().unwrap()).collect();
        assert_eq!(phs, vec!["b", "n", "n", "e"]);
        assert!(req.iter().all(|e| e.at(&["id"]).as_f64() == Some(7.0)));
    }

    #[test]
    fn pipeline_phases_render_on_their_own_thread_track() {
        // the overlapped loop's emission order: prestage wraps compute,
        // handoff follows — prestage/handoff on tid 2, the rest on tid 1
        let evs = vec![
            ev(0, 0, EventKind::PhaseBegin { phase: Phase::Step }),
            ev(0, 1, EventKind::PhaseBegin { phase: Phase::Prestage }),
            ev(0, 2, EventKind::PhaseBegin { phase: Phase::Compute }),
            ev(0, 3, EventKind::PhaseEnd { phase: Phase::Compute }),
            ev(0, 4, EventKind::PhaseEnd { phase: Phase::Prestage }),
            ev(0, 5, EventKind::PhaseBegin { phase: Phase::Handoff }),
            ev(0, 6, EventKind::ReplanFallback { group: 0 }),
            ev(0, 7, EventKind::PhaseEnd { phase: Phase::Handoff }),
            ev(0, 8, EventKind::PhaseEnd { phase: Phase::Step }),
        ];
        let doc = chrome_trace(&evs);
        let out = doc.get("traceEvents").unwrap().as_arr().unwrap();
        let tid = |i: usize| out[i].at(&["tid"]).as_f64().unwrap() as usize;
        assert_eq!((tid(0), tid(2), tid(3), tid(8)), (1, 1, 1, 1), "serve track");
        assert_eq!(
            (tid(1), tid(4), tid(5), tid(6), tid(7)),
            (2, 2, 2, 2, 2),
            "worker track"
        );
        // one stack across both tracks still balances (strict nesting)
        let mut stack: Vec<String> = Vec::new();
        for e in out {
            match e.at(&["ph"]).as_str().unwrap() {
                "B" => stack.push(e.at(&["name"]).as_str().unwrap().to_string()),
                "E" => assert_eq!(stack.pop().as_deref(), e.at(&["name"]).as_str()),
                _ => {}
            }
        }
        assert!(stack.is_empty());
    }

    #[test]
    fn export_is_deterministic_and_parses() {
        let a = chrome_trace(&sample()).to_string();
        let b = chrome_trace(&sample()).to_string();
        assert_eq!(a, b);
        assert!(Json::parse(&a).is_ok());
    }

    #[test]
    fn sharded_export_gives_each_shard_its_own_named_process_track() {
        let shard0 = sample();
        let shard1 = vec![ev(0, 0, EventKind::ReqArrive { id: 9 })];
        let doc = chrome_trace_sharded(&[shard0.clone(), shard1]);
        let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
        // one process_name metadata event per shard, pids 1 and 2
        let meta: Vec<&Json> = evs
            .iter()
            .filter(|e| e.at(&["ph"]).as_str() == Some("M"))
            .collect();
        assert_eq!(meta.len(), 2);
        assert_eq!(meta[0].at(&["pid"]).as_f64(), Some(1.0));
        assert_eq!(meta[0].at(&["args", "name"]).as_str(), Some("shard-0"));
        assert_eq!(meta[1].at(&["pid"]).as_f64(), Some(2.0));
        assert_eq!(meta[1].at(&["args", "name"]).as_str(), Some("shard-1"));
        // every non-metadata event carries its shard's pid
        let pids: Vec<f64> = evs
            .iter()
            .filter(|e| e.at(&["ph"]).as_str() != Some("M"))
            .map(|e| e.at(&["pid"]).as_f64().unwrap())
            .collect();
        assert_eq!(pids.len(), shard0.len() + 1);
        assert!(pids[..shard0.len()].iter().all(|&p| p == 1.0));
        assert_eq!(pids[shard0.len()], 2.0);
        // shard 0 alone renders byte-identically to the single-loop export
        // (modulo the wrapping metadata event)
        let single = chrome_trace(&shard0);
        let one = chrome_trace_sharded(&[shard0]);
        let single_evs = single.get("traceEvents").unwrap().as_arr().unwrap();
        let one_evs = one.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(one_evs.len(), single_evs.len() + 1);
        for (a, b) in single_evs.iter().zip(one_evs.iter().skip(1)) {
            assert_eq!(a.to_string(), b.to_string());
        }
    }
}
