//! Per-policy decode-step pipeline builders.
//!
//! Each policy adds one decoder layer's tasks to the [`Sim`] and returns the
//! task whose finish is "this layer's output is ready".  The structural
//! differences between the baselines live entirely here — durations come
//! from the shared [`StepCtx`] cost library, so a policy can only win by
//! *scheduling*, exactly as in the paper.

use super::core::{ResourceId, Sim, TaskId, TaskKind};
use crate::config::{HardwareConfig, ModelConfig};

/// The paper's systems (§4 baselines + §5 related work).
///
/// A minimal plan-and-predict round trip — simulate a short latency-oriented
/// decode under KVPR and read back the per-step split points the LP chose:
///
/// ```
/// use kvpr::config::{HardwareConfig, ModelConfig, WorkloadConfig};
/// use kvpr::sim::{simulate_decode, Policy, RunConfig};
///
/// let cfg = RunConfig::new(
///     ModelConfig::opt_6_7b(),
///     HardwareConfig::a100_x16(),
///     WorkloadConfig::latency_oriented(256, 4), // prompt 256, generate 4
///     Policy::Kvpr,
/// );
/// let report = simulate_decode(&cfg);
/// assert_eq!(report.splits.len(), 4);         // one LP solve per step
/// assert!(report.tok_per_s > 0.0);
/// // the non-split baseline never recomputes
/// let base = simulate_decode(&RunConfig::new(
///     ModelConfig::opt_6_7b(),
///     HardwareConfig::a100_x16(),
///     WorkloadConfig::latency_oriented(256, 4),
///     Policy::FlexGen,
/// ));
/// assert!(base.splits.iter().all(|&l| l == 0));
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Hugging Face Accelerate: KV offloaded, synchronous transfers.
    Accelerate,
    /// DeepSpeed Inference: synchronous KV offloading with chunked
    /// transfers (modelled as extra per-layer link latency).
    DeepSpeed,
    /// FlexGen: full KV transfer overlapped with neighbouring compute.
    FlexGen,
    /// KVPR with the fine-grained weight pipeline (paper Fig 5b).
    Kvpr,
    /// KVPR without hiding: recompute waits for the *full* MHA weight
    /// transfer (paper Fig 5a / Table 2 middle row).
    KvprNoHide,
    /// ALISA-style: recompute the prefix first, then transfer the rest —
    /// no overlap between the two (paper §5).
    AlisaLike,
    /// FastDecode: attention on the CPU, KV never crosses the link.
    FastDecode,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Accelerate => "accelerate",
            Policy::DeepSpeed => "deepspeed",
            Policy::FlexGen => "flexgen",
            Policy::Kvpr => "kvpr",
            Policy::KvprNoHide => "kvpr-nohide",
            Policy::AlisaLike => "alisa",
            Policy::FastDecode => "fastdecode",
        }
    }

    pub fn uses_split(&self) -> bool {
        matches!(self, Policy::Kvpr | Policy::KvprNoHide | Policy::AlisaLike)
    }
}

/// Shared cost library + resource handles for one decode step.
#[derive(Debug, Clone)]
pub struct StepCtx {
    pub model: ModelConfig,
    pub hw: HardwareConfig,
    pub batch: usize,
    /// Valid cached tokens before this step (s').
    pub kv_len: usize,
    pub weights_offloaded: bool,
    /// Group-wise 4-bit wire compression of transferred KV (paper §4.4):
    /// 0.625 bytes per fp16 element → ratio 0.3125.
    pub kv_quant: bool,
    /// Planned split (tokens recomputed on GPU); 0 for full transfer.
    pub l: usize,
    pub gpu: ResourceId,
    pub h2d: ResourceId,
    pub d2h: ResourceId,
    pub cpu: ResourceId,
}

impl StepCtx {
    fn quant_ratio(&self) -> f64 {
        if self.kv_quant {
            // 8-byte group header / 64 elems + 0.5 byte payload, vs fp16
            0.3125
        } else {
            1.0
        }
    }

    pub fn kv_xfer_s(&self, tokens: usize) -> f64 {
        if tokens == 0 {
            return 0.0;
        }
        let bytes = self.model.kv_bytes_per_layer(self.batch, tokens) as f64 * self.quant_ratio();
        self.hw.link_time(bytes as u64)
    }

    pub fn act_xfer_s(&self, l: usize) -> f64 {
        if l == 0 {
            return 0.0;
        }
        self.hw.link_time(self.model.act_bytes_per_layer(self.batch, l))
    }

    pub fn weight_xfer_s(&self, bytes: u64) -> f64 {
        self.hw.link_time(bytes)
    }

    pub fn recompute_s(&self, l: usize) -> f64 {
        if l == 0 {
            return 0.0;
        }
        self.hw.gpu_time(self.model.recompute_flops(self.batch, l))
    }

    pub fn attn_ffn_s(&self) -> f64 {
        self.hw
            .gpu_time(self.model.decode_flops_per_layer(self.batch, self.kv_len + 1))
    }

    /// GPU side of FastDecode: projections + FFN only (attention is on CPU).
    pub fn proj_ffn_s(&self) -> f64 {
        let flops = self.model.decode_flops_per_layer(self.batch, 0);
        self.hw.gpu_time(flops)
    }

    pub fn cpu_attn_s(&self) -> f64 {
        let flops = 4.0 * self.batch as f64 * (self.kv_len + 1) as f64 * self.model.hidden as f64;
        flops / self.hw.cpu_flops
    }

    pub fn store_s(&self) -> f64 {
        // k_new + v_new + x back to host
        let bytes = 3 * (self.batch * self.model.hidden * self.model.dtype_bytes) as u64;
        self.hw.link_time(bytes)
    }
}

/// Add one decoder layer under `policy`.  `prev` is the previous layer's
/// output-ready task (compute dependency), `weights_ready` an optional
/// externally managed weight-transfer join (column schedule shares weights
/// across batches).  Returns this layer's output-ready task.
pub fn build_layer(
    sim: &mut Sim,
    policy: Policy,
    ctx: &StepCtx,
    prev: Option<TaskId>,
    weights_ready: Option<TaskId>,
) -> TaskId {
    let dep = |p: &Option<TaskId>| p.map(|t| vec![t]).unwrap_or_default();
    match policy {
        Policy::Accelerate | Policy::DeepSpeed => {
            // synchronous: transfer cannot start before the previous layer's
            // compute is done (no double buffering in the offload path)
            let extra = if policy == Policy::DeepSpeed {
                // chunked transfer: 4 extra round-trip latencies per layer
                4.0 * ctx.hw.pcie_latency_s
            } else {
                0.0
            };
            let mut deps = dep(&prev);
            let w = if ctx.weights_offloaded {
                let t = sim.task(
                    ctx.h2d,
                    TaskKind::WeightXfer,
                    ctx.weight_xfer_s(ctx.model.weight_bytes_per_layer()),
                    &deps,
                );
                deps = vec![t];
                Some(t)
            } else {
                None
            };
            let kv = sim.task(
                ctx.h2d,
                TaskKind::KvXfer,
                ctx.kv_xfer_s(ctx.kv_len) + extra,
                &deps,
            );
            let mut cdeps = vec![kv];
            if let Some(w) = w {
                cdeps.push(w);
            }
            if let Some(w) = weights_ready {
                cdeps.push(w);
            }
            let c = sim.task(ctx.gpu, TaskKind::AttnFfn, ctx.attn_ffn_s(), &cdeps);
            sim.task(ctx.d2h, TaskKind::Store, ctx.store_s(), &[c]);
            c
        }
        Policy::FlexGen => {
            // overlapped full transfer: the link runs ahead (FIFO), compute
            // depends only on *its* transfer — double buffering
            let mut wdeps = Vec::new();
            if let Some(w) = weights_ready {
                wdeps.push(w);
            } else if ctx.weights_offloaded {
                let t = sim.task(
                    ctx.h2d,
                    TaskKind::WeightXfer,
                    ctx.weight_xfer_s(ctx.model.weight_bytes_per_layer()),
                    &[],
                );
                wdeps.push(t);
            }
            let kv = sim.task(ctx.h2d, TaskKind::KvXfer, ctx.kv_xfer_s(ctx.kv_len), &[]);
            let mut cdeps = vec![kv];
            cdeps.extend(wdeps);
            cdeps.extend(dep(&prev));
            let c = sim.task(ctx.gpu, TaskKind::AttnFfn, ctx.attn_ffn_s(), &cdeps);
            sim.task(ctx.d2h, TaskKind::Store, ctx.store_s(), &[c]);
            c
        }
        Policy::Kvpr | Policy::KvprNoHide | Policy::AlisaLike => {
            let l = ctx.l.min(ctx.kv_len);
            let rest = ctx.kv_len - l;

            // weight traffic: fine-grained splits W_K/W_V out front
            let (w_kv, w_rest) = if let Some(w) = weights_ready {
                (Some(w), Some(w))
            } else if ctx.weights_offloaded {
                if policy == Policy::Kvpr {
                    let wk = sim.task(
                        ctx.h2d,
                        TaskKind::WeightXfer,
                        ctx.weight_xfer_s(ctx.model.kv_proj_weight_bytes()),
                        &[],
                    );
                    let wr = sim.task(
                        ctx.h2d,
                        TaskKind::WeightXfer,
                        ctx.weight_xfer_s(
                            ctx.model.weight_bytes_per_layer() - ctx.model.kv_proj_weight_bytes(),
                        ),
                        &[],
                    );
                    (Some(wk), Some(wr))
                } else {
                    // coarse: one blob, recompute waits for all of it
                    let w = sim.task(
                        ctx.h2d,
                        TaskKind::WeightXfer,
                        ctx.weight_xfer_s(ctx.model.weight_bytes_per_layer()),
                        &[],
                    );
                    (Some(w), Some(w))
                }
            } else {
                (None, None)
            };

            let act = sim.task(ctx.h2d, TaskKind::ActXfer, ctx.act_xfer_s(l), &[]);

            let mut rdeps = vec![act];
            if let Some(w) = w_kv {
                rdeps.push(w);
            }
            let rec = sim.task(ctx.gpu, TaskKind::Recompute, ctx.recompute_s(l), &rdeps);

            // the remainder: KVPR streams it concurrently (FIFO after act);
            // ALISA only issues it after recomputation finishes
            let rest_deps: Vec<TaskId> = if policy == Policy::AlisaLike { vec![rec] } else { vec![] };
            let kv = sim.task(ctx.h2d, TaskKind::KvXfer, ctx.kv_xfer_s(rest), &rest_deps);

            let mut cdeps = vec![rec, kv];
            if let Some(w) = w_rest {
                cdeps.push(w);
            }
            cdeps.extend(dep(&prev));
            let c = sim.task(ctx.gpu, TaskKind::AttnFfn, ctx.attn_ffn_s(), &cdeps);
            sim.task(ctx.d2h, TaskKind::Store, ctx.store_s(), &[c]);
            c
        }
        Policy::FastDecode => {
            // KV stays host-side; GPU does projections/FFN, CPU the attention
            let mut pdeps = dep(&prev);
            if let Some(w) = weights_ready {
                pdeps.push(w);
            }
            let proj = sim.task(ctx.gpu, TaskKind::AttnFfn, ctx.proj_ffn_s(), &pdeps);
            // ship q/k/v activations over (small)
            let act = sim.task(ctx.d2h, TaskKind::ActXfer, 3.0 * ctx.act_xfer_s(1), &[proj]);
            sim.task(ctx.cpu, TaskKind::CpuAttn, ctx.cpu_attn_s(), &[act])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::HardwareConfig;

    fn ctx(sim: &mut Sim, l: usize) -> StepCtx {
        StepCtx {
            model: ModelConfig::opt_6_7b(),
            hw: HardwareConfig::a100_x16(),
            batch: 32,
            kv_len: 1024,
            weights_offloaded: false,
            kv_quant: false,
            l,
            gpu: sim.resource("gpu"),
            h2d: sim.resource("h2d"),
            d2h: sim.resource("d2h"),
            cpu: sim.resource("cpu"),
        }
    }

    fn run_layers(policy: Policy, l: usize, n: usize) -> f64 {
        let mut sim = Sim::new();
        let c = ctx(&mut sim, l);
        let mut prev = None;
        for _ in 0..n {
            prev = Some(build_layer(&mut sim, policy, &c, prev, None));
        }
        sim.finish(prev.unwrap())
    }

    #[test]
    fn kvpr_beats_flexgen_beats_accelerate() {
        // the paper's headline ordering at its own scale
        let acc = run_layers(Policy::Accelerate, 0, 8);
        let flex = run_layers(Policy::FlexGen, 0, 8);
        let mut sim = Sim::new();
        let c = ctx(&mut sim, 0);
        // solve the LP for the kvpr split
        let cost = crate::scheduler::CostModel::from_hardware(&c.hw, &c.model, c.batch);
        let solver =
            crate::scheduler::SplitSolver::new(cost, crate::scheduler::SchedulePolicy::RowByRow);
        let l = solver.solve(1024, 1024).l;
        assert!(l > 0, "LP must choose to recompute at paper scale");
        let kvpr = run_layers(Policy::Kvpr, l, 8);
        assert!(flex <= acc, "flexgen {flex} vs accelerate {acc}");
        assert!(kvpr < flex, "kvpr {kvpr} vs flexgen {flex}");
    }

    #[test]
    fn alisa_no_overlap_is_slower_than_kvpr() {
        let cost = crate::scheduler::CostModel::from_hardware(
            &HardwareConfig::a100_x16(),
            &ModelConfig::opt_6_7b(),
            32,
        );
        let solver =
            crate::scheduler::SplitSolver::new(cost, crate::scheduler::SchedulePolicy::RowByRow);
        let l = solver.solve(1024, 1024).l;
        let kvpr = run_layers(Policy::Kvpr, l, 8);
        let alisa = run_layers(Policy::AlisaLike, l, 8);
        assert!(kvpr < alisa, "kvpr {kvpr} vs alisa {alisa}");
    }

    #[test]
    fn quant_reduces_kv_transfer_time() {
        let mut sim = Sim::new();
        let mut c = ctx(&mut sim, 0);
        let t_fp16 = c.kv_xfer_s(1024);
        c.kv_quant = true;
        let t_q = c.kv_xfer_s(1024);
        assert!(t_q < t_fp16 * 0.4, "{t_q} vs {t_fp16}");
    }

    #[test]
    fn fastdecode_moves_no_kv() {
        let mut sim = Sim::new();
        let c = ctx(&mut sim, 0);
        let mut prev = None;
        for _ in 0..4 {
            prev = Some(build_layer(&mut sim, Policy::FastDecode, &c, prev, None));
        }
        assert_eq!(sim.kind_total(TaskKind::KvXfer), 0.0);
        assert!(sim.kind_total(TaskKind::CpuAttn) > 0.0);
    }

    #[test]
    fn deepspeed_slower_than_accelerate_by_latency() {
        let acc = run_layers(Policy::Accelerate, 0, 8);
        let ds = run_layers(Policy::DeepSpeed, 0, 8);
        assert!(ds > acc);
        assert!(ds - acc < 8.0 * 5.0 * HardwareConfig::a100_x16().pcie_latency_s + 1e-9);
    }
}
