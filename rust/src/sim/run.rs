//! Whole-decode simulation driver: steps × layers × batches, with the LP
//! re-solved each step (paper: "determined adaptively"), producing the
//! metrics every bench harness prints.

use super::core::{Sim, TaskKind};
use super::policies::{build_layer, Policy, StepCtx};
use crate::config::{HardwareConfig, ModelConfig, Objective, WorkloadConfig};
use crate::scheduler::{CostModel, SchedulePolicy, SplitSolver};

/// One simulated configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: ModelConfig,
    pub hw: HardwareConfig,
    pub workload: WorkloadConfig,
    pub policy: Policy,
    /// Cap l at the prompt length (paper Eq. 11 constraint).
    pub l_cap_prompt: bool,
}

impl RunConfig {
    pub fn new(model: ModelConfig, hw: HardwareConfig, workload: WorkloadConfig, policy: Policy) -> Self {
        RunConfig { model, hw, workload, policy, l_cap_prompt: true }
    }
}

/// A point of the Fig 8 utilization/memory timeline.
#[derive(Debug, Clone, Copy)]
pub struct UtilSample {
    pub t_s: f64,
    pub gpu_util: f64,
    pub link_util: f64,
}

/// Simulation outputs.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: Policy,
    /// Wall time of the decode stage.
    pub decode_s: f64,
    /// Decode throughput, generated tokens / second.
    pub tok_per_s: f64,
    /// Mean GPU busy fraction during decode (Fig 8).
    pub gpu_util: f64,
    pub link_util: f64,
    /// Seconds per task kind (Fig 10 breakdown).
    pub kind_totals: Vec<(TaskKind, f64)>,
    /// Split point per step (Fig 12).
    pub splits: Vec<usize>,
    /// Estimated peak device memory.
    pub peak_gpu_bytes: u64,
    /// Utilization time series (Fig 8), binned.
    pub util_series: Vec<UtilSample>,
    pub n_tasks: usize,
}

impl RunReport {
    pub fn kind_total(&self, kind: TaskKind) -> f64 {
        self.kind_totals
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, v)| *v)
            .unwrap_or(0.0)
    }

    /// Fig 10-style percentage breakdown over transfer+compute kinds.
    pub fn breakdown_pct(&self) -> Vec<(TaskKind, f64)> {
        let total: f64 = self.kind_totals.iter().map(|(_, v)| v).sum();
        self.kind_totals
            .iter()
            .map(|(k, v)| (*k, 100.0 * v / total.max(1e-12)))
            .collect()
    }
}

/// Simulate the decode stage of `cfg` and report.
pub fn simulate_decode(cfg: &RunConfig) -> RunReport {
    let wl = &cfg.workload;
    let mut sim = Sim::new();
    let gpu = sim.resource("gpu");
    let h2d = sim.resource("h2d");
    let d2h = sim.resource("d2h");
    let cpu = sim.resource("cpu");

    let sched_policy = match wl.objective {
        Objective::Latency => SchedulePolicy::RowByRow,
        Objective::Throughput => SchedulePolicy::ColumnByColumn,
    };
    let cost: CostModel = {
        let c = CostModel::from_hardware(&cfg.hw, &cfg.model, wl.batch);
        if wl.kv_quant_4bit {
            c.with_kv_quant(0.3125)
        } else {
            c
        }
    };
    let solver = SplitSolver::new(cost, sched_policy);

    let mut splits = Vec::with_capacity(wl.gen_len);
    let mut prev_step_end = None;

    for step in 0..wl.gen_len {
        let kv_len = wl.seq_len_at(step);
        let l = if cfg.policy.uses_split() {
            let l_max = if cfg.l_cap_prompt { wl.prompt_len } else { kv_len };
            solver.solve(kv_len, l_max).l
        } else {
            0
        };
        splits.push(l);

        let ctx = StepCtx {
            model: cfg.model.clone(),
            hw: cfg.hw.clone(),
            batch: wl.batch,
            kv_len,
            weights_offloaded: wl.weights_offloaded,
            kv_quant: wl.kv_quant_4bit,
            l,
            gpu,
            h2d,
            d2h,
            cpu,
        };

        let mut batch_ends = Vec::with_capacity(wl.n_batches);
        for layer in 0..cfg.model.n_layers {
            // column schedule: one weight transfer per layer serves the
            // whole batch group (the throughput regime's point)
            let weights_ready = if wl.weights_offloaded && wl.n_batches > 1 {
                Some(sim.task(
                    h2d,
                    TaskKind::WeightXfer,
                    ctx.weight_xfer_s(cfg.model.weight_bytes_per_layer()),
                    &[],
                ))
            } else {
                None
            };
            for b in 0..wl.n_batches {
                let prev = if layer == 0 {
                    prev_step_end
                } else {
                    batch_ends.get(b).copied()
                };
                let out = build_layer(&mut sim, cfg.policy, &ctx, prev, weights_ready);
                if layer == 0 {
                    batch_ends.push(out);
                } else {
                    batch_ends[b] = out;
                }
            }
        }
        // lm_head for the step (per batch group, on the GPU)
        let head_flops =
            2.0 * (wl.batch * cfg.model.hidden * cfg.model.vocab) as f64 * wl.n_batches as f64;
        let head = sim.task(
            gpu,
            TaskKind::Other,
            cfg.hw.gpu_time(head_flops),
            &batch_ends,
        );
        prev_step_end = Some(head);
    }

    let decode_s = sim.makespan();
    let tokens = wl.total_generated_tokens();
    let kinds = [
        TaskKind::WeightXfer,
        TaskKind::KvXfer,
        TaskKind::ActXfer,
        TaskKind::Recompute,
        TaskKind::AttnFfn,
        TaskKind::CpuAttn,
        TaskKind::Store,
        TaskKind::Other,
    ];
    let kind_totals: Vec<(TaskKind, f64)> =
        kinds.iter().map(|&k| (k, sim.kind_total(k))).collect();

    // peak device memory: resident weights (latency regime) or one layer's
    // double-buffered weights (throughput), plus double-buffered staged KV
    // at final length, plus activations
    let final_len = wl.seq_len_at(wl.gen_len);
    let weights_bytes = if wl.weights_offloaded {
        2 * cfg.model.weight_bytes_per_layer()
    } else {
        cfg.model.weight_bytes_per_layer() * cfg.model.n_layers as u64
            + (cfg.model.vocab * cfg.model.hidden * cfg.model.dtype_bytes) as u64
    };
    let staged_kv = 2 * cfg.model.kv_bytes_per_layer(wl.batch, final_len);
    let acts = (wl.batch * cfg.model.hidden * cfg.model.dtype_bytes * 4) as u64;
    let peak_gpu_bytes = weights_bytes + staged_kv + acts;

    let dt = (decode_s / 120.0).max(1e-6);
    let gpu_series = sim.util_series(gpu, dt);
    let link_series = sim.util_series(h2d, dt);
    let util_series = gpu_series
        .iter()
        .zip(&link_series)
        .enumerate()
        .map(|(i, (g, l))| UtilSample { t_s: i as f64 * dt, gpu_util: *g, link_util: *l })
        .collect();

    RunReport {
        policy: cfg.policy,
        decode_s,
        tok_per_s: tokens as f64 / decode_s.max(1e-12),
        gpu_util: sim.busy(gpu) / decode_s.max(1e-12),
        link_util: sim.busy(h2d) / decode_s.max(1e-12),
        kind_totals,
        splits,
        peak_gpu_bytes,
        util_series,
        n_tasks: sim.n_tasks(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lat_cfg(policy: Policy) -> RunConfig {
        RunConfig::new(
            ModelConfig::opt_6_7b(),
            HardwareConfig::a100_x16(),
            WorkloadConfig::latency_oriented(256, 16),
            policy,
        )
    }

    fn thr_cfg(policy: Policy) -> RunConfig {
        let mut wl = WorkloadConfig::throughput_oriented(512, 8);
        wl.n_batches = 4; // keep tests fast
        RunConfig::new(ModelConfig::opt_6_7b(), HardwareConfig::a100_x16(), wl, policy)
    }

    #[test]
    fn kvpr_lowers_latency_vs_accelerate() {
        let base = simulate_decode(&lat_cfg(Policy::Accelerate));
        let kvpr = simulate_decode(&lat_cfg(Policy::Kvpr));
        assert!(
            kvpr.decode_s < base.decode_s,
            "kvpr {} vs accelerate {}",
            kvpr.decode_s,
            base.decode_s
        );
        // paper claims up to ~35%; require a solid double-digit cut here
        let cut = 1.0 - kvpr.decode_s / base.decode_s;
        assert!(cut > 0.10, "latency cut only {:.1}%", cut * 100.0);
    }

    #[test]
    fn kvpr_raises_throughput_vs_flexgen() {
        let flex = simulate_decode(&thr_cfg(Policy::FlexGen));
        let kvpr = simulate_decode(&thr_cfg(Policy::Kvpr));
        assert!(
            kvpr.tok_per_s > flex.tok_per_s,
            "kvpr {} vs flexgen {}",
            kvpr.tok_per_s,
            flex.tok_per_s
        );
    }

    #[test]
    fn kvpr_improves_gpu_utilization() {
        // Fig 8: utilization rises (85% → 99% in the paper)
        let flex = simulate_decode(&thr_cfg(Policy::FlexGen));
        let kvpr = simulate_decode(&thr_cfg(Policy::Kvpr));
        assert!(kvpr.gpu_util > flex.gpu_util, "{} vs {}", kvpr.gpu_util, flex.gpu_util);
    }

    #[test]
    fn quant_raises_throughput_further() {
        // Fig 9
        let plain = simulate_decode(&thr_cfg(Policy::Kvpr));
        let mut cfg = thr_cfg(Policy::Kvpr);
        cfg.workload.kv_quant_4bit = true;
        let quant = simulate_decode(&cfg);
        assert!(quant.tok_per_s > plain.tok_per_s);
    }

    #[test]
    fn splits_grow_with_sequence() {
        // Fig 12 trend
        let kvpr = simulate_decode(&lat_cfg(Policy::Kvpr));
        assert_eq!(kvpr.splits.len(), 16);
        assert!(kvpr.splits.iter().all(|&l| l <= 256), "l capped at prompt");
        assert!(kvpr.splits.windows(2).all(|w| w[1] >= w[0]));
        assert!(*kvpr.splits.last().unwrap() > 0);
    }

    #[test]
    fn breakdown_shifts_from_kv_to_compute() {
        // Fig 10: KVPR cuts KV transfer share, grows GPU compute share
        let flex = simulate_decode(&thr_cfg(Policy::FlexGen));
        let kvpr = simulate_decode(&thr_cfg(Policy::Kvpr));
        let kv_share = |r: &RunReport| {
            r.kind_total(TaskKind::KvXfer)
                / r.kind_totals.iter().map(|(_, v)| v).sum::<f64>()
        };
        assert!(kv_share(&kvpr) < kv_share(&flex));
        assert!(kvpr.kind_total(TaskKind::Recompute) > 0.0);
        assert!(kvpr.kind_total(TaskKind::ActXfer) > 0.0);
    }

    #[test]
    fn report_is_self_consistent() {
        let r = simulate_decode(&lat_cfg(Policy::Kvpr));
        assert!(r.decode_s > 0.0);
        assert!(r.gpu_util > 0.0 && r.gpu_util <= 1.0 + 1e-9);
        assert!(r.link_util > 0.0 && r.link_util <= 1.0 + 1e-9);
        assert!(!r.util_series.is_empty());
        assert!(r.peak_gpu_bytes > 0);
        assert!(r.n_tasks > 0);
    }

    #[test]
    fn fastdecode_single_process_is_viable() {
        // with one process the CPU path works fine (Fig 14's left edge)
        let fd = simulate_decode(&thr_cfg(Policy::FastDecode));
        assert!(fd.tok_per_s > 0.0);
        assert_eq!(fd.kind_total(TaskKind::KvXfer), 0.0);
    }
}
