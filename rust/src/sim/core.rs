//! Timeline simulator: list scheduling over FIFO resources.

/// What a task models — drives the Fig 10 breakdown and Fig 8 timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    WeightXfer,
    KvXfer,
    ActXfer,
    Recompute,
    AttnFfn,
    CpuAttn,
    Store,
    Other,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskId(pub usize);

#[derive(Debug, Clone)]
struct Resource {
    name: String,
    avail: f64,
    busy: f64,
    intervals: Vec<(f64, f64, TaskKind)>,
}

#[derive(Debug, Clone, Copy)]
struct TaskRec {
    finish: f64,
    #[allow(dead_code)]
    resource: ResourceId,
    kind: TaskKind,
    dur: f64,
}

/// The simulator state.  Create resources, then add tasks in dependency
/// order (deps must already exist); `makespan` and the per-kind/per-resource
/// accounting fall out.
#[derive(Debug, Clone, Default)]
pub struct Sim {
    resources: Vec<Resource>,
    tasks: Vec<TaskRec>,
}

impl Sim {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn resource(&mut self, name: &str) -> ResourceId {
        self.resources.push(Resource {
            name: name.to_string(),
            avail: 0.0,
            busy: 0.0,
            intervals: Vec::new(),
        });
        ResourceId(self.resources.len() - 1)
    }

    /// Add a task: starts at max(resource available, deps' finishes).
    pub fn task(&mut self, res: ResourceId, kind: TaskKind, dur: f64, deps: &[TaskId]) -> TaskId {
        assert!(dur >= 0.0 && dur.is_finite(), "bad duration {dur}");
        let dep_ready = deps
            .iter()
            .map(|d| self.tasks[d.0].finish)
            .fold(0.0f64, f64::max);
        let r = &mut self.resources[res.0];
        let start = r.avail.max(dep_ready);
        let finish = start + dur;
        r.avail = finish;
        r.busy += dur;
        if dur > 0.0 {
            r.intervals.push((start, finish, kind));
        }
        self.tasks.push(TaskRec { finish, resource: res, kind, dur });
        TaskId(self.tasks.len() - 1)
    }

    /// Zero-duration join point over dependencies.
    pub fn join(&mut self, res: ResourceId, deps: &[TaskId]) -> TaskId {
        self.task(res, TaskKind::Other, 0.0, deps)
    }

    pub fn finish(&self, t: TaskId) -> f64 {
        self.tasks[t.0].finish
    }

    /// Latest finish time over all tasks.
    pub fn makespan(&self) -> f64 {
        self.tasks.iter().map(|t| t.finish).fold(0.0, f64::max)
    }

    /// Total busy time on a resource.
    pub fn busy(&self, res: ResourceId) -> f64 {
        self.resources[res.0].busy
    }

    pub fn resource_name(&self, res: ResourceId) -> &str {
        &self.resources[res.0].name
    }

    /// Busy fraction of a resource over [t0, t1].
    pub fn utilization(&self, res: ResourceId, t0: f64, t1: f64) -> f64 {
        assert!(t1 > t0);
        let mut busy = 0.0;
        for &(s, f, _) in &self.resources[res.0].intervals {
            let lo = s.max(t0);
            let hi = f.min(t1);
            if hi > lo {
                busy += hi - lo;
            }
        }
        busy / (t1 - t0)
    }

    /// Total time spent in tasks of `kind` (across resources).
    pub fn kind_total(&self, kind: TaskKind) -> f64 {
        self.tasks
            .iter()
            .filter(|t| t.kind == kind)
            .map(|t| t.dur)
            .sum()
    }

    /// Utilization time series for a resource, binned at `dt`.
    pub fn util_series(&self, res: ResourceId, dt: f64) -> Vec<f64> {
        let end = self.makespan();
        if end <= 0.0 {
            return Vec::new();
        }
        let n = (end / dt).ceil() as usize;
        let mut out = Vec::with_capacity(n);
        for i in 0..n {
            let t0 = i as f64 * dt;
            out.push(self.utilization(res, t0, (t0 + dt).min(end).max(t0 + 1e-12)));
        }
        out
    }

    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_on_one_resource() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        let a = sim.task(gpu, TaskKind::AttnFfn, 1.0, &[]);
        let b = sim.task(gpu, TaskKind::AttnFfn, 2.0, &[]);
        assert_eq!(sim.finish(a), 1.0);
        assert_eq!(sim.finish(b), 3.0); // FIFO on the resource
        assert_eq!(sim.makespan(), 3.0);
    }

    #[test]
    fn parallel_resources_overlap() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        let link = sim.resource("link");
        let x = sim.task(link, TaskKind::KvXfer, 5.0, &[]);
        let c = sim.task(gpu, TaskKind::AttnFfn, 4.0, &[]);
        assert_eq!(sim.makespan(), 5.0); // overlapped, not 9
        let j = sim.join(gpu, &[x, c]);
        assert_eq!(sim.finish(j), 5.0);
    }

    #[test]
    fn dependencies_serialize_across_resources() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        let link = sim.resource("link");
        let x = sim.task(link, TaskKind::ActXfer, 2.0, &[]);
        let r = sim.task(gpu, TaskKind::Recompute, 3.0, &[x]);
        assert_eq!(sim.finish(r), 5.0);
    }

    #[test]
    fn kvpr_shape_in_miniature() {
        // act(1) → recompute(3) ∥ rest-kv(4, after act on the same link)
        // → merge(1): makespan = 1 + max(3, 4) + 1 = 6
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        let link = sim.resource("link");
        let act = sim.task(link, TaskKind::ActXfer, 1.0, &[]);
        let rest = sim.task(link, TaskKind::KvXfer, 4.0, &[]); // queued after act
        let rec = sim.task(gpu, TaskKind::Recompute, 3.0, &[act]);
        let merge = sim.task(gpu, TaskKind::AttnFfn, 1.0, &[rec, rest]);
        assert_eq!(sim.finish(merge), 6.0);
        // vs full transfer: 2·(1+4)... the win is the overlap
    }

    #[test]
    fn utilization_and_busy() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        sim.task(gpu, TaskKind::AttnFfn, 1.0, &[]);
        let link = sim.resource("link");
        sim.task(link, TaskKind::KvXfer, 4.0, &[]);
        assert_eq!(sim.busy(gpu), 1.0);
        assert!((sim.utilization(gpu, 0.0, 4.0) - 0.25).abs() < 1e-12);
        assert!((sim.utilization(link, 0.0, 4.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn kind_accounting() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        sim.task(gpu, TaskKind::Recompute, 1.5, &[]);
        sim.task(gpu, TaskKind::Recompute, 0.5, &[]);
        sim.task(gpu, TaskKind::AttnFfn, 1.0, &[]);
        assert_eq!(sim.kind_total(TaskKind::Recompute), 2.0);
        assert_eq!(sim.kind_total(TaskKind::AttnFfn), 1.0);
    }

    #[test]
    fn util_series_bins() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        sim.task(gpu, TaskKind::AttnFfn, 1.0, &[]);
        let link = sim.resource("link");
        sim.task(link, TaskKind::KvXfer, 2.0, &[]);
        let series = sim.util_series(gpu, 0.5);
        assert_eq!(series.len(), 4);
        assert!((series[0] - 1.0).abs() < 1e-9);
        assert!((series[3] - 0.0).abs() < 1e-9);
    }

    #[test]
    fn join_is_free() {
        let mut sim = Sim::new();
        let gpu = sim.resource("gpu");
        let a = sim.task(gpu, TaskKind::AttnFfn, 1.0, &[]);
        let j = sim.join(gpu, &[a]);
        assert_eq!(sim.finish(j), 1.0);
        assert_eq!(sim.busy(gpu), 1.0);
    }
}
