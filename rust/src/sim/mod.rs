//! Discrete-event simulator of the paper's testbeds.
//!
//! DESIGN.md §2: our container has no A100/PCIe, so every paper table and
//! figure is regenerated on a timeline simulator parameterised by the
//! paper's hardware (`HardwareConfig::a100_x16` / `rtx5000_x8`) and model
//! geometries.  The simulator is a *list scheduler over FIFO resources*
//! (GPU, H2D link, D2H link, CPU): each task occupies one resource for an
//! analytic duration and starts when both its dependencies and its resource
//! are free — exactly the semantics of CUDA streams + PCIe DMA queues that
//! the real systems (and our engine's `transfer::Link`) exhibit.
//!
//! Policies implemented (paper §4 + §5 baselines):
//! `Accelerate` (sync KV offloading), `DeepSpeed`, `FlexGen` (overlapped
//! full transfer), `Kvpr` (+`fine_grained` hiding flag), `KvprNoHide`,
//! `AlisaLike` (recompute then transfer), `FastDecode` (CPU attention).

mod core;
mod policies;
mod run;

pub use self::core::{ResourceId, Sim, TaskId, TaskKind};
pub use policies::{Policy, StepCtx};
pub use run::{simulate_decode, RunConfig, RunReport, UtilSample};

/// Public re-export of the per-layer pipeline builder (used by custom
/// topologies like `paper::fig14_multigpu`'s shared-CPU setup).
pub use policies::build_layer as build_layer_pub;
