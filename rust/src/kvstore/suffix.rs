//! The device-resident-suffix invariant, in one place.
//!
//! Residency in the tiered store is a *suffix* property: the gpu tier holds
//! a contiguous run of blocks ending at a sequence's newest valid token.
//! Every placement decision — counting resident tokens, mirroring the
//! engine's device window, extending the run with promotions (including
//! the disk→dram hop that starts a two-hop promotion), picking the
//! eviction victim that keeps the run contiguous — walks the same top-down
//! block order with the same valid-block arithmetic, differing only in
//! where it stops.  PR 2 re-implemented that walk four times with subtly
//! different break conditions; [`SuffixRuns`] owns it once:
//!
//! * which blocks are *valid* (cover at least one of the sequence's
//!   `tokens` cached tokens),
//! * how many tokens each valid block covers (the top block may be short),
//! * the top-down iteration order that makes "resident run" well-defined,
//! * the [`BlockClass`] taxonomy the walkers branch on.
//!
//! The walkers themselves live in [`store`](super::store) as thin loops
//! over this iterator; the property test at the bottom of this file pins
//! the iterator against standalone re-implementations of all four legacy
//! walks across randomized four-tier layouts.

use crate::memory::PoolGuard;

use super::block::Tier;
use super::migrate::MigrationId;

/// A reference to an in-flight migration of one block: the store-side
/// marker whose lifecycle (queued → staged → in-flight → landed) is owned
/// by the [`MigrationEngine`](super::MigrationEngine).
#[derive(Debug, Clone, Copy)]
pub struct PendingRef {
    pub id: MigrationId,
    /// Destination tier.  Together with the block's settled tier this
    /// decides the in-flight [`BlockClass`]: [`Tier::GpuHbm`] marks a
    /// promotion, an upward move short of the gpu marks a disk→dram hop,
    /// and a downward move marks a demotion (out of gpu) or spill (out of
    /// dram).
    pub to: Tier,
}

/// One block's placement state (store-internal).
pub struct BlockState {
    /// Tier the block is *settled* in.  While a migration is in flight the
    /// field still names the source tier (promotion/hop) or the tier being
    /// left (demotion/spill); [`BlockState::class`] is the authoritative
    /// view.
    pub tier: Tier,
    /// The tier reservation.  `None` while a demotion or spill is in
    /// flight: the source bytes are released the moment the move is issued
    /// (the host cache holds the canonical rows; the link traffic models
    /// writeback), which is what lets a full tier never stall the step
    /// loop.
    pub guard: Option<PoolGuard>,
    /// KV bytes dropped (X kept): the block costs ⅓ and must be covered by
    /// the recompute path when its tokens are needed.
    pub kv_dropped: bool,
    /// In-flight migration, if any.
    pub pending: Option<PendingRef>,
    /// Serving step at which this block was last demoted out of the gpu
    /// tier or spilled out of dram — the anti-thrash cool-down input: a
    /// freshly demoted/spilled block is not re-promoted for
    /// `promote_cooldown` *steps* (the step counter ticks once per
    /// `pump_migrations` call, not per touch, so the hysteresis does not
    /// shrink as concurrency grows).
    pub demoted_at: Option<u64>,
    /// Serving step at which this block last moved *up* a rung (its
    /// disk→dram hop landed) — the spill-side cool-down input, mirroring
    /// `demoted_at`: a just-promoted block is not re-spillable for
    /// `spill_cooldown` steps, so promotion/spill ping-pong under
    /// adversarial alternating reuse is bounded the same way
    /// promotion/demotion ping-pong already is.
    pub promoted_at: Option<u64>,
    /// Chain hash of the [`PrefixRegistry`](super::PrefixRegistry) entry
    /// this block adopts, when the block is a shared-prefix marker: the
    /// registry owns the real tier reservation (this block's `guard` is
    /// `None`) and the ref count.  Shared markers never migrate, are never
    /// eviction victims, and cost the planner zero transfer — divergence
    /// goes through the copy-on-write path, which privatizes the marker
    /// (clears this field) and decrements the registry.
    pub shared: Option<u64>,
}

/// What a suffix walker sees when it looks at one block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockClass {
    /// Settled in the gpu tier, KV intact: part of a resident run.
    Resident,
    /// A promotion is in flight: will extend the run when it lands.
    PromotionInFlight,
    /// A demotion is in flight: **already non-resident** — its gpu bytes
    /// were released at issuance, so residency accounting (and the
    /// planner's transfer term) must treat it as a hole immediately.
    DemotionInFlight,
    /// The first hop of a two-hop promotion (disk→dram) is in flight: the
    /// block is on its way up but cannot extend the run until it settles
    /// in dram and a later step issues the dram→gpu leg.
    HopInFlight,
    /// A dram→disk spill writeback is in flight: the dram bytes were
    /// released at issuance, so the block is disk-side for planning —
    /// but never a residency hole the engine must shed (it was not on
    /// device to begin with).
    SpillInFlight,
    /// Settled in a host tier (pinned/dram), KV intact: a one-hop
    /// promotion candidate.
    Host,
    /// Settled on the disk tier, KV intact: promoting it is a two-hop
    /// (disk→dram→gpu) migration staged across steps.
    Disk,
    /// KV dropped (X kept): only the recompute path can cover it.
    Dropped,
    /// A shared-prefix marker adopting a
    /// [`PrefixRegistry`](super::PrefixRegistry) entry: the
    /// registry holds the bytes (host-tier side), other sequences may
    /// depend on the same entry, and the planner prices the span at zero
    /// transfer.  Never migrated, never an eviction victim.
    Shared,
}

impl BlockState {
    pub fn class(&self) -> BlockClass {
        if self.shared.is_some() {
            BlockClass::Shared
        } else if let Some(p) = &self.pending {
            if p.to == Tier::GpuHbm {
                BlockClass::PromotionInFlight
            } else if p.to < self.tier {
                BlockClass::HopInFlight
            } else if self.tier == Tier::GpuHbm {
                BlockClass::DemotionInFlight
            } else {
                BlockClass::SpillInFlight
            }
        } else if self.kv_dropped {
            BlockClass::Dropped
        } else if self.tier == Tier::GpuHbm {
            BlockClass::Resident
        } else if self.tier == Tier::DiskNvme {
            BlockClass::Disk
        } else {
            BlockClass::Host
        }
    }
}

/// One step of a [`SuffixRuns`] walk.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunBlock {
    /// Block index within the sequence.
    pub idx: usize,
    /// Valid tokens this block covers (the top block may be short).
    pub tokens: usize,
    pub class: BlockClass,
}

/// Top-down iterator over the *valid* blocks of one sequence: from the
/// block holding the newest cached token down to block 0.  Each item
/// reports the block's index, how many of the sequence's `tokens` it
/// covers, and its [`BlockClass`].  Walkers express their break condition
/// over the class stream instead of re-deriving the arithmetic.
pub struct SuffixRuns<'a> {
    blocks: &'a [BlockState],
    tokens: usize,
    bt: usize,
    /// Number of not-yet-yielded valid blocks (yield order `idx-1 .. 0`).
    idx: usize,
}

impl<'a> SuffixRuns<'a> {
    pub fn new(blocks: &'a [BlockState], tokens: usize, block_tokens: usize) -> Self {
        let idx = Self::valid_blocks(tokens, block_tokens, blocks.len());
        SuffixRuns { blocks, tokens, bt: block_tokens, idx }
    }

    /// Blocks covering at least one of `tokens` cached tokens.
    pub fn valid_blocks(tokens: usize, block_tokens: usize, n_blocks: usize) -> usize {
        tokens.div_ceil(block_tokens).min(n_blocks)
    }

    /// Valid tokens block `idx` covers (0 past the valid range).
    pub fn tokens_at(tokens: usize, block_tokens: usize, idx: usize) -> usize {
        tokens.saturating_sub(idx * block_tokens).min(block_tokens)
    }

    /// Tokens of the resident suffix: the run of settled gpu blocks ending
    /// at the newest valid token.  In-flight demotions released their gpu
    /// bytes at issuance, so they terminate the run like any other hole.
    pub fn resident_tokens(self) -> usize {
        self.take_while(|rb| rb.class == BlockClass::Resident)
            .map(|rb| rb.tokens)
            .sum()
    }
}

impl Iterator for SuffixRuns<'_> {
    type Item = RunBlock;

    fn next(&mut self) -> Option<RunBlock> {
        if self.idx == 0 {
            return None;
        }
        self.idx -= 1;
        let idx = self.idx;
        Some(RunBlock {
            idx,
            tokens: Self::tokens_at(self.tokens, self.bt, idx),
            class: self.blocks[idx].class(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{check_property, Prng};

    const BT: usize = 16;

    fn block(class: BlockClass) -> BlockState {
        let (tier, kv_dropped, pending) = match class {
            BlockClass::Resident => (Tier::GpuHbm, false, None),
            BlockClass::PromotionInFlight => (
                Tier::CpuDram,
                false,
                Some(PendingRef { id: MigrationId::test_id(1), to: Tier::GpuHbm }),
            ),
            BlockClass::DemotionInFlight => (
                Tier::GpuHbm,
                false,
                Some(PendingRef { id: MigrationId::test_id(2), to: Tier::Pinned }),
            ),
            BlockClass::HopInFlight => (
                Tier::DiskNvme,
                false,
                Some(PendingRef { id: MigrationId::test_id(3), to: Tier::CpuDram }),
            ),
            BlockClass::SpillInFlight => (
                Tier::CpuDram,
                false,
                Some(PendingRef { id: MigrationId::test_id(4), to: Tier::DiskNvme }),
            ),
            BlockClass::Host => (Tier::CpuDram, false, None),
            BlockClass::Disk => (Tier::DiskNvme, false, None),
            BlockClass::Dropped => (Tier::Pinned, true, None),
            // shared markers are built explicitly (shared field) in the
            // tests that need them; the class-driven helper never does
            BlockClass::Shared => unreachable!("build shared markers explicitly"),
        };
        BlockState {
            tier,
            guard: None,
            kv_dropped,
            pending,
            demoted_at: None,
            promoted_at: None,
            shared: None,
        }
    }

    fn random_layout(rng: &mut Prng) -> (Vec<BlockState>, usize) {
        let n = 1 + rng.index(8);
        let mut blocks: Vec<BlockState> = Vec::with_capacity(n);
        // a realistic layout: optional dropped prefix, then a random mix
        let dropped_prefix = rng.index(n + 1) / 2;
        for i in 0..n {
            let class = if i < dropped_prefix {
                BlockClass::Dropped
            } else {
                match rng.index(8) {
                    0 => BlockClass::Resident,
                    1 => BlockClass::PromotionInFlight,
                    2 => BlockClass::DemotionInFlight,
                    3 => BlockClass::Dropped,
                    4 => BlockClass::Disk,
                    5 => BlockClass::HopInFlight,
                    6 => BlockClass::SpillInFlight,
                    _ => BlockClass::Host,
                }
            };
            blocks.push(block(class));
        }
        // tokens in [0, n*BT], sometimes leaving trailing invalid blocks
        // and sometimes a short top block
        let tokens = rng.index(n * BT + 1);
        (blocks, tokens)
    }

    // -- standalone re-implementations of the four store walkers -----------
    // (the literal loops store.rs used to carry, extended to the disk tier,
    // kept here as the oracle)

    fn legacy_valid(blocks: &[BlockState], tokens: usize) -> usize {
        tokens.div_ceil(BT).min(blocks.len())
    }

    fn legacy_tokens_at(tokens: usize, idx: usize) -> usize {
        tokens.saturating_sub(idx * BT).min(BT)
    }

    /// `gpu_resident_tokens`: settled-gpu run from the top.
    fn legacy_resident(blocks: &[BlockState], tokens: usize) -> usize {
        let mut covered = 0;
        let mut idx = legacy_valid(blocks, tokens);
        while idx > 0 {
            idx -= 1;
            let b = &blocks[idx];
            if b.tier == Tier::GpuHbm && b.pending.is_none() && !b.kv_dropped {
                covered += legacy_tokens_at(tokens, idx);
            } else {
                break;
            }
        }
        covered
    }

    /// `sync_device_suffix`: host/disk blocks to flip while covering the
    /// engine's window; breaks on any in-flight migration.
    fn legacy_sync_todo(blocks: &[BlockState], tokens: usize, engine_resident: usize) -> Vec<usize> {
        let mut todo = Vec::new();
        let mut covered = 0usize;
        let mut idx = legacy_valid(blocks, tokens);
        while idx > 0 && covered < engine_resident {
            idx -= 1;
            let b = &blocks[idx];
            covered += legacy_tokens_at(tokens, idx);
            if b.pending.is_some() {
                break;
            }
            if b.tier != Tier::GpuHbm && !b.kv_dropped {
                todo.push(idx);
            }
        }
        todo
    }

    /// `begin_promotions`: promotion targets extending the run downward;
    /// the bool marks a disk block needing the disk→dram hop first.  A
    /// disk block above (settled or mid-hop) caps deeper blocks at the
    /// dram rung — a gpu promotion under it could only land suffix-broken.
    fn legacy_promo_targets(
        blocks: &[BlockState],
        tokens: usize,
        max: usize,
    ) -> Vec<(usize, bool)> {
        let mut targets = Vec::new();
        let mut hop_above = false;
        let mut idx = legacy_valid(blocks, tokens);
        while idx > 0 && targets.len() < max {
            idx -= 1;
            let b = &blocks[idx];
            if let Some(pm) = &b.pending {
                // upward moves (to gpu, or the disk→dram hop) are on their
                // way; downward moves are holes the walk stops at
                if pm.to == Tier::GpuHbm {
                    continue;
                }
                if pm.to < b.tier {
                    hop_above = true;
                    continue;
                }
                break;
            }
            if b.tier == Tier::GpuHbm {
                continue;
            }
            if b.kv_dropped {
                break;
            }
            if b.tier == Tier::DiskNvme {
                targets.push((idx, true));
                hop_above = true;
            } else if !hop_above {
                targets.push((idx, false));
            }
        }
        targets
    }

    /// `evict_gpu_victim`: the lowest block of the top resident run.
    fn legacy_run_start(blocks: &[BlockState], tokens: usize) -> Option<usize> {
        let mut run_start: Option<usize> = None;
        let mut idx = legacy_valid(blocks, tokens);
        while idx > 0 {
            idx -= 1;
            let b = &blocks[idx];
            if b.tier == Tier::GpuHbm && b.pending.is_none() && !b.kv_dropped {
                run_start = Some(idx);
            } else {
                break;
            }
        }
        run_start
    }

    // -- the same four walks expressed over SuffixRuns ---------------------

    fn runs_sync_todo(blocks: &[BlockState], tokens: usize, engine_resident: usize) -> Vec<usize> {
        let mut todo = Vec::new();
        let mut covered = 0usize;
        for rb in SuffixRuns::new(blocks, tokens, BT) {
            if covered >= engine_resident {
                break;
            }
            covered += rb.tokens;
            match rb.class {
                BlockClass::PromotionInFlight
                | BlockClass::DemotionInFlight
                | BlockClass::HopInFlight
                | BlockClass::SpillInFlight => break,
                BlockClass::Host | BlockClass::Disk => todo.push(rb.idx),
                BlockClass::Resident | BlockClass::Dropped | BlockClass::Shared => {}
            }
        }
        todo
    }

    fn runs_promo_targets(blocks: &[BlockState], tokens: usize, max: usize) -> Vec<(usize, bool)> {
        let mut targets = Vec::new();
        let mut hop_above = false;
        for rb in SuffixRuns::new(blocks, tokens, BT) {
            if targets.len() >= max {
                break;
            }
            match rb.class {
                BlockClass::Resident | BlockClass::PromotionInFlight => continue,
                BlockClass::HopInFlight => hop_above = true,
                BlockClass::DemotionInFlight
                | BlockClass::SpillInFlight
                | BlockClass::Dropped
                | BlockClass::Shared => break,
                BlockClass::Host => {
                    if !hop_above {
                        targets.push((rb.idx, false));
                    }
                }
                BlockClass::Disk => {
                    targets.push((rb.idx, true));
                    hop_above = true;
                }
            }
        }
        targets
    }

    fn runs_run_start(blocks: &[BlockState], tokens: usize) -> Option<usize> {
        SuffixRuns::new(blocks, tokens, BT)
            .take_while(|rb| rb.class == BlockClass::Resident)
            .map(|rb| rb.idx)
            .last()
    }

    #[test]
    fn suffix_runs_reproduces_all_four_legacy_walkers() {
        check_property("suffix-runs == legacy walkers", 500, |rng| {
            let (blocks, tokens) = random_layout(rng);
            let resident = SuffixRuns::new(&blocks, tokens, BT).resident_tokens();
            if resident != legacy_resident(&blocks, tokens) {
                return Err(format!(
                    "resident {} != legacy {} (tokens {tokens})",
                    resident,
                    legacy_resident(&blocks, tokens)
                ));
            }
            let window = rng.index(tokens + BT + 1);
            if runs_sync_todo(&blocks, tokens, window) != legacy_sync_todo(&blocks, tokens, window)
            {
                return Err(format!("sync todo diverged (tokens {tokens}, window {window})"));
            }
            let max = rng.index(blocks.len() + 2);
            if runs_promo_targets(&blocks, tokens, max)
                != legacy_promo_targets(&blocks, tokens, max)
            {
                return Err(format!("promo targets diverged (tokens {tokens}, max {max})"));
            }
            if runs_run_start(&blocks, tokens) != legacy_run_start(&blocks, tokens) {
                return Err(format!("eviction run start diverged (tokens {tokens})"));
            }
            Ok(())
        });
    }

    #[test]
    fn short_top_block_and_invalid_tail() {
        // 3 blocks, 20 tokens: block 1 holds 4 valid tokens, block 2 none
        let blocks = vec![
            block(BlockClass::Resident),
            block(BlockClass::Resident),
            block(BlockClass::Host),
        ];
        let items: Vec<RunBlock> = SuffixRuns::new(&blocks, 20, BT).collect();
        assert_eq!(items.len(), 2, "invalid tail block must not be yielded");
        assert_eq!(items[0], RunBlock { idx: 1, tokens: 4, class: BlockClass::Resident });
        assert_eq!(items[1], RunBlock { idx: 0, tokens: 16, class: BlockClass::Resident });
        assert_eq!(SuffixRuns::new(&blocks, 20, BT).resident_tokens(), 20);
    }

    #[test]
    fn demotion_in_flight_is_a_hole() {
        // top block settled-gpu, next demoting: the run stops at the hole
        let blocks = vec![block(BlockClass::DemotionInFlight), block(BlockClass::Resident)];
        assert_eq!(SuffixRuns::new(&blocks, 32, BT).resident_tokens(), 16);
        // a pending promotion is not resident either (bytes still moving)
        let blocks = vec![block(BlockClass::PromotionInFlight), block(BlockClass::Resident)];
        assert_eq!(SuffixRuns::new(&blocks, 32, BT).resident_tokens(), 16);
    }

    #[test]
    fn disk_side_classes_classify_by_direction() {
        // settled on disk
        assert_eq!(block(BlockClass::Disk).class(), BlockClass::Disk);
        // disk→dram (upward, short of gpu) is a hop
        assert_eq!(block(BlockClass::HopInFlight).class(), BlockClass::HopInFlight);
        // dram→disk (downward, not out of gpu) is a spill
        assert_eq!(block(BlockClass::SpillInFlight).class(), BlockClass::SpillInFlight);
        // gpu→disk (downward, out of gpu) stays a demotion
        let b = BlockState {
            tier: Tier::GpuHbm,
            guard: None,
            kv_dropped: false,
            pending: Some(PendingRef { id: MigrationId::test_id(9), to: Tier::DiskNvme }),
            demoted_at: None,
            promoted_at: None,
            shared: None,
        };
        assert_eq!(b.class(), BlockClass::DemotionInFlight);
        // neither disk-side class is ever resident
        let blocks = vec![block(BlockClass::Disk), block(BlockClass::Resident)];
        assert_eq!(SuffixRuns::new(&blocks, 32, BT).resident_tokens(), 16);
    }

    #[test]
    fn shared_marker_class_wins_over_everything() {
        // a shared-prefix marker is Shared no matter what else the state
        // says: the registry owns the bytes, so tier/pending/kv_dropped
        // are irrelevant until CoW privatizes it
        let b = BlockState {
            tier: Tier::CpuDram,
            guard: None,
            kv_dropped: true,
            pending: Some(PendingRef { id: MigrationId::test_id(7), to: Tier::GpuHbm }),
            demoted_at: None,
            promoted_at: None,
            shared: Some(0xfeed),
        };
        assert_eq!(b.class(), BlockClass::Shared);
        // a shared block below a resident run terminates the run (it is
        // host-side data; the planner prices it separately at zero cost)
        let mut shared = block(BlockClass::Host);
        shared.shared = Some(1);
        let blocks = vec![shared, block(BlockClass::Resident)];
        assert_eq!(SuffixRuns::new(&blocks, 32, BT).resident_tokens(), 16);
    }

    #[test]
    fn zero_tokens_is_empty() {
        let blocks = vec![block(BlockClass::Resident)];
        assert_eq!(SuffixRuns::new(&blocks, 0, BT).count(), 0);
        assert_eq!(SuffixRuns::new(&[], 64, BT).count(), 0);
    }
}
