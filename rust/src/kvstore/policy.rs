//! Pluggable eviction policies for the tiered KV store.
//!
//! The store hands a policy a slate of candidate [`BlockView`]s and asks
//! which one to give up.  [`Lru`] is the classical recency baseline; the
//! [`RecomputeAware`] policy is the KVPR-specific one: it scores each block
//! by the time it would take to *bring the block's contribution back* and
//! evicts the cheapest.  A block whose tokens fall inside the planner's
//! split region `[0, l*)` is rebuilt from its retained X activations at the
//! recompute rate A (Eq. 8/9) — dropping its KV and keeping X — while a
//! block beyond `l*` would have to be re-transferred at the link rate C
//! (Eq. 6).  This generalises the Eq. (11) split from "how to fetch the
//! cache this step" into "what to keep resident at all".

use super::block::BlockId;
use crate::scheduler::CostModel;

/// What the store knows about a candidate block when choosing a victim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockView {
    pub id: BlockId,
    /// Tokens this block covers (may be short for the last block).
    pub tokens: usize,
    /// First token position the block covers within its sequence.
    pub start_token: usize,
    /// The owning sequence's current cached length s'.
    pub seq_len: usize,
    /// Store clock at which the owning sequence last decoded.
    pub last_use: u64,
    /// The split point l* the planner currently chooses for the owning
    /// sequence: tokens below it are recomputed from X anyway.
    pub split_l: usize,
}

/// An eviction policy: pick the index of the block to give up.
pub trait EvictPolicy: Send {
    fn name(&self) -> &'static str;

    /// `candidates` is non-empty; return the index of the victim.
    fn victim(&self, candidates: &[BlockView]) -> usize;
}

/// Least-recently-used: evict the block of the sequence that decoded
/// longest ago (ties broken by id for determinism).
#[derive(Debug, Clone, Copy, Default)]
pub struct Lru;

impl EvictPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn victim(&self, candidates: &[BlockView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| (b.last_use, b.id))
            .map(|(i, _)| i)
            .expect("victim() over empty candidate slate")
    }
}

/// Recompute-aware eviction driven by the profiler's [`CostModel`].
#[derive(Debug, Clone)]
pub struct RecomputeAware {
    pub cost: CostModel,
}

impl RecomputeAware {
    pub fn new(cost: CostModel) -> Self {
        RecomputeAware { cost }
    }

    /// Seconds to re-materialise this block's contribution if evicted:
    /// tokens inside `[0, split_l)` cost the recompute path (ship X, run
    /// the KV projections), tokens beyond it cost a KV re-transfer.
    pub fn refill_cost(&self, b: &BlockView) -> f64 {
        let rec = b.split_l.saturating_sub(b.start_token).min(b.tokens);
        let xfer = b.tokens - rec;
        rec as f64 * (self.cost.recompute_per_token_s + self.cost.transfer_act_per_token_s)
            + xfer as f64 * self.cost.transfer_kv_per_token_s
    }
}

impl EvictPolicy for RecomputeAware {
    fn name(&self) -> &'static str {
        "recompute-aware"
    }

    fn victim(&self, candidates: &[BlockView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by(|(_, x), (_, y)| {
                self.refill_cost(x)
                    .partial_cmp(&self.refill_cost(y))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(x.last_use.cmp(&y.last_use))
                    .then(x.id.cmp(&y.id))
            })
            .map(|(i, _)| i)
            .expect("victim() over empty candidate slate")
    }
}

/// Config-level policy selector: the coordinator carries this in its
/// config and builds the boxed policy once the engine's *measured*
/// [`CostModel`] is available at startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictKind {
    Lru,
    RecomputeAware,
}

impl EvictKind {
    pub fn build(&self, cost: CostModel) -> Box<dyn EvictPolicy> {
        self.build_wire(cost, false)
    }

    /// Build the policy with the migration wire width taken into account:
    /// when `kv_quant_wire` is set, evicted-KV refills re-transfer at the
    /// int4 wire width (0.625 B/elem instead of 4), so the scoring model's
    /// transfer term shrinks by the same ratio the
    /// [`MigrationEngine`](super::MigrationEngine) charges on the link —
    /// the refill-cost comparison stays honest under quantization.
    pub fn build_wire(&self, cost: CostModel, kv_quant_wire: bool) -> Box<dyn EvictPolicy> {
        let cost = if kv_quant_wire {
            let ratio = crate::kvcache::ELEM_BYTES_INT4_G64 / crate::kvcache::ELEM_BYTES_F32;
            cost.with_kv_quant(ratio)
        } else {
            cost
        };
        match self {
            EvictKind::Lru => Box::new(Lru),
            EvictKind::RecomputeAware => Box::new(RecomputeAware::new(cost)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(seq: u64, idx: usize, start: usize, last_use: u64, split_l: usize) -> BlockView {
        BlockView {
            id: BlockId { seq, idx },
            tokens: 32,
            start_token: start,
            seq_len: 128,
            last_use,
            split_l,
        }
    }

    fn cheap_recompute() -> CostModel {
        CostModel {
            recompute_per_token_s: 1e-7, // A ≪ C: recompute nearly free
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        }
    }

    #[test]
    fn lru_picks_stalest() {
        let cands = [view(1, 0, 0, 30, 0), view(2, 0, 0, 10, 0), view(3, 0, 0, 20, 0)];
        assert_eq!(Lru.victim(&cands), 1);
    }

    #[test]
    fn lru_ties_break_by_id() {
        let cands = [view(2, 1, 0, 5, 0), view(1, 0, 0, 5, 0)];
        assert_eq!(Lru.victim(&cands), 1);
    }

    #[test]
    fn recompute_aware_prefers_split_region_blocks() {
        let p = RecomputeAware::new(cheap_recompute());
        // block A sits fully inside the split region [0, 64): cheap rebuild;
        // block B sits beyond it: a full KV re-transfer
        let a = view(1, 0, 0, 50, 64);
        let b = view(2, 2, 64, 1, 64); // even *older*, but expensive to refill
        assert_eq!(p.victim(&[b, a]), 1, "must pick the recomputable block");
        assert!(p.refill_cost(&a) < p.refill_cost(&b));
    }

    #[test]
    fn recompute_aware_partial_overlap_scores_between() {
        let p = RecomputeAware::new(cheap_recompute());
        let inside = view(1, 0, 0, 0, 64);
        let straddle = view(1, 1, 48, 0, 64); // 16 tokens in, 16 out
        let outside = view(1, 2, 96, 0, 64);
        let ci = p.refill_cost(&inside);
        let cs = p.refill_cost(&straddle);
        let co = p.refill_cost(&outside);
        assert!(ci < cs && cs < co, "{ci} {cs} {co}");
    }

    #[test]
    fn wire_quant_shrinks_the_transfer_refill_side() {
        // balanced costs: recomputing a block ≈ re-transferring it, so the
        // full-width policy is near-indifferent...
        let cost = CostModel {
            recompute_per_token_s: 4e-7,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let plain = RecomputeAware::new(cost.clone());
        let quant = EvictKind::RecomputeAware.build_wire(cost, true);
        // a block beyond the split (pure re-transfer refill): int4 wire
        // makes its refill 0.15625× the full-width score
        let beyond = view(1, 2, 64, 0, 0);
        let full = plain.refill_cost(&beyond);
        // recompute the quantized score through the public surface: the
        // boxed policy must now *prefer evicting* the transfer-refillable
        // block over a recompute-refillable one of equal recency
        let inside = view(2, 0, 0, 0, 64);
        assert_eq!(
            plain.victim(&[beyond, inside]),
            1,
            "full width: recompute side is cheaper to refill"
        );
        assert_eq!(
            quant.victim(&[beyond, inside]),
            0,
            "int4 wire: the transfer side becomes the cheap refill"
        );
        let q = RecomputeAware::new(
            CostModel {
                recompute_per_token_s: 4e-7,
                transfer_kv_per_token_s: 1e-6,
                transfer_act_per_token_s: 5e-7,
                gpu_overhead_s: 0.0,
                link_latency_s: 0.0,
            }
            .with_kv_quant(0.15625),
        );
        assert!((q.refill_cost(&beyond) - full * 0.15625).abs() < 1e-12);
    }

    #[test]
    fn recompute_aware_ties_fall_back_to_recency() {
        let p = RecomputeAware::new(cheap_recompute());
        // identical positions → identical cost → stalest wins
        let a = view(1, 0, 0, 9, 0);
        let b = view(2, 0, 0, 3, 0);
        assert_eq!(p.victim(&[a, b]), 1);
    }
}
