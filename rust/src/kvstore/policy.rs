//! Pluggable eviction, demotion and spill policies for the tiered KV store.
//!
//! The store hands a policy a slate of candidate [`BlockView`]s and asks
//! which one to give up.  [`Lru`] is the classical recency baseline; the
//! [`RecomputeAware`] policy is the KVPR-specific one: it scores each block
//! by the time it would take to *bring the block's contribution back* and
//! evicts the cheapest.  A block whose tokens fall inside the planner's
//! split region `[0, l*)` is rebuilt from its retained X activations at the
//! recompute rate A (Eq. 8/9) — dropping its KV and keeping X — while a
//! block beyond `l*` would have to be re-transferred at the link rate C
//! (Eq. 6).  This generalises the Eq. (11) split from "how to fetch the
//! cache this step" into "what to keep resident at all".
//!
//! Three victim questions, three lenses over the same cost model:
//!
//! * [`EvictPolicy::victim`] — reclamation in place (drop KV, keep X):
//!   pure refill cost, no writeback crosses a wire.
//! * [`EvictPolicy::demote_victim`] — gpu eviction: the refill cost *plus*
//!   the demotion writeback, scored at the migration wire width
//!   (`wire_elem_bytes`) — under int4 wire quantization the writeback is
//!   ~6.4× cheaper than full width, and scoring it at full width would
//!   bias victim choice toward small blocks whose refill is expensive.
//! * [`EvictPolicy::spill_victim`] — dram→disk capacity spill: the NVMe
//!   writeback plus the *two-hop* (disk→dram→gpu) reload of whatever the
//!   recompute path will not cover — so spill prefers cold blocks whose
//!   recompute-aware refill beats their two-hop reload.

use super::block::BlockId;
use crate::scheduler::CostModel;

/// What the store knows about a candidate block when choosing a victim.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockView {
    pub id: BlockId,
    /// Tokens this block covers (may be short for the last block).
    pub tokens: usize,
    /// First token position the block covers within its sequence.
    pub start_token: usize,
    /// The owning sequence's current cached length s'.
    pub seq_len: usize,
    /// Store clock at which the owning sequence last decoded.
    pub last_use: u64,
    /// The split point l* the planner currently chooses for the owning
    /// sequence: tokens below it are recomputed from X anyway.
    pub split_l: usize,
    /// Live dependents when the block backs a shared-prefix registry
    /// entry (0 for a private block).  Evicting a shared block strands
    /// *every* dependent, so the recompute-aware lenses multiply the
    /// refill side by `max(shared_refs, 1)` — the writeback still crosses
    /// the wire once.
    pub shared_refs: usize,
}

/// An eviction policy: pick the index of the block to give up.
pub trait EvictPolicy: Send {
    fn name(&self) -> &'static str;

    /// Reclamation victim (drop KV in place): `candidates` is non-empty;
    /// return the index of the victim.
    fn victim(&self, candidates: &[BlockView]) -> usize;

    /// Gpu-eviction victim: like [`EvictPolicy::victim`] but the move also
    /// pays a demotion writeback on the wire.  Defaults to the plain
    /// victim for policies that do not model traffic.
    fn demote_victim(&self, candidates: &[BlockView]) -> usize {
        self.victim(candidates)
    }

    /// Dram→disk spill victim: the move pays an NVMe writeback now and a
    /// two-hop reload later for tokens the recompute path will not cover.
    /// Defaults to the plain victim.
    fn spill_victim(&self, candidates: &[BlockView]) -> usize {
        self.victim(candidates)
    }
}

/// Least-recently-used: evict the block of the sequence that decoded
/// longest ago (ties broken by id for determinism).
#[derive(Debug, Clone, Copy, Default)]
pub struct Lru;

impl EvictPolicy for Lru {
    fn name(&self) -> &'static str {
        "lru"
    }

    fn victim(&self, candidates: &[BlockView]) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by_key(|(_, b)| (b.last_use, b.id))
            .map(|(i, _)| i)
            .expect("victim() over empty candidate slate")
    }
}

/// Recompute-aware victim selection driven by the profiler's [`CostModel`].
///
/// `cost.transfer_kv_per_token_s` is expected at the *migration wire
/// width* (see [`EvictKind::build_tiered`]): refill transfers, demotion
/// writebacks and spill writebacks all cross the wires at that width, so
/// one coefficient serves every lens.
#[derive(Debug, Clone)]
pub struct RecomputeAware {
    pub cost: CostModel,
    /// NVMe wire time per byte relative to the CPU↔GPU interconnect
    /// (pcie_bytes_per_sec / nvme_bytes_per_sec); feeds the spill lens.
    pub nvme_factor: f64,
}

impl RecomputeAware {
    /// Defaults the NVMe gap to the link model's
    /// [`NVME_BANDWIDTH_FACTOR`](crate::transfer::NVME_BANDWIDTH_FACTOR).
    pub fn new(cost: CostModel) -> Self {
        Self::with_nvme_factor(cost, crate::transfer::NVME_BANDWIDTH_FACTOR)
    }

    pub fn with_nvme_factor(cost: CostModel, nvme_factor: f64) -> Self {
        assert!(nvme_factor > 0.0, "nvme_factor must be positive");
        RecomputeAware { cost, nvme_factor }
    }

    /// Seconds to re-materialise this block's contribution if evicted:
    /// tokens inside `[0, split_l)` cost the recompute path (ship X, run
    /// the KV projections), tokens beyond it cost a KV re-transfer.  A
    /// shared block is refilled once *per dependent* — every sequence
    /// adopting the prefix loses the bytes — so the whole refill side
    /// scales by `max(shared_refs, 1)`.
    pub fn refill_cost(&self, b: &BlockView) -> f64 {
        let rec = b.split_l.saturating_sub(b.start_token).min(b.tokens);
        let xfer = b.tokens - rec;
        let per_dependent = rec as f64
            * (self.cost.recompute_per_token_s + self.cost.transfer_act_per_token_s)
            + xfer as f64 * self.cost.transfer_kv_per_token_s;
        per_dependent * b.shared_refs.max(1) as f64
    }

    /// Full cost of demoting this block out of the gpu tier: the refill
    /// *plus* the eviction writeback, both at the wire width the
    /// [`MigrationEngine`](super::MigrationEngine) charges.  Scoring the
    /// writeback at full storage width instead would overweight large
    /// blocks by the quantization ratio (~6.4× under int4 wire).
    pub fn demote_cost(&self, b: &BlockView) -> f64 {
        self.refill_cost(b) + b.tokens as f64 * self.cost.transfer_kv_per_token_s
    }

    /// Full cost of spilling this block to disk: the NVMe writeback now,
    /// plus — for the tokens the split region's recompute path will not
    /// cover — a *two-hop* reload (disk→dram at NVMe speed, then dram→gpu
    /// at interconnect speed) whenever the block is needed again.
    pub fn spill_cost(&self, b: &BlockView) -> f64 {
        let kv = self.cost.transfer_kv_per_token_s;
        let rec = b.split_l.saturating_sub(b.start_token).min(b.tokens);
        let xfer = b.tokens - rec;
        // the writeback crosses the NVMe wire once; the reload side is
        // paid per dependent of a shared block, like refill_cost
        let reload = rec as f64
            * (self.cost.recompute_per_token_s + self.cost.transfer_act_per_token_s)
            + xfer as f64 * kv * (1.0 + self.nvme_factor);
        b.tokens as f64 * kv * self.nvme_factor + reload * b.shared_refs.max(1) as f64
    }

    fn min_by_score(
        &self,
        candidates: &[BlockView],
        score: impl Fn(&BlockView) -> f64,
    ) -> usize {
        candidates
            .iter()
            .enumerate()
            .min_by(|(_, x), (_, y)| {
                score(x)
                    .partial_cmp(&score(y))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(x.last_use.cmp(&y.last_use))
                    .then(x.id.cmp(&y.id))
            })
            .map(|(i, _)| i)
            .expect("victim() over empty candidate slate")
    }
}

impl EvictPolicy for RecomputeAware {
    fn name(&self) -> &'static str {
        "recompute-aware"
    }

    fn victim(&self, candidates: &[BlockView]) -> usize {
        self.min_by_score(candidates, |b| self.refill_cost(b))
    }

    fn demote_victim(&self, candidates: &[BlockView]) -> usize {
        self.min_by_score(candidates, |b| self.demote_cost(b))
    }

    fn spill_victim(&self, candidates: &[BlockView]) -> usize {
        self.min_by_score(candidates, |b| self.spill_cost(b))
    }
}

/// Config-level policy selector: the coordinator carries this in its
/// config and builds the boxed policy once the engine's *measured*
/// [`CostModel`] is available at startup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictKind {
    Lru,
    RecomputeAware,
}

impl EvictKind {
    pub fn build(&self, cost: CostModel) -> Box<dyn EvictPolicy> {
        self.build_wire(cost, false)
    }

    /// Build the policy with the migration wire width taken into account:
    /// when `kv_quant_wire` is set, evicted-KV refills and demotion/spill
    /// writebacks are all scored at the int4 wire width (0.625 B/elem
    /// instead of 4), the same ratio the
    /// [`MigrationEngine`](super::MigrationEngine) charges on the link —
    /// the cost comparison stays honest under quantization.
    pub fn build_wire(&self, cost: CostModel, kv_quant_wire: bool) -> Box<dyn EvictPolicy> {
        self.build_tiered(cost, kv_quant_wire, crate::transfer::NVME_BANDWIDTH_FACTOR)
    }

    /// [`EvictKind::build_wire`] with the disk tier's measured NVMe/PCIe
    /// speed ratio (feeds the spill lens's two-hop reload term).
    pub fn build_tiered(
        &self,
        cost: CostModel,
        kv_quant_wire: bool,
        nvme_factor: f64,
    ) -> Box<dyn EvictPolicy> {
        let wire = if kv_quant_wire {
            crate::kvcache::ELEM_BYTES_INT4_G64
        } else {
            crate::kvcache::ELEM_BYTES_F32
        };
        self.build_for_wire(cost, wire, nvme_factor)
    }

    /// [`EvictKind::build_tiered`] with the **exact** migration wire width
    /// in bytes per f32 element — whatever the topology declares, not just
    /// the plain/int4 pair: every scoring lens scales its transfer terms
    /// by `wire_elem_bytes / 4.0`, the same ratio the
    /// [`MigrationEngine`](super::MigrationEngine) charges on the link, so
    /// victim ordering cannot diverge from the bytes that actually move.
    pub fn build_for_wire(
        &self,
        cost: CostModel,
        wire_elem_bytes: f64,
        nvme_factor: f64,
    ) -> Box<dyn EvictPolicy> {
        assert!(wire_elem_bytes > 0.0, "wire_elem_bytes must be positive");
        let cost = cost.with_kv_quant(wire_elem_bytes / crate::kvcache::ELEM_BYTES_F32);
        match self {
            EvictKind::Lru => Box::new(Lru),
            EvictKind::RecomputeAware => {
                Box::new(RecomputeAware::with_nvme_factor(cost, nvme_factor))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(seq: u64, idx: usize, start: usize, last_use: u64, split_l: usize) -> BlockView {
        BlockView {
            id: BlockId { seq, idx },
            tokens: 32,
            start_token: start,
            seq_len: 128,
            last_use,
            split_l,
            shared_refs: 0,
        }
    }

    fn cheap_recompute() -> CostModel {
        CostModel {
            recompute_per_token_s: 1e-7, // A ≪ C: recompute nearly free
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        }
    }

    #[test]
    fn lru_picks_stalest() {
        let cands = [view(1, 0, 0, 30, 0), view(2, 0, 0, 10, 0), view(3, 0, 0, 20, 0)];
        assert_eq!(Lru.victim(&cands), 1);
        // Lru's demote/spill lenses are recency too (no traffic model)
        assert_eq!(Lru.demote_victim(&cands), 1);
        assert_eq!(Lru.spill_victim(&cands), 1);
    }

    #[test]
    fn lru_ties_break_by_id() {
        let cands = [view(2, 1, 0, 5, 0), view(1, 0, 0, 5, 0)];
        assert_eq!(Lru.victim(&cands), 1);
    }

    #[test]
    fn recompute_aware_prefers_split_region_blocks() {
        let p = RecomputeAware::new(cheap_recompute());
        // block A sits fully inside the split region [0, 64): cheap rebuild;
        // block B sits beyond it: a full KV re-transfer
        let a = view(1, 0, 0, 50, 64);
        let b = view(2, 2, 64, 1, 64); // even *older*, but expensive to refill
        assert_eq!(p.victim(&[b, a]), 1, "must pick the recomputable block");
        assert!(p.refill_cost(&a) < p.refill_cost(&b));
    }

    #[test]
    fn recompute_aware_partial_overlap_scores_between() {
        let p = RecomputeAware::new(cheap_recompute());
        let inside = view(1, 0, 0, 0, 64);
        let straddle = view(1, 1, 48, 0, 64); // 16 tokens in, 16 out
        let outside = view(1, 2, 96, 0, 64);
        let ci = p.refill_cost(&inside);
        let cs = p.refill_cost(&straddle);
        let co = p.refill_cost(&outside);
        assert!(ci < cs && cs < co, "{ci} {cs} {co}");
    }

    #[test]
    fn demote_scoring_adds_the_writeback_and_flips_the_victim() {
        // A: 32 tokens inside the split region (cheap refill by recompute);
        // B: 24 tokens beyond it (expensive refill by re-transfer).
        // Refill-only scoring prefers evicting A (1.92e-5 < 2.4e-5), but
        // demoting A also writes 32 tokens back over the wire — the full
        // demotion cost makes B the correct victim (5.12e-5 > 4.8e-5).
        let p = RecomputeAware::new(cheap_recompute());
        let a = view(1, 0, 0, 0, 32); // 32 tokens, all recomputable
        let mut b = view(2, 2, 64, 0, 0); // beyond split
        b.tokens = 24;
        assert_eq!(p.victim(&[a, b]), 0, "refill lens picks the recomputable block");
        assert_eq!(p.demote_victim(&[a, b]), 1, "writeback-aware lens picks the smaller block");
        assert!(p.demote_cost(&a) > p.demote_cost(&b));
        assert!(p.refill_cost(&a) < p.refill_cost(&b));
    }

    #[test]
    fn demote_writeback_is_scored_at_wire_width() {
        // The ROADMAP bug: scoring the writeback at full storage width
        // while the MigrationEngine charges int4 wire bytes (0.15625×)
        // overweights large blocks by ~6.4×.  With recompute nearly free:
        //   A: 32 tokens inside the split   B: 24 tokens beyond it
        // at the int4 wire width A is the cheaper demotion (its writeback
        // shrank with the wire); at full width the stale scoring would
        // evict B instead.
        let cost = CostModel {
            recompute_per_token_s: 1e-9,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 0.0,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let a = view(1, 0, 0, 0, 32);
        let mut b = view(2, 2, 64, 0, 0);
        b.tokens = 24;
        let quant = EvictKind::RecomputeAware.build_wire(cost.clone(), true);
        assert_eq!(quant.demote_victim(&[a, b]), 0, "wire-width writeback must pick A");
        // the buggy full-width writeback score, reconstructed by hand,
        // orders the candidates the other way
        let wire = RecomputeAware::new(cost.clone().with_kv_quant(0.15625));
        let full_wb = |v: &BlockView| {
            wire.refill_cost(v) + v.tokens as f64 * cost.transfer_kv_per_token_s
        };
        assert!(
            full_wb(&a) > full_wb(&b),
            "full-width writeback would have biased the choice to B: {} vs {}",
            full_wb(&a),
            full_wb(&b)
        );
        assert!(wire.demote_cost(&a) < wire.demote_cost(&b));
    }

    #[test]
    fn spill_prefers_recompute_covered_blocks() {
        let p = RecomputeAware::new(cheap_recompute());
        // same size, same recency: the block inside the split region never
        // needs its two-hop reload (recompute covers it), so it spills
        let inside = view(1, 0, 0, 5, 64);
        let beyond = view(2, 2, 64, 5, 64);
        assert_eq!(p.spill_victim(&[beyond, inside]), 1);
        assert!(p.spill_cost(&inside) < p.spill_cost(&beyond));
        // the two-hop reload term scales with the NVMe gap
        let slow = RecomputeAware::with_nvme_factor(cheap_recompute(), 16.0);
        assert!(slow.spill_cost(&beyond) > p.spill_cost(&beyond));
    }

    #[test]
    fn wire_quant_shrinks_the_transfer_refill_side() {
        // balanced costs: recomputing a block ≈ re-transferring it, so the
        // full-width policy is near-indifferent...
        let cost = CostModel {
            recompute_per_token_s: 4e-7,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let plain = RecomputeAware::new(cost.clone());
        let quant = EvictKind::RecomputeAware.build_wire(cost, true);
        // a block beyond the split (pure re-transfer refill): int4 wire
        // makes its refill 0.15625× the full-width score
        let beyond = view(1, 2, 64, 0, 0);
        let full = plain.refill_cost(&beyond);
        // recompute the quantized score through the public surface: the
        // boxed policy must now *prefer evicting* the transfer-refillable
        // block over a recompute-refillable one of equal recency
        let inside = view(2, 0, 0, 0, 64);
        assert_eq!(
            plain.victim(&[beyond, inside]),
            1,
            "full width: recompute side is cheaper to refill"
        );
        assert_eq!(
            quant.victim(&[beyond, inside]),
            0,
            "int4 wire: the transfer side becomes the cheap refill"
        );
        let q = RecomputeAware::new(
            CostModel {
                recompute_per_token_s: 4e-7,
                transfer_kv_per_token_s: 1e-6,
                transfer_act_per_token_s: 5e-7,
                gpu_overhead_s: 0.0,
                link_latency_s: 0.0,
            }
            .with_kv_quant(0.15625),
        );
        assert!((q.refill_cost(&beyond) - full * 0.15625).abs() < 1e-12);
    }

    #[test]
    fn build_for_wire_scales_by_the_exact_width() {
        // a topology can declare any wire width (e.g. fp16 = 2.0 B/elem);
        // the scoring lenses must scale by that exact ratio, not collapse
        // to the plain/int4 pair
        let cost = CostModel {
            recompute_per_token_s: 4e-7,
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 5e-7,
            gpu_overhead_s: 0.0,
            link_latency_s: 0.0,
        };
        let beyond = view(1, 2, 64, 0, 0); // pure transfer refill
        let full = RecomputeAware::new(cost.clone()).refill_cost(&beyond);
        // reconstruct the fp16-wire score the boxed policy must be using
        let fp16 = RecomputeAware::new(cost.clone().with_kv_quant(0.5));
        assert!((fp16.refill_cost(&beyond) - full * 0.5).abs() < 1e-15);
        // at fp16 the transfer side (0.5e-6/tok) still loses to recompute
        // + act (0.9e-6/tok)... so compare orderings through the public
        // surface at a width where the choice flips: 2.0 B/elem halves
        // the transfer refill below the recompute side
        let inside = view(2, 0, 0, 0, 64);
        let plain = EvictKind::RecomputeAware.build_for_wire(cost.clone(), 4.0, 4.0);
        assert_eq!(plain.victim(&[beyond, inside]), 1, "full width: recompute is cheaper");
        let half = EvictKind::RecomputeAware.build_for_wire(cost, 2.0, 4.0);
        assert_eq!(half.victim(&[beyond, inside]), 0, "fp16 wire: transfer side wins");
    }

    #[test]
    fn shared_refs_multiply_the_refill_side_only() {
        let p = RecomputeAware::new(cheap_recompute());
        let private = view(1, 2, 64, 0, 0); // pure transfer refill
        let mut shared = private;
        shared.shared_refs = 3;
        // refill: the whole score is refill, so it scales exactly 3×
        assert!((p.refill_cost(&shared) - 3.0 * p.refill_cost(&private)).abs() < 1e-15);
        // demote: writeback is paid once, so the score grows by less
        // than 3× but by exactly 2× the private refill
        let delta = p.demote_cost(&shared) - p.demote_cost(&private);
        assert!((delta - 2.0 * p.refill_cost(&private)).abs() < 1e-15);
        // spill: the NVMe writeback term stays single too
        let writeback = 32.0 * p.cost.transfer_kv_per_token_s * p.nvme_factor;
        let reload_private = p.spill_cost(&private) - writeback;
        assert!((p.spill_cost(&shared) - (writeback + 3.0 * reload_private)).abs() < 1e-12);
        // and the ordering consequence: with many dependents, a shared
        // block outscores (is kept over) an otherwise-identical private
        // block of the same recency
        assert_eq!(p.victim(&[shared, private]), 1, "evict the private twin");
        assert_eq!(p.demote_victim(&[shared, private]), 1);
        assert_eq!(p.spill_victim(&[shared, private]), 1);
    }

    #[test]
    fn recompute_aware_ties_fall_back_to_recency() {
        let p = RecomputeAware::new(cheap_recompute());
        // identical positions → identical cost → stalest wins
        let a = view(1, 0, 0, 9, 0);
        let b = view(2, 0, 0, 3, 0);
        assert_eq!(p.victim(&[a, b]), 1);
    }
}
