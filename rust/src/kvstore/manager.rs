//! Tier pools, the migration links, and pinned staging — the resource layer
//! under the [`MigrationEngine`](super::MigrationEngine).
//!
//! The manager owns the four tier [`BlockPool`]s, the two [`Link`]s
//! migrations ride — the CPU↔GPU interconnect for gpu↔pinned↔dram traffic
//! and a slower, higher-latency **NVMe link** for anything touching the
//! disk tier — and the [`PinnedPool`] staging freelist, whose buffers are
//! charged against the *pinned tier's own* [`MemPool`], so staging
//! occupancy and pinned-resident blocks compete for the same capacity,
//! exactly as on a real machine.
//!
//! Scheduling — and all counting — lives one layer up: the migration
//! engine decides *when* bytes move (queued → staged → in-flight →
//! landed, under the per-step link-byte budget); this layer only answers
//! "reserve these bytes in that tier" and "which wire does this hop ride".
//! PR 2's `migrate_sync` — a blocking link wait on the caller, used by the
//! old eviction path — is gone with the serving loop's last synchronous
//! migration.

use crate::memory::MemPool;
use crate::transfer::{Link, LinkConfig, PinnedPool};

use super::block::{BlockPool, Tier};

/// Aggregate migration-traffic counters — a view derived from the
/// [`MigrationEngine`](super::MigrationEngine)'s lifecycle stats (one
/// counter, two lenses: the engine tracks the lifecycle, this names the
/// link-traffic slice of it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Migrations put on a link (either wire).
    pub migrations: u64,
    /// Wire bytes put on the links (post-quantization widths).
    pub migrated_bytes: u64,
}

/// Byte accounting for host tiers **shared across worker shards**: each
/// shard's [`TierManager`] owns its private gpu pool but draws pinned,
/// dram and disk reservations from these [`MemPool`]s, which clone by
/// reference ([`MemPool`] is `Arc`-shared) — so N shards admitting
/// concurrently compete for one host budget, exactly as N GPUs over one
/// host do.  Build once (in the router), clone into every shard.
#[derive(Clone)]
pub struct SharedHostTiers {
    pinned: MemPool,
    dram: MemPool,
    disk: MemPool,
}

impl SharedHostTiers {
    pub fn new(pinned_bytes: u64, dram_bytes: u64, disk_bytes: u64) -> Self {
        SharedHostTiers {
            pinned: MemPool::new(Tier::Pinned.name(), pinned_bytes),
            dram: MemPool::new(Tier::CpuDram.name(), dram_bytes),
            disk: MemPool::new(Tier::DiskNvme.name(), disk_bytes),
        }
    }

    /// The shared pool backing `tier`; `None` for the (per-shard) gpu tier.
    pub fn pool(&self, tier: Tier) -> Option<&MemPool> {
        match tier {
            Tier::GpuHbm => None,
            Tier::Pinned => Some(&self.pinned),
            Tier::CpuDram => Some(&self.dram),
            Tier::DiskNvme => Some(&self.disk),
        }
    }
}

impl std::fmt::Debug for SharedHostTiers {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SharedHostTiers")
            .field("pinned_used", &self.pinned.used())
            .field("dram_used", &self.dram.used())
            .field("disk_used", &self.disk.used())
            .finish()
    }
}

/// Owns the four tier pools, the two migration links, and pinned staging.
pub struct TierManager {
    gpu: BlockPool,
    pinned: BlockPool,
    dram: BlockPool,
    disk: BlockPool,
    link: Link,
    nvme: Link,
    staging: PinnedPool,
}

impl TierManager {
    pub fn new(
        gpu_bytes: u64,
        pinned_bytes: u64,
        dram_bytes: u64,
        disk_bytes: u64,
        link: LinkConfig,
        nvme: LinkConfig,
    ) -> Self {
        // the pinned tier's byte pool is shared with the staging freelist so
        // pinned blocks and pinned staging buffers draw from one budget
        let pinned_mem = MemPool::new(Tier::Pinned.name(), pinned_bytes);
        TierManager {
            gpu: BlockPool::new(Tier::GpuHbm, gpu_bytes),
            pinned: BlockPool::from_pool(Tier::Pinned, pinned_mem.clone()),
            dram: BlockPool::new(Tier::CpuDram, dram_bytes),
            disk: BlockPool::new(Tier::DiskNvme, disk_bytes),
            link: Link::new(link),
            nvme: Link::new(nvme),
            staging: PinnedPool::with_accounting(pinned_mem),
        }
    }

    /// A shard-local manager over **shared host tiers**: the gpu pool is
    /// private to this shard, while pinned/dram/disk block reservations —
    /// and pinned staging — charge the shared [`SharedHostTiers`] pools.
    pub fn with_shared_host(
        gpu_bytes: u64,
        shared: &SharedHostTiers,
        link: LinkConfig,
        nvme: LinkConfig,
    ) -> Self {
        TierManager {
            gpu: BlockPool::new(Tier::GpuHbm, gpu_bytes),
            pinned: BlockPool::from_pool(Tier::Pinned, shared.pinned.clone()),
            dram: BlockPool::from_pool(Tier::CpuDram, shared.dram.clone()),
            disk: BlockPool::from_pool(Tier::DiskNvme, shared.disk.clone()),
            link: Link::new(link),
            nvme: Link::new(nvme),
            staging: PinnedPool::with_accounting(shared.pinned.clone()),
        }
    }

    pub fn pool(&self, tier: Tier) -> &BlockPool {
        match tier {
            Tier::GpuHbm => &self.gpu,
            Tier::Pinned => &self.pinned,
            Tier::CpuDram => &self.dram,
            Tier::DiskNvme => &self.disk,
        }
    }

    /// The CPU↔GPU interconnect (gpu↔pinned↔dram migrations).
    pub fn link(&self) -> &Link {
        &self.link
    }

    /// The NVMe wire (anything touching the disk tier).
    pub fn nvme(&self) -> &Link {
        &self.nvme
    }

    /// The wire a `from → to` migration rides: a hop with either endpoint
    /// on disk moves at NVMe speed, everything else at interconnect speed.
    pub fn link_for(&self, from: Tier, to: Tier) -> &Link {
        if from.is_disk() || to.is_disk() {
            &self.nvme
        } else {
            &self.link
        }
    }

    pub fn staging(&self) -> &PinnedPool {
        &self.staging
    }

    /// Reserve `bytes` in `tier`; `None` when the tier is full.
    pub fn grab(&self, tier: Tier, bytes: u64) -> Option<crate::memory::PoolGuard> {
        self.pool(tier).grab(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> TierManager {
        TierManager::new(
            1 << 20,
            1 << 20,
            4 << 20,
            16 << 20,
            LinkConfig::unthrottled(),
            LinkConfig::unthrottled(),
        )
    }

    #[test]
    fn grab_reserves_and_releases_per_tier() {
        let m = mgr();
        let g = m.grab(Tier::GpuHbm, 4096).unwrap();
        assert_eq!(m.pool(Tier::GpuHbm).used(), 4096);
        assert_eq!(m.pool(Tier::Pinned).used(), 0);
        drop(g);
        assert_eq!(m.pool(Tier::GpuHbm).used(), 0);
        let g = m.grab(Tier::DiskNvme, 8192).unwrap();
        assert_eq!(m.pool(Tier::DiskNvme).used(), 8192);
        drop(g);
    }

    #[test]
    fn grab_fails_when_tier_full() {
        let m = TierManager::new(
            4096,
            1 << 20,
            1 << 20,
            0, // no disk tier configured
            LinkConfig::unthrottled(),
            LinkConfig::unthrottled(),
        );
        let _held = m.grab(Tier::GpuHbm, 4096).unwrap();
        assert!(m.grab(Tier::GpuHbm, 4096).is_none());
        assert!(m.grab(Tier::DiskNvme, 1).is_none(), "zero-capacity disk tier");
    }

    #[test]
    fn disk_hops_ride_the_nvme_wire() {
        let m = mgr();
        assert!(std::ptr::eq(m.link_for(Tier::CpuDram, Tier::DiskNvme), m.nvme()));
        assert!(std::ptr::eq(m.link_for(Tier::DiskNvme, Tier::CpuDram), m.nvme()));
        assert!(std::ptr::eq(m.link_for(Tier::CpuDram, Tier::GpuHbm), m.link()));
        assert!(std::ptr::eq(m.link_for(Tier::GpuHbm, Tier::Pinned), m.link()));
    }

    #[test]
    fn shared_host_tiers_account_across_managers() {
        let shared = SharedHostTiers::new(1 << 20, 4 << 20, 16 << 20);
        let a = TierManager::with_shared_host(
            1 << 20,
            &shared,
            LinkConfig::unthrottled(),
            LinkConfig::unthrottled(),
        );
        let b = TierManager::with_shared_host(
            1 << 20,
            &shared,
            LinkConfig::unthrottled(),
            LinkConfig::unthrottled(),
        );
        // a host-tier grab in shard A is visible to shard B's pool...
        let g = a.grab(Tier::CpuDram, 4096).unwrap();
        assert_eq!(b.pool(Tier::CpuDram).used(), 4096, "dram budget is shared");
        assert_eq!(shared.pool(Tier::CpuDram).unwrap().used(), 4096);
        // ...but gpu tiers stay private to each shard
        let _h = a.grab(Tier::GpuHbm, 4096).unwrap();
        assert_eq!(b.pool(Tier::GpuHbm).used(), 0, "gpu budget is per-shard");
        drop(g);
        assert_eq!(b.pool(Tier::CpuDram).used(), 0);
    }

    #[test]
    fn staging_charges_the_pinned_tier() {
        let m = mgr();
        // a staging buffer is pinned-accounted: after the first get the
        // pinned pool has grown by the staged bytes even though no *block*
        // lives there
        let buf = m.staging().get(2048);
        assert!(
            m.pool(Tier::Pinned).used() >= 8192,
            "staging not pinned-accounted: {}",
            m.pool(Tier::Pinned).used()
        );
        assert_eq!(m.pool(Tier::Pinned).mem().name(), "pinned");
        m.staging().put(buf);
    }
}
