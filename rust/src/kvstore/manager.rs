//! Tier migration: moving a block's bytes between pools over the link.
//!
//! Migrations are modelled the way the engine models every other copy: the
//! bytes ride a [`Link`] (so they take wall-clock time and show up in link
//! stats) and the host side stages through the [`PinnedPool`] — whose
//! buffers are charged against the *pinned tier's own* [`MemPool`], so
//! staging occupancy and pinned-resident blocks compete for the same
//! capacity, exactly as on a real machine.
//!
//! Promotions (towards the GPU) are **asynchronous**: [`TierManager::begin_migration`]
//! grabs the destination reservation and puts the transfer in flight;
//! the caller completes it later with [`TierManager::finish_migration`]
//! once [`PendingMigration::is_done`].  Demotions run synchronously on the
//! caller via [`TierManager::migrate_sync`] — bounded by one block's link
//! time; making them asynchronous too is a ROADMAP follow-on (it becomes
//! necessary once a disk tier adds real writeback).

use crate::memory::{MemPool, PoolGuard};
use crate::transfer::{Link, LinkConfig, PinnedPool, Priority, TransferHandle};

use super::block::{BlockPool, Tier};

/// An in-flight block migration: destination reservation already held,
/// bytes still on the link, staging buffer pinned until completion.
pub struct PendingMigration {
    to: Tier,
    handle: TransferHandle,
    guard: PoolGuard,
    staging: Vec<f32>,
}

impl PendingMigration {
    /// Destination tier of this migration.
    pub fn to(&self) -> Tier {
        self.to
    }

    /// Non-blocking: has the transfer landed?
    pub fn is_done(&self) -> bool {
        self.handle.is_done()
    }
}

/// Aggregate migration counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    pub migrations: u64,
    pub migrated_bytes: u64,
}

/// Owns the three tier pools and the migration link.
pub struct TierManager {
    gpu: BlockPool,
    pinned: BlockPool,
    dram: BlockPool,
    link: Link,
    staging: PinnedPool,
    stats: TierStats,
}

impl TierManager {
    pub fn new(gpu_bytes: u64, pinned_bytes: u64, dram_bytes: u64, link: LinkConfig) -> Self {
        // the pinned tier's byte pool is shared with the staging freelist so
        // pinned blocks and pinned staging buffers draw from one budget
        let pinned_mem = MemPool::new(Tier::Pinned.name(), pinned_bytes);
        TierManager {
            gpu: BlockPool::new(Tier::GpuHbm, gpu_bytes),
            pinned: BlockPool::from_pool(Tier::Pinned, pinned_mem.clone()),
            dram: BlockPool::new(Tier::CpuDram, dram_bytes),
            link: Link::new(link),
            staging: PinnedPool::with_accounting(pinned_mem),
            stats: TierStats::default(),
        }
    }

    pub fn pool(&self, tier: Tier) -> &BlockPool {
        match tier {
            Tier::GpuHbm => &self.gpu,
            Tier::Pinned => &self.pinned,
            Tier::CpuDram => &self.dram,
        }
    }

    pub fn stats(&self) -> TierStats {
        self.stats
    }

    pub fn link(&self) -> &Link {
        &self.link
    }

    pub fn staging(&self) -> &PinnedPool {
        &self.staging
    }

    /// Reserve `bytes` in `tier`; `None` when the tier is full.
    pub fn grab(&self, tier: Tier, bytes: u64) -> Option<PoolGuard> {
        self.pool(tier).grab(bytes)
    }

    /// Start moving a block of `bytes` into `to`: reserve the destination,
    /// pin a staging buffer, put the bytes on the link.  `None` when the
    /// destination tier is full (the caller evicts and retries).  The
    /// source reservation stays with the caller until it swaps guards in
    /// [`Self::finish_migration`]'s result.
    pub fn begin_migration(
        &mut self,
        to: Tier,
        bytes: u64,
        priority: Priority,
    ) -> Option<PendingMigration> {
        let guard = self.pool(to).grab(bytes)?;
        let n = (bytes / 4) as usize;
        let staging = self.staging.get(n);
        let handle = self.link.submit_timing(n, priority);
        self.stats.migrations += 1;
        self.stats.migrated_bytes += bytes;
        Some(PendingMigration { to, handle, guard, staging })
    }

    /// Complete a migration (blocking if the transfer is still in flight);
    /// returns the destination reservation for the caller to install.
    pub fn finish_migration(&mut self, pm: PendingMigration) -> (Tier, PoolGuard) {
        let PendingMigration { to, handle, guard, staging } = pm;
        handle.wait();
        self.staging.put(staging);
        (to, guard)
    }

    /// Synchronous host-side move timing for `bytes` (demotion path):
    /// stage through the pinned pool and wait the link out.  Guard shuffling
    /// is the caller's job (it owns both tiers' reservations).
    pub fn migrate_sync(&mut self, bytes: u64) {
        let n = (bytes / 4) as usize;
        let staging = self.staging.get(n);
        let handle = self.link.submit_timing(n, Priority::Normal);
        handle.wait();
        self.staging.put(staging);
        self.stats.migrations += 1;
        self.stats.migrated_bytes += bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> TierManager {
        TierManager::new(1 << 20, 1 << 20, 4 << 20, LinkConfig::unthrottled())
    }

    #[test]
    fn async_migration_moves_reservation() {
        let mut m = mgr();
        let src = m.grab(Tier::CpuDram, 4096).unwrap();
        let pm = m
            .begin_migration(Tier::GpuHbm, 4096, Priority::High)
            .expect("gpu tier has room");
        assert_eq!(m.pool(Tier::GpuHbm).used(), 4096, "destination reserved up front");
        let (to, guard) = m.finish_migration(pm);
        assert_eq!(to, Tier::GpuHbm);
        drop(src); // caller swaps: source reservation released...
        assert_eq!(m.pool(Tier::CpuDram).used(), 0);
        assert_eq!(guard.bytes(), 4096); // ...destination held by the new guard
        assert_eq!(m.stats().migrations, 1);
        assert_eq!(m.stats().migrated_bytes, 4096);
    }

    #[test]
    fn begin_migration_fails_when_destination_full() {
        let mut m = TierManager::new(4096, 1 << 20, 1 << 20, LinkConfig::unthrottled());
        let _held = m.grab(Tier::GpuHbm, 4096).unwrap();
        assert!(m.begin_migration(Tier::GpuHbm, 4096, Priority::High).is_none());
    }

    #[test]
    fn staging_charges_the_pinned_tier() {
        let mut m = mgr();
        // a migration's staging buffer is pinned-accounted: after the first
        // migration the pinned pool has grown by the staged bytes even
        // though no *block* lives there
        m.migrate_sync(8192);
        assert!(
            m.pool(Tier::Pinned).used() >= 8192,
            "staging not pinned-accounted: {}",
            m.pool(Tier::Pinned).used()
        );
        assert_eq!(m.pool(Tier::Pinned).mem().name(), "pinned");
    }

    #[test]
    fn migration_rides_the_link() {
        let mut m = mgr();
        m.migrate_sync(4096);
        assert_eq!(m.link().stats().total_bytes(), 4096);
        assert_eq!(m.link().stats().total_transfers(), 1);
    }
}
