//! Tier pools, the migration link, and pinned staging — the resource layer
//! under the [`MigrationEngine`](super::MigrationEngine).
//!
//! The manager owns the three tier [`BlockPool`]s, the [`Link`] migrations
//! ride, and the [`PinnedPool`] staging freelist — whose buffers are
//! charged against the *pinned tier's own* [`MemPool`], so staging
//! occupancy and pinned-resident blocks compete for the same capacity,
//! exactly as on a real machine.
//!
//! Scheduling — and all counting — lives one layer up: the migration
//! engine decides *when* bytes move (queued → staged → in-flight →
//! landed, under the per-step link-byte budget); this layer only answers
//! "reserve these bytes in that tier".  PR 2's `migrate_sync`
//! — a blocking link wait on the caller, used by the old eviction path —
//! is gone with the serving loop's last synchronous migration.

use crate::memory::MemPool;
use crate::transfer::{Link, LinkConfig, PinnedPool};

use super::block::{BlockPool, Tier};

/// Aggregate migration-traffic counters — a view derived from the
/// [`MigrationEngine`](super::MigrationEngine)'s lifecycle stats (one
/// counter, two lenses: the engine tracks the lifecycle, this names the
/// link-traffic slice of it).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierStats {
    /// Migrations put on the link.
    pub migrations: u64,
    /// Wire bytes put on the link (post-quantization widths).
    pub migrated_bytes: u64,
}

/// Owns the three tier pools, the migration link, and pinned staging.
pub struct TierManager {
    gpu: BlockPool,
    pinned: BlockPool,
    dram: BlockPool,
    link: Link,
    staging: PinnedPool,
}

impl TierManager {
    pub fn new(gpu_bytes: u64, pinned_bytes: u64, dram_bytes: u64, link: LinkConfig) -> Self {
        // the pinned tier's byte pool is shared with the staging freelist so
        // pinned blocks and pinned staging buffers draw from one budget
        let pinned_mem = MemPool::new(Tier::Pinned.name(), pinned_bytes);
        TierManager {
            gpu: BlockPool::new(Tier::GpuHbm, gpu_bytes),
            pinned: BlockPool::from_pool(Tier::Pinned, pinned_mem.clone()),
            dram: BlockPool::new(Tier::CpuDram, dram_bytes),
            link: Link::new(link),
            staging: PinnedPool::with_accounting(pinned_mem),
        }
    }

    pub fn pool(&self, tier: Tier) -> &BlockPool {
        match tier {
            Tier::GpuHbm => &self.gpu,
            Tier::Pinned => &self.pinned,
            Tier::CpuDram => &self.dram,
        }
    }

    pub fn link(&self) -> &Link {
        &self.link
    }

    pub fn staging(&self) -> &PinnedPool {
        &self.staging
    }

    /// Reserve `bytes` in `tier`; `None` when the tier is full.
    pub fn grab(&self, tier: Tier, bytes: u64) -> Option<crate::memory::PoolGuard> {
        self.pool(tier).grab(bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr() -> TierManager {
        TierManager::new(1 << 20, 1 << 20, 4 << 20, LinkConfig::unthrottled())
    }

    #[test]
    fn grab_reserves_and_releases_per_tier() {
        let m = mgr();
        let g = m.grab(Tier::GpuHbm, 4096).unwrap();
        assert_eq!(m.pool(Tier::GpuHbm).used(), 4096);
        assert_eq!(m.pool(Tier::Pinned).used(), 0);
        drop(g);
        assert_eq!(m.pool(Tier::GpuHbm).used(), 0);
    }

    #[test]
    fn grab_fails_when_tier_full() {
        let m = TierManager::new(4096, 1 << 20, 1 << 20, LinkConfig::unthrottled());
        let _held = m.grab(Tier::GpuHbm, 4096).unwrap();
        assert!(m.grab(Tier::GpuHbm, 4096).is_none());
    }

    #[test]
    fn staging_charges_the_pinned_tier() {
        let m = mgr();
        // a staging buffer is pinned-accounted: after the first get the
        // pinned pool has grown by the staged bytes even though no *block*
        // lives there
        let buf = m.staging().get(2048);
        assert!(
            m.pool(Tier::Pinned).used() >= 8192,
            "staging not pinned-accounted: {}",
            m.pool(Tier::Pinned).used()
        );
        assert_eq!(m.pool(Tier::Pinned).mem().name(), "pinned");
        m.staging().put(buf);
    }
}
