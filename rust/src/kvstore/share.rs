//! Cross-request prefix sharing: content-hashed, ref-counted,
//! copy-on-write KV blocks.
//!
//! At production scale most traffic repeats system prompts and few-shot
//! preambles, so the biggest lever left on the transfer-vs-recompute
//! economics is to not materialize the same prefix KV per request at all.
//! The [`PrefixRegistry`] is that lever's bookkeeping core:
//!
//! * **Content-hashed chain entries.**  A prompt is split into the store's
//!   fixed `block_tokens`-sized blocks (the byte tokenizer makes one byte
//!   one token) and each *full* block gets a chain hash
//!   `h_i = fnv(h_{i-1}, block bytes)` — so a hash identifies not just a
//!   block's content but its entire left context, and equal hashes mean
//!   equal prefixes.  A partial trailing block is never shared.
//! * **Longest-shared-prefix lookup.**  [`PrefixRegistry::match_prefix`]
//!   walks `h_0, h_1, …` while entries exist; the walk's length is the
//!   longest previously-registered prefix, by construction contiguous
//!   from the start of the prompt.
//! * **Ref-counted ownership.**  The first request to carry a prefix
//!   *registers* its blocks — the registry takes over the host-tier
//!   reservation ([`PoolGuard`]) and the request's own
//!   `BlockState` becomes a guard-less *shared marker*.  Every later
//!   request with the same prefix *adopts* the entries (`refs += 1`) and
//!   pays **zero** new bytes and zero transfer for those tokens.
//!   Retirement decrements; an entry whose refs reach 0 stays *parked* as
//!   cross-request cache until capacity pressure trims it (LRU,
//!   leaf-first so interior chain links never dangle).  An entry with
//!   live dependents is never trimmed, never evicted.
//! * **Copy-on-write divergence.**  A writer to a shared block (in the
//!   serving loop: cross-shard session migration parking a prefix deep)
//!   gets a private clone under its own reservation; the shared original
//!   keeps its other dependents and its bytes, untouched
//!   ([`PrefixRegistry::privatize`]).
//!
//! The registry is pure accounting — the actual K/V rows live in the
//! engine's per-session host cache, which is exactly why store-level
//! sharing cannot perturb decode math (bit-identical tokens come for
//! free).  Integration lives in
//! [`KvStore::admit_shared`](super::KvStore::admit_shared); the planner
//! sees adopted prefixes as the zero-transfer `shared_prefix` span of
//! [`PlanInput`](crate::scheduler::PlanInput), and the
//! [`Router`](crate::coordinator::Router) hashes the same bytes
//! ([`share_key`]) so same-prefix requests land on the shard already
//! holding the blocks.
//!
//! ```
//! use kvpr::kvstore::PrefixRegistry;
//!
//! let mut reg = PrefixRegistry::new(8); // 8 tokens (= bytes) per block
//! let prompt = b"You are a helpful assistant. User: hi";
//! assert!(reg.match_prefix(prompt).is_empty(), "nothing registered yet");
//!
//! // first request: register every full prompt block (4 of them; the
//! // 5-byte tail block is partial and never shared)
//! let chain = PrefixRegistry::chain(prompt, 8);
//! assert_eq!(chain.len(), 4);
//! let mut parent = None;
//! for &h in &chain {
//!     reg.register(h, parent, 1024, None);
//!     parent = Some(h);
//! }
//!
//! // second request, same system prompt, different question: the walk
//! // finds the shared blocks and adoption costs zero new bytes
//! let hit = reg.match_prefix(b"You are a helpful assistant. User: what is 2+2?");
//! assert_eq!(hit.len(), 4);
//! for &h in &hit {
//!     reg.adopt(h);
//! }
//! assert_eq!(reg.refs(chain[3]), 2);
//!
//! // retirement decrements instead of freeing; the last release parks
//! // the entries as reusable cross-request cache
//! for &h in &hit {
//!     reg.release(h);
//! }
//! assert_eq!(reg.refs(chain[3]), 1);
//! ```

use std::collections::BTreeMap;

use crate::memory::PoolGuard;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x100_0000_01b3;

fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Affinity key over the first `prefix_bytes` of a prompt — the hash the
/// [`Router`](crate::coordinator::Router) uses to steer same-prefix
/// requests to the shard whose registry already holds their blocks.
/// Prompts shorter than the window hash whole, so the key degrades
/// gracefully to full-prompt affinity.
pub fn share_key(prompt: &[u8], prefix_bytes: usize) -> u64 {
    let n = prefix_bytes.min(prompt.len());
    fnv1a(FNV_OFFSET, &prompt[..n])
}

/// One shared-prefix chain entry.
#[derive(Debug)]
struct Entry {
    /// Chain hash of the previous block's entry (`None` for block 0).
    parent: Option<u64>,
    /// Live dependents: sequences whose admission adopted this entry and
    /// have not yet retired or diverged.  0 means *parked* — reusable
    /// cache, trimmable under pressure, never while refs > 0.
    refs: usize,
    /// Bytes of the block this entry owns in its tier.
    bytes: u64,
    /// The real tier reservation (the adopting sequences' markers hold
    /// `guard: None`).  `None` only in tests/doctests that exercise the
    /// accounting without a pool.
    guard: Option<PoolGuard>,
    /// Recency clock value at the last adopt/register (LRU trim input).
    last_use: u64,
}

/// Counters of registry activity, surfaced through
/// [`KvStore::share_stats`](super::KvStore::share_stats) into the serving
/// metrics' `ShareTotals`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShareStats {
    /// Chain entries created (first-writer registrations).
    pub registered: u64,
    /// Adoptions by later same-prefix requests (each is one block of KV
    /// neither transferred nor recomputed).
    pub adoptions: u64,
    /// Dependent retirements (refcount decrements via [`PrefixRegistry::release`]).
    pub releases: u64,
    /// Copy-on-write divergences: a dependent privatized its marker and
    /// left the shared original untouched.
    pub cow_clones: u64,
    /// Parked entries trimmed under capacity pressure.
    pub trimmed: u64,
}

/// Content-hashed, ref-counted registry of shared KV prefix blocks.
///
/// See the [module docs](self) for the design; the runnable example there
/// doubles as the registry's doctest.
#[derive(Debug, Default)]
pub struct PrefixRegistry {
    block_tokens: usize,
    entries: BTreeMap<u64, Entry>,
    clock: u64,
    stats: ShareStats,
}

impl PrefixRegistry {
    pub fn new(block_tokens: usize) -> Self {
        assert!(block_tokens > 0, "block_tokens must be positive");
        PrefixRegistry { block_tokens, ..PrefixRegistry::default() }
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// The chain hashes of every *full* `block_tokens`-sized block of
    /// `prompt`: `h_i = fnv(h_{i-1}, block_i bytes)`, so equal `h_i` means
    /// the entire prefix through block `i` is byte-identical.  A partial
    /// trailing block yields no hash — it is never shareable.
    pub fn chain(prompt: &[u8], block_tokens: usize) -> Vec<u64> {
        assert!(block_tokens > 0, "block_tokens must be positive");
        let mut out = Vec::with_capacity(prompt.len() / block_tokens);
        let mut parent = FNV_OFFSET;
        for block in prompt.chunks_exact(block_tokens) {
            let h = fnv1a(fnv1a(FNV_OFFSET, &parent.to_le_bytes()), block);
            out.push(h);
            parent = h;
        }
        out
    }

    /// Longest-shared-prefix lookup: the chain hashes of `prompt`'s
    /// leading blocks that are all present in the registry, in block
    /// order.  The result's length × `block_tokens` is the token span an
    /// admission can adopt instead of transferring or recomputing.
    pub fn match_prefix(&self, prompt: &[u8]) -> Vec<u64> {
        let mut chain = Self::chain(prompt, self.block_tokens);
        let matched = chain.iter().take_while(|h| self.entries.contains_key(*h)).count();
        chain.truncate(matched);
        chain
    }

    /// Whether an entry with chain hash `h` exists (parked or live).
    pub fn contains(&self, h: u64) -> bool {
        self.entries.contains_key(&h)
    }

    /// Live dependents of entry `h` (0 when parked or absent).
    pub fn refs(&self, h: u64) -> usize {
        self.entries.get(&h).map_or(0, |e| e.refs)
    }

    /// Entries currently in the registry (live + parked).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parked entries (refs == 0) and the bytes their guards still hold —
    /// the trimmable cross-request cache.
    pub fn parked_bytes(&self) -> u64 {
        self.entries.values().filter(|e| e.refs == 0).map(|e| e.bytes).sum()
    }

    pub fn stats(&self) -> ShareStats {
        self.stats
    }

    /// Register a new chain entry: the first writer hands over its tier
    /// reservation and becomes the entry's first dependent (refs = 1).
    /// `parent` must be the previous block's chain hash (`None` for block
    /// 0) so trimming can keep chains contiguous.
    pub fn register(&mut self, h: u64, parent: Option<u64>, bytes: u64, guard: Option<PoolGuard>) {
        debug_assert!(!self.entries.contains_key(&h), "duplicate registration");
        self.clock += 1;
        self.entries
            .insert(h, Entry { parent, refs: 1, bytes, guard, last_use: self.clock });
        self.stats.registered += 1;
    }

    /// Adopt entry `h` as a new dependent (`refs += 1`); returns `false`
    /// when no such entry exists.  Adoption of a parked entry revives it —
    /// that is the cross-request cache hit.
    pub fn adopt(&mut self, h: u64) -> bool {
        self.clock += 1;
        let clock = self.clock;
        match self.entries.get_mut(&h) {
            Some(e) => {
                e.refs += 1;
                e.last_use = clock;
                self.stats.adoptions += 1;
                true
            }
            None => false,
        }
    }

    /// Retire one dependent of `h`: decrements instead of freeing.  The
    /// entry (and its bytes) stays parked as reusable cache once the last
    /// dependent leaves.
    pub fn release(&mut self, h: u64) {
        if let Some(e) = self.entries.get_mut(&h) {
            debug_assert!(e.refs > 0, "release without a live dependent");
            e.refs = e.refs.saturating_sub(1);
            self.stats.releases += 1;
        }
    }

    /// Copy-on-write divergence: one dependent stops sharing `h` (it took
    /// a private clone under its own reservation).  The shared original
    /// keeps its bytes and its other dependents, bit-identical — the
    /// registry only drops the diverging dependent's ref.
    pub fn privatize(&mut self, h: u64) {
        if let Some(e) = self.entries.get_mut(&h) {
            debug_assert!(e.refs > 0, "privatize without a live dependent");
            e.refs = e.refs.saturating_sub(1);
            self.stats.cow_clones += 1;
        }
    }

    /// Roll back a registration made earlier in a failed admission: the
    /// entry is removed outright and its reservation drops.  Only valid
    /// while the registering admission is the sole dependent and no later
    /// block was chained onto it (rollbacks run child-first).
    pub fn unregister(&mut self, h: u64) {
        if let Some(e) = self.entries.remove(&h) {
            debug_assert!(e.refs <= 1, "unregister with other live dependents");
            debug_assert!(
                !self.entries.values().any(|c| c.parent == Some(h)),
                "unregister would orphan chained children"
            );
            self.stats.registered = self.stats.registered.saturating_sub(1);
        }
    }

    /// Trim parked entries (refs == 0) under capacity pressure until at
    /// least `need_bytes` of reservations have been dropped or nothing
    /// parked remains.  Trimming is LRU-first and **leaf-first**: an
    /// entry is only removable while no other entry chains onto it, so a
    /// match walk never finds a chain with a missing interior link.
    /// Entries with live dependents are never touched.  Returns bytes
    /// freed.
    pub fn trim(&mut self, need_bytes: u64) -> u64 {
        let mut freed = 0u64;
        while freed < need_bytes {
            let parents: std::collections::BTreeSet<u64> =
                self.entries.values().filter_map(|e| e.parent).collect();
            let victim = self
                .entries
                .iter()
                .filter(|(h, e)| e.refs == 0 && !parents.contains(*h))
                .min_by_key(|(_, e)| e.last_use)
                .map(|(&h, _)| h);
            let Some(h) = victim else { break };
            let e = self.entries.remove(&h).expect("victim exists");
            freed += e.bytes; // guard drops here: the tier bytes free
            self.stats.trimmed += 1;
        }
        freed
    }
}

/// What [`KvStore::admit_shared`](super::KvStore::admit_shared) reused:
/// the adopted span (zero bytes, zero transfer) plus how many new chain
/// entries this admission contributed for later requests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SharedAdmit {
    /// Blocks adopted from the registry (the share *hit*).
    pub matched_blocks: usize,
    /// Tokens those blocks cover — the `shared_prefix` span handed to the
    /// planner.
    pub shared_tokens: usize,
    /// New chain entries registered by this admission (the share *fill*).
    pub registered_blocks: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::MemPool;
    use crate::util::prng::{check_property, prop_cases};

    #[test]
    fn chain_hashes_identify_content_and_context() {
        let a = PrefixRegistry::chain(b"aaaabbbbcccc", 4);
        let b = PrefixRegistry::chain(b"aaaabbbbdddd", 4);
        assert_eq!(a.len(), 3);
        assert_eq!(a[0], b[0]);
        assert_eq!(a[1], b[1]);
        assert_ne!(a[2], b[2], "differing block, differing hash");
        // same bytes, different left context: the chain seed differs
        let c = PrefixRegistry::chain(b"xxxxbbbb", 4);
        assert_ne!(a[1], c[1], "content equal but context differs");
        // a partial tail block is never hashed
        assert_eq!(PrefixRegistry::chain(b"aaaab", 4).len(), 1);
        assert!(PrefixRegistry::chain(b"abc", 4).is_empty());
    }

    #[test]
    fn share_key_windows_the_prompt() {
        assert_eq!(share_key(b"same-prefix A", 11), share_key(b"same-prefix B", 11));
        assert_ne!(share_key(b"same-prefix A", 13), share_key(b"same-prefix B", 13));
        // shorter than the window: whole-prompt key, no panic
        assert_eq!(share_key(b"ab", 64), share_key(b"ab", 2));
    }

    #[test]
    fn match_prefix_finds_the_longest_registered_chain() {
        let mut reg = PrefixRegistry::new(4);
        let chain = PrefixRegistry::chain(b"aaaabbbbcccc", 4);
        reg.register(chain[0], None, 100, None);
        reg.register(chain[1], Some(chain[0]), 100, None);
        assert_eq!(reg.match_prefix(b"aaaabbbbcccc"), &chain[..2]);
        assert_eq!(reg.match_prefix(b"aaaabbbbzzzz"), &chain[..2]);
        assert_eq!(reg.match_prefix(b"aaaazzzz").len(), 1);
        assert!(reg.match_prefix(b"zzzzaaaa").is_empty());
    }

    #[test]
    fn parked_entries_survive_as_cache_and_revive_on_adopt() {
        let mut reg = PrefixRegistry::new(4);
        let chain = PrefixRegistry::chain(b"aaaa", 4);
        reg.register(chain[0], None, 100, None);
        reg.release(chain[0]);
        assert_eq!(reg.refs(chain[0]), 0);
        assert_eq!(reg.parked_bytes(), 100);
        // still matchable: the cross-request cache hit
        assert_eq!(reg.match_prefix(b"aaaa").len(), 1);
        assert!(reg.adopt(chain[0]));
        assert_eq!(reg.refs(chain[0]), 1);
        assert_eq!(reg.parked_bytes(), 0);
    }

    #[test]
    fn trim_is_lru_leaf_first_and_never_touches_live_entries() {
        let pool = MemPool::new("cpu-dram", 1000);
        let mut reg = PrefixRegistry::new(4);
        let chain = PrefixRegistry::chain(b"aaaabbbb", 4);
        reg.register(chain[0], None, 100, Some(pool.alloc(100).unwrap()));
        reg.register(chain[1], Some(chain[0]), 100, Some(pool.alloc(100).unwrap()));
        let lone = PrefixRegistry::chain(b"zzzz", 4)[0];
        reg.register(lone, None, 100, Some(pool.alloc(100).unwrap()));
        assert_eq!(pool.used(), 300);

        // chain[1] is live: neither it nor its parent may go
        reg.release(chain[0]); // parent parked, but chained onto
        reg.release(lone); // parked leaf, oldest registration order
        let freed = reg.trim(u64::MAX);
        assert_eq!(freed, 100, "only the parked leaf is trimmable");
        assert!(!reg.contains(lone));
        assert!(reg.contains(chain[0]), "interior link survives while its child lives");
        assert_eq!(pool.used(), 200, "trimmed guard released its bytes");

        // once the child parks too, the chain trims leaf-first
        reg.release(chain[1]);
        assert_eq!(reg.trim(u64::MAX), 200);
        assert!(reg.is_empty());
        assert_eq!(pool.used(), 0);
    }

    /// Refcount soundness under random interleavings of register / adopt /
    /// release / privatize / trim: no entry with live dependents is ever
    /// freed, every reservation is released once all dependents retire,
    /// and copy-on-write divergence leaves the shared original
    /// bit-identical.  `KVPR_PROPTEST_CASES` scales the case count (the
    /// nightly CI job runs 10000).
    #[test]
    fn share_refcount_soundness_property() {
        check_property("share_refcount_soundness", prop_cases(300), |rng| {
            let pool = MemPool::new("cpu-dram", u64::MAX);
            let mut reg = PrefixRegistry::new(4);
            // model state alongside the registry: per-hash expected refs
            // and the block "content" a real store would hold
            let mut model: BTreeMap<u64, (usize, Vec<u8>)> = BTreeMap::new();
            // a small prompt alphabet forces heavy hash collisions-by-design
            // (identical prefixes), exercising adopt/park/revive paths
            let prompts: Vec<Vec<u8>> = (0..4)
                .map(|i| {
                    let base = vec![b'a' + i as u8; 8];
                    [base, vec![b'0' + i as u8; 4]].concat()
                })
                .collect();
            // live sequences: which hashes each currently depends on
            let mut live: Vec<Vec<u64>> = Vec::new();
            for _ in 0..rng.range(10, 60) {
                match rng.index(4) {
                    // admit: adopt the matched chain, register the rest
                    0 => {
                        let p = &prompts[rng.index(prompts.len())];
                        let chain = PrefixRegistry::chain(p, 4);
                        let mut deps = Vec::new();
                        let mut parent = None;
                        for (i, &h) in chain.iter().enumerate() {
                            if reg.adopt(h) {
                                model.get_mut(&h).expect("model tracks registry").0 += 1;
                            } else {
                                let guard = pool.alloc(10).expect("unbounded pool");
                                reg.register(h, parent, 10, Some(guard));
                                model.insert(h, (1, p[i * 4..(i + 1) * 4].to_vec()));
                            }
                            deps.push(h);
                            parent = Some(h);
                        }
                        live.push(deps);
                    }
                    // retire: release every dependency
                    1 if !live.is_empty() => {
                        let deps = live.swap_remove(rng.index(live.len()));
                        for h in deps {
                            reg.release(h);
                            model.get_mut(&h).expect("model tracks registry").0 -= 1;
                        }
                    }
                    // CoW divergence: one sequence privatizes its deepest
                    // shared block; the original must stay bit-identical
                    2 if !live.is_empty() => {
                        let i = rng.index(live.len());
                        if let Some(h) = live[i].pop() {
                            let before = model.get(&h).expect("model tracks registry").1.clone();
                            reg.privatize(h);
                            model.get_mut(&h).expect("model tracks registry").0 -= 1;
                            let mut clone = before.clone();
                            clone[0] ^= 0xff; // the writer mutates its clone...
                            let after = &model.get(&h).expect("model tracks registry").1;
                            if *after != before || clone[0] == before[0] {
                                return Err("CoW mutated the shared original".into());
                            }
                        }
                    }
                    // pressure: trim whatever is parked
                    _ => {
                        reg.trim(rng.range(1, 200));
                        model.retain(|h, _| reg.contains(*h));
                    }
                }
                // invariant: the registry's refs match the model exactly —
                // in particular no entry with live dependents disappeared
                for (h, (refs, _)) in &model {
                    if reg.refs(*h) != *refs {
                        return Err(format!(
                            "refs diverged for {h:#x}: registry {} model {refs}",
                            reg.refs(*h)
                        ));
                    }
                }
                for deps in &live {
                    for h in deps {
                        if !reg.contains(*h) {
                            return Err(format!("entry {h:#x} freed with live dependents"));
                        }
                    }
                }
            }
            // drain: retire everything, then trim — nothing may leak
            for deps in live.drain(..) {
                for h in deps {
                    reg.release(h);
                }
            }
            reg.trim(u64::MAX);
            if !reg.is_empty() {
                return Err(format!("{} entries leaked after all dependents retired", reg.len()));
            }
            if pool.used() != 0 {
                return Err(format!("{} bytes leaked after trim", pool.used()));
            }
            Ok(())
        });
    }
}
