//! The unified asynchronous migration engine.
//!
//! Every byte that crosses a tier boundary — promotion, demotion, prefetch,
//! disk spill — now moves through **one lifecycle**:
//!
//! ```text
//!   queued ──▶ staged ──▶ in-flight ──▶ landed
//!   (dest      (staging    (bytes on     (polled by the store,
//!    reserved)  pinned)     a wire)       guard installed)
//! ```
//!
//! * **Queued** — the destination reservation is held (so capacity
//!   decisions are made at request time, when the store can still evict),
//!   but no staging buffer is pinned and nothing rides a link.
//! * **Staged** — a pinned staging buffer is charged against the pinned
//!   tier; transient: [`MigrationEngine::pump`] stages and launches in one
//!   motion, bounded by the per-step **link-byte budget**.
//! * **In-flight** — the wire bytes ride the [`Link`](crate::transfer::Link)
//!   the hop's endpoints select: the CPU↔GPU interconnect for
//!   gpu↔pinned↔dram traffic, the slower NVMe wire for anything touching
//!   the disk tier ([`Priority::High`] for demand promotions, `Normal` for
//!   everything else, so urgent traffic overtakes speculative traffic).
//! * **Landed** — [`MigrationEngine::poll`] drains finished transfers and
//!   hands the destination guards back to the store, which installs them.
//!
//! Nothing in this module ever blocks on a link.  Even teardown
//! ([`MigrationEngine::finish`], the sequence-release path) just parks an
//! in-flight transfer on a drain list that later polls sweep.  The serving
//! loop only ever calls [`MigrationEngine::pump`] /
//! [`MigrationEngine::poll`] — PR 2's `migrate_sync` (one block's link
//! wait per eviction, on the step loop's critical path) is gone.
//!
//! Class order under the budget: demand promotions launch first, then
//! gpu-eviction writebacks, then prefetch, then **spill**
//! ([`MigrationClass::Spill`], dram→disk).  Spill is strictly
//! leftover-budget traffic: it is never granted the oversized-block
//! progress override the other classes get, so a contended step spends its
//! whole grant on tier traffic the decode path needs before a single spill
//! byte moves.
//!
//! Wire width: migrations charge `wire_elem_bytes` per f32 element on the
//! wire (4.0 plain, 0.625 with int4 wire quantization), while tier
//! reservations always hold the full storage bytes — quantization shrinks
//! traffic, not occupancy.

use std::collections::VecDeque;

use crate::memory::PoolGuard;
use crate::obs::{EventKind, MigPhase, Tracer};
use crate::transfer::{LinkConfig, Priority, TransferHandle};

use super::block::{BlockId, Tier};
use super::manager::{TierManager, TierStats};

/// Identifier of one migration through its whole lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MigrationId(u64);

impl MigrationId {
    #[cfg(test)]
    pub(crate) fn test_id(n: u64) -> MigrationId {
        MigrationId(n)
    }
}

/// Why a migration was requested; decides link priority and pump order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationClass {
    /// Demand promotion: a group needs this block resident for its next
    /// step.  Launched first, rides its wire at high priority.
    Promote,
    /// Eviction writeback out of the gpu tier.  Launched before prefetch —
    /// a stuck demotion pins a lower-tier reservation the store already
    /// committed to.
    Demote,
    /// Speculative promotion issued by the
    /// [`Prefetcher`](super::Prefetcher) ahead of need.
    Prefetch,
    /// Capacity spill, dram→disk.  Launched last and **only within** the
    /// step's remaining budget (no oversized-block progress override):
    /// spill is background capacity maintenance, so it consumes exactly
    /// the link time the step's demand traffic left over.
    Spill,
}

impl MigrationClass {
    /// Stable lowercase label (trace events, tables).
    pub fn name(self) -> &'static str {
        match self {
            MigrationClass::Promote => "promote",
            MigrationClass::Demote => "demote",
            MigrationClass::Prefetch => "prefetch",
            MigrationClass::Spill => "spill",
        }
    }

    fn rank(self) -> u8 {
        match self {
            MigrationClass::Promote => 0,
            MigrationClass::Demote => 1,
            MigrationClass::Prefetch => 2,
            MigrationClass::Spill => 3,
        }
    }

    fn priority(self) -> Priority {
        match self {
            MigrationClass::Promote => Priority::High,
            MigrationClass::Demote | MigrationClass::Prefetch | MigrationClass::Spill => {
                Priority::Normal
            }
        }
    }
}

/// Aggregate lifecycle counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Migrations accepted into the queue (destination reserved).
    pub requested: u64,
    /// Migrations staged + put on a wire.
    pub launched: u64,
    /// Migrations whose transfer completed and was polled.
    pub landed: u64,
    /// Migrations torn down before landing (sequence released).
    pub canceled: u64,
    /// Pump passes that left work queued because the step's link-byte
    /// budget was exhausted.
    pub budget_deferrals: u64,
    /// Wire bytes actually put on the links (post-quantization).
    pub wire_bytes: u64,
    /// Wire bytes that rode the NVMe link (disk-tier hops; a subset of
    /// `wire_bytes`).
    pub nvme_wire_bytes: u64,
}

/// A queued migration: destination reservation held, nothing launched.
struct Queued {
    id: MigrationId,
    block: BlockId,
    from: Tier,
    to: Tier,
    wire_bytes: u64,
    class: MigrationClass,
    dest: PoolGuard,
}

/// An in-flight migration: staging pinned, bytes riding a wire.  Carries
/// its hop/class/bytes tags through to landing so the landed trace event
/// is as fully tagged as the queued one.
struct InFlight {
    id: MigrationId,
    block: BlockId,
    from: Tier,
    to: Tier,
    class: MigrationClass,
    wire_bytes: u64,
    dest: PoolGuard,
    staging: Vec<f32>,
    handle: TransferHandle,
}

/// A completed migration, ready for the store to install.
pub struct Landed {
    pub id: MigrationId,
    pub block: BlockId,
    pub to: Tier,
    /// The destination-tier reservation, held since request time.
    pub guard: PoolGuard,
}

/// One lifecycle for all tier traffic, scheduled against a per-step
/// link-byte budget.  Owns the [`TierManager`] (pools + links + staging).
pub struct MigrationEngine {
    mgr: TierManager,
    queued: VecDeque<Queued>,
    inflight: Vec<InFlight>,
    /// Canceled while in flight: the requester is gone, so the transfer is
    /// drained opportunistically by [`MigrationEngine::poll`] — never
    /// waited on — and its staging buffer / destination reservation are
    /// reclaimed when the bytes stop moving.
    draining: Vec<InFlight>,
    next_id: u64,
    /// Link bytes still grantable this step.
    budget: u64,
    /// Whether anything launched this step (progress guarantee for blocks
    /// larger than the whole budget).
    launched_this_step: bool,
    /// Wire bytes launched under the current step's grant (budget audit).
    step_wire_bytes: u64,
    wire_elem_bytes: f64,
    stats: MigrationStats,
    /// Lifecycle trace sink (the no-op sink unless the serving loop
    /// installs its tracer via [`MigrationEngine::set_tracer`]).
    tracer: Tracer,
}

impl MigrationEngine {
    pub fn new(
        gpu_bytes: u64,
        pinned_bytes: u64,
        dram_bytes: u64,
        disk_bytes: u64,
        link: LinkConfig,
        nvme: LinkConfig,
        wire_elem_bytes: f64,
    ) -> Self {
        Self::with_manager(
            TierManager::new(gpu_bytes, pinned_bytes, dram_bytes, disk_bytes, link, nvme),
            wire_elem_bytes,
        )
    }

    /// An engine over a caller-built [`TierManager`] — the seam sharded
    /// workers use: each shard's manager holds a private gpu pool over
    /// [`SharedHostTiers`](super::SharedHostTiers)-backed host pools.
    pub fn with_manager(mgr: TierManager, wire_elem_bytes: f64) -> Self {
        assert!(wire_elem_bytes > 0.0, "wire_elem_bytes must be positive");
        MigrationEngine {
            mgr,
            queued: VecDeque::new(),
            inflight: Vec::new(),
            draining: Vec::new(),
            next_id: 1,
            budget: 0,
            launched_this_step: false,
            step_wire_bytes: 0,
            wire_elem_bytes,
            stats: MigrationStats::default(),
            tracer: Tracer::disabled(),
        }
    }

    /// Route lifecycle events (queued → staged → in-flight → landed, plus
    /// cancellations) into `tracer`, tagged with tier hop, class and wire
    /// bytes.  The engine starts with the no-op sink.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// The tier pools / links / staging this engine migrates over.
    pub fn tiers(&self) -> &TierManager {
        &self.mgr
    }

    pub fn stats(&self) -> MigrationStats {
        self.stats
    }

    /// The link-traffic lens on the lifecycle counters (migrations put on
    /// the wires and their wire bytes) — derived, never double-booked.
    pub fn tier_stats(&self) -> TierStats {
        TierStats { migrations: self.stats.launched, migrated_bytes: self.stats.wire_bytes }
    }

    /// Bytes `storage_bytes` of f32 storage put on the wire.
    pub fn wire_bytes_of(&self, storage_bytes: u64) -> u64 {
        ((storage_bytes / 4) as f64 * self.wire_elem_bytes).ceil() as u64
    }

    /// Migrations anywhere in the lifecycle (queued or in flight).
    pub fn open_count(&self) -> usize {
        self.queued.len() + self.inflight.len()
    }

    /// Canceled migrations still vacating their reservations (reclaimed by
    /// the next [`MigrationEngine::poll`] once their transfer stops).
    pub fn draining_count(&self) -> usize {
        self.draining.len()
    }

    /// Wire bytes launched under the current step's grant so far.
    pub fn step_launched_wire_bytes(&self) -> u64 {
        self.step_wire_bytes
    }

    /// Request a migration of `block` out of `from` into `to`: reserves the
    /// destination immediately (so the caller's capacity/eviction logic
    /// sees the true tier state) and queues the transfer for a budgeted
    /// launch on the wire the endpoints select.  `None` when the
    /// destination tier is full — the caller evicts and retries.
    pub fn request(
        &mut self,
        block: BlockId,
        from: Tier,
        to: Tier,
        storage_bytes: u64,
        class: MigrationClass,
    ) -> Option<MigrationId> {
        let dest = self.mgr.grab(to, storage_bytes)?;
        let id = MigrationId(self.next_id);
        self.next_id += 1;
        let wire_bytes = self.wire_bytes_of(storage_bytes);
        self.queued.push_back(Queued { id, block, from, to, wire_bytes, class, dest });
        self.stats.requested += 1;
        self.tracer.emit(|| EventKind::Migration {
            id: id.0,
            phase: MigPhase::Queued,
            class: class.name().to_string(),
            from: from.name().to_string(),
            to: to.name().to_string(),
            bytes: wire_bytes,
        });
        Some(id)
    }

    /// Open a new scheduling step with `budget_bytes` of link grant.
    /// Unused budget does not carry over — the budget models "what the
    /// link can absorb alongside this step's decode traffic", which resets
    /// every step.
    pub fn begin_step(&mut self, budget_bytes: u64) {
        self.budget = budget_bytes;
        self.launched_this_step = false;
        self.step_wire_bytes = 0;
    }

    /// Stage + launch queued migrations in class order (demand promotions,
    /// then demotions, then prefetch, then spill; FIFO within a class)
    /// until the step's budget runs out.  A block wider than the whole
    /// budget still launches when it is first in line and nothing launched
    /// yet this step, so oversized blocks cannot wedge the queue — except
    /// a [`MigrationClass::Spill`], which never gets the override: spill
    /// strictly consumes leftover budget.  Returns migrations launched.
    pub fn pump(&mut self) -> usize {
        let mut launched = 0;
        loop {
            let Some(best) = self
                .queued
                .iter()
                .enumerate()
                .min_by_key(|(pos, q)| (q.class.rank(), q.id, *pos))
                .map(|(pos, _)| pos)
            else {
                break;
            };
            let head = &self.queued[best];
            let affordable = self.budget > 0
                && (head.wire_bytes <= self.budget
                    || (!self.launched_this_step && head.class != MigrationClass::Spill));
            if !affordable {
                self.stats.budget_deferrals += 1;
                break;
            }
            let q = self.queued.remove(best).expect("index from enumerate");
            // staged: pin the wire-sized staging buffer...
            let n = (q.wire_bytes.div_ceil(4)) as usize;
            let staging = self.mgr.staging().get(n);
            self.tracer.emit(|| EventKind::Migration {
                id: q.id.0,
                phase: MigPhase::Staged,
                class: q.class.name().to_string(),
                from: q.from.name().to_string(),
                to: q.to.name().to_string(),
                bytes: q.wire_bytes,
            });
            // ...and in-flight: the wire bytes ride the hop's wire
            let handle = self.mgr.link_for(q.from, q.to).submit_timing(n, q.class.priority());
            if q.from.is_disk() || q.to.is_disk() {
                self.stats.nvme_wire_bytes += q.wire_bytes;
            }
            self.budget = self.budget.saturating_sub(q.wire_bytes);
            self.launched_this_step = true;
            self.step_wire_bytes += q.wire_bytes;
            self.stats.launched += 1;
            self.stats.wire_bytes += q.wire_bytes;
            self.tracer.emit(|| EventKind::Migration {
                id: q.id.0,
                phase: MigPhase::InFlight,
                class: q.class.name().to_string(),
                from: q.from.name().to_string(),
                to: q.to.name().to_string(),
                bytes: q.wire_bytes,
            });
            self.inflight.push(InFlight {
                id: q.id,
                block: q.block,
                from: q.from,
                to: q.to,
                class: q.class,
                wire_bytes: q.wire_bytes,
                dest: q.dest,
                staging,
                handle,
            });
            launched += 1;
        }
        launched
    }

    /// Drain every landed migration (non-blocking).  Staging buffers go
    /// back to the pinned pool; destination guards go to the caller.
    /// Canceled in-flight migrations drain here too (resources reclaimed,
    /// nothing returned — their requester is gone).
    pub fn poll(&mut self) -> Vec<Landed> {
        let mut i = 0;
        while i < self.draining.len() {
            if self.draining[i].handle.is_done() {
                let fin = self.draining.swap_remove(i);
                fin.handle.wait(); // already done: returns immediately
                self.mgr.staging().put(fin.staging);
                // fin.dest drops: the destination reservation rolls back
            } else {
                i += 1;
            }
        }
        let mut landed = Vec::new();
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].handle.is_done() {
                let fin = self.inflight.swap_remove(i);
                fin.handle.wait(); // already done: returns immediately
                self.mgr.staging().put(fin.staging);
                self.stats.landed += 1;
                self.tracer.emit(|| EventKind::Migration {
                    id: fin.id.0,
                    phase: MigPhase::Landed,
                    class: fin.class.name().to_string(),
                    from: fin.from.name().to_string(),
                    to: fin.to.name().to_string(),
                    bytes: fin.wire_bytes,
                });
                landed.push(Landed { id: fin.id, block: fin.block, to: fin.to, guard: fin.dest });
            } else {
                i += 1;
            }
        }
        landed
    }

    /// Tear down one migration, whatever its phase — without blocking: a
    /// queued migration is dropped on the spot (destination reservation
    /// released); an in-flight one is parked on the drain list and its
    /// staging buffer / destination reservation are reclaimed by a later
    /// [`MigrationEngine::poll`] once the bytes stop moving.  The
    /// sequence-release path calls this, so retirement never waits on the
    /// link either.
    pub fn finish(&mut self, id: MigrationId) {
        if let Some(pos) = self.queued.iter().position(|q| q.id == id) {
            let q = self.queued.remove(pos).expect("position from iter");
            self.tracer.emit(|| EventKind::Migration {
                id: q.id.0,
                phase: MigPhase::Canceled,
                class: q.class.name().to_string(),
                from: q.from.name().to_string(),
                to: q.to.name().to_string(),
                bytes: q.wire_bytes,
            });
            drop(q);
            self.stats.canceled += 1;
            return;
        }
        if let Some(pos) = self.inflight.iter().position(|f| f.id == id) {
            let f = self.inflight.swap_remove(pos);
            self.tracer.emit(|| EventKind::Migration {
                id: f.id.0,
                phase: MigPhase::Canceled,
                class: f.class.name().to_string(),
                from: f.from.name().to_string(),
                to: f.to.name().to_string(),
                bytes: f.wire_bytes,
            });
            self.draining.push(f);
            self.stats.canceled += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::{check_property, prop_cases};

    const BB: u64 = 4096;

    fn engine(link: LinkConfig) -> MigrationEngine {
        let nvme = LinkConfig::nvme_below(&link);
        MigrationEngine::new(4 * BB, 16 * BB, 16 * BB, 16 * BB, link, nvme, 4.0)
    }

    fn bid(seq: u64, idx: usize) -> BlockId {
        BlockId { seq, idx }
    }

    #[test]
    fn lifecycle_queued_launched_landed() {
        let mut e = engine(LinkConfig::unthrottled());
        let id = e
            .request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
            .expect("gpu has room");
        assert_eq!(e.tiers().pool(Tier::GpuHbm).used(), BB, "destination reserved up front");
        assert_eq!(e.open_count(), 1);
        assert_eq!(e.poll().len(), 0, "nothing launched yet");
        e.begin_step(u64::MAX);
        assert_eq!(e.pump(), 1);
        // unthrottled link lands near-instantly on the worker thread
        let landed = poll_until(&mut e, 1);
        assert_eq!(landed[0].id, id);
        assert_eq!(landed[0].to, Tier::GpuHbm);
        assert_eq!(landed[0].guard.bytes(), BB);
        assert_eq!(e.open_count(), 0);
        let s = e.stats();
        assert_eq!((s.requested, s.launched, s.landed), (1, 1, 1));
        assert_eq!(s.nvme_wire_bytes, 0, "no disk endpoint, no NVMe traffic");
    }

    fn poll_until(e: &mut MigrationEngine, want: usize) -> Vec<Landed> {
        let mut out = Vec::new();
        for _ in 0..500 {
            out.extend(e.poll());
            if out.len() >= want {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        out
    }

    #[test]
    fn request_fails_when_destination_full() {
        let mut e = MigrationEngine::new(
            BB,
            BB,
            BB,
            0,
            LinkConfig::unthrottled(),
            LinkConfig::unthrottled(),
            4.0,
        );
        let _held = e.tiers().grab(Tier::GpuHbm, BB).unwrap();
        assert!(e
            .request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
            .is_none());
        // a zero-capacity disk tier rejects spill requests the same way
        assert!(e
            .request(bid(1, 0), Tier::CpuDram, Tier::DiskNvme, BB, MigrationClass::Spill)
            .is_none());
        assert_eq!(e.stats().requested, 0);
    }

    #[test]
    fn budget_gates_launches_per_step() {
        let mut e = engine(LinkConfig::unthrottled());
        for i in 0..3 {
            e.request(bid(1, i), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
                .unwrap();
        }
        // budget fits exactly one block's wire bytes per step
        e.begin_step(BB);
        assert_eq!(e.pump(), 1, "one launch per budget grant");
        assert_eq!(e.step_launched_wire_bytes(), BB);
        assert_eq!(e.stats().budget_deferrals, 1);
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        assert_eq!(e.stats().launched, 3);
        assert_eq!(poll_until(&mut e, 3).len(), 3);
    }

    #[test]
    fn oversized_block_still_makes_progress() {
        let mut e = engine(LinkConfig::unthrottled());
        e.request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(10); // far below one block's wire bytes
        assert_eq!(e.pump(), 1, "head of line launches even over budget");
        e.request(bid(1, 1), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        assert_eq!(e.pump(), 0, "budget now exhausted for this step");
    }

    #[test]
    fn zero_budget_launches_nothing() {
        let mut e = engine(LinkConfig::unthrottled());
        e.request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(0);
        assert_eq!(e.pump(), 0);
        assert_eq!(e.open_count(), 1);
    }

    #[test]
    fn demand_promotions_launch_before_prefetch() {
        let mut e = engine(LinkConfig::unthrottled());
        let pf = e
            .request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Prefetch)
            .unwrap();
        let pr = e
            .request(bid(2, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
            .unwrap();
        e.begin_step(BB); // budget for one launch
        assert_eq!(e.pump(), 1);
        let landed = poll_until(&mut e, 1);
        assert_eq!(landed[0].id, pr, "demand promotion overtakes older prefetch");
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        assert_eq!(poll_until(&mut e, 1)[0].id, pf);
    }

    #[test]
    fn spill_only_consumes_leftover_budget() {
        let mut e = engine(LinkConfig::unthrottled());
        let sp = e
            .request(bid(1, 0), Tier::CpuDram, Tier::DiskNvme, BB, MigrationClass::Spill)
            .unwrap();
        let pr = e
            .request(bid(2, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
            .unwrap();
        // budget for exactly one block: the promotion takes the whole grant
        // and the older spill defers
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        assert_eq!(poll_until(&mut e, 1)[0].id, pr);
        assert_eq!(e.open_count(), 1, "spill still queued");
        // a 2-block grant leaves leftover for the spill alongside new
        // demand traffic
        e.request(bid(3, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(2 * BB);
        assert_eq!(e.pump(), 2, "promotion + leftover spill");
        let mut landed = poll_until(&mut e, 2);
        landed.sort_by_key(|l| l.id);
        assert!(landed.iter().any(|l| l.id == sp && l.to == Tier::DiskNvme));
        assert!(e.stats().nvme_wire_bytes >= BB, "spill rode the NVMe wire");
    }

    #[test]
    fn spill_never_gets_the_oversize_override() {
        let mut e = engine(LinkConfig::unthrottled());
        e.request(bid(1, 0), Tier::CpuDram, Tier::DiskNvme, BB, MigrationClass::Spill).unwrap();
        // budget below one block: a promotion would ride the progress
        // override here, a spill must not
        e.begin_step(10);
        assert_eq!(e.pump(), 0, "spill must not launch over budget");
        assert_eq!(e.open_count(), 1);
        assert!(e.stats().budget_deferrals >= 1);
        // with a full grant it launches normally
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        assert_eq!(poll_until(&mut e, 1).len(), 1);
    }

    #[test]
    fn wire_quant_shrinks_link_bytes_not_reservations() {
        let mut e = MigrationEngine::new(
            4 * BB,
            16 * BB,
            16 * BB,
            16 * BB,
            LinkConfig::unthrottled(),
            LinkConfig::unthrottled(),
            0.625, // int4 wire
        );
        e.request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        assert_eq!(e.tiers().pool(Tier::GpuHbm).used(), BB, "occupancy stays full-width");
        e.begin_step(u64::MAX);
        e.pump();
        poll_until(&mut e, 1);
        let wire = e.wire_bytes_of(BB);
        assert_eq!(wire, BB / 4 * 5 / 8, "0.625 B per f32 element");
        assert_eq!(e.stats().wire_bytes, wire);
        assert_eq!(e.tiers().link().stats().total_bytes(), wire.div_ceil(4) * 4);
    }

    #[test]
    fn finish_tears_down_any_phase_without_blocking() {
        let mut e = engine(LinkConfig::unthrottled());
        let a = e
            .request(bid(1, 0), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
            .unwrap();
        let b = e
            .request(bid(1, 1), Tier::CpuDram, Tier::GpuHbm, BB, MigrationClass::Promote)
            .unwrap();
        e.begin_step(BB);
        e.pump(); // a launches, b stays queued
        e.finish(a); // in flight: parked on the drain list, no wait
        e.finish(b); // queued: reservation released on the spot
        assert_eq!(e.open_count(), 0);
        assert_eq!(e.stats().canceled, 2);
        // a's destination reservation drains via poll once the transfer
        // stops moving — never via a blocking wait
        for _ in 0..500 {
            let drained = e.poll();
            assert!(drained.is_empty(), "canceled migrations must not be handed out");
            if e.tiers().pool(Tier::GpuHbm).used() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(e.tiers().pool(Tier::GpuHbm).used(), 0, "both reservations released");
    }

    /// One queued entry as the oracle sees it.
    #[derive(Clone, Copy)]
    struct OracleEntry {
        id: u64,
        rank: u8,
        wire: u64,
        spill: bool,
    }

    /// Mirror of [`MigrationEngine::pump`]'s launch rule: returns the
    /// launched entries' wire bytes, removing them from `queue`.
    fn oracle_pump(queue: &mut Vec<OracleEntry>, mut budget: u64) -> Vec<u64> {
        let mut launched = Vec::new();
        loop {
            let Some(pos) = queue
                .iter()
                .enumerate()
                .min_by_key(|(_, q)| (q.rank, q.id))
                .map(|(pos, _)| pos)
            else {
                break;
            };
            let q = queue[pos];
            let affordable =
                budget > 0 && (q.wire <= budget || (launched.is_empty() && !q.spill));
            if !affordable {
                break;
            }
            queue.remove(pos);
            budget = budget.saturating_sub(q.wire);
            launched.push(q.wire);
        }
        launched
    }

    /// Satellite acceptance: with promotions, demotions and spill all in
    /// flight, the budgeted pump (a) always makes progress when demand
    /// traffic is queued and any budget is granted, (b) never exceeds the
    /// step's link-byte grant except through the single oversized-block
    /// override — which spill traffic is never given.  Pinned against an
    /// independent re-implementation of the launch rule across randomized
    /// request mixes, sizes and per-step grants.  `KVPR_PROPTEST_CASES`
    /// scales the case count (the nightly extended CI job runs it high).
    #[test]
    fn budgeted_pump_matches_oracle_across_three_classes() {
        let cases = prop_cases(150);
        check_property("pump budget/progress with spill contention", cases, |rng| {
            let cap = 1u64 << 30;
            let mut e = MigrationEngine::new(
                cap,
                cap,
                cap,
                cap,
                LinkConfig::unthrottled(),
                LinkConfig::unthrottled(),
                4.0,
            );
            let mut oracle: Vec<OracleEntry> = Vec::new();
            let mut seq = 0u64;
            for round in 0..30 {
                // enqueue a random mix; storage bytes are multiples of 4 so
                // wire bytes == storage bytes at width 4.0
                for _ in 0..rng.index(4) {
                    seq += 1;
                    let bytes = (1 + rng.index(64)) as u64 * 4;
                    let (from, to, class) = match rng.index(3) {
                        0 => (Tier::CpuDram, Tier::GpuHbm, MigrationClass::Promote),
                        1 => (Tier::GpuHbm, Tier::Pinned, MigrationClass::Demote),
                        _ => (Tier::CpuDram, Tier::DiskNvme, MigrationClass::Spill),
                    };
                    e.request(BlockId { seq, idx: 0 }, from, to, bytes, class)
                        .expect("ample tiers");
                    oracle.push(OracleEntry {
                        id: seq, // ids are assigned in request order
                        rank: class.rank(),
                        wire: bytes,
                        spill: class == MigrationClass::Spill,
                    });
                }
                let budget = rng.index(600) as u64;
                let had_demand = oracle.iter().any(|q| !q.spill);
                e.begin_step(budget);
                let launched = e.pump();
                let expect = oracle_pump(&mut oracle, budget);
                if launched != expect.len() {
                    return Err(format!(
                        "round {round}: engine launched {launched}, oracle {} (budget {budget})",
                        expect.len()
                    ));
                }
                let bytes = e.step_launched_wire_bytes();
                if bytes != expect.iter().sum::<u64>() {
                    return Err(format!(
                        "round {round}: step bytes {bytes} != oracle {}",
                        expect.iter().sum::<u64>()
                    ));
                }
                // progress guarantee: demand traffic + any grant → a launch
                if had_demand && budget > 0 && launched == 0 {
                    return Err(format!("round {round}: no progress under budget {budget}"));
                }
                // budget audit: the grant can only be exceeded by a single
                // oversized first launch (the progress override) — once it
                // fires the remaining budget saturates to zero, so nothing
                // else may have launched that step
                if bytes > budget && expect.len() != 1 {
                    return Err(format!(
                        "round {round}: {} launches exceeded the grant together \
                         (bytes {bytes}, budget {budget})",
                        expect.len()
                    ));
                }
                // recycle staging/occupancy now and then, like the serving loop
                if rng.index(3) == 0 {
                    let _ = e.poll();
                }
            }
            // everything queued must drain under ample grants (progress)
            for _ in 0..200 {
                if e.queued.is_empty() {
                    break;
                }
                e.begin_step(u64::MAX);
                e.pump();
            }
            if !e.queued.is_empty() {
                return Err("queue failed to drain under ample budget".into());
            }
            Ok(())
        });
    }
}
