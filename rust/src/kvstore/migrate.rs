//! The unified asynchronous migration engine.
//!
//! Every byte that crosses a tier boundary — promotion, demotion, prefetch
//! — now moves through **one lifecycle**:
//!
//! ```text
//!   queued ──▶ staged ──▶ in-flight ──▶ landed
//!   (dest      (staging    (bytes on     (polled by the store,
//!    reserved)  pinned)     the link)      guard installed)
//! ```
//!
//! * **Queued** — the destination reservation is held (so capacity
//!   decisions are made at request time, when the store can still evict),
//!   but no staging buffer is pinned and nothing rides the link.
//! * **Staged** — a pinned staging buffer is charged against the pinned
//!   tier; transient: [`MigrationEngine::pump`] stages and launches in one
//!   motion, bounded by the per-step **link-byte budget**.
//! * **In-flight** — the wire bytes ride the [`Link`](crate::transfer::Link)
//!   ([`Priority::High`] for demand promotions, `Normal` for prefetch and
//!   demotions, so urgent traffic overtakes speculative traffic).
//! * **Landed** — [`MigrationEngine::poll`] drains finished transfers and
//!   hands the destination guards back to the store, which installs them.
//!
//! Nothing in this module ever blocks on the link.  Even teardown
//! ([`MigrationEngine::finish`], the sequence-release path) just parks an
//! in-flight transfer on a drain list that later polls sweep.  The serving
//! loop only ever calls [`MigrationEngine::pump`] /
//! [`MigrationEngine::poll`] — PR 2's `migrate_sync` (one block's link
//! wait per eviction, on the step loop's critical path) is gone.
//!
//! Wire width: migrations charge `wire_elem_bytes` per f32 element on the
//! link (4.0 plain, 0.625 with int4 wire quantization), while tier
//! reservations always hold the full storage bytes — quantization shrinks
//! traffic, not occupancy.

use std::collections::VecDeque;

use crate::memory::PoolGuard;
use crate::transfer::{LinkConfig, Priority, TransferHandle};

use super::block::{BlockId, Tier};
use super::manager::{TierManager, TierStats};

/// Identifier of one migration through its whole lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct MigrationId(u64);

impl MigrationId {
    #[cfg(test)]
    pub(crate) fn test_id(n: u64) -> MigrationId {
        MigrationId(n)
    }
}

/// Why a migration was requested; decides link priority and pump order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationClass {
    /// Demand promotion: a group needs this block resident for its next
    /// step.  Launched first, rides the link at high priority.
    Promote,
    /// Eviction writeback.  Launched before prefetch — a stuck demotion
    /// pins a lower-tier reservation the store already committed to.
    Demote,
    /// Speculative promotion issued by the
    /// [`Prefetcher`](super::Prefetcher) ahead of need.  Launched last.
    Prefetch,
}

impl MigrationClass {
    fn rank(self) -> u8 {
        match self {
            MigrationClass::Promote => 0,
            MigrationClass::Demote => 1,
            MigrationClass::Prefetch => 2,
        }
    }

    fn priority(self) -> Priority {
        match self {
            MigrationClass::Promote => Priority::High,
            MigrationClass::Demote | MigrationClass::Prefetch => Priority::Normal,
        }
    }
}

/// Aggregate lifecycle counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationStats {
    /// Migrations accepted into the queue (destination reserved).
    pub requested: u64,
    /// Migrations staged + put on the link.
    pub launched: u64,
    /// Migrations whose transfer completed and was polled.
    pub landed: u64,
    /// Migrations torn down before landing (sequence released).
    pub canceled: u64,
    /// Pump passes that left work queued because the step's link-byte
    /// budget was exhausted.
    pub budget_deferrals: u64,
    /// Wire bytes actually put on the link (post-quantization).
    pub wire_bytes: u64,
}

/// A queued migration: destination reservation held, nothing launched.
struct Queued {
    id: MigrationId,
    block: BlockId,
    to: Tier,
    wire_bytes: u64,
    class: MigrationClass,
    dest: PoolGuard,
}

/// An in-flight migration: staging pinned, bytes riding the link.
struct InFlight {
    id: MigrationId,
    block: BlockId,
    to: Tier,
    dest: PoolGuard,
    staging: Vec<f32>,
    handle: TransferHandle,
}

/// A completed migration, ready for the store to install.
pub struct Landed {
    pub id: MigrationId,
    pub block: BlockId,
    pub to: Tier,
    /// The destination-tier reservation, held since request time.
    pub guard: PoolGuard,
}

/// One lifecycle for all tier traffic, scheduled against a per-step
/// link-byte budget.  Owns the [`TierManager`] (pools + link + staging).
pub struct MigrationEngine {
    mgr: TierManager,
    queued: VecDeque<Queued>,
    inflight: Vec<InFlight>,
    /// Canceled while in flight: the requester is gone, so the transfer is
    /// drained opportunistically by [`MigrationEngine::poll`] — never
    /// waited on — and its staging buffer / destination reservation are
    /// reclaimed when the bytes stop moving.
    draining: Vec<InFlight>,
    next_id: u64,
    /// Link bytes still grantable this step.
    budget: u64,
    /// Whether anything launched this step (progress guarantee for blocks
    /// larger than the whole budget).
    launched_this_step: bool,
    wire_elem_bytes: f64,
    stats: MigrationStats,
}

impl MigrationEngine {
    pub fn new(
        gpu_bytes: u64,
        pinned_bytes: u64,
        dram_bytes: u64,
        link: LinkConfig,
        wire_elem_bytes: f64,
    ) -> Self {
        assert!(wire_elem_bytes > 0.0, "wire_elem_bytes must be positive");
        MigrationEngine {
            mgr: TierManager::new(gpu_bytes, pinned_bytes, dram_bytes, link),
            queued: VecDeque::new(),
            inflight: Vec::new(),
            draining: Vec::new(),
            next_id: 1,
            budget: 0,
            launched_this_step: false,
            wire_elem_bytes,
            stats: MigrationStats::default(),
        }
    }

    /// The tier pools / link / staging this engine migrates over.
    pub fn tiers(&self) -> &TierManager {
        &self.mgr
    }

    pub fn stats(&self) -> MigrationStats {
        self.stats
    }

    /// The link-traffic lens on the lifecycle counters (migrations put on
    /// the link and their wire bytes) — derived, never double-booked.
    pub fn tier_stats(&self) -> TierStats {
        TierStats { migrations: self.stats.launched, migrated_bytes: self.stats.wire_bytes }
    }

    /// Bytes `storage_bytes` of f32 storage put on the wire.
    pub fn wire_bytes_of(&self, storage_bytes: u64) -> u64 {
        ((storage_bytes / 4) as f64 * self.wire_elem_bytes).ceil() as u64
    }

    /// Migrations anywhere in the lifecycle (queued or in flight).
    pub fn open_count(&self) -> usize {
        self.queued.len() + self.inflight.len()
    }

    /// Canceled migrations still vacating their reservations (reclaimed by
    /// the next [`MigrationEngine::poll`] once their transfer stops).
    pub fn draining_count(&self) -> usize {
        self.draining.len()
    }

    /// Request a migration of `block` into `to`: reserves the destination
    /// immediately (so the caller's capacity/eviction logic sees the true
    /// tier state) and queues the transfer for a budgeted launch.  `None`
    /// when the destination tier is full — the caller evicts and retries.
    pub fn request(
        &mut self,
        block: BlockId,
        to: Tier,
        storage_bytes: u64,
        class: MigrationClass,
    ) -> Option<MigrationId> {
        let dest = self.mgr.grab(to, storage_bytes)?;
        let id = MigrationId(self.next_id);
        self.next_id += 1;
        self.queued.push_back(Queued {
            id,
            block,
            to,
            wire_bytes: self.wire_bytes_of(storage_bytes),
            class,
            dest,
        });
        self.stats.requested += 1;
        Some(id)
    }

    /// Open a new scheduling step with `budget_bytes` of link grant.
    /// Unused budget does not carry over — the budget models "what the
    /// link can absorb alongside this step's decode traffic", which resets
    /// every step.
    pub fn begin_step(&mut self, budget_bytes: u64) {
        self.budget = budget_bytes;
        self.launched_this_step = false;
    }

    /// Stage + launch queued migrations in class order (demand promotions,
    /// then demotions, then prefetch; FIFO within a class) until the
    /// step's budget runs out.  A block wider than the whole budget still
    /// launches when it is first in line and nothing launched yet this
    /// step, so oversized blocks cannot wedge the queue.  Returns
    /// migrations launched.
    pub fn pump(&mut self) -> usize {
        let mut launched = 0;
        loop {
            let Some(best) = self
                .queued
                .iter()
                .enumerate()
                .min_by_key(|(pos, q)| (q.class.rank(), q.id, *pos))
                .map(|(pos, _)| pos)
            else {
                break;
            };
            let affordable = self.budget > 0
                && (self.queued[best].wire_bytes <= self.budget || !self.launched_this_step);
            if !affordable {
                self.stats.budget_deferrals += 1;
                break;
            }
            let q = self.queued.remove(best).expect("index from enumerate");
            // staged: pin the wire-sized staging buffer...
            let n = (q.wire_bytes.div_ceil(4)) as usize;
            let staging = self.mgr.staging().get(n);
            // ...and in-flight: the wire bytes ride the link
            let handle = self.mgr.link().submit_timing(n, q.class.priority());
            self.budget = self.budget.saturating_sub(q.wire_bytes);
            self.launched_this_step = true;
            self.stats.launched += 1;
            self.stats.wire_bytes += q.wire_bytes;
            self.inflight.push(InFlight {
                id: q.id,
                block: q.block,
                to: q.to,
                dest: q.dest,
                staging,
                handle,
            });
            launched += 1;
        }
        launched
    }

    /// Drain every landed migration (non-blocking).  Staging buffers go
    /// back to the pinned pool; destination guards go to the caller.
    /// Canceled in-flight migrations drain here too (resources reclaimed,
    /// nothing returned — their requester is gone).
    pub fn poll(&mut self) -> Vec<Landed> {
        let mut i = 0;
        while i < self.draining.len() {
            if self.draining[i].handle.is_done() {
                let fin = self.draining.swap_remove(i);
                fin.handle.wait(); // already done: returns immediately
                self.mgr.staging().put(fin.staging);
                // fin.dest drops: the destination reservation rolls back
            } else {
                i += 1;
            }
        }
        let mut landed = Vec::new();
        let mut i = 0;
        while i < self.inflight.len() {
            if self.inflight[i].handle.is_done() {
                let fin = self.inflight.swap_remove(i);
                fin.handle.wait(); // already done: returns immediately
                self.mgr.staging().put(fin.staging);
                self.stats.landed += 1;
                landed.push(Landed { id: fin.id, block: fin.block, to: fin.to, guard: fin.dest });
            } else {
                i += 1;
            }
        }
        landed
    }

    /// Tear down one migration, whatever its phase — without blocking: a
    /// queued migration is dropped on the spot (destination reservation
    /// released); an in-flight one is parked on the drain list and its
    /// staging buffer / destination reservation are reclaimed by a later
    /// [`MigrationEngine::poll`] once the bytes stop moving.  The
    /// sequence-release path calls this, so retirement never waits on the
    /// link either.
    pub fn finish(&mut self, id: MigrationId) {
        if let Some(pos) = self.queued.iter().position(|q| q.id == id) {
            drop(self.queued.remove(pos));
            self.stats.canceled += 1;
            return;
        }
        if let Some(pos) = self.inflight.iter().position(|f| f.id == id) {
            self.draining.push(self.inflight.swap_remove(pos));
            self.stats.canceled += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BB: u64 = 4096;

    fn engine(link: LinkConfig) -> MigrationEngine {
        MigrationEngine::new(4 * BB, 16 * BB, 16 * BB, link, 4.0)
    }

    fn bid(seq: u64, idx: usize) -> BlockId {
        BlockId { seq, idx }
    }

    #[test]
    fn lifecycle_queued_launched_landed() {
        let mut e = engine(LinkConfig::unthrottled());
        let id = e
            .request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Promote)
            .expect("gpu has room");
        assert_eq!(e.tiers().pool(Tier::GpuHbm).used(), BB, "destination reserved up front");
        assert_eq!(e.open_count(), 1);
        assert_eq!(e.poll().len(), 0, "nothing launched yet");
        e.begin_step(u64::MAX);
        assert_eq!(e.pump(), 1);
        // unthrottled link lands near-instantly on the worker thread
        let landed = poll_until(&mut e, 1);
        assert_eq!(landed[0].id, id);
        assert_eq!(landed[0].to, Tier::GpuHbm);
        assert_eq!(landed[0].guard.bytes(), BB);
        assert_eq!(e.open_count(), 0);
        let s = e.stats();
        assert_eq!((s.requested, s.launched, s.landed), (1, 1, 1));
    }

    fn poll_until(e: &mut MigrationEngine, want: usize) -> Vec<Landed> {
        let mut out = Vec::new();
        for _ in 0..500 {
            out.extend(e.poll());
            if out.len() >= want {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        out
    }

    #[test]
    fn request_fails_when_destination_full() {
        let mut e = MigrationEngine::new(BB, BB, BB, LinkConfig::unthrottled(), 4.0);
        let _held = e.tiers().grab(Tier::GpuHbm, BB).unwrap();
        assert!(e.request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Promote).is_none());
        assert_eq!(e.stats().requested, 0);
    }

    #[test]
    fn budget_gates_launches_per_step() {
        let mut e = engine(LinkConfig::unthrottled());
        for i in 0..3 {
            e.request(bid(1, i), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        }
        // budget fits exactly one block's wire bytes per step
        e.begin_step(BB);
        assert_eq!(e.pump(), 1, "one launch per budget grant");
        assert_eq!(e.stats().budget_deferrals, 1);
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        assert_eq!(e.stats().launched, 3);
        assert_eq!(poll_until(&mut e, 3).len(), 3);
    }

    #[test]
    fn oversized_block_still_makes_progress() {
        let mut e = engine(LinkConfig::unthrottled());
        e.request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(10); // far below one block's wire bytes
        assert_eq!(e.pump(), 1, "head of line launches even over budget");
        e.request(bid(1, 1), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        assert_eq!(e.pump(), 0, "budget now exhausted for this step");
    }

    #[test]
    fn zero_budget_launches_nothing() {
        let mut e = engine(LinkConfig::unthrottled());
        e.request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(0);
        assert_eq!(e.pump(), 0);
        assert_eq!(e.open_count(), 1);
    }

    #[test]
    fn demand_promotions_launch_before_prefetch() {
        let mut e = engine(LinkConfig::unthrottled());
        let pf = e.request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Prefetch).unwrap();
        let pr = e.request(bid(2, 0), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(BB); // budget for one launch
        assert_eq!(e.pump(), 1);
        let landed = poll_until(&mut e, 1);
        assert_eq!(landed[0].id, pr, "demand promotion overtakes older prefetch");
        e.begin_step(BB);
        assert_eq!(e.pump(), 1);
        assert_eq!(poll_until(&mut e, 1)[0].id, pf);
    }

    #[test]
    fn wire_quant_shrinks_link_bytes_not_reservations() {
        let mut e = MigrationEngine::new(
            4 * BB,
            16 * BB,
            16 * BB,
            LinkConfig::unthrottled(),
            0.625, // int4 wire
        );
        e.request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        assert_eq!(e.tiers().pool(Tier::GpuHbm).used(), BB, "occupancy stays full-width");
        e.begin_step(u64::MAX);
        e.pump();
        poll_until(&mut e, 1);
        let wire = e.wire_bytes_of(BB);
        assert_eq!(wire, BB / 4 * 5 / 8, "0.625 B per f32 element");
        assert_eq!(e.stats().wire_bytes, wire);
        assert_eq!(e.tiers().link().stats().total_bytes(), wire.div_ceil(4) * 4);
    }

    #[test]
    fn finish_tears_down_any_phase_without_blocking() {
        let mut e = engine(LinkConfig::unthrottled());
        let a = e.request(bid(1, 0), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        let b = e.request(bid(1, 1), Tier::GpuHbm, BB, MigrationClass::Promote).unwrap();
        e.begin_step(BB);
        e.pump(); // a launches, b stays queued
        e.finish(a); // in flight: parked on the drain list, no wait
        e.finish(b); // queued: reservation released on the spot
        assert_eq!(e.open_count(), 0);
        assert_eq!(e.stats().canceled, 2);
        // a's destination reservation drains via poll once the transfer
        // stops moving — never via a blocking wait
        for _ in 0..500 {
            let drained = e.poll();
            assert!(drained.is_empty(), "canceled migrations must not be handed out");
            if e.tiers().pool(Tier::GpuHbm).used() == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(e.tiers().pool(Tier::GpuHbm).used(), 0, "both reservations released");
    }
}
