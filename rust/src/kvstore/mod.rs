//! Tiered, block-granular KV store with one asynchronous migration
//! lifecycle for all tier traffic.
//!
//! PR 1's serving loop budgeted KV as one flat per-batch reservation; PR 2
//! turned that into a managed three-tier store; PR 3 made every tier
//! crossing asynchronous.  This revision extends the hierarchy one level
//! down — the KV-cache management survey's full production layout — with
//! an **NVMe disk tier** below cpu-dram, while keeping KVPR's core claim
//! intact: *the GPU never idles waiting on any wire*.  All traffic moves
//! through a single engine with one lifecycle:
//!
//! ```text
//!   queued ──▶ staged ──▶ in-flight ──▶ landed
//! ```
//!
//! * [`BlockPool`] / [`Tier`] — fixed-size token blocks, one byte-accounted
//!   reservation each, across the gpu-hbm ⊃ pinned ⊃ cpu-dram ⊃ disk-nvme
//!   tier *chain* ([`crate::memory::MemPool`] underneath).
//! * [`TierManager`] — the resource layer: tier pools, the two migration
//!   wires — the CPU↔GPU [`Link`](crate::transfer::Link) and a slower,
//!   higher-latency NVMe link for disk-tier hops — and the pinned-accounted
//!   [`PinnedPool`](crate::transfer::PinnedPool) staging freelist.
//! * [`MigrationEngine`] — the scheduler: every migration reserves its
//!   destination at request time, then waits in the queue until the
//!   serving loop grants a per-step **link-byte budget**; launches ride
//!   their wire in class order ([`MigrationClass`]: demand promotions,
//!   then gpu-eviction demotions, then prefetch, then dram→disk spill —
//!   which only ever consumes leftover budget) and completions are
//!   *polled*, never waited for, on the serving path.
//! * [`KvStore`] — placement, residency and reclamation: resident gpu
//!   blocks form a *suffix* of each sequence's tokens (the newest KV), so
//!   they shrink the per-step H2D transfer term the planner sees (the
//!   `resident` input of one
//!   [`PlanInput`](crate::scheduler::PlanInput) per group, consumed by
//!   [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch)).
//!   Evictions issue **asynchronous demotions**: the victim's gpu bytes
//!   free at issuance and the writeback lands later, so a full gpu tier
//!   never stalls the step loop; a victim then sits out a configurable
//!   cool-down before re-promotion (anti-thrash hysteresis).  A
//!   **capacity-aware spill** check demotes cold dram blocks to disk
//!   before admission pressure becomes backpressure, and promoting a
//!   disk-resident block back is a **two-hop** (disk→dram→gpu) migration
//!   the store stages across steps.  Admission that still cannot place a
//!   block parks it on the disk tier directly, and as the last resort
//!   drops prefix KV while keeping the X activations, trading stored
//!   bytes for recompute work.  The suffix invariant itself lives in one
//!   place — the `suffix` module's `SuffixRuns` iterator — which every
//!   placement walk shares.
//! * [`Prefetcher`] — bounded-depth speculative promotion of a group's
//!   blocks ahead of its decode step, as [`MigrationClass::Prefetch`]
//!   traffic through the same engine (including disk→dram hop warming).
//! * [`EvictPolicy`] — pluggable victim selection with three lenses:
//!   in-place reclamation (refill only), gpu demotion (refill + writeback
//!   at the wire width) and disk spill (NVMe writeback + two-hop reload);
//!   [`Lru`] recency vs the [`RecomputeAware`] scores driven by the
//!   profiler's [`CostModel`](crate::scheduler::CostModel).  Under int4
//!   wire quantization the migration traffic and every scoring lens use
//!   the quantized element width.
//! * [`PrefixRegistry`] — cross-request prefix sharing: content-hashed,
//!   ref-counted chain entries over full prompt blocks.  Admission adopts
//!   a new request's longest shared prefix in place at zero new bytes and
//!   zero transfer ([`KvStore::admit_shared`]); retirement decrements
//!   instead of freeing; a diverging writer takes a copy-on-write private
//!   clone while the shared original keeps its other dependents.
//! * [`sim`] — deterministic analytic comparison of eviction strategies on
//!   skewed reuse workloads (`simulate_eviction`), including the async
//!   demotion cost of a budgeted gpu tier and the four-tier spill model
//!   (disk capacity, NVMe read-through), feeding `BENCH_kvstore.json`.
//!
//! The serving integration lives in
//! [`ContinuousServer`](crate::coordinator::ContinuousServer): the tier
//! layout itself arrives as a declarative
//! [`TierTopology`](crate::scheduler::TierTopology)
//! ([`KvStoreConfig::from_topology`]), admission goes through
//! [`KvStore::admit`] instead of hard backpressure; each step the loop
//! *polls* landed migrations, mirrors placement into the engine's
//! device-resident suffix
//! ([`Engine::sync_residency`](crate::engine::Engine::sync_residency)),
//! queues prefetch, and grants the step's link-byte budget via
//! [`KvStore::pump_migrations`] — sized adaptively from the planner's
//! predicted idle-link slack
//! ([`StepPlan::link_slack_bytes`](crate::scheduler::StepPlan::link_slack_bytes)).

pub mod block;
pub mod manager;
pub mod migrate;
pub mod policy;
pub mod prefetch;
pub mod share;
pub mod sim;
pub mod store;
mod suffix;

pub use block::{BlockId, BlockPool, Tier};
pub use manager::{SharedHostTiers, TierManager, TierStats};
pub use migrate::{MigrationClass, MigrationEngine, MigrationId, MigrationStats};
pub use policy::{BlockView, EvictKind, EvictPolicy, Lru, RecomputeAware};
pub use prefetch::{PrefetchStats, Prefetcher};
pub use share::{share_key, PrefixRegistry, ShareStats, SharedAdmit};
pub use sim::{simulate_eviction, EvictionSimConfig, EvictionSimReport, SimSeq};
pub use store::{KvStore, KvStoreConfig, StoreStats};
