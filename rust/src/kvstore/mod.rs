//! Tiered, block-granular KV store with recompute-aware eviction and
//! asynchronous prefetch.
//!
//! PR 1's serving loop budgeted KV as one flat per-batch reservation: a
//! session either fit the host budget or queued.  This subsystem turns
//! that single counter into a managed, three-tier store — the production
//! layout the KV-cache management literature describes — and generalises
//! KVPR's Eq. (11) from "how to fetch the cache this step" into "what to
//! keep resident at all":
//!
//! * [`BlockPool`] / [`Tier`] — fixed-size token blocks, one byte-accounted
//!   reservation each, across gpu-hbm / pinned / cpu-dram pools
//!   ([`crate::memory::MemPool`] underneath).
//! * [`TierManager`] — migrates blocks between tiers over a
//!   [`Link`](crate::transfer::Link), staging through the pinned-accounted
//!   [`PinnedPool`](crate::transfer::PinnedPool).
//! * [`KvStore`] — placement, residency and reclamation: resident gpu
//!   blocks form a *suffix* of each sequence's tokens (the newest KV), so
//!   they shrink the per-step H2D transfer term the planner sees
//!   ([`Planner::plan_batch_tiered`](crate::scheduler::Planner::plan_batch_tiered));
//!   admission that would backpressure may instead drop prefix KV and keep
//!   the X activations, trading stored bytes for recompute work.
//! * [`Prefetcher`] — bounded-depth asynchronous promotion of a group's
//!   blocks ahead of its decode step.
//! * [`EvictPolicy`] — pluggable victim selection: [`Lru`] recency vs the
//!   [`RecomputeAware`] refill-cost score driven by the profiler's
//!   [`CostModel`](crate::scheduler::CostModel).
//! * [`sim`] — deterministic analytic comparison of eviction strategies on
//!   skewed reuse workloads (`simulate_eviction`), feeding
//!   `BENCH_kvstore.json`.
//!
//! The serving integration lives in
//! [`ContinuousServer`](crate::coordinator::ContinuousServer): admission
//! goes through [`KvStore::admit`] instead of hard backpressure, the
//! prefetcher runs every event-loop step, and the engine mirrors the gpu
//! tier as a device-resident KV suffix
//! ([`Engine::set_resident_target`](crate::engine::Engine::set_resident_target)).

pub mod block;
pub mod manager;
pub mod policy;
pub mod prefetch;
pub mod sim;
pub mod store;

pub use block::{BlockId, BlockPool, Tier};
pub use manager::{PendingMigration, TierManager, TierStats};
pub use policy::{BlockView, EvictKind, EvictPolicy, Lru, RecomputeAware};
pub use prefetch::{PrefetchStats, Prefetcher};
pub use sim::{simulate_eviction, EvictionSimConfig, EvictionSimReport, SimSeq};
pub use store::{KvStore, KvStoreConfig, StoreStats};
