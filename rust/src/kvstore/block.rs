//! Tiers and the block-granular pool layer.
//!
//! A **block** is the unit of placement: `block_tokens` consecutive token
//! rows of one sequence's K/V (+X) across *all* layers.  Each block holds
//! exactly one [`PoolGuard`] in the [`MemPool`] of the tier it currently
//! lives in, so tier occupancy is byte-accounted with the same machinery
//! (and the same capacity enforcement) the engine uses for device memory.

use crate::memory::{MemPool, PoolGuard};

/// Storage tier of one KV block, fastest first — the full production
/// hierarchy the KV-cache management survey describes: GPU HBM over pinned
/// host memory over pageable CPU DRAM over NVMe storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Tier {
    GpuHbm,
    Pinned,
    CpuDram,
    DiskNvme,
}

impl Tier {
    /// Pool name, matching the [`MemPool`] naming convention used elsewhere.
    pub fn name(&self) -> &'static str {
        match self {
            Tier::GpuHbm => "gpu-hbm",
            Tier::Pinned => "pinned",
            Tier::CpuDram => "cpu-dram",
            Tier::DiskNvme => "disk-nvme",
        }
    }

    /// The next tier down (demotion target); `None` from the bottom.
    pub fn lower(&self) -> Option<Tier> {
        match self {
            Tier::GpuHbm => Some(Tier::Pinned),
            Tier::Pinned => Some(Tier::CpuDram),
            Tier::CpuDram => Some(Tier::DiskNvme),
            Tier::DiskNvme => None,
        }
    }

    /// Whether a migration touching this tier rides the NVMe link rather
    /// than the CPU↔GPU interconnect.
    pub fn is_disk(&self) -> bool {
        matches!(self, Tier::DiskNvme)
    }

    /// All tiers, fastest first.
    pub const ALL: [Tier; 4] = [Tier::GpuHbm, Tier::Pinned, Tier::CpuDram, Tier::DiskNvme];
}

/// Identifier of a block: the owning sequence plus its index within the
/// sequence's block list (block `idx` covers tokens
/// `[idx * block_tokens, (idx + 1) * block_tokens)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BlockId {
    pub seq: u64,
    pub idx: usize,
}

/// A block-granular allocator over one tier's byte pool.  Thin by design:
/// capacity enforcement, peak tracking and RAII release all come from
/// [`MemPool`]; this layer only adds the tier identity and the
/// grab-as-`Option` idiom the placement loops want.
#[derive(Debug, Clone)]
pub struct BlockPool {
    tier: Tier,
    pool: MemPool,
}

impl BlockPool {
    pub fn new(tier: Tier, capacity_bytes: u64) -> Self {
        BlockPool { tier, pool: MemPool::new(tier.name(), capacity_bytes) }
    }

    /// Wrap an existing pool (shared accounting — e.g. the pinned tier's
    /// pool is also charged by [`crate::transfer::PinnedPool`] staging).
    pub fn from_pool(tier: Tier, pool: MemPool) -> Self {
        BlockPool { tier, pool }
    }

    pub fn tier(&self) -> Tier {
        self.tier
    }

    /// The underlying byte pool (for capacity/used/peak queries).
    pub fn mem(&self) -> &MemPool {
        &self.pool
    }

    /// Reserve `bytes` for one block; `None` when the tier is full.
    pub fn grab(&self, bytes: u64) -> Option<PoolGuard> {
        self.pool.alloc(bytes).ok()
    }

    pub fn used(&self) -> u64 {
        self.pool.used()
    }

    pub fn capacity(&self) -> u64 {
        self.pool.capacity()
    }

    pub fn available(&self) -> u64 {
        self.pool.available()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_order_and_names() {
        assert!(Tier::GpuHbm < Tier::Pinned);
        assert!(Tier::Pinned < Tier::CpuDram);
        assert!(Tier::CpuDram < Tier::DiskNvme);
        assert_eq!(Tier::GpuHbm.name(), "gpu-hbm");
        assert_eq!(Tier::DiskNvme.name(), "disk-nvme");
        assert_eq!(Tier::GpuHbm.lower(), Some(Tier::Pinned));
        assert_eq!(Tier::Pinned.lower(), Some(Tier::CpuDram));
        assert_eq!(Tier::CpuDram.lower(), Some(Tier::DiskNvme));
        assert_eq!(Tier::DiskNvme.lower(), None);
        assert!(Tier::DiskNvme.is_disk() && !Tier::CpuDram.is_disk());
        assert_eq!(Tier::ALL.len(), 4);
    }

    #[test]
    fn grab_accounts_and_releases() {
        let p = BlockPool::new(Tier::Pinned, 100);
        let g = p.grab(60).expect("fits");
        assert_eq!(p.used(), 60);
        assert_eq!(p.available(), 40);
        assert!(p.grab(50).is_none(), "over capacity");
        drop(g);
        assert_eq!(p.used(), 0);
        assert!(p.grab(100).is_some());
    }

    #[test]
    fn shared_pool_accounting() {
        let mem = MemPool::new("pinned", 1000);
        let p = BlockPool::from_pool(Tier::Pinned, mem.clone());
        let _g = p.grab(400).unwrap();
        // the external handle observes the same accounting
        assert_eq!(mem.used(), 400);
        let _other = mem.alloc(500).unwrap();
        assert!(p.grab(200).is_none(), "shared capacity is shared");
    }

    #[test]
    fn block_id_orders_by_seq_then_idx() {
        let a = BlockId { seq: 1, idx: 9 };
        let b = BlockId { seq: 2, idx: 0 };
        assert!(a < b);
    }
}
