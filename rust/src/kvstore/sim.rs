//! Analytic eviction-strategy comparison (the kvstore's `sim` lens).
//!
//! Replays a skewed reuse workload against a byte-budgeted store and
//! integrates per-step times from the [`CostModel`], so eviction policies
//! can be compared deterministically without wall-clock noise, the same
//! way [`crate::sim`] compares transfer schedules.
//!
//! The capacity lever under test is **recompute-aware reclamation**: when
//! admission runs short, the policy picks blocks whose KV to drop (keeping
//! X).  A block inside the planner's split region is covered by the
//! recompute path at no extra step cost; a block beyond it forces the
//! planner's `l` floor past the optimum, and every later step of that
//! sequence pays `objective(max(l*, floor)) − objective(l*)` for it.
//! [`RecomputeAware`](super::RecomputeAware) therefore sustains at least
//! the decode throughput of [`Lru`](super::Lru) at equal admission
//! schedules — the property `rust/benches/perf_hotpath.rs` tracks in
//! `BENCH_kvstore.json`.

use crate::scheduler::{CostModel, SchedulePolicy, SplitSolver};

use super::block::BlockId;
use super::policy::{BlockView, EvictPolicy};

/// One simulated sequence.
#[derive(Debug, Clone, Copy)]
pub struct SimSeq {
    pub prompt: usize,
    pub gen: usize,
    /// Step period in rounds: 1 = steps every round (hot), k = every k-th
    /// round (cold).  This is the reuse skew.
    pub period: usize,
}

/// Workload + budget for one eviction simulation.
#[derive(Debug, Clone)]
pub struct EvictionSimConfig {
    pub cost: CostModel,
    /// Total store capacity across host tiers.
    pub capacity_bytes: u64,
    pub block_tokens: usize,
    /// Host bytes per cached token (K + V + X across layers).
    pub bytes_per_token: u64,
    pub seqs: Vec<SimSeq>,
    /// Safety cap on simulated rounds.
    pub max_rounds: usize,
}

impl EvictionSimConfig {
    /// The canonical skewed-reuse workload: two hot decoders and six cold
    /// long-context sequences over a budget ~30 % short of their sum.
    pub fn skewed_reuse(cost: CostModel) -> Self {
        let bytes_per_token = 3 * 4 * 256 * 4; // K/V/X × layers × hidden × f32
        let mut seqs = vec![SimSeq { prompt: 64, gen: 48, period: 1 }; 2];
        seqs.extend(vec![SimSeq { prompt: 96, gen: 16, period: 4 }; 6]);
        let total: u64 = seqs
            .iter()
            .map(|s| (s.prompt + s.gen) as u64 * bytes_per_token)
            .sum();
        EvictionSimConfig {
            cost,
            capacity_bytes: total * 7 / 10,
            block_tokens: 16,
            bytes_per_token,
            seqs,
            max_rounds: 2000,
        }
    }
}

/// Outcome of one policy run.
#[derive(Debug, Clone)]
pub struct EvictionSimReport {
    pub policy: String,
    pub steps: u64,
    pub wall_s: f64,
    pub steps_per_s: f64,
    pub link_busy_s: f64,
    /// Link busy fraction of wall time (clamped: the analytic link term
    /// overlaps compute inside a step).
    pub link_busy_frac: f64,
    /// KV-drop reclamation events.
    pub evictions: u64,
    pub peak_concurrency: usize,
    pub completed: usize,
}

struct SeqState {
    admitted: bool,
    done: bool,
    /// Cached tokens s'.
    s: usize,
    produced: usize,
    /// Dropped-KV prefix in tokens (the planner floor).
    dropped: usize,
    held_bytes: u64,
    last_use: u64,
}

/// Run the workload under `policy` and report throughput and reclamation.
pub fn simulate_eviction(cfg: &EvictionSimConfig, policy: &dyn EvictPolicy) -> EvictionSimReport {
    let solver = SplitSolver::new(cfg.cost.clone(), SchedulePolicy::RowByRow);
    let bt = cfg.block_tokens;
    let bpt = cfg.bytes_per_token;
    let mut st: Vec<SeqState> = cfg
        .seqs
        .iter()
        .map(|_| SeqState {
            admitted: false,
            done: false,
            s: 0,
            produced: 0,
            dropped: 0,
            held_bytes: 0,
            last_use: 0,
        })
        .collect();

    let mut clock = 0u64;
    let mut steps = 0u64;
    let mut wall = 0.0f64;
    let mut link_busy = 0.0f64;
    let mut drops = 0u64;
    let mut peak = 0usize;

    for round in 0..cfg.max_rounds {
        if st.iter().all(|s| s.done) {
            break;
        }
        // -- admission (FIFO, reclaim-by-dropping-KV when short) ------------
        let used: u64 = st.iter().map(|s| s.held_bytes).sum();
        let mut free = cfg.capacity_bytes.saturating_sub(used);
        for i in 0..st.len() {
            if st[i].admitted || st[i].done {
                continue;
            }
            let need = (cfg.seqs[i].prompt + cfg.seqs[i].gen) as u64 * bpt;
            while free < need {
                // candidate slate: each admitted sequence's next droppable
                // block (contiguous prefix, fully valid)
                let mut cands: Vec<(usize, BlockView)> = Vec::new();
                for (j, s) in st.iter().enumerate() {
                    if !s.admitted || s.done {
                        continue;
                    }
                    let idx = s.dropped / bt;
                    if s.dropped + bt > s.s {
                        continue;
                    }
                    cands.push((
                        j,
                        BlockView {
                            id: BlockId { seq: j as u64, idx },
                            tokens: bt,
                            start_token: s.dropped,
                            seq_len: s.s,
                            last_use: s.last_use,
                            split_l: solver.solve(s.s, s.s).l,
                        },
                    ));
                }
                if cands.is_empty() {
                    break;
                }
                let views: Vec<BlockView> = cands.iter().map(|(_, v)| *v).collect();
                let (j, _) = cands[policy.victim(&views)];
                let block_bytes = bt as u64 * bpt;
                let freed = block_bytes - block_bytes.div_ceil(3); // KV out, X kept
                st[j].dropped += bt;
                st[j].held_bytes = st[j].held_bytes.saturating_sub(freed);
                free += freed;
                drops += 1;
            }
            if free >= need {
                free -= need;
                st[i].admitted = true;
                st[i].held_bytes = need;
                st[i].s = cfg.seqs[i].prompt;
            } else {
                break; // head-of-line backpressure
            }
        }
        peak = peak.max(st.iter().filter(|s| s.admitted && !s.done).count());

        // -- decode steps for every due sequence ----------------------------
        for i in 0..st.len() {
            if !st[i].admitted || st[i].done || round % cfg.seqs[i].period != 0 {
                continue;
            }
            clock += 1;
            st[i].last_use = clock;
            let s = st[i].s;
            let l_star = solver.solve(s, s).l;
            let l = l_star.max(st[i].dropped).min(s);
            wall += solver.objective(l, s);
            let c = &cfg.cost;
            link_busy += c.link_latency_s
                + c.transfer_kv_per_token_s * (s - l) as f64
                + c.transfer_act_per_token_s * l as f64;
            steps += 1;
            st[i].s += 1;
            st[i].produced += 1;
            if st[i].produced >= cfg.seqs[i].gen {
                st[i].done = true;
                st[i].held_bytes = 0;
            }
        }
    }

    EvictionSimReport {
        policy: policy.name().to_string(),
        steps,
        wall_s: wall,
        steps_per_s: if wall > 0.0 { steps as f64 / wall } else { 0.0 },
        link_busy_s: link_busy,
        link_busy_frac: if wall > 0.0 { (link_busy / wall).min(1.0) } else { 0.0 },
        evictions: drops,
        peak_concurrency: peak,
        completed: st.iter().filter(|s| s.done).count(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::{Lru, RecomputeAware};

    fn cost() -> CostModel {
        CostModel {
            recompute_per_token_s: 0.3e-6, // A = 0.3 C: recompute is the cheap side
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 0.5e-6,
            gpu_overhead_s: 1e-6,
            link_latency_s: 1e-6,
        }
    }

    #[test]
    fn recompute_aware_sustains_at_least_lru_throughput() {
        // Acceptance: on a skewed reuse workload under a tight budget,
        // recompute-aware eviction sustains ≥ the decode throughput of LRU.
        let cfg = EvictionSimConfig::skewed_reuse(cost());
        let lru = simulate_eviction(&cfg, &Lru);
        let ra = simulate_eviction(&cfg, &RecomputeAware::new(cost()));
        assert_eq!(lru.completed, cfg.seqs.len(), "lru must finish the workload");
        assert_eq!(ra.completed, cfg.seqs.len(), "ra must finish the workload");
        // identical admission schedule → identical step counts; only the
        // per-step floor penalties differ
        assert_eq!(ra.steps, lru.steps);
        assert!(
            ra.steps_per_s >= lru.steps_per_s * (1.0 - 1e-9),
            "recompute-aware {} vs lru {} steps/s",
            ra.steps_per_s,
            lru.steps_per_s
        );
        assert!(ra.evictions > 0, "the budget must actually be tight");
    }

    #[test]
    fn ample_capacity_needs_no_eviction() {
        let mut cfg = EvictionSimConfig::skewed_reuse(cost());
        cfg.capacity_bytes *= 4;
        let r = simulate_eviction(&cfg, &Lru);
        assert_eq!(r.evictions, 0);
        assert_eq!(r.completed, cfg.seqs.len());
        assert!(r.peak_concurrency >= cfg.seqs.len(), "everything runs at once");
    }

    #[test]
    fn report_is_self_consistent() {
        let cfg = EvictionSimConfig::skewed_reuse(cost());
        let r = simulate_eviction(&cfg, &Lru);
        assert!(r.steps > 0);
        assert!(r.wall_s > 0.0);
        assert!(r.steps_per_s > 0.0);
        assert!(r.link_busy_frac > 0.0 && r.link_busy_frac <= 1.0);
        assert!(r.peak_concurrency >= 1);
    }
}
