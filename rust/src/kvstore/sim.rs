//! Analytic eviction-strategy comparison (the kvstore's `sim` lens).
//!
//! Replays a skewed reuse workload against a byte-budgeted store and
//! integrates per-step times from the [`CostModel`], so eviction policies
//! can be compared deterministically without wall-clock noise, the same
//! way [`crate::sim`] compares transfer schedules.
//!
//! The capacity lever under test is **recompute-aware reclamation**: when
//! admission runs short, the policy picks blocks whose KV to drop (keeping
//! X).  A block inside the planner's split region is covered by the
//! recompute path at no extra step cost; a block beyond it forces the
//! planner's `l` floor past the optimum, and every later step of that
//! sequence pays `objective(max(l*, floor)) − objective(l*)` for it.
//! [`RecomputeAware`](super::RecomputeAware) therefore sustains at least
//! the decode throughput of [`Lru`](super::Lru) at equal admission
//! schedules — the property `rust/benches/perf_hotpath.rs` tracks in
//! `BENCH_kvstore.json`.
//!
//! With `gpu_bytes` set the sim adds the **resident-suffix tier model**
//! mirroring the live store's asynchronous migrations: due sequences
//! promote their suffix (fully overlapped prefetch), a full tier demotes
//! run-start blocks through the policy, and each demotion charges the
//! link its wire time but the wall only `demote_serial_frac` of it — the
//! async-writeback residue.  Setting `demote_serial_frac = 1.0` recovers
//! PR 2's synchronous `migrate_sync` eviction for comparison, which is
//! how the tests pin that polling beats blocking at identical schedules.
//!
//! With `disk_bytes` set the sim becomes **four-tier**: admission
//! shortfalls *spill* the policy's chosen prefix blocks to an emulated
//! NVMe tier (full bytes back, KV preserved) before they drop any KV.
//! Spill writebacks charge the NVMe wire (`nvme_factor` × the
//! interconnect's per-byte time) and the wall only `spill_serial_frac` of
//! it — the same async residue shape as demotions.  A spilled token the
//! step's split does not cover pays a **read-through surcharge** (the
//! extra NVMe hop of the two-hop reload), and each step picks the cheaper
//! of the three-tier split or a split raised to cover the whole disk
//! prefix by recompute — the planner's topology-fold candidate pair
//! (`Planner::plan_batch` over a disk-span `PlanInput`) in closed form.
//! Recompute-aware spill therefore targets blocks the split covers anyway
//! (zero surcharge), which is exactly what the live policy's spill lens
//! scores.

use crate::scheduler::{CostModel, SchedulePolicy, SplitSolver, TierTopology};

use super::block::BlockId;
use super::policy::{BlockView, EvictPolicy};

/// One simulated sequence.
#[derive(Debug, Clone, Copy)]
pub struct SimSeq {
    pub prompt: usize,
    pub gen: usize,
    /// Step period in rounds: 1 = steps every round (hot), k = every k-th
    /// round (cold).  This is the reuse skew.
    pub period: usize,
}

/// Workload + budget for one eviction simulation.
#[derive(Debug, Clone)]
pub struct EvictionSimConfig {
    pub cost: CostModel,
    /// Total store capacity across host tiers.
    pub capacity_bytes: u64,
    pub block_tokens: usize,
    /// Host bytes per cached token (K + V + X across layers).
    pub bytes_per_token: u64,
    pub seqs: Vec<SimSeq>,
    /// Safety cap on simulated rounds.
    pub max_rounds: usize,
    /// gpu tier capacity for the resident-suffix model; 0 disables it
    /// (host-only reclamation, the PR 2 shape).
    pub gpu_bytes: u64,
    /// Wire-byte ratio on migrations (1.0 = full f32 width; 0.15625 under
    /// int4 wire quantization).
    pub wire_ratio: f64,
    /// Fraction of a demotion's wire time the step loop cannot hide.
    /// Asynchronous demotions overlap decode, so only a residue surfaces
    /// as wall time; 1.0 recovers the old synchronous `migrate_sync`
    /// model (the step loop waits the whole writeback out).
    pub demote_serial_frac: f64,
    /// NVMe disk tier capacity; 0 disables the four-tier spill model.
    pub disk_bytes: u64,
    /// NVMe wire time per byte relative to the interconnect (the
    /// `LinkConfig::nvme_below` ratio).
    pub nvme_factor: f64,
    /// Fraction of a spill writeback's NVMe time the step loop cannot
    /// hide (async-writeback residue, like `demote_serial_frac`).
    pub spill_serial_frac: f64,
    /// Arrival round per sequence (trace replay): sequence `i` is not
    /// offered to admission before round `arrivals[i]`.  Empty — the
    /// synthetic-workload default — offers everything at round 0.
    pub arrivals: Vec<usize>,
    /// Adoptable shared-prefix tokens per sequence (cross-request prefix
    /// sharing): the first admitted sharer materializes the preamble in
    /// the registry, and every later sharer adopts its block-rounded span
    /// for free — admission reserves that many fewer bytes, which is the
    /// hit-rate-vs-capacity frontier the sharing e2e pins.  Adopted blocks
    /// belong to the registry: reclamation never drops or spills them.
    /// Empty disables sharing.
    pub shared: Vec<usize>,
}

impl EvictionSimConfig {
    /// The canonical skewed-reuse workload: two hot decoders and six cold
    /// long-context sequences over a budget ~30 % short of their sum.
    pub fn skewed_reuse(cost: CostModel) -> Self {
        let bytes_per_token = 3 * 4 * 256 * 4; // K/V/X × layers × hidden × f32
        let mut seqs = vec![SimSeq { prompt: 64, gen: 48, period: 1 }; 2];
        seqs.extend(vec![SimSeq { prompt: 96, gen: 16, period: 4 }; 6]);
        let total: u64 = seqs
            .iter()
            .map(|s| (s.prompt + s.gen) as u64 * bytes_per_token)
            .sum();
        EvictionSimConfig {
            cost,
            capacity_bytes: total * 7 / 10,
            block_tokens: 16,
            bytes_per_token,
            seqs,
            max_rounds: 2000,
            gpu_bytes: 0,
            wire_ratio: 1.0,
            demote_serial_frac: 0.25,
            disk_bytes: 0,
            nvme_factor: crate::transfer::NVME_BANDWIDTH_FACTOR,
            spill_serial_frac: 0.25,
            arrivals: Vec::new(),
            shared: Vec::new(),
        }
    }

    /// Trace replay: one sim sequence per request of a generated workload
    /// [`Trace`](crate::workload::Trace), arrival-gated at its step and
    /// stepping every round (`period` 1) — the analytic twin of
    /// [`Submit::dispatch`](crate::coordinator::Submit::dispatch) replay,
    /// sharing the serving loop's decode-step clock.  Capacities default
    /// to ample (everything fits); narrow them by hand or read a declared
    /// chain via [`with_topology`](EvictionSimConfig::with_topology) to
    /// make reclamation observable.  The trace's per-request shared-prefix
    /// tokens flow into [`shared`](EvictionSimConfig::shared).
    pub fn from_trace(cost: CostModel, trace: &crate::workload::Trace) -> Self {
        let bytes_per_token: u64 = 3 * 4 * 256 * 4; // K/V/X × layers × hidden × f32
        let seqs: Vec<SimSeq> = trace
            .requests
            .iter()
            .map(|r| SimSeq {
                prompt: r.prompt_tokens.max(1),
                gen: r.gen_tokens.max(1),
                period: 1,
            })
            .collect();
        let arrivals: Vec<usize> = trace.requests.iter().map(|r| r.step).collect();
        let shared: Vec<usize> = trace.requests.iter().map(|r| r.shared_prefix_tokens).collect();
        let total: u64 = seqs
            .iter()
            .map(|s| (s.prompt + s.gen) as u64 * bytes_per_token)
            .sum();
        let span = trace.max_step() + trace.total_gen_tokens() as usize + 64;
        EvictionSimConfig {
            cost,
            capacity_bytes: total.max(1),
            block_tokens: 16,
            bytes_per_token,
            seqs,
            max_rounds: span,
            gpu_bytes: 0,
            wire_ratio: 1.0,
            demote_serial_frac: 0.25,
            disk_bytes: 0,
            nvme_factor: crate::transfer::NVME_BANDWIDTH_FACTOR,
            spill_serial_frac: 0.25,
            arrivals,
            shared,
        }
    }

    /// [`EvictionSimConfig::skewed_reuse`] with a gpu tier sized to ~40 %
    /// of the workload: promotions/demotions flow through the policy and
    /// the async demotion cost model becomes observable.
    pub fn skewed_reuse_tiered(cost: CostModel) -> Self {
        let mut cfg = Self::skewed_reuse(cost);
        cfg.gpu_bytes = cfg.capacity_bytes * 4 / 10;
        cfg
    }

    /// [`EvictionSimConfig::skewed_reuse_tiered`] with an NVMe tier large
    /// enough to absorb every spill: the four-tier model — admission
    /// shortfalls spill before they drop, and read-through surcharges make
    /// the spill-victim choice observable.
    pub fn skewed_reuse_four_tier(cost: CostModel) -> Self {
        let mut cfg = Self::skewed_reuse_tiered(cost);
        cfg.disk_bytes = cfg.capacity_bytes * 2;
        cfg
    }

    /// Take the tier model from a calibrated [`TierTopology`] instead of
    /// the hand-set fields: the gpu rung's capacity, the summed host
    /// rungs (pinned + cpu-dram) as `capacity_bytes`, the disk rung's
    /// capacity, the chain's disk-hop surcharge as `nvme_factor`, and the
    /// chain's wire element width as `wire_ratio` — so the analytic sim
    /// and the live store read the *same* declared chain and their cost
    /// models cannot drift.  A zero-capacity gpu or host rung keeps the
    /// workload-relative default sizing (chains built for a serving loop
    /// leave the gpu rung at 0 to inherit the KV budget).
    pub fn with_topology(mut self, topo: &TierTopology) -> Self {
        use super::block::Tier;
        if let Some(i) = topo.tier_named(Tier::GpuHbm.name()) {
            if topo.tier(i).capacity_bytes > 0 {
                self.gpu_bytes = topo.tier(i).capacity_bytes;
            }
        }
        let host: u64 = [Tier::Pinned.name(), Tier::CpuDram.name()]
            .iter()
            .filter_map(|n| topo.tier_named(n))
            .map(|i| topo.tier(i).capacity_bytes)
            .sum();
        if host > 0 {
            self.capacity_bytes = host;
        }
        if let Some(i) = topo.tier_named(Tier::DiskNvme.name()) {
            self.disk_bytes = topo.tier(i).capacity_bytes;
            self.nvme_factor = topo.hop_factor(i);
        } else {
            self.disk_bytes = 0;
        }
        self.wire_ratio = topo.wire_elem_bytes() / 4.0;
        self
    }
}

/// Outcome of one policy run.
#[derive(Debug, Clone)]
pub struct EvictionSimReport {
    pub policy: String,
    pub steps: u64,
    pub wall_s: f64,
    pub steps_per_s: f64,
    pub link_busy_s: f64,
    /// Link busy fraction of wall time (clamped: the analytic link term
    /// overlaps compute inside a step).
    pub link_busy_frac: f64,
    /// KV-drop reclamation events.
    pub evictions: u64,
    /// gpu-tier demotions (resident-suffix model; 0 when `gpu_bytes` is 0).
    pub demotions: u64,
    /// Link seconds spent on demotion writebacks (async: only
    /// `demote_serial_frac` of this surfaces as wall time).
    pub demote_link_s: f64,
    /// Dram→disk spill events (four-tier model; 0 when `disk_bytes` is 0).
    pub spills: u64,
    /// NVMe seconds spent on spill writebacks (async: only
    /// `spill_serial_frac` of this surfaces as wall time).
    pub spill_link_s: f64,
    /// Wall seconds of NVMe read-through: spilled tokens the chosen split
    /// did not cover, re-read over the extra hop every step they were
    /// needed.  The spill-victim quality signal: a policy that spills
    /// recompute-covered blocks keeps this at zero.
    pub readthrough_s: f64,
    pub peak_concurrency: usize,
    pub completed: usize,
    /// Per-sequence admission delay in rounds (admission round − arrival
    /// round), in sequence order, admitted sequences only.  The analytic
    /// queueing-delay term of TTFT: percentile it for the workload
    /// bench's p99-TTFT-in-steps column.
    pub admit_delay_steps: Vec<usize>,
}

struct SeqState {
    admitted: bool,
    done: bool,
    /// Cached tokens s'.
    s: usize,
    produced: usize,
    /// Dropped-KV prefix in tokens (the planner floor).
    dropped: usize,
    held_bytes: u64,
    last_use: u64,
    /// gpu-resident suffix in tokens (resident-suffix model).
    resident: usize,
    /// Tokens spilled to the disk tier (contiguous above the dropped
    /// prefix; four-tier model).
    spilled: usize,
    /// Shared-prefix tokens adopted from the registry at admission: held
    /// for free (an earlier sharer's bytes back them) and never dropped or
    /// spilled — the registry owns them.
    adopted: usize,
}

/// Run the workload under `policy` and report throughput and reclamation.
pub fn simulate_eviction(cfg: &EvictionSimConfig, policy: &dyn EvictPolicy) -> EvictionSimReport {
    let solver = SplitSolver::new(cfg.cost.clone(), SchedulePolicy::RowByRow);
    let bt = cfg.block_tokens;
    let bpt = cfg.bytes_per_token;
    let mut st: Vec<SeqState> = cfg
        .seqs
        .iter()
        .map(|_| SeqState {
            admitted: false,
            done: false,
            s: 0,
            produced: 0,
            dropped: 0,
            held_bytes: 0,
            last_use: 0,
            resident: 0,
            spilled: 0,
            adopted: 0,
        })
        .collect();

    // arrival gating (trace replay): sequence i is invisible to admission
    // before round arrive(i); the synthetic workloads leave this empty
    let arrive = |i: usize| cfg.arrivals.get(i).copied().unwrap_or(0);
    let mut admit_round: Vec<Option<usize>> = vec![None; cfg.seqs.len()];
    // prefix-sharing registry: the widest block-rounded preamble span a
    // sharer has materialized so far (registered entries park at refs 0,
    // so the span stays adoptable for the rest of the run)
    let mut registered_tokens = 0usize;

    let mut clock = 0u64;
    let mut steps = 0u64;
    let mut wall = 0.0f64;
    let mut link_busy = 0.0f64;
    let mut drops = 0u64;
    let mut demotions = 0u64;
    let mut demote_link = 0.0f64;
    let mut spills = 0u64;
    let mut spill_link = 0.0f64;
    let mut readthrough = 0.0f64;
    let mut peak = 0usize;

    for round in 0..cfg.max_rounds {
        if st.iter().all(|s| s.done) {
            break;
        }
        // -- admission (FIFO, reclaim-by-dropping-KV when short) ------------
        let used: u64 = st.iter().map(|s| s.held_bytes).sum();
        let mut free = cfg.capacity_bytes.saturating_sub(used);
        for i in 0..st.len() {
            if st[i].admitted || st[i].done || round < arrive(i) {
                continue;
            }
            // cross-request sharing: adopt whatever block-rounded span of
            // this sequence's preamble an earlier sharer already
            // registered — those tokens cost no new bytes
            let shareable = cfg.shared.get(i).copied().unwrap_or(0).min(cfg.seqs[i].prompt);
            let adopted = ((shareable / bt) * bt).min(registered_tokens);
            let need = (cfg.seqs[i].prompt + cfg.seqs[i].gen - adopted) as u64 * bpt;
            while free < need {
                let block_bytes = bt as u64 * bpt;
                // four-tier: spill first — the policy's chosen prefix
                // block moves to disk, giving its *full* bytes back and
                // keeping the KV reachable (two-hop).  The NVMe writeback
                // is async: the wire is charged in full, the wall only the
                // serial residue.
                if cfg.disk_bytes > 0 {
                    let disk_used: u64 = st
                        .iter()
                        .filter(|s| !s.done)
                        .map(|s| s.spilled as u64 * bpt)
                        .sum();
                    if disk_used + block_bytes <= cfg.disk_bytes {
                        let mut cands: Vec<(usize, BlockView)> = Vec::new();
                        for (j, s) in st.iter().enumerate() {
                            if !s.admitted || s.done {
                                continue;
                            }
                            // the adopted preamble is registry-owned —
                            // spilling starts past it
                            let start = s.adopted + s.dropped + s.spilled;
                            if start + bt > s.s {
                                continue;
                            }
                            cands.push((
                                j,
                                BlockView {
                                    id: BlockId { seq: j as u64, idx: start / bt },
                                    tokens: bt,
                                    start_token: start,
                                    seq_len: s.s,
                                    last_use: s.last_use,
                                    split_l: solver.solve(s.s, s.s).l,
                                    shared_refs: 0,
                                },
                            ));
                        }
                        if !cands.is_empty() {
                            let views: Vec<BlockView> = cands.iter().map(|(_, v)| *v).collect();
                            let (j, _) = cands[policy.spill_victim(&views)];
                            st[j].spilled += bt;
                            st[j].held_bytes = st[j].held_bytes.saturating_sub(block_bytes);
                            st[j].resident = st[j].resident.min(
                                st[j].s
                                    .saturating_sub(st[j].adopted + st[j].dropped + st[j].spilled),
                            );
                            let wire = bt as f64
                                * cfg.cost.transfer_kv_per_token_s
                                * cfg.wire_ratio
                                * cfg.nvme_factor;
                            link_busy += wire;
                            spill_link += wire;
                            wall += cfg.spill_serial_frac * wire;
                            spills += 1;
                            free += block_bytes;
                            continue;
                        }
                    }
                }
                // candidate slate: each admitted sequence's next droppable
                // block (contiguous prefix, fully valid, not behind a
                // spilled region — dropping on-disk KV frees no host byte)
                let mut cands: Vec<(usize, BlockView)> = Vec::new();
                for (j, s) in st.iter().enumerate() {
                    if !s.admitted || s.done || s.spilled > 0 {
                        continue;
                    }
                    // dropping starts past the registry-owned adopted span
                    let start = s.adopted + s.dropped;
                    if start + bt > s.s {
                        continue;
                    }
                    cands.push((
                        j,
                        BlockView {
                            id: BlockId { seq: j as u64, idx: start / bt },
                            tokens: bt,
                            start_token: start,
                            seq_len: s.s,
                            last_use: s.last_use,
                            split_l: solver.solve(s.s, s.s).l,
                            shared_refs: 0,
                        },
                    ));
                }
                if cands.is_empty() {
                    break;
                }
                let views: Vec<BlockView> = cands.iter().map(|(_, v)| *v).collect();
                let (j, _) = cands[policy.victim(&views)];
                let freed = block_bytes - block_bytes.div_ceil(3); // KV out, X kept
                st[j].dropped += bt;
                st[j].held_bytes = st[j].held_bytes.saturating_sub(freed);
                // a grown dropped prefix can meet the resident suffix;
                // the dropped tokens' gpu residency (if any) is void
                st[j].resident = st[j].resident.min(st[j].s - (st[j].adopted + st[j].dropped));
                free += freed;
                drops += 1;
            }
            if free >= need {
                free -= need;
                st[i].admitted = true;
                st[i].held_bytes = need;
                st[i].s = cfg.seqs[i].prompt;
                st[i].adopted = adopted;
                admit_round[i] = Some(round);
                // this sharer's own preamble span is registered from here on
                registered_tokens = registered_tokens.max((shareable / bt) * bt);
            } else {
                break; // head-of-line backpressure
            }
        }
        peak = peak.max(st.iter().filter(|s| s.admitted && !s.done).count());

        // -- gpu tier: promote due sequences' suffixes, evict via policy ----
        // Promotions ride the link fully overlapped (they are prefetched
        // ahead of the step); demotions are asynchronous writebacks whose
        // gpu bytes free at issuance — only `demote_serial_frac` of their
        // wire time surfaces as wall time (1.0 recovers the synchronous
        // eviction of PR 2).
        if cfg.gpu_bytes > 0 {
            let c = &cfg.cost;
            // s is fixed until the decode section, so one solve per
            // sequence serves every candidate slate this round
            let round_split: Vec<usize> = st
                .iter()
                .map(|s| if s.admitted && !s.done { solver.solve(s.s, s.s).l } else { 0 })
                .collect();
            for i in 0..st.len() {
                if !st[i].admitted || st[i].done || round % cfg.seqs[i].period != 0 {
                    continue;
                }
                loop {
                    // dropped-prefix tokens have no stored KV to promote —
                    // the live store's promotion walk breaks at a dropped
                    // block — and spilled tokens stay disk-side (their
                    // reload is the read-through term, not the suffix), so
                    // residency can never waive either region's cost
                    let want = st[i]
                        .s
                        .saturating_sub(st[i].adopted + st[i].dropped + st[i].spilled)
                        .saturating_sub(st[i].resident);
                    if want == 0 {
                        break;
                    }
                    let take = bt.min(want);
                    let need = take as u64 * bpt;
                    let gpu_used: u64 =
                        st.iter().map(|s| s.resident as u64 * bpt).sum();
                    if gpu_used + need <= cfg.gpu_bytes {
                        st[i].resident += take;
                        link_busy +=
                            take as f64 * c.transfer_kv_per_token_s * cfg.wire_ratio;
                        continue;
                    }
                    // full: demote another sequence's run-start block
                    let mut cands: Vec<(usize, BlockView)> = Vec::new();
                    for (j, s) in st.iter().enumerate() {
                        if j == i || !s.admitted || s.done || s.resident == 0 {
                            continue;
                        }
                        let start = s.s - s.resident;
                        cands.push((
                            j,
                            BlockView {
                                id: BlockId { seq: j as u64, idx: start / bt },
                                tokens: bt.min(s.resident),
                                start_token: start,
                                seq_len: s.s,
                                last_use: s.last_use,
                                split_l: round_split[j],
                                shared_refs: 0,
                            },
                        ));
                    }
                    if cands.is_empty() {
                        break; // nothing evictable: the suffix stays partial
                    }
                    let views: Vec<BlockView> = cands.iter().map(|(_, v)| *v).collect();
                    // the demotion lens: refill plus writeback at wire width
                    let (j, _) = cands[policy.demote_victim(&views)];
                    let dropped_t = bt.min(st[j].resident);
                    st[j].resident -= dropped_t;
                    let wire = dropped_t as f64 * c.transfer_kv_per_token_s * cfg.wire_ratio;
                    link_busy += wire;
                    demote_link += wire;
                    wall += c.link_latency_s + cfg.demote_serial_frac * wire;
                    demotions += 1;
                }
            }
        }

        // -- decode steps for every due sequence ----------------------------
        for i in 0..st.len() {
            if !st[i].admitted || st[i].done || round % cfg.seqs[i].period != 0 {
                continue;
            }
            clock += 1;
            st[i].last_use = clock;
            let s = st[i].s;
            // the resident suffix leaves the transfer and recompute terms
            let r = st[i].resident.min(s);
            let s_eff = s - r;
            let l_star = solver.solve(s_eff, s_eff).l;
            // a dropped region sits above the adopted preamble, so covering
            // it by recompute means splitting past adopted + dropped
            let drop_floor = if st[i].dropped > 0 {
                (st[i].adopted + st[i].dropped).min(s_eff)
            } else {
                0
            };
            let l_a = l_star.max(drop_floor).min(s_eff);
            // four-tier: a spilled token the split does not cover re-reads
            // over the extra NVMe hop this step; covering the whole disk
            // prefix by recompute may be cheaper (the closed-form twin of
            // Planner::plan_batch's topology-fold candidate pair)
            let disk_end = if st[i].spilled > 0 {
                (st[i].adopted + st[i].dropped + st[i].spilled).min(s_eff)
            } else {
                0
            };
            let rt_per_tok =
                cfg.cost.transfer_kv_per_token_s * cfg.wire_ratio * cfg.nvme_factor;
            let rt = |l: usize| disk_end.saturating_sub(l) as f64 * rt_per_tok;
            let l_b = disk_end.max(l_a);
            let (l, rt_s) =
                if solver.objective(l_b, s_eff) + rt(l_b) < solver.objective(l_a, s_eff) + rt(l_a)
                {
                    (l_b, rt(l_b))
                } else {
                    (l_a, rt(l_a))
                };
            wall += solver.objective(l, s_eff) + rt_s;
            readthrough += rt_s;
            link_busy += rt_s;
            let c = &cfg.cost;
            link_busy += c.link_latency_s
                + c.transfer_kv_per_token_s * (s_eff - l) as f64
                + c.transfer_act_per_token_s * l as f64;
            steps += 1;
            st[i].s += 1;
            st[i].produced += 1;
            if st[i].produced >= cfg.seqs[i].gen {
                st[i].done = true;
                st[i].held_bytes = 0;
                st[i].resident = 0;
                st[i].spilled = 0; // disk reservations release with the seq
            }
        }
    }

    EvictionSimReport {
        policy: policy.name().to_string(),
        steps,
        wall_s: wall,
        steps_per_s: if wall > 0.0 { steps as f64 / wall } else { 0.0 },
        link_busy_s: link_busy,
        link_busy_frac: if wall > 0.0 { (link_busy / wall).min(1.0) } else { 0.0 },
        evictions: drops,
        demotions,
        demote_link_s: demote_link,
        spills,
        spill_link_s: spill_link,
        readthrough_s: readthrough,
        peak_concurrency: peak,
        completed: st.iter().filter(|s| s.done).count(),
        admit_delay_steps: admit_round
            .iter()
            .enumerate()
            .filter_map(|(i, r)| r.map(|r| r - arrive(i)))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::{Lru, RecomputeAware};

    fn cost() -> CostModel {
        CostModel {
            recompute_per_token_s: 0.3e-6, // A = 0.3 C: recompute is the cheap side
            transfer_kv_per_token_s: 1e-6,
            transfer_act_per_token_s: 0.5e-6,
            gpu_overhead_s: 1e-6,
            link_latency_s: 1e-6,
        }
    }

    #[test]
    fn recompute_aware_sustains_at_least_lru_throughput() {
        // Acceptance: on a skewed reuse workload under a tight budget,
        // recompute-aware eviction sustains ≥ the decode throughput of LRU.
        let cfg = EvictionSimConfig::skewed_reuse(cost());
        let lru = simulate_eviction(&cfg, &Lru);
        let ra = simulate_eviction(&cfg, &RecomputeAware::new(cost()));
        assert_eq!(lru.completed, cfg.seqs.len(), "lru must finish the workload");
        assert_eq!(ra.completed, cfg.seqs.len(), "ra must finish the workload");
        // identical admission schedule → identical step counts; only the
        // per-step floor penalties differ
        assert_eq!(ra.steps, lru.steps);
        assert!(
            ra.steps_per_s >= lru.steps_per_s * (1.0 - 1e-9),
            "recompute-aware {} vs lru {} steps/s",
            ra.steps_per_s,
            lru.steps_per_s
        );
        assert!(ra.evictions > 0, "the budget must actually be tight");
    }

    #[test]
    fn ample_capacity_needs_no_eviction() {
        let mut cfg = EvictionSimConfig::skewed_reuse(cost());
        cfg.capacity_bytes *= 4;
        let r = simulate_eviction(&cfg, &Lru);
        assert_eq!(r.evictions, 0);
        assert_eq!(r.completed, cfg.seqs.len());
        assert!(r.peak_concurrency >= cfg.seqs.len(), "everything runs at once");
    }

    #[test]
    fn shared_prefixes_widen_the_admission_frontier() {
        // Four identical chat turns over a 32-token shared preamble.  The
        // budget fits one full sequence plus three adopters exactly
        // (80 + 3 × 48 = 224 tokens), so with sharing on everything admits
        // at round 0 with zero reclamation; clearing `shared` asks for
        // 320 tokens and forces KV drops to squeeze in — the hit-rate-vs-
        // capacity frontier in miniature.
        let bpt = 3 * 4 * 256 * 4u64;
        let mut cfg = EvictionSimConfig {
            cost: cost(),
            capacity_bytes: 224 * bpt,
            block_tokens: 16,
            bytes_per_token: bpt,
            seqs: vec![SimSeq { prompt: 64, gen: 16, period: 1 }; 4],
            max_rounds: 2000,
            gpu_bytes: 0,
            wire_ratio: 1.0,
            demote_serial_frac: 0.25,
            disk_bytes: 0,
            nvme_factor: crate::transfer::NVME_BANDWIDTH_FACTOR,
            spill_serial_frac: 0.25,
            arrivals: Vec::new(),
            shared: vec![32; 4],
        };
        let shared = simulate_eviction(&cfg, &Lru);
        assert_eq!(shared.completed, 4);
        assert_eq!(shared.peak_concurrency, 4, "adopters must all fit at once");
        assert_eq!(shared.evictions, 0, "adoption covers the shortfall without drops");

        cfg.shared.clear();
        let unshared = simulate_eviction(&cfg, &Lru);
        assert_eq!(unshared.completed, 4);
        assert!(unshared.evictions > 0, "without sharing the budget must be short");
        // drop floors surcharge the unshared run's decode steps
        assert_eq!(shared.steps, unshared.steps);
        assert!(
            shared.wall_s <= unshared.wall_s + 1e-12,
            "sharing must not slow the same workload: {} vs {}",
            shared.wall_s,
            unshared.wall_s
        );
    }

    #[test]
    fn report_is_self_consistent() {
        let cfg = EvictionSimConfig::skewed_reuse(cost());
        let r = simulate_eviction(&cfg, &Lru);
        assert!(r.steps > 0);
        assert!(r.wall_s > 0.0);
        assert!(r.steps_per_s > 0.0);
        assert!(r.link_busy_frac > 0.0 && r.link_busy_frac <= 1.0);
        assert!(r.peak_concurrency >= 1);
        assert_eq!(r.demotions, 0, "no gpu tier configured");
        assert_eq!(r.demote_link_s, 0.0);
        assert_eq!(r.spills, 0, "no disk tier configured");
        assert_eq!(r.spill_link_s, 0.0);
        assert_eq!(r.readthrough_s, 0.0);
    }

    #[test]
    fn four_tier_spills_before_dropping_and_completes() {
        let cfg = EvictionSimConfig::skewed_reuse_four_tier(cost());
        let four = simulate_eviction(&cfg, &RecomputeAware::new(cost()));
        assert!(four.spills > 0, "the tight budget must spill");
        assert!(four.spill_link_s > 0.0, "spill writebacks must charge the NVMe wire");
        assert_eq!(four.completed, cfg.seqs.len());
        // spill frees full blocks (and is tried first), so the same
        // workload needs no more KV drops than the drop-only three-tier run
        let three = EvictionSimConfig::skewed_reuse_tiered(cost());
        let r3 = simulate_eviction(&three, &RecomputeAware::new(cost()));
        assert!(r3.evictions > 0, "the three-tier run must actually be short on capacity");
        assert!(
            four.evictions <= r3.evictions,
            "spill must not increase drops: {} vs {}",
            four.evictions,
            r3.evictions
        );
    }

    #[test]
    fn topology_config_matches_the_hand_set_four_tier_model() {
        // the declared chain and the hand-set fields describe the same
        // hardware → identical analytic runs (topology is data, not a fork)
        let hand = EvictionSimConfig::skewed_reuse_four_tier(cost());
        let topo = crate::scheduler::TierTopology::standard(
            hand.gpu_bytes,
            0,
            hand.capacity_bytes,
        )
        .with_disk(hand.disk_bytes, 0.9)
        .calibrated_bps(100e6, 30e-6);
        let from_topo = EvictionSimConfig::skewed_reuse_tiered(cost()).with_topology(&topo);
        assert_eq!(from_topo.gpu_bytes, hand.gpu_bytes);
        assert_eq!(from_topo.capacity_bytes, hand.capacity_bytes, "host rungs are read too");
        assert_eq!(from_topo.disk_bytes, hand.disk_bytes);
        assert!((from_topo.nvme_factor - hand.nvme_factor).abs() < 1e-9);
        assert!((from_topo.wire_ratio - hand.wire_ratio).abs() < 1e-12);
        let a = simulate_eviction(&hand, &Lru);
        let b = simulate_eviction(&from_topo, &Lru);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.spills, b.spills);
        assert!((a.wall_s - b.wall_s).abs() < 1e-12, "{} vs {}", a.wall_s, b.wall_s);
    }

    #[test]
    fn zero_disk_capacity_is_exactly_the_three_tier_model() {
        let mut gated = EvictionSimConfig::skewed_reuse_four_tier(cost());
        gated.disk_bytes = 0;
        let a = simulate_eviction(&EvictionSimConfig::skewed_reuse_tiered(cost()), &Lru);
        let b = simulate_eviction(&gated, &Lru);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.evictions, b.evictions);
        assert_eq!(b.spills, 0);
        assert!((a.wall_s - b.wall_s).abs() < 1e-12, "{} vs {}", a.wall_s, b.wall_s);
    }

    #[test]
    fn recompute_aware_spill_never_reads_through_more_than_lru() {
        // the spill lens prefers blocks the split region covers, so its
        // read-through surcharge is bounded by the recency baseline's
        let cfg = EvictionSimConfig::skewed_reuse_four_tier(cost());
        let lru = simulate_eviction(&cfg, &Lru);
        let ra = simulate_eviction(&cfg, &RecomputeAware::new(cost()));
        assert!(lru.spills > 0 && ra.spills > 0);
        assert!(
            ra.readthrough_s <= lru.readthrough_s + 1e-12,
            "ra {} vs lru {}",
            ra.readthrough_s,
            lru.readthrough_s
        );
    }

    #[test]
    fn async_demotion_beats_the_synchronous_eviction_model() {
        // a tight gpu tier forces run-start demotions; the async model
        // (gpu bytes free at issuance, writeback overlapped) must charge
        // the link the full wire time but the wall only a residue — the
        // synchronous PR 2 model (demote_serial_frac = 1.0, the step loop
        // waits migrate_sync out) pays strictly more wall for the *same*
        // step count
        let cfg = EvictionSimConfig::skewed_reuse_tiered(cost());
        let async_r = simulate_eviction(&cfg, &Lru);
        assert!(async_r.demotions > 0, "the gpu tier must actually be contended");
        assert!(async_r.demote_link_s > 0.0);
        assert_eq!(async_r.completed, cfg.seqs.len());

        let mut sync_cfg = cfg.clone();
        sync_cfg.demote_serial_frac = 1.0;
        let sync_r = simulate_eviction(&sync_cfg, &Lru);
        assert_eq!(sync_r.steps, async_r.steps, "the cost model must not change the schedule");
        assert_eq!(sync_r.demotions, async_r.demotions);
        assert!(
            sync_r.wall_s > async_r.wall_s,
            "sync eviction must cost wall time: {} vs {}",
            sync_r.wall_s,
            async_r.wall_s
        );
        assert!(async_r.steps_per_s > sync_r.steps_per_s);
    }

    #[test]
    fn residency_shrinks_step_cost() {
        // with an ample gpu tier every suffix is fully resident: steps pay
        // no transfer at all, so wall collapses versus the host-only run
        let host_only = EvictionSimConfig::skewed_reuse(cost());
        let mut tiered = host_only.clone();
        tiered.gpu_bytes = tiered.capacity_bytes * 4; // everything fits
        let a = simulate_eviction(&host_only, &Lru);
        let b = simulate_eviction(&tiered, &Lru);
        assert_eq!(a.steps, b.steps);
        assert_eq!(b.demotions, 0, "ample tier never evicts");
        assert!(b.wall_s < a.wall_s, "residency must cut step cost: {} vs {}", b.wall_s, a.wall_s);
    }

    #[test]
    fn staggered_arrivals_gate_admission_without_changing_work() {
        let mut base = EvictionSimConfig::skewed_reuse(cost());
        base.seqs = vec![SimSeq { prompt: 32, gen: 8, period: 1 }; 4];
        base.capacity_bytes = 4 * 40 * base.bytes_per_token; // ample
        let all_at_once = simulate_eviction(&base, &Lru);
        assert_eq!(all_at_once.peak_concurrency, 4);
        assert_eq!(all_at_once.admit_delay_steps, vec![0; 4]);

        // gaps wider than a sequence lifetime: lifetimes never overlap
        let mut gated = base.clone();
        gated.arrivals = vec![0, 40, 80, 120];
        let staggered = simulate_eviction(&gated, &Lru);
        assert_eq!(staggered.completed, 4);
        assert_eq!(
            staggered.steps, all_at_once.steps,
            "arrival time moves work, not its amount"
        );
        assert_eq!(staggered.peak_concurrency, 1);
        // ample capacity admits at the arrival round exactly
        assert_eq!(staggered.admit_delay_steps, vec![0; 4]);
    }

    #[test]
    fn from_trace_replays_the_workload_arrival_schedule() {
        let trace = crate::workload::WorkloadSpec::bursty_chat().generate();
        let cfg = EvictionSimConfig::from_trace(cost(), &trace);
        assert_eq!(cfg.seqs.len(), trace.requests.len());
        assert_eq!(
            cfg.arrivals,
            trace.requests.iter().map(|r| r.step).collect::<Vec<_>>()
        );
        let r = simulate_eviction(&cfg, &Lru);
        assert_eq!(r.completed, trace.requests.len(), "ample defaults finish the trace");
        assert_eq!(r.steps, trace.total_gen_tokens(), "one decode step per generated token");
        assert_eq!(r.evictions, 0);
        assert!(
            r.admit_delay_steps.iter().all(|&d| d == 0),
            "ample capacity admits on arrival: {:?}",
            r.admit_delay_steps
        );
    }

    #[test]
    fn wire_quant_shrinks_demotion_traffic() {
        let cfg = EvictionSimConfig::skewed_reuse_tiered(cost());
        let mut quant = cfg.clone();
        quant.wire_ratio = 0.15625; // int4 wire
        let full = simulate_eviction(&cfg, &Lru);
        let q = simulate_eviction(&quant, &Lru);
        assert_eq!(full.demotions, q.demotions, "same schedule, thinner wire");
        assert!(q.demote_link_s < full.demote_link_s * 0.16);
    }
}
