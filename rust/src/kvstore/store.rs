//! The tiered, block-granular KV store.
//!
//! [`KvStore`] tracks, for every admitted sequence (decode group), where
//! each of its fixed-size token blocks lives — gpu-hbm, pinned, cpu-dram
//! or disk-nvme — with one byte-accounted reservation per block.  All tier
//! traffic (promotions, demotions, prefetch, spill) moves through the
//! embedded [`MigrationEngine`] under one queued → staged → in-flight →
//! landed lifecycle, so **nothing on the serving path ever waits on a
//! link**:
//!
//! * **Promotion** ([`KvStore::begin_promotions`] /
//!   [`KvStore::poll_landed`]): pull a sequence's blocks up into the gpu
//!   tier ahead of its next decode step.  Resident blocks form a *suffix*
//!   of the valid tokens (the newest KV), so every step's H2D transfer
//!   shrinks by the resident length — the "already-on-GPU blocks shrink
//!   the transfer term" `resident` input of the
//!   [`PlanInput`](crate::scheduler::PlanInput) handed to
//!   [`Planner::plan_batch`](crate::scheduler::Planner::plan_batch).
//!   A **disk-resident** block promotes in *two hops* staged across steps:
//!   the walk first issues disk→dram at NVMe speed; once that hop lands
//!   the next step's walk picks the (now host) block up for the dram→gpu
//!   leg — no step ever waits for either wire.
//! * **Eviction**: when the gpu tier is full, the configured
//!   [`EvictPolicy`](super::EvictPolicy) picks a victim among the *lowest*
//!   blocks of other sequences' resident runs (so residency stays a
//!   suffix), scored by the **demotion lens** (refill + writeback at wire
//!   width).  The demotion is issued **asynchronously**: the victim's gpu
//!   bytes are released immediately (the host rows are canonical; the link
//!   traffic models writeback) and the block is non-resident from that
//!   instant.  A freshly demoted block then sits out a cool-down before it
//!   can be re-promoted (anti-thrash hysteresis).
//! * **Capacity-aware spill** ([`KvStore::pump_migrations`] per step, and
//!   admission on demand): when the dram tier runs past the configured
//!   watermark — i.e. *before* admission would backpressure — cold,
//!   settled dram blocks are spilled to the disk tier, chosen by the
//!   policy's **spill lens** (NVMe writeback + two-hop reload of whatever
//!   recompute won't cover).  The dram bytes free at issuance; the
//!   writeback rides the NVMe wire strictly within leftover step budget
//!   ([`MigrationClass::Spill`]).  Admission that still cannot place a
//!   block parks it on the disk tier directly (a brand-new block holds no
//!   KV yet, so the "move" is pure reservation accounting) and, failing
//!   even that, *drops the KV and keeps the X* of prefix blocks — the
//!   Eq. (11) insight turned into a capacity lever.  The dropped prefix
//!   becomes a planner floor (`l ≥ dropped`), reported by
//!   [`KvStore::kv_dropped_tokens`]; the disk-resident prefix feeds the
//!   planner's two-hop term via [`KvStore::disk_resident_tokens`].
//!
//! The residency invariant itself — which blocks are valid, how many
//! tokens each covers, the top-down run order — lives in the `suffix`
//! module's `SuffixRuns` iterator; every walker here is a thin loop over
//! it.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::scheduler::TierTopology;
use crate::transfer::LinkConfig;

use super::block::{BlockId, Tier};
use super::manager::{SharedHostTiers, TierManager, TierStats};
use super::migrate::{MigrationClass, MigrationEngine, MigrationStats};
use super::policy::{BlockView, EvictPolicy};
use super::share::{PrefixRegistry, ShareStats, SharedAdmit};
use super::suffix::{BlockClass, BlockState, PendingRef, SuffixRuns};

/// Construction parameters for a [`KvStore`].
#[derive(Debug, Clone)]
pub struct KvStoreConfig {
    /// gpu-hbm tier capacity — the KV-dedicated slice of device memory.
    pub gpu_bytes: u64,
    /// Pinned host tier capacity (also backs migration staging buffers).
    pub pinned_bytes: u64,
    /// Cold cpu-dram tier capacity.
    pub dram_bytes: u64,
    /// NVMe disk tier capacity below dram; 0 disables the tier (the PR 3
    /// three-tier layout).
    pub disk_bytes: u64,
    /// Tokens per block.  Match the smallest artifact L bucket so dropped-KV
    /// floors land on a real recompute bucket.
    pub block_tokens: usize,
    /// Migration link shaping (PCIe-ish, for gpu↔pinned↔dram hops).
    pub link: LinkConfig,
    /// NVMe link shaping for disk-tier hops (slower, higher latency).
    pub nvme_link: LinkConfig,
    /// Wire bytes per f32 element on migrations: 4.0 plain, 0.625 under
    /// int4 wire quantization.  Tier occupancy always stays full-width.
    pub wire_elem_bytes: f64,
    /// Anti-thrash hysteresis: a block demoted or spilled within the last
    /// `promote_cooldown` *serving steps* ([`KvStore::pump_migrations`]
    /// calls) is not re-promoted.  0 disables the cool-down.
    pub promote_cooldown: u64,
    /// The spill-side mirror of `promote_cooldown`: a block whose
    /// disk→dram hop landed within the last `spill_cooldown` serving
    /// steps is not re-spillable, so a promotion/spill ping-pong under
    /// adversarial alternating reuse is bounded from both directions.
    /// 0 disables the cool-down.
    pub spill_cooldown: u64,
    /// Dram-occupancy floor below the watermark: spill declines while
    /// dram occupancy is at or below this fraction of the tier, bounding
    /// how far below the watermark admission-driven spills can drain the
    /// tier.  0.0 disables the floor.
    pub spill_floor: f64,
    /// Capacity-aware spill: when dram occupancy exceeds this fraction of
    /// the tier, cold blocks spill to disk ahead of admission pressure.
    /// 0.0 (or a zero-capacity disk tier) disables proactive spill.
    pub spill_watermark: f64,
    /// Spills issued per serving step at most (bounds the queue the
    /// leftover budget has to drain).
    pub spill_max_per_step: usize,
    /// Shard-shared host tiers: when set, pinned/dram/disk reservations
    /// draw from these `Arc`-shared pools instead of private ones (the
    /// `pinned_bytes`/`dram_bytes`/`disk_bytes` fields are ignored — the
    /// shared pools carry the capacities), so N worker shards compete for
    /// one host budget.  `None` keeps the single-worker private layout.
    pub shared_host: Option<SharedHostTiers>,
}

impl KvStoreConfig {
    pub fn new(gpu_bytes: u64) -> Self {
        let link = LinkConfig::with_bandwidth(30e6);
        let nvme_link = LinkConfig::nvme_below(&link);
        KvStoreConfig {
            gpu_bytes,
            pinned_bytes: 64 << 20,
            dram_bytes: 256 << 20,
            disk_bytes: 0,
            block_tokens: 32,
            link,
            nvme_link,
            wire_elem_bytes: 4.0,
            promote_cooldown: 4,
            spill_cooldown: 4,
            spill_floor: 0.0,
            spill_watermark: 0.9,
            spill_max_per_step: 2,
            shared_host: None,
        }
    }

    /// Realise a **calibrated** [`TierTopology`] as a store layout: tier
    /// capacities come from the chain's named rungs (a missing rung gets
    /// capacity 0, disabling it), the migration wires are the chain's
    /// declared links paced at `chunk_bytes`, the wire element width and
    /// the dram spill watermark come off the specs.  The runtime knobs
    /// the topology does not describe (block size, cool-downs, per-step
    /// spill bound) keep [`KvStoreConfig::new`]'s defaults — set them on
    /// the returned config.
    pub fn from_topology(topo: &TierTopology, chunk_bytes: usize) -> Self {
        let cap =
            |name: &str| topo.tier_named(name).map_or(0, |i| topo.tier(i).capacity_bytes);
        // the store's gpu↔pinned↔dram wire is the chain's device boundary
        // — tier 1's up-link, the same rung the planner's
        // `primary_bytes_per_sec` slack conversion reads, so the grant and
        // the emulated wire can never disagree
        let link = topo
            .tiers()
            .get(1)
            .filter(|t| t.up.is_resolved())
            .map(|t| t.up.to_link_config(chunk_bytes))
            .unwrap_or_else(LinkConfig::unthrottled);
        // whatever rung sits below the base — an NVMe disk or a sharded
        // worker's remote hop — maps onto the store's deep-tier slot, its
        // declared wire becoming the "nvme" link (same surcharge seam the
        // planner's hop_factor prices)
        let nvme_link = topo
            .deep_tier()
            .map(|i| topo.tier(i).up.to_link_config(chunk_bytes))
            .unwrap_or_else(|| LinkConfig::nvme_below(&link));
        let spill_watermark = topo
            .tier_named(Tier::CpuDram.name())
            .map_or(0.0, |i| topo.tier(i).spill_watermark);
        KvStoreConfig {
            gpu_bytes: cap(Tier::GpuHbm.name()),
            pinned_bytes: cap(Tier::Pinned.name()),
            dram_bytes: cap(Tier::CpuDram.name()),
            disk_bytes: topo.deep_tier().map_or(0, |i| topo.tier(i).capacity_bytes),
            link,
            nvme_link,
            wire_elem_bytes: topo.wire_elem_bytes(),
            spill_watermark,
            ..KvStoreConfig::new(0)
        }
    }
}

/// Per-sequence bookkeeping.
struct SeqEntry {
    blocks: Vec<BlockState>,
    block_bytes: u64,
    /// Valid cached tokens (the paper's s'); grows as decode proceeds.
    tokens: usize,
    /// Latest planner split l* for this sequence (eviction scoring input).
    split_l: usize,
    last_use: u64,
}

impl SeqEntry {
    fn runs(&self, bt: usize) -> SuffixRuns<'_> {
        SuffixRuns::new(&self.blocks, self.tokens, bt)
    }
}

/// Aggregate store counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    pub admitted: u64,
    pub promotions_started: u64,
    pub promotions_landed: u64,
    /// Asynchronous demotions issued (gpu bytes released at issuance).
    pub demotions: u64,
    /// Demotion writebacks that landed in their destination tier.
    pub demotions_landed: u64,
    pub kv_drops: u64,
    /// Landed promotions discarded because an eviction broke the resident
    /// suffix over them while they were in flight.
    pub promotions_wasted: u64,
    /// Top blocks flipped to gpu without link traffic (their KV was
    /// produced on-device by the decode step itself).
    pub device_syncs: u64,
    /// Promotion walks stopped at a cooling-down block (anti-thrash).
    pub cooldown_skips: u64,
    /// Spill candidate scans stopped at a freshly-promoted block (the
    /// spill-side cool-down — anti-thrash in the other direction).
    pub spill_cooldown_skips: u64,
    /// Dram→disk spills issued (dram bytes released at issuance).
    pub spills: u64,
    /// Spill writebacks that landed on the disk tier.
    pub spills_landed: u64,
    /// Disk→dram promotion hops issued (first leg of a two-hop promotion).
    pub hops: u64,
    /// Hops that landed in dram (the block becomes a one-hop candidate).
    pub hops_landed: u64,
    /// Blocks parked on the disk tier directly at admission (no KV moved —
    /// a brand-new block is reservation only).
    pub disk_admissions: u64,
    /// Prefix blocks parked on the deep tier by
    /// [`KvStore::park_prefix_deep`] — a migrated session's KV sitting
    /// behind the shard's remote hop (or policy-placed on disk).
    pub remote_parks: u64,
    /// Stranded resident blocks reclaimed by the per-step sweep: settled
    /// gpu blocks left *below* a non-resident block (the sequence grew but
    /// a full gpu tier kept its new top block cold), where the eviction
    /// walk — which only sees the bottom of the *top* resident run — can
    /// never reach them.
    pub stranded_reclaims: u64,
}

/// The tiered block-granular KV store.
pub struct KvStore {
    mig: MigrationEngine,
    policy: Box<dyn EvictPolicy>,
    seqs: BTreeMap<u64, SeqEntry>,
    block_tokens: usize,
    promote_cooldown: u64,
    spill_cooldown: u64,
    spill_floor: f64,
    spill_watermark: f64,
    spill_max_per_step: usize,
    /// Recency clock: ticks once per [`KvStore::touch`]/[`KvStore::admit`]
    /// (LRU input; advances with *activity*, so it is concurrency-scaled).
    clock: u64,
    /// Serving-step counter: ticks once per [`KvStore::pump_migrations`]
    /// call — the cool-down timebase, so hysteresis spans the same number
    /// of event-loop steps regardless of how many groups are decoding.
    step: u64,
    /// Cross-request prefix sharing, off unless
    /// [`KvStore::enable_prefix_sharing`] opted in.  The registry owns the
    /// host-tier reservations of shared blocks; the adopting sequences'
    /// `BlockState`s are guard-less markers.
    share: Option<PrefixRegistry>,
    stats: StoreStats,
}

impl KvStore {
    pub fn new(cfg: KvStoreConfig, policy: Box<dyn EvictPolicy>) -> Self {
        assert!(cfg.block_tokens > 0, "block_tokens must be positive");
        let mgr = match &cfg.shared_host {
            // a shard: private gpu pool, host reservations charge the
            // shared cross-shard pools
            Some(shared) => {
                TierManager::with_shared_host(cfg.gpu_bytes, shared, cfg.link, cfg.nvme_link)
            }
            None => TierManager::new(
                cfg.gpu_bytes,
                cfg.pinned_bytes,
                cfg.dram_bytes,
                cfg.disk_bytes,
                cfg.link,
                cfg.nvme_link,
            ),
        };
        KvStore {
            mig: MigrationEngine::with_manager(mgr, cfg.wire_elem_bytes),
            policy,
            seqs: BTreeMap::new(),
            block_tokens: cfg.block_tokens,
            promote_cooldown: cfg.promote_cooldown,
            spill_cooldown: cfg.spill_cooldown,
            spill_floor: cfg.spill_floor,
            spill_watermark: cfg.spill_watermark,
            spill_max_per_step: cfg.spill_max_per_step,
            clock: 0,
            step: 0,
            share: None,
            stats: StoreStats::default(),
        }
    }

    /// Opt into cross-request prefix sharing: later
    /// [`KvStore::admit_shared`] calls match, adopt and register
    /// content-hashed prefix blocks through the embedded
    /// [`PrefixRegistry`].  Idempotent; plain [`KvStore::admit`] is
    /// unaffected either way.
    pub fn enable_prefix_sharing(&mut self) {
        if self.share.is_none() {
            self.share = Some(PrefixRegistry::new(self.block_tokens));
        }
    }

    /// Whether [`KvStore::enable_prefix_sharing`] was called.
    pub fn prefix_sharing_enabled(&self) -> bool {
        self.share.is_some()
    }

    /// Registry activity counters (all zero while sharing is off).
    pub fn share_stats(&self) -> ShareStats {
        self.share.as_ref().map(PrefixRegistry::stats).unwrap_or_default()
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn stats(&self) -> StoreStats {
        self.stats
    }

    pub fn tier_stats(&self) -> TierStats {
        self.mig.tier_stats()
    }

    /// Lifecycle counters of the embedded migration engine.
    pub fn migration_stats(&self) -> MigrationStats {
        self.mig.stats()
    }

    /// Wire bytes the embedded migration engine launched under the current
    /// step's grant (the actual half of the serving loop's plan-vs-actual
    /// ledger; resets at each [`KvStore::pump_migrations`]).
    pub fn step_launched_wire_bytes(&self) -> u64 {
        self.mig.step_launched_wire_bytes()
    }

    /// Route the embedded migration engine's lifecycle events into
    /// `tracer` (see [`MigrationEngine::set_tracer`]).
    pub fn set_tracer(&mut self, tracer: crate::obs::Tracer) {
        self.mig.set_tracer(tracer);
    }

    /// Bytes currently reserved in `tier`.
    pub fn tier_used(&self, tier: Tier) -> u64 {
        self.mig.tiers().pool(tier).used()
    }

    /// Admit a sequence whose full-capacity cache is `total_bytes` split
    /// into `n_blocks` blocks.  Blocks are placed cold-first in the *host*
    /// tiers only (dram, then pinned) — the gpu tier is a cache layer
    /// filled exclusively by promotion/sync, so its capacity can never be
    /// parked under not-yet-valid admission blocks that eviction (which
    /// only walks resident suffix runs) could not reclaim.  When the host
    /// tiers are full the store reclaims, in order of preference: spill a
    /// cold valid dram block to disk (full bytes back, KV preserved), park
    /// the new — still empty — block on the disk tier directly, and only
    /// then drop droppable KV prefixes.  On failure the new sequence's
    /// partial reservations roll back and the caller backpressures; spills
    /// already issued for it are *not* undone — they are the same
    /// capacity-relief moves the watermark check would make under the same
    /// dram pressure, and the spilled KV stays reachable (two-hop) — while
    /// KV drops are attempted last precisely because they cannot be.
    pub fn admit(&mut self, seq: u64, total_bytes: u64, n_blocks: usize) -> Result<()> {
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        if n_blocks == 0 {
            bail!("admit with zero blocks");
        }
        let block_bytes = total_bytes.div_ceil(n_blocks as u64);
        // feasibility pre-check, side-effect free: a hopeless admission
        // must not drain other sequences' droppable KV or spill their
        // blocks (the serving loop retries every step, so leaked drops
        // would compound into planner floors for every running group).
        // Spill adds no *net* capacity (it moves bytes host→disk), so the
        // ceiling is host + disk free plus droppable KV.
        let free = self.host_free_bytes();
        if free + self.reclaimable_bytes() < block_bytes * n_blocks as u64 {
            bail!(
                "kvstore cannot fit sequence {seq}: {} bytes needed, {} free + reclaimable",
                block_bytes * n_blocks as u64,
                free + self.reclaimable_bytes()
            );
        }
        let mut blocks = Vec::with_capacity(n_blocks);
        for _ in 0..n_blocks {
            match self.place_host_block(block_bytes) {
                Some((tier, guard)) => blocks.push(BlockState {
                    tier,
                    guard: Some(guard),
                    kv_dropped: false,
                    pending: None,
                    demoted_at: None,
                    promoted_at: None,
                    shared: None,
                }),
                None => {
                    // `blocks` drops here, rolling the reservations back
                    bail!(
                        "kvstore exhausted admitting sequence {seq}: placed {} of {n_blocks} blocks",
                        blocks.len()
                    );
                }
            }
        }
        self.clock += 1;
        self.seqs.insert(
            seq,
            SeqEntry { blocks, block_bytes, tokens: 0, split_l: 0, last_use: self.clock },
        );
        self.stats.admitted += 1;
        Ok(())
    }

    /// Free bytes across every non-gpu tier — the admission feasibility
    /// ceiling (spill moves bytes between these pools, it adds none).
    fn host_free_bytes(&self) -> u64 {
        self.mig.tiers().pool(Tier::CpuDram).available()
            + self.mig.tiers().pool(Tier::Pinned).available()
            + self.mig.tiers().pool(Tier::DiskNvme).available()
    }

    /// One rung of the admission placement ladder — dram, then pinned,
    /// then spill-to-make-room, then park-on-disk, then drop prefix KV —
    /// shared by [`KvStore::admit`] and [`KvStore::admit_shared`].
    fn place_host_block(&mut self, block_bytes: u64) -> Option<(Tier, crate::memory::PoolGuard)> {
        loop {
            if let Some(g) = self.mig.tiers().grab(Tier::CpuDram, block_bytes) {
                break Some((Tier::CpuDram, g));
            }
            if let Some(g) = self.mig.tiers().grab(Tier::Pinned, block_bytes) {
                break Some((Tier::Pinned, g));
            }
            // spill a cold valid block to disk: frees its full dram
            // bytes and keeps its KV reachable (two-hop reload)
            if self.spill_one().is_some() {
                continue;
            }
            // nothing spillable: this (empty) block parks on disk —
            // pure reservation, no bytes cross any wire
            if let Some(g) = self.mig.tiers().grab(Tier::DiskNvme, block_bytes) {
                self.stats.disk_admissions += 1;
                break Some((Tier::DiskNvme, g));
            }
            if self.reclaim_kv_one().is_none() {
                break None;
            }
        }
    }

    /// [`KvStore::admit`] with cross-request prefix sharing: the longest
    /// registered prefix of `prompt` (full blocks only, and never the
    /// whole sequence — decode always owns at least one private block to
    /// grow into) is **adopted** in place at zero new bytes, the rest of
    /// the full prompt blocks are **registered** for later requests, and
    /// only the remainder goes through the ordinary placement ladder.
    /// Sharing off (or no match) degrades to a plain admission.  The
    /// returned [`SharedAdmit`] carries the adopted span — the planner's
    /// zero-transfer `shared_prefix` — and under capacity pressure parked
    /// (refs = 0) registry entries are trimmed LRU-first before the
    /// admission is declared infeasible.  On failure every adoption,
    /// registration and private reservation this call made rolls back.
    pub fn admit_shared(
        &mut self,
        seq: u64,
        total_bytes: u64,
        n_blocks: usize,
        prompt: &[u8],
    ) -> Result<SharedAdmit> {
        if self.share.is_none() {
            self.admit(seq, total_bytes, n_blocks)?;
            return Ok(SharedAdmit::default());
        }
        if self.seqs.contains_key(&seq) {
            bail!("sequence {seq} already admitted");
        }
        if n_blocks == 0 {
            bail!("admit with zero blocks");
        }
        let block_bytes = total_bytes.div_ceil(n_blocks as u64);
        let bt = self.block_tokens;
        let shareable = (prompt.len() / bt).min(n_blocks.saturating_sub(1));
        let chain = PrefixRegistry::chain(&prompt[..shareable * bt], bt);
        let mut matched = {
            let reg = self.share.as_ref().expect("sharing checked on");
            chain.iter().take_while(|h| reg.contains(**h)).count()
        };
        // feasibility, side-effect free: matched blocks cost nothing, so
        // only the private remainder (and fresh registrations, which hold
        // real bytes) count against free + reclaimable
        let mut needed = block_bytes * (n_blocks - matched) as u64;
        let avail = self.host_free_bytes() + self.reclaimable_bytes();
        if avail < needed {
            // parked (refs == 0) registry entries are reclaimable cache;
            // the trim may drop part of the matched chain, so re-match
            self.share.as_mut().expect("sharing checked on").trim(needed - avail);
            let reg = self.share.as_ref().expect("sharing checked on");
            matched = chain.iter().take_while(|h| reg.contains(**h)).count();
            needed = block_bytes * (n_blocks - matched) as u64;
            if self.host_free_bytes() + self.reclaimable_bytes() < needed {
                bail!(
                    "kvstore cannot fit sequence {seq}: {needed} private bytes needed after \
                     a {matched}-block share hit"
                );
            }
        }
        let mut blocks: Vec<BlockState> = Vec::with_capacity(n_blocks);
        let mut adopted: Vec<u64> = Vec::new();
        let mut registered: Vec<u64> = Vec::new();
        let marker = |h: u64| BlockState {
            // the tier is nominal: the registry owns the real reservation
            tier: Tier::CpuDram,
            guard: None,
            kv_dropped: false,
            pending: None,
            demoted_at: None,
            promoted_at: None,
            shared: Some(h),
        };
        for &h in chain.iter().take(matched) {
            let hit = self.share.as_mut().expect("sharing checked on").adopt(h);
            debug_assert!(hit, "matched entry vanished mid-admission");
            adopted.push(h);
            blocks.push(marker(h));
        }
        let mut failed = false;
        // unmatched full prompt blocks: this request is the first writer —
        // the registry takes the reservation, the sequence holds a marker
        for i in matched..shareable {
            match self.place_host_block(block_bytes) {
                Some((_, guard)) => {
                    let h = chain[i];
                    let parent = if i == 0 { None } else { Some(chain[i - 1]) };
                    self.share
                        .as_mut()
                        .expect("sharing checked on")
                        .register(h, parent, block_bytes, Some(guard));
                    registered.push(h);
                    blocks.push(marker(h));
                }
                None => {
                    failed = true;
                    break;
                }
            }
        }
        if !failed {
            for _ in blocks.len()..n_blocks {
                match self.place_host_block(block_bytes) {
                    Some((tier, guard)) => blocks.push(BlockState {
                        tier,
                        guard: Some(guard),
                        kv_dropped: false,
                        pending: None,
                        demoted_at: None,
                        promoted_at: None,
                        shared: None,
                    }),
                    None => {
                        failed = true;
                        break;
                    }
                }
            }
        }
        if failed {
            let reg = self.share.as_mut().expect("sharing checked on");
            // child-first so no unregistration orphans a chained entry
            for &h in registered.iter().rev() {
                reg.unregister(h);
            }
            for &h in &adopted {
                reg.release(h);
            }
            // `blocks` drops here, rolling the private reservations back
            bail!(
                "kvstore exhausted admitting sequence {seq}: placed {} of {n_blocks} blocks \
                 ({matched} shared)",
                blocks.len()
            );
        }
        self.clock += 1;
        self.seqs.insert(
            seq,
            SeqEntry { blocks, block_bytes, tokens: 0, split_l: 0, last_use: self.clock },
        );
        self.stats.admitted += 1;
        Ok(SharedAdmit {
            matched_blocks: matched,
            shared_tokens: matched * bt,
            registered_blocks: registered.len(),
        })
    }

    /// Tokens of `seq`'s leading shared-marker blocks that are already
    /// valid — the zero-transfer `shared_prefix` span handed to the
    /// planner's [`PlanInput`](crate::scheduler::PlanInput).
    pub fn shared_prefix_tokens(&self, seq: u64) -> usize {
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        let blocks = e.blocks.iter().take_while(|b| b.shared.is_some()).count();
        (blocks * self.block_tokens).min(e.tokens)
    }

    /// Park the first `tokens` worth of `seq`'s prefix blocks on the deep
    /// tier (disk, or a sharded worker's remote hop — whichever rung the
    /// topology declared below the base).  This is how cross-shard session
    /// migration is priced: the migrated session's prefix KV lives in host
    /// tiers the new shard reaches only over its remote wire, so the
    /// stealing shard admits the sequence and parks that prefix deep —
    /// pure reservation accounting now (the freshly-admitted blocks hold
    /// no KV), but once decode validates them they count into
    /// [`KvStore::disk_resident_tokens`], the planner's hop-surcharge
    /// term, and reload through the ordinary two-hop promotion path over
    /// the declared remote link.  The walk stops at the first block it
    /// must not move (gpu-resident, migrating, or dropped).  Returns
    /// blocks parked (blocks already deep count as parked).
    pub fn park_prefix_deep(&mut self, seq: u64, tokens: usize) -> usize {
        let want = tokens / self.block_tokens;
        let Some(block_bytes) = self.seqs.get(&seq).map(|e| e.block_bytes) else { return 0 };
        let mut parked = 0;
        for idx in 0..want {
            let Some(b) = self.seqs.get(&seq).and_then(|e| e.blocks.get(idx)) else { break };
            if b.tier == Tier::DiskNvme && b.pending.is_none() && b.shared.is_none() {
                parked += 1;
                continue;
            }
            // copy-on-write divergence: parking a *shared* block moves its
            // bytes, which the other dependents must not see — this
            // sequence takes a private clone under its own deep-tier
            // reservation and stops depending on the registry entry; the
            // shared original keeps its bytes and its other dependents,
            // bit-identical
            if let Some(h) = b.shared {
                let Some(guard) = self.mig.tiers().grab(Tier::DiskNvme, block_bytes) else {
                    break;
                };
                self.share
                    .as_mut()
                    .expect("shared marker implies sharing on")
                    .privatize(h);
                let e = self.seqs.get_mut(&seq).expect("seq checked above");
                let b = &mut e.blocks[idx];
                b.shared = None;
                b.guard = Some(guard);
                b.tier = Tier::DiskNvme;
                self.stats.remote_parks += 1;
                parked += 1;
                continue;
            }
            if b.tier == Tier::GpuHbm || b.pending.is_some() || b.guard.is_none() || b.kv_dropped
            {
                break;
            }
            let Some(guard) = self.mig.tiers().grab(Tier::DiskNvme, block_bytes) else { break };
            let e = self.seqs.get_mut(&seq).expect("seq checked above");
            let b = &mut e.blocks[idx];
            b.guard = Some(guard); // host-tier reservation released
            b.tier = Tier::DiskNvme;
            self.stats.remote_parks += 1;
            parked += 1;
        }
        parked
    }

    /// Retire a sequence, releasing every reservation — without blocking:
    /// queued migrations are dropped on the spot; launched ones are parked
    /// on the engine's drain list and their staging buffers / destination
    /// reservations are reclaimed by a later [`KvStore::poll_landed`] once
    /// the bytes stop moving, so retirement never waits on the link and no
    /// phantom pinned charge is stranded.
    ///
    /// Retirement of a shared-prefix dependent *decrements* the registry
    /// refs instead of freeing: the entries (and their bytes) stay parked
    /// as cross-request cache for the next same-prefix admission.
    pub fn release(&mut self, seq: u64) {
        if let Some(e) = self.seqs.remove(&seq) {
            for b in e.blocks {
                if let Some(p) = b.pending {
                    self.mig.finish(p.id);
                }
                if let Some(h) = b.shared {
                    if let Some(reg) = self.share.as_mut() {
                        reg.release(h);
                    }
                }
            }
        }
    }

    /// Record a decode step: current cached length and the planner's split.
    pub fn touch(&mut self, seq: u64, tokens: usize, split_l: usize) {
        self.clock += 1;
        if let Some(e) = self.seqs.get_mut(&seq) {
            e.tokens = e.tokens.max(tokens);
            e.split_l = split_l;
            e.last_use = self.clock;
        }
    }

    /// Tokens of the sequence's *resident suffix*: the run of settled
    /// gpu-tier blocks ending at the newest valid token.  A block whose
    /// demotion is in flight already released its gpu bytes, so it counts
    /// as a hole — the planner's `resident` input shrinks the moment an
    /// eviction is issued, never after.
    pub fn gpu_resident_tokens(&self, seq: u64) -> usize {
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        e.runs(self.block_tokens).resident_tokens()
    }

    /// Valid tokens of `seq`'s blocks whose demotion *out of the gpu tier*
    /// is currently in flight.  Non-zero means the engine's device window
    /// must shed those rows *this* step (the store's gpu bytes are already
    /// reusable).  Spill writebacks (dram→disk) are never counted: those
    /// blocks were not on device to begin with.
    pub fn demotion_inflight_tokens(&self, seq: u64) -> usize {
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        e.runs(self.block_tokens)
            .filter(|rb| rb.class == BlockClass::DemotionInFlight)
            .map(|rb| rb.tokens)
            .sum()
    }

    /// Length of the contiguous dropped-KV prefix — the planner's `l` floor.
    pub fn kv_dropped_tokens(&self, seq: u64) -> usize {
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        e.blocks.iter().take_while(|b| b.kv_dropped).count() * self.block_tokens
    }

    /// Valid tokens of the sequence's *disk-side prefix*: blocks settled
    /// on (or writing back to, or hopping up from) the disk tier in the
    /// contiguous region above the dropped prefix.  The planner's two-hop
    /// transfer term: fetching these tokens this step costs an NVMe hop on
    /// top of the interconnect, so a split that covers them by recompute
    /// may win even when the three-tier plan would not recompute at all.
    pub fn disk_resident_tokens(&self, seq: u64) -> usize {
        let Some(e) = self.seqs.get(&seq) else { return 0 };
        let bt = self.block_tokens;
        let valid = SuffixRuns::valid_blocks(e.tokens, bt, e.blocks.len());
        let mut total = 0;
        for idx in 0..valid {
            match e.blocks[idx].class() {
                // dropped and shared blocks cost the fetch term nothing;
                // the disk-side scan continues above them
                BlockClass::Dropped | BlockClass::Shared => {}
                BlockClass::Disk | BlockClass::SpillInFlight | BlockClass::HopInFlight => {
                    total += SuffixRuns::tokens_at(e.tokens, bt, idx);
                }
                _ => break,
            }
        }
        total
    }

    /// Migrations open (queued or in flight) across all sequences.
    pub fn pending_count(&self) -> usize {
        self.mig.open_count()
    }

    /// Open migrations belonging to `seq`'s blocks.
    pub fn pending_count_of(&self, seq: u64) -> usize {
        self.seqs
            .get(&seq)
            .map_or(0, |e| e.blocks.iter().filter(|b| b.pending.is_some()).count())
    }

    /// Canceled migrations (released sequences) whose tier reservations
    /// are still draining — reclaimed by [`KvStore::poll_landed`] once
    /// their transfers stop moving.  Admission that fails while this is
    /// non-zero should poll and retry rather than give up: the bytes are
    /// coming back.
    pub fn draining_count(&self) -> usize {
        self.mig.draining_count()
    }

    /// The engine keeps the newest `engine_resident` tokens on device for
    /// free (their K/V was just computed there); mirror that into the gpu
    /// tier's accounting where the budget allows — no link traffic — and
    /// return the store-backed resident token count.  A disk-parked block
    /// flips the same way (its rows were just produced on device; the disk
    /// reservation simply rolls back).  When the gpu tier cannot back the
    /// engine's window, the returned count is smaller and the caller
    /// demotes the engine window to match (budget enforcement).
    pub fn sync_device_suffix(&mut self, seq: u64, engine_resident: usize) -> usize {
        let bt = self.block_tokens;
        let todo: Vec<usize> = {
            let Some(e) = self.seqs.get(&seq) else { return 0 };
            let mut todo = Vec::new();
            let mut covered = 0usize;
            for rb in e.runs(bt) {
                if covered >= engine_resident {
                    break;
                }
                covered += rb.tokens;
                match rb.class {
                    // a migration is already moving this one; let it land
                    BlockClass::PromotionInFlight
                    | BlockClass::DemotionInFlight
                    | BlockClass::HopInFlight
                    | BlockClass::SpillInFlight => break,
                    // the registry owns a shared marker's bytes — the
                    // device window never flips it
                    BlockClass::Shared => break,
                    BlockClass::Host | BlockClass::Disk => todo.push(rb.idx),
                    BlockClass::Resident | BlockClass::Dropped => {}
                }
            }
            todo
        };
        let Some(block_bytes) = self.seqs.get(&seq).map(|e| e.block_bytes) else { return 0 };
        for idx in todo {
            let Some(guard) = self.mig.tiers().grab(Tier::GpuHbm, block_bytes) else { break };
            let Some(e) = self.seqs.get_mut(&seq) else { break };
            let b = &mut e.blocks[idx];
            b.guard = Some(guard); // old tier reservation released
            b.tier = Tier::GpuHbm;
            self.stats.device_syncs += 1;
        }
        self.gpu_resident_tokens(seq)
    }

    /// Queue up to `max_blocks` promotions extending `seq`'s resident
    /// suffix downward.  A host block promotes in one hop; a disk block
    /// promotes in two — this walk issues the disk→dram leg (NVMe wire)
    /// and a *later* step's walk finds the landed block in dram and issues
    /// the dram→gpu leg, so two-hop promotions stage across steps without
    /// ever blocking.  When the gpu tier is full, the eviction policy's
    /// demotion lens picks other sequences' run-start blocks to demote
    /// asynchronously — their gpu bytes free immediately.  A block still
    /// cooling down from a recent demotion or spill stops the walk
    /// (anti-thrash).  The migrations launch on later
    /// [`KvStore::pump_migrations`] calls, within the step budget.
    /// Returns migrations queued.
    pub fn begin_promotions(
        &mut self,
        seq: u64,
        max_blocks: usize,
        class: MigrationClass,
    ) -> usize {
        let bt = self.block_tokens;
        let cooldown = self.promote_cooldown;
        let step = self.step;
        let mut cooled = 0u64;
        let (targets, block_bytes) = {
            let Some(e) = self.seqs.get(&seq) else { return 0 };
            let mut targets: Vec<(usize, bool)> = Vec::new();
            // a disk block above (settled or mid-hop) caps every deeper
            // block at the dram rung: a gpu promotion issued under it
            // would land suffix-broken and be discarded by poll_landed,
            // wasting the wire bytes and the budget they rode on
            let mut hop_above = false;
            for rb in e.runs(bt) {
                if targets.len() >= max_blocks {
                    break;
                }
                match rb.class {
                    // part of the established run / already on its way up
                    BlockClass::Resident | BlockClass::PromotionInFlight => continue,
                    BlockClass::HopInFlight => {
                        hop_above = true;
                        continue;
                    }
                    // a hole being written back, nothing to promote below
                    // a dropped prefix, and shared markers never migrate
                    // (the planner prices them at zero transfer instead)
                    BlockClass::DemotionInFlight
                    | BlockClass::SpillInFlight
                    | BlockClass::Dropped
                    | BlockClass::Shared => break,
                    BlockClass::Host | BlockClass::Disk => {
                        let is_hop = rb.class == BlockClass::Disk;
                        if !is_hop && hop_above {
                            // already in dram; nothing useful to issue
                            // until the hop above settles
                            continue;
                        }
                        if cooldown > 0 {
                            if let Some(at) = e.blocks[rb.idx].demoted_at {
                                if step.saturating_sub(at) < cooldown {
                                    // freshly demoted/spilled: promoting it
                                    // back would ping-pong with the move
                                    // that just freed it
                                    cooled += 1;
                                    break;
                                }
                            }
                        }
                        targets.push((rb.idx, is_hop));
                        if is_hop {
                            hop_above = true;
                        }
                    }
                }
            }
            (targets, e.block_bytes)
        };
        self.stats.cooldown_skips += cooled;
        let mut issued = 0;
        'targets: for (idx, is_hop) in targets {
            let pend = if is_hop {
                // first leg of the two-hop promotion: disk→dram.  A full
                // dram tier gets one spill attempt to make room; failing
                // that, the walk stops and retries next step.
                let bid = BlockId { seq, idx };
                let mut req =
                    self.mig.request(bid, Tier::DiskNvme, Tier::CpuDram, block_bytes, class);
                if req.is_none() && self.spill_one().is_some() {
                    req =
                        self.mig.request(bid, Tier::DiskNvme, Tier::CpuDram, block_bytes, class);
                }
                let Some(id) = req else { break 'targets };
                self.stats.hops += 1;
                PendingRef { id, to: Tier::CpuDram }
            } else {
                // evict until the block fits: victims' blocks may be smaller
                // than ours (different batch buckets), so one demotion is not
                // always enough; the loop is bounded by the candidate supply
                let bid = BlockId { seq, idx };
                let from = self
                    .seqs
                    .get(&seq)
                    .map_or(Tier::CpuDram, |e| e.blocks[idx].tier);
                let id = loop {
                    if let Some(id) =
                        self.mig.request(bid, from, Tier::GpuHbm, block_bytes, class)
                    {
                        break id;
                    }
                    if !self.evict_gpu_victim(seq) {
                        break 'targets;
                    }
                };
                self.stats.promotions_started += 1;
                PendingRef { id, to: Tier::GpuHbm }
            };
            let Some(e) = self.seqs.get_mut(&seq) else { break };
            e.blocks[idx].pending = Some(pend);
            issued += 1;
        }
        issued
    }

    /// Grant this step's link-byte budget and launch queued migrations
    /// against it (class order: demand promotions, demotions, prefetch,
    /// spill).  Before granting, the capacity-aware spill check runs: dram
    /// occupancy above the watermark queues cold-block spills — strictly
    /// leftover-budget traffic — so admission pressure is relieved ahead
    /// of the backpressure it would otherwise become.  Returns migrations
    /// launched.  The serving loop calls this once per step; completions
    /// come back through [`KvStore::poll_landed`].
    pub fn pump_migrations(&mut self, budget_bytes: u64) -> usize {
        self.step += 1; // the cool-down timebase: one tick per serving step
        self.spill_to_watermark();
        self.sweep_stranded_residents();
        self.mig.begin_step(budget_bytes);
        self.mig.pump()
    }

    /// Install every landed migration (non-blocking); returns how many
    /// were installed.  Demotions, spills and hops settle unconditionally
    /// in their destination tier.  A landed *promotion* is only installed
    /// into the gpu tier while it still extends the resident suffix from
    /// above — if an eviction opened a hole over it in the meantime,
    /// installing would strand gpu bytes no eviction walk can ever reach,
    /// so the new reservation is dropped and the block stays where it was.
    pub fn poll_landed(&mut self) -> usize {
        let mut landed_total = 0;
        let step = self.step;
        let mut promos: BTreeMap<u64, Vec<(usize, crate::memory::PoolGuard)>> = BTreeMap::new();
        for l in self.mig.poll() {
            if l.to == Tier::GpuHbm {
                promos.entry(l.block.seq).or_default().push((l.block.idx, l.guard));
            } else {
                // demotion/spill writeback or disk→dram hop: install in
                // the destination tier
                let Some(e) = self.seqs.get_mut(&l.block.seq) else { continue };
                let b = &mut e.blocks[l.block.idx];
                debug_assert!(b.pending.as_ref().is_some_and(|p| p.id == l.id));
                let was = b.tier;
                b.pending = None;
                b.guard = Some(l.guard);
                b.tier = l.to;
                if was == Tier::GpuHbm {
                    self.stats.demotions_landed += 1;
                } else if l.to < was {
                    // the hop moved the block *up*: start its spill-side
                    // cool-down so it is not immediately re-spillable
                    b.promoted_at = Some(step);
                    self.stats.hops_landed += 1;
                } else {
                    self.stats.spills_landed += 1;
                }
                landed_total += 1;
            }
        }
        let bt = self.block_tokens;
        for (seq, mut list) in promos {
            let Some(e) = self.seqs.get_mut(&seq) else { continue };
            // walk top-down so an upper block landing this pass extends
            // the run before the one below it is judged; ascending sort so
            // the tail of the list is always the next (largest) index
            list.sort_by_key(|(i, _)| *i);
            let mut suffix_ok = true;
            let mut idx = SuffixRuns::valid_blocks(e.tokens, bt, e.blocks.len());
            while idx > 0 {
                idx -= 1;
                if list.last().is_some_and(|(i, _)| *i == idx) {
                    let (_, guard) = list.pop().unwrap();
                    let b = &mut e.blocks[idx];
                    b.pending = None;
                    if suffix_ok {
                        b.guard = Some(guard);
                        b.tier = Tier::GpuHbm;
                        self.stats.promotions_landed += 1;
                        landed_total += 1;
                    } else {
                        self.stats.promotions_wasted += 1;
                        // guard drops: the gpu reservation rolls back
                    }
                }
                let b = &e.blocks[idx];
                // an in-flight promotion still counts as run-extending (it
                // will land); anything else non-resident is a hole
                match b.class() {
                    BlockClass::Resident | BlockClass::PromotionInFlight => {}
                    _ => suffix_ok = false,
                }
            }
            // landed promotions for blocks past the valid range (can only
            // happen if tokens shrank, which they never do) — drop guards
            debug_assert!(list.is_empty(), "landed promotion outside the valid range");
        }
        landed_total
    }

    /// Issue an asynchronous demotion of one other sequence's run-start
    /// block (the policy's demotion lens): the destination reservation is
    /// taken in a lower tier — pinned, then dram, then disk as the last
    /// resort — the victim's gpu bytes free **immediately**, and the
    /// writeback rides its wire under the step budget.  Returns false when
    /// there is no candidate or no room below.
    fn evict_gpu_victim(&mut self, exclude_seq: u64) -> bool {
        let bt = self.block_tokens;
        let mut cands: Vec<BlockView> = Vec::new();
        for (&sid, e) in self.seqs.iter() {
            if sid == exclude_seq {
                continue;
            }
            // the lowest block of the top gpu run: evicting it keeps the
            // remaining residency a suffix
            let run_start = e
                .runs(bt)
                .take_while(|rb| rb.class == BlockClass::Resident)
                .map(|rb| rb.idx)
                .last();
            if let Some(idx) = run_start {
                cands.push(BlockView {
                    id: BlockId { seq: sid, idx },
                    tokens: SuffixRuns::tokens_at(e.tokens, bt, idx),
                    start_token: idx * bt,
                    seq_len: e.tokens,
                    last_use: e.last_use,
                    split_l: e.split_l,
                    // shared blocks never reach the gpu tier, so demotion
                    // candidates are always private
                    shared_refs: 0,
                });
            }
        }
        if cands.is_empty() {
            return false;
        }
        let v = cands[self.policy.demote_victim(&cands)];
        self.demote_block(v.id.seq, v.id.idx)
    }

    /// Issue the asynchronous demotion of one settled gpu block: the
    /// destination reservation is taken in a lower tier — pinned, then
    /// dram, then disk as the last resort — the gpu bytes free
    /// **immediately**, and the writeback rides its wire under the step
    /// budget.  Returns false when no tier below has room.
    fn demote_block(&mut self, seq: u64, idx: usize) -> bool {
        let Some(bytes) = self.seqs.get(&seq).map(|e| e.block_bytes) else { return false };
        let bid = BlockId { seq, idx };
        let req = self
            .mig
            .request(bid, Tier::GpuHbm, Tier::Pinned, bytes, MigrationClass::Demote)
            .map(|id| (id, Tier::Pinned))
            .or_else(|| {
                self.mig
                    .request(bid, Tier::GpuHbm, Tier::CpuDram, bytes, MigrationClass::Demote)
                    .map(|id| (id, Tier::CpuDram))
            })
            .or_else(|| {
                self.mig
                    .request(bid, Tier::GpuHbm, Tier::DiskNvme, bytes, MigrationClass::Demote)
                    .map(|id| (id, Tier::DiskNvme))
            });
        let Some((id, to)) = req else { return false };
        let step = self.step;
        let Some(e) = self.seqs.get_mut(&seq) else { return false };
        let b = &mut e.blocks[idx];
        b.guard = None; // gpu reservation released *now*: no link wait
        b.pending = Some(PendingRef { id, to });
        b.demoted_at = Some(step);
        self.stats.demotions += 1;
        true
    }

    /// Reclaim **stranded** residents: settled gpu blocks sitting below a
    /// non-resident block.  The eviction walk only ever demotes the bottom
    /// of a sequence's *top* resident run, so a block that stays resident
    /// while the sequence grows past it — tokens advanced but a full gpu
    /// tier kept the new top block cold — is unreachable to it, and its
    /// gpu bytes would be pinned until the sequence retires.  (It is not
    /// counted by [`KvStore::gpu_resident_tokens`] either, so it shrinks
    /// no transfer term: pure waste.)  The sweep demotes such blocks
    /// asynchronously, exactly like an eviction, and runs once per
    /// [`KvStore::pump_migrations`] step.
    fn sweep_stranded_residents(&mut self) {
        let bt = self.block_tokens;
        let mut stranded: Vec<(u64, usize)> = Vec::new();
        for (&sid, e) in self.seqs.iter() {
            let mut suffix_ok = true;
            for rb in e.runs(bt) {
                match rb.class {
                    // the same run-extension rule as poll_landed's install
                    // gate: an in-flight promotion will land and join the
                    // suffix
                    BlockClass::Resident | BlockClass::PromotionInFlight if suffix_ok => {}
                    BlockClass::Resident => stranded.push((sid, rb.idx)),
                    _ => suffix_ok = false,
                }
            }
        }
        for (sid, idx) in stranded {
            if !self.demote_block(sid, idx) {
                break; // no room below: leave the rest for a later step
            }
            self.stats.stranded_reclaims += 1;
        }
    }

    /// Capacity-aware spill: while dram occupancy sits above the
    /// watermark, move cold valid blocks to disk (bounded per step).
    fn spill_to_watermark(&mut self) {
        if self.spill_watermark <= 0.0 {
            return;
        }
        // no disk tier: never pay the candidate scan (three-tier layouts
        // keep the default watermark but can't spill anywhere)
        if self.mig.tiers().pool(Tier::DiskNvme).capacity() == 0 {
            return;
        }
        let cap = self.mig.tiers().pool(Tier::CpuDram).capacity();
        if cap == 0 {
            return;
        }
        let mut spilled = 0;
        while spilled < self.spill_max_per_step {
            let used = self.mig.tiers().pool(Tier::CpuDram).used();
            if (used as f64) <= self.spill_watermark * cap as f64 {
                break;
            }
            if self.spill_one().is_none() {
                break;
            }
            spilled += 1;
        }
    }

    /// Spill one cold block to the disk tier (the policy's spill lens):
    /// the disk reservation is taken, the dram bytes free **immediately**,
    /// and the writeback rides the NVMe wire as leftover-budget
    /// [`MigrationClass::Spill`] traffic.  Per sequence the only candidate
    /// is the block *extending its contiguous dropped/disk-side prefix*
    /// (and it must be a fully-valid, settled dram block), so the spilled
    /// region stays literally prefix-shaped — which is what keeps
    /// [`KvStore::disk_resident_tokens`]' lens (and the planner/sim
    /// two-hop terms built on it) honest.  A pinned, resident or
    /// in-flight block ends a sequence's spillable prefix, and so does a
    /// block whose disk→dram hop landed within the last `spill_cooldown`
    /// steps (the spill-side anti-thrash hysteresis).  Spill also
    /// declines outright while dram occupancy sits at or below the
    /// `spill_floor` fraction — admission-driven spills cannot drain the
    /// tier arbitrarily far under the watermark.  Returns the dram bytes
    /// freed, or `None` when nothing is spillable / the disk tier is
    /// full.
    fn spill_one(&mut self) -> Option<u64> {
        if self.mig.tiers().pool(Tier::DiskNvme).capacity() == 0 {
            return None;
        }
        if self.spill_floor > 0.0 {
            let dram = self.mig.tiers().pool(Tier::CpuDram);
            if (dram.used() as f64) <= self.spill_floor * dram.capacity() as f64 {
                return None;
            }
        }
        let bt = self.block_tokens;
        let cooldown = self.spill_cooldown;
        let step = self.step;
        let mut cooled = 0u64;
        let mut cands: Vec<BlockView> = Vec::new();
        for (&sid, e) in self.seqs.iter() {
            for (idx, b) in e.blocks.iter().enumerate() {
                if (idx + 1) * bt > e.tokens {
                    break; // only fully-valid blocks carry spillable KV
                }
                match b.class() {
                    // already below the line (or owned by the registry,
                    // which never spills): the prefix continues above
                    BlockClass::Dropped
                    | BlockClass::Disk
                    | BlockClass::SpillInFlight
                    | BlockClass::Shared => continue,
                    // dram-settled: the one block that extends the prefix
                    BlockClass::Host if b.tier == Tier::CpuDram => {
                        if cooldown > 0 {
                            if let Some(at) = b.promoted_at {
                                if step.saturating_sub(at) < cooldown {
                                    // it just hopped up; spilling it back
                                    // would ping-pong with that promotion
                                    cooled += 1;
                                    break;
                                }
                            }
                        }
                        cands.push(BlockView {
                            id: BlockId { seq: sid, idx },
                            tokens: bt,
                            start_token: idx * bt,
                            seq_len: e.tokens,
                            last_use: e.last_use,
                            split_l: e.split_l,
                            shared_refs: 0,
                        });
                        break;
                    }
                    // pinned, resident or in-flight: spilling anything
                    // above it would break the prefix lens — stop here
                    _ => break,
                }
            }
        }
        self.stats.spill_cooldown_skips += cooled;
        if cands.is_empty() {
            return None;
        }
        let v = cands[self.policy.spill_victim(&cands)];
        let bytes = self.seqs.get(&v.id.seq).map(|e| e.block_bytes)?;
        let id =
            self.mig
                .request(v.id, Tier::CpuDram, Tier::DiskNvme, bytes, MigrationClass::Spill)?;
        let step = self.step;
        let e = self.seqs.get_mut(&v.id.seq)?;
        let b = &mut e.blocks[v.id.idx];
        b.guard = None; // dram bytes free *now*; writeback rides NVMe later
        b.pending = Some(PendingRef { id, to: Tier::DiskNvme });
        b.demoted_at = Some(step); // anti-thrash: no instant re-promotion
        self.stats.spills += 1;
        Some(bytes)
    }

    /// Bytes that dropping every currently-droppable KV prefix would free
    /// (the contiguous chain of fully-valid, non-gpu, settled blocks
    /// above each sequence's dropped prefix) — the admission pre-check's
    /// reclaim ceiling.
    fn reclaimable_bytes(&self) -> u64 {
        let bt = self.block_tokens;
        let mut total = 0u64;
        for e in self.seqs.values() {
            let kv = e.block_bytes - e.block_bytes.div_ceil(3);
            let mut idx = e.blocks.iter().take_while(|b| b.kv_dropped).count();
            while idx < e.blocks.len() {
                let b = &e.blocks[idx];
                // a shared marker ends the droppable chain: its KV belongs
                // to the registry and other dependents still need it
                if (idx + 1) * bt > e.tokens
                    || b.tier == Tier::GpuHbm
                    || b.pending.is_some()
                    || b.shared.is_some()
                {
                    break;
                }
                total += kv;
                idx += 1;
            }
        }
        total
    }

    /// Drop the KV (keep X) of one policy-chosen block, freeing ≈⅔ of its
    /// bytes in place.  Only fully-valid, non-gpu, settled blocks extending
    /// a sequence's contiguous dropped prefix qualify.  Returns bytes freed.
    fn reclaim_kv_one(&mut self) -> Option<u64> {
        let bt = self.block_tokens;
        let mut cands: Vec<BlockView> = Vec::new();
        for (&sid, e) in self.seqs.iter() {
            let idx = e.blocks.iter().take_while(|b| b.kv_dropped).count();
            if idx >= e.blocks.len() {
                continue;
            }
            let b = &e.blocks[idx];
            if (idx + 1) * bt > e.tokens
                || b.tier == Tier::GpuHbm
                || b.pending.is_some()
                || b.shared.is_some()
            {
                continue;
            }
            cands.push(BlockView {
                id: BlockId { seq: sid, idx },
                tokens: bt,
                start_token: idx * bt,
                seq_len: e.tokens,
                last_use: e.last_use,
                split_l: e.split_l,
                shared_refs: 0,
            });
        }
        if cands.is_empty() {
            return None;
        }
        let v = cands[self.policy.victim(&cands)];
        let (tier, bytes) = {
            let e = self.seqs.get(&v.id.seq)?;
            (e.blocks[v.id.idx].tier, e.block_bytes)
        };
        let x_bytes = bytes.div_ceil(3); // X is one of the three K/V/X tensors
        // shrink in place: release the full-block guard, re-grab X-only
        self.seqs.get_mut(&v.id.seq)?.blocks[v.id.idx].guard = None;
        let guard = self.mig.tiers().grab(tier, x_bytes);
        let e = self.seqs.get_mut(&v.id.seq)?;
        let b = &mut e.blocks[v.id.idx];
        b.guard = guard;
        b.kv_dropped = true;
        self.stats.kv_drops += 1;
        Some(bytes - x_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::policy::Lru;

    const BB: u64 = 3000; // block bytes in these tests

    fn store(gpu_blocks: u64, pinned_blocks: u64, dram_blocks: u64) -> KvStore {
        store_cfg(gpu_blocks, pinned_blocks, dram_blocks, |_| {})
    }

    fn store_cfg(
        gpu_blocks: u64,
        pinned_blocks: u64,
        dram_blocks: u64,
        tweak: impl FnOnce(&mut KvStoreConfig),
    ) -> KvStore {
        let mut cfg = KvStoreConfig {
            gpu_bytes: gpu_blocks * BB,
            pinned_bytes: pinned_blocks * BB,
            dram_bytes: dram_blocks * BB,
            disk_bytes: 0, // three-tier layout unless a test opts in
            block_tokens: 16,
            link: LinkConfig::unthrottled(),
            nvme_link: LinkConfig::unthrottled(),
            wire_elem_bytes: 4.0,
            promote_cooldown: 0, // most tests want no hysteresis
            spill_cooldown: 0,
            spill_floor: 0.0,
            spill_watermark: 0.0, // proactive spill off unless opted in
            spill_max_per_step: 2,
            shared_host: None,
        };
        tweak(&mut cfg);
        KvStore::new(cfg, Box::new(Lru))
    }

    /// Launch everything queued (unbounded budget) and poll until `want`
    /// migrations have installed.
    fn pump_and_land(s: &mut KvStore, want: usize) -> usize {
        s.pump_migrations(u64::MAX);
        let mut total = 0;
        for _ in 0..500 {
            total += s.poll_landed();
            if total >= want {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        total
    }

    #[test]
    fn park_prefix_deep_moves_fresh_host_blocks_to_the_deep_tier() {
        let mut s = store_cfg(2, 2, 4, |c| c.disk_bytes = 8 * BB);
        s.admit(1, 4 * BB, 4).unwrap();
        // park the first two blocks' worth of tokens (block_tokens = 16)
        assert_eq!(s.park_prefix_deep(1, 32), 2);
        assert_eq!(s.tier_used(Tier::DiskNvme), 2 * BB);
        assert_eq!(s.stats().remote_parks, 2);
        // idempotent: already-deep blocks count without re-reserving
        assert_eq!(s.park_prefix_deep(1, 32), 2);
        assert_eq!(s.tier_used(Tier::DiskNvme), 2 * BB);
        assert_eq!(s.stats().remote_parks, 2);
        // once decode validates them, the parked prefix is the planner's
        // deep (hop-surcharged) term
        s.touch(1, 64, 0);
        assert_eq!(s.disk_resident_tokens(1), 32);
    }

    #[test]
    fn park_prefix_deep_stops_at_zero_capacity_deep_tier() {
        let mut s = store(2, 2, 4); // disk_bytes = 0
        s.admit(1, 2 * BB, 2).unwrap();
        assert_eq!(s.park_prefix_deep(1, 32), 0, "no deep capacity, nothing moves");
        assert_eq!(s.tier_used(Tier::DiskNvme), 0);
    }

    #[test]
    fn admit_places_cold_first_in_host_tiers_and_rolls_back() {
        let mut s = store(1, 1, 2);
        s.admit(1, 3 * BB, 3).unwrap();
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB);
        assert_eq!(s.tier_used(Tier::Pinned), BB);
        // the gpu tier is a promotion-only cache: admission never parks
        // blocks there, so eviction can always reclaim it
        assert_eq!(s.tier_used(Tier::GpuHbm), 0);
        // host tiers full, no disk, nothing droppable (tokens == 0) →
        // fails clean
        let used_before: u64 = Tier::ALL.iter().map(|&t| s.tier_used(t)).sum();
        assert!(s.admit(2, 2 * BB, 2).is_err());
        let used_after: u64 = Tier::ALL.iter().map(|&t| s.tier_used(t)).sum();
        assert_eq!(used_before, used_after, "failed admit must roll back");
    }

    #[test]
    fn release_frees_everything() {
        let mut s = store(0, 0, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        assert_eq!(s.tier_used(Tier::CpuDram), 4 * BB);
        s.release(1);
        assert_eq!(s.tier_used(Tier::CpuDram), 0);
    }

    #[test]
    fn device_suffix_sync_respects_gpu_budget() {
        let mut s = store(1, 0, 4); // gpu fits one block
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 40, 0); // 3 valid blocks (16+16+8 tokens)
        // engine says its window covers 24 tokens (top partial 8 + one full 16)
        let r = s.sync_device_suffix(1, 24);
        assert_eq!(r, 8, "budget backs only the top block (8 valid tokens)");
        assert_eq!(s.tier_used(Tier::GpuHbm), BB);
        assert_eq!(s.stats().device_syncs, 1);
    }

    #[test]
    fn promotions_queue_launch_and_land() {
        let mut s = store(2, 0, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 32, 0); // blocks 0 and 1 valid
        let issued = s.begin_promotions(1, 2, MigrationClass::Promote);
        assert_eq!(issued, 2);
        assert_eq!(s.pending_count(), 2);
        // queued migrations do not move until the step grants link budget
        assert_eq!(s.poll_landed(), 0);
        assert_eq!(s.migration_stats().launched, 0);
        // in-flight promotions do not count as resident yet
        assert_eq!(s.gpu_resident_tokens(1), 0);
        assert_eq!(pump_and_land(&mut s, 2), 2);
        assert_eq!(s.gpu_resident_tokens(1), 32);
        assert_eq!(s.tier_used(Tier::GpuHbm), 2 * BB);
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB, "source reservations released");
        assert_eq!(s.stats().promotions_landed, 2);
        assert_eq!(s.migration_stats().landed, 2);
    }

    #[test]
    fn step_budget_spreads_launches_across_steps() {
        let mut s = store(4, 0, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 64, 0); // all 4 blocks valid
        assert_eq!(s.begin_promotions(1, 4, MigrationClass::Promote), 4);
        // one block's wire bytes per step: four steps to launch the queue
        for step in 1..=4 {
            assert_eq!(s.pump_migrations(BB), 1, "step {step} launches one");
        }
        assert_eq!(s.migration_stats().budget_deferrals, 3);
        let mut landed = 0;
        for _ in 0..500 {
            landed += s.poll_landed();
            if landed >= 4 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(landed, 4);
        assert_eq!(s.gpu_resident_tokens(1), 64);
    }

    #[test]
    fn full_gpu_tier_evicts_other_seq_without_blocking() {
        let mut s = store(1, 1, 4);
        s.admit(1, 2 * BB, 2).unwrap();
        s.admit(2, 2 * BB, 2).unwrap();
        s.touch(1, 16, 0);
        assert_eq!(s.sync_device_suffix(1, 16), 16, "seq 1 takes the gpu block");
        s.touch(2, 16, 0); // seq 2 is now more recent than seq 1
        let issued = s.begin_promotions(2, 1, MigrationClass::Promote);
        assert_eq!(issued, 1, "async eviction must have made room instantly");
        assert!(s.stats().demotions >= 1);
        // the victim is non-resident from the instant the demotion is
        // issued (its gpu bytes are already reusable) — no link wait
        assert_eq!(s.gpu_resident_tokens(1), 0, "lru victim demoted");
        assert!(s.demotion_inflight_tokens(1) > 0, "writeback still in flight");
        pump_and_land(&mut s, 2); // the demotion writeback + the promotion
        assert_eq!(s.gpu_resident_tokens(2), 16);
        assert_eq!(s.demotion_inflight_tokens(1), 0);
        assert_eq!(s.stats().demotions_landed, 1);
        // the victim settled one tier down
        assert_eq!(s.tier_used(Tier::Pinned), BB);
    }

    #[test]
    fn cooldown_blocks_repromotion_of_fresh_victim() {
        let mut s = store_cfg(1, 2, 4, |c| c.promote_cooldown = 3);
        s.admit(1, 2 * BB, 2).unwrap();
        s.admit(2, 2 * BB, 2).unwrap();
        s.touch(1, 16, 0);
        assert_eq!(s.sync_device_suffix(1, 16), 16);
        s.touch(2, 16, 0);
        // seq 2 steals the only gpu block; seq 1's block 0 is demoted
        assert_eq!(s.begin_promotions(2, 1, MigrationClass::Promote), 1);
        pump_and_land(&mut s, 2); // one pump = serving step 1
        assert_eq!(s.gpu_resident_tokens(2), 16);
        // seq 1 immediately wants back in: the cool-down stops the
        // ping-pong (without it this would demote seq 2 right away).
        // Touch activity does NOT age the cool-down — only serving steps
        // do, so heavy concurrency cannot wear the hysteresis off early.
        s.touch(1, 16, 0);
        s.touch(1, 16, 0);
        s.touch(1, 16, 0);
        assert_eq!(s.begin_promotions(1, 1, MigrationClass::Promote), 0);
        assert_eq!(s.stats().cooldown_skips, 1);
        assert_eq!(s.stats().demotions, 1, "no second demotion");
        // two more serving steps age the victim past the cool-down
        s.pump_migrations(0); // step 2
        s.pump_migrations(0); // step 3
        assert_eq!(s.begin_promotions(1, 1, MigrationClass::Promote), 1);
        assert!(s.stats().demotions >= 2);
    }

    #[test]
    fn stranded_resident_below_a_cold_top_block_is_swept_back() {
        // gpu fits one block; seq 1's first block flips resident, then the
        // sequence grows and the full gpu tier keeps the new top block
        // cold: the settled resident block now sits *below* a never-flipped
        // block, where the eviction walk (bottom of the *top* resident run
        // only) can never reach it
        let mut s = store(1, 2, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 16, 0);
        assert_eq!(s.sync_device_suffix(1, 16), 16);
        s.touch(1, 32, 0);
        assert_eq!(s.sync_device_suffix(1, 32), 0, "gpu full: the new top block stays cold");
        assert_eq!(s.tier_used(Tier::GpuHbm), BB, "…but the old resident block holds gpu bytes");

        // the regression: another sequence cannot promote — the walk finds
        // no victim, yet the tier is "full" of unreachable bytes
        s.admit(2, BB, 1).unwrap();
        s.touch(2, 16, 0);
        assert_eq!(s.begin_promotions(2, 1, MigrationClass::Promote), 0);
        assert_eq!(s.stats().demotions, 0, "eviction never saw the stranded block");
        assert_eq!(s.tier_used(Tier::GpuHbm), BB, "gpu bytes stranded");

        // the per-step sweep demotes the stranded block like any other
        // async eviction: gpu bytes free at issuance
        s.pump_migrations(u64::MAX);
        assert_eq!(s.stats().stranded_reclaims, 1);
        assert_eq!(s.stats().demotions, 1);
        assert_eq!(s.tier_used(Tier::GpuHbm), 0);
        assert_eq!(s.begin_promotions(2, 1, MigrationClass::Promote), 1, "tier reclaimed");
        assert!(pump_and_land(&mut s, 2) >= 2, "demotion writeback + promotion land");
        assert_eq!(s.gpu_resident_tokens(2), 16);
        // the sweep is idempotent: nothing left to reclaim
        s.pump_migrations(u64::MAX);
        assert_eq!(s.stats().stranded_reclaims, 1);
    }

    #[test]
    fn admission_reclaims_by_dropping_kv() {
        let mut s = store(0, 0, 2);
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 32); // both blocks fully valid
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB);
        // nothing free, no disk, but seq 1's prefix KV is droppable: 2
        // drops free 2 × ⅔·BB = 4000 ≥ BB, so the new block fits
        s.admit(2, BB, 1).unwrap();
        assert!(s.stats().kv_drops >= 1);
        assert_eq!(s.kv_dropped_tokens(1) % 16, 0);
        assert!(s.kv_dropped_tokens(1) >= 16);
        assert!(s.tier_used(Tier::CpuDram) <= 2 * BB);
    }

    #[test]
    fn dropped_prefix_reports_planner_floor() {
        let mut s = store(0, 0, 2);
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 32);
        assert_eq!(s.kv_dropped_tokens(1), 0);
        let freed = s.reclaim_kv_one().expect("droppable");
        assert_eq!(freed, BB - BB.div_ceil(3), "KV is ⅔ of the K/V/X block");
        assert_eq!(s.tier_used(Tier::CpuDram), BB + BB.div_ceil(3));
        assert_eq!(s.kv_dropped_tokens(1), 16);
    }

    #[test]
    fn wire_quant_charges_int4_bytes_on_migrations() {
        let mut s = store_cfg(2, 0, 4, |c| c.wire_elem_bytes = 0.625);
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 32, 0);
        s.begin_promotions(1, 2, MigrationClass::Promote);
        pump_and_land(&mut s, 2);
        let wire_per_block = ((BB / 4) as f64 * 0.625).ceil() as u64;
        assert_eq!(s.tier_stats().migrated_bytes, 2 * wire_per_block);
        // occupancy stays full-width: quantization shrinks traffic only
        assert_eq!(s.tier_used(Tier::GpuHbm), 2 * BB);
    }

    #[test]
    fn release_mid_flight_reclaims_everything() {
        let mut s = store(2, 2, 4);
        s.admit(1, 4 * BB, 4).unwrap();
        s.touch(1, 32, 0);
        s.begin_promotions(1, 2, MigrationClass::Promote);
        s.pump_migrations(u64::MAX); // launched but maybe not landed
        s.release(1); // non-blocking: in-flight migrations go to draining
        assert_eq!(s.pending_count(), 0);
        assert_eq!(s.tier_used(Tier::CpuDram), 0, "source reservations released");
        // the in-flight destination reservations drain via polling once
        // their transfers stop moving — release itself never waits
        for _ in 0..500 {
            s.poll_landed();
            if s.tier_used(Tier::GpuHbm) == 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(s.tier_used(Tier::GpuHbm), 0, "in-flight dest reservations released");
        // the pinned tier may keep staging-buffer charges (pinned regions
        // stay pinned by design) but no *blocks*
        assert!(s.tier_used(Tier::Pinned) <= 2 * BB, "only staging charges remain");
    }

    // -- disk-tier behaviors ------------------------------------------------

    #[test]
    fn admission_overflows_cold_blocks_to_disk() {
        // host tiers fit one block; the rest of the (empty) sequence parks
        // on disk with zero wire traffic
        let mut s = store_cfg(0, 0, 1, |c| c.disk_bytes = 8 * BB);
        s.admit(1, 4 * BB, 4).unwrap();
        assert_eq!(s.tier_used(Tier::CpuDram), BB);
        assert_eq!(s.tier_used(Tier::DiskNvme), 3 * BB);
        assert_eq!(s.stats().disk_admissions, 3);
        assert_eq!(s.migration_stats().launched, 0, "no bytes crossed a wire");
        // the disk prefix is reported for the planner's two-hop term once
        // those blocks hold valid tokens — block 0 (dram) is not disk-side
        s.touch(1, 64, 0);
        assert_eq!(s.disk_resident_tokens(1), 0, "prefix scan stops at the dram block");
        s.release(1);
        assert_eq!(s.tier_used(Tier::DiskNvme), 0);
    }

    #[test]
    fn watermark_spill_frees_dram_without_blocking() {
        // dram full (2/2 blocks) and a 50% watermark: the step's spill
        // check queues cold-block spills whose dram bytes free instantly
        let mut s = store_cfg(0, 0, 2, |c| {
            c.disk_bytes = 8 * BB;
            c.spill_watermark = 0.5;
        });
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 0); // both blocks fully valid → spillable
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB);
        s.pump_migrations(u64::MAX);
        assert!(s.stats().spills >= 1, "watermark must trigger spill");
        assert!(s.tier_used(Tier::CpuDram) <= BB, "dram bytes free at issuance");
        assert!(s.tier_used(Tier::DiskNvme) > 0, "disk reservation held");
        // the writeback lands via polling on later steps, never a wait
        let mut landed = 0;
        for _ in 0..500 {
            landed += s.poll_landed();
            if s.stats().spills_landed >= 1 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert!(landed >= 1 && s.stats().spills_landed >= 1);
        assert_eq!(s.disk_resident_tokens(1), 16, "spilled block 0 is the disk prefix");
    }

    #[test]
    fn admission_spills_before_dropping_kv() {
        // host tiers hold seq 1's two valid blocks; admitting seq 2 spills
        // (full bytes back, KV preserved on disk) instead of dropping KV
        let mut s = store_cfg(0, 0, 2, |c| c.disk_bytes = 8 * BB);
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 32);
        s.admit(2, BB, 1).unwrap();
        assert!(s.stats().spills >= 1, "spill must be preferred");
        assert_eq!(s.stats().kv_drops, 0, "no KV dropped while spill can reclaim");
        assert_eq!(s.kv_dropped_tokens(1), 0);
    }

    #[test]
    fn two_hop_promotion_stages_across_steps() {
        // seq 1's valid blocks sit on disk (spilled); promoting them takes
        // a disk→dram hop on one step and dram→gpu on a later one — the
        // walk never waits on either wire
        let mut s = store_cfg(2, 0, 2, |c| c.disk_bytes = 8 * BB);
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 0);
        // push both blocks down to disk
        while s.spill_one().is_some() {}
        assert_eq!(s.stats().spills, 2);
        pump_and_land(&mut s, 2);
        assert_eq!(s.stats().spills_landed, 2);
        assert_eq!(s.tier_used(Tier::CpuDram), 0);
        assert_eq!(s.disk_resident_tokens(1), 32);
        // step A: the promotion walk issues hops, not gpu promotions
        let issued = s.begin_promotions(1, 2, MigrationClass::Promote);
        assert_eq!(issued, 2);
        assert_eq!(s.stats().hops, 2);
        assert_eq!(s.stats().promotions_started, 0, "no direct gpu leg yet");
        assert_eq!(s.gpu_resident_tokens(1), 0);
        pump_and_land(&mut s, 2);
        assert_eq!(s.stats().hops_landed, 2);
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB, "hop landed in dram");
        assert_eq!(s.disk_resident_tokens(1), 0);
        // step B: the walk now sees host blocks and issues the gpu leg
        let issued = s.begin_promotions(1, 2, MigrationClass::Promote);
        assert_eq!(issued, 2);
        assert_eq!(s.stats().promotions_started, 2);
        pump_and_land(&mut s, 2);
        assert_eq!(s.gpu_resident_tokens(1), 32);
        assert_eq!(s.tier_used(Tier::DiskNvme), 0, "disk reservations released");
    }

    #[test]
    fn zero_disk_capacity_keeps_three_tier_behavior() {
        let mut s = store(0, 0, 2); // disk_bytes = 0
        s.admit(1, 2 * BB, 2).unwrap();
        s.touch(1, 32, 32);
        assert!(s.spill_one().is_none(), "no disk tier, no spill");
        s.pump_migrations(u64::MAX);
        assert_eq!(s.stats().spills, 0);
        // admission still reclaims by dropping KV, exactly like PR 3
        s.admit(2, BB, 1).unwrap();
        assert!(s.stats().kv_drops >= 1);
    }

    // -- spill-side hysteresis ----------------------------------------------

    #[test]
    fn spill_cooldown_bounds_ping_pong_under_alternating_reuse() {
        // Adversarial alternating reuse over a one-block dram tier: each
        // sequence's promotion can only make room by spilling the other's
        // just-promoted block.  Without the spill-side cool-down the pair
        // swaps through the disk tier forever (one spill + one hop per
        // alternation); with it, the walk finds no spillable block while
        // the fresh promotee cools and issues nothing.
        let mut s = store_cfg(0, 0, 1, |c| {
            c.disk_bytes = 8 * BB;
            c.spill_cooldown = 8;
        });
        s.admit(1, BB, 1).unwrap();
        s.touch(1, 16, 0);
        // seq 2's admission spills seq 1's block to make room
        s.admit(2, BB, 1).unwrap();
        s.touch(2, 16, 0);
        assert_eq!(s.stats().spills, 1);
        pump_and_land(&mut s, 1); // the spill writeback lands: seq 1 is disk-side
        // seq 1 hops back up; the hop's room is made by spilling seq 2
        assert_eq!(s.begin_promotions(1, 1, MigrationClass::Promote), 1);
        assert_eq!(s.stats().spills, 2);
        assert_eq!(s.stats().hops, 1);
        pump_and_land(&mut s, 2); // spill writeback + hop land; seq 1 starts cooling
        // the adversarial alternation: each side immediately wants back in
        for _ in 0..6 {
            s.touch(2, 16, 0);
            assert_eq!(
                s.begin_promotions(2, 1, MigrationClass::Promote),
                0,
                "hopping seq 2 up would spill the just-promoted block"
            );
            s.touch(1, 16, 0);
            assert_eq!(s.begin_promotions(1, 1, MigrationClass::Promote), 0, "already home");
        }
        assert_eq!(s.stats().spills, 2, "no ping-pong: the cool-down held the line");
        assert_eq!(s.stats().hops, 1);
        assert!(s.stats().spill_cooldown_skips >= 6);
        // hysteresis bounds the thrash, it must not deadlock: once the
        // cool-down ages out (serving steps, not touches), seq 2 proceeds
        for _ in 0..8 {
            s.pump_migrations(0);
        }
        s.touch(2, 16, 0);
        assert_eq!(s.begin_promotions(2, 1, MigrationClass::Promote), 1, "cool-down expired");
        assert_eq!(s.stats().spills, 3);
    }

    #[test]
    fn spill_floor_holds_dram_occupancy_under_the_watermark() {
        // dram of 4 blocks with a 50 % floor: spill works down to the
        // floor and then declines, even under admission pressure
        let mut s = store_cfg(0, 0, 4, |c| {
            c.disk_bytes = 8 * BB;
            c.spill_floor = 0.5;
        });
        s.admit(1, 3 * BB, 3).unwrap();
        s.touch(1, 48, 0); // all three blocks fully valid → spillable
        assert_eq!(s.tier_used(Tier::CpuDram), 3 * BB);
        assert!(s.spill_one().is_some(), "above the floor: spill proceeds");
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB);
        assert!(
            s.spill_one().is_none(),
            "at the floor (2/4 blocks): spill must decline, not drain the tier"
        );
        assert_eq!(s.stats().spills, 1);
    }

    #[test]
    fn config_from_topology_maps_named_rungs() {
        use crate::scheduler::TierTopology;
        let topo = TierTopology::standard(7 * BB, 2 * BB, 4 * BB)
            .with_disk(9 * BB, 0.5)
            .calibrated_bps(100e6, 30e-6);
        let cfg = KvStoreConfig::from_topology(&topo, 64 << 10);
        assert_eq!(cfg.gpu_bytes, 7 * BB);
        assert_eq!(cfg.pinned_bytes, 2 * BB);
        assert_eq!(cfg.dram_bytes, 4 * BB);
        assert_eq!(cfg.disk_bytes, 9 * BB);
        assert_eq!(cfg.link.bytes_per_sec, 100e6);
        assert!((cfg.nvme_link.bytes_per_sec - 25e6).abs() < 1.0);
        assert!(cfg.nvme_link.latency_s > cfg.link.latency_s);
        assert_eq!(cfg.spill_watermark, 0.5);
        assert_eq!(cfg.wire_elem_bytes, 4.0);
        // the store built from it has the declared tier capacities
        let s = KvStore::new(cfg, Box::new(Lru));
        assert_eq!(s.mig.tiers().pool(Tier::GpuHbm).capacity(), 7 * BB);
        assert_eq!(s.mig.tiers().pool(Tier::DiskNvme).capacity(), 9 * BB);
        // a three-tier chain disables the disk rung by capacity
        let three = TierTopology::standard(BB, BB, BB).calibrated_bps(100e6, 30e-6);
        let cfg = KvStoreConfig::from_topology(&three, 64 << 10);
        assert_eq!(cfg.disk_bytes, 0);
        assert!(cfg.spill_watermark >= 1.0, "no disk rung: the watermark never binds");
    }

    // -- prefix sharing -----------------------------------------------------

    #[test]
    fn admit_shared_adopts_matched_prefix_at_zero_new_bytes() {
        let mut s = store(0, 0, 8);
        s.enable_prefix_sharing();
        let prompt = vec![b'p'; 32]; // two full 16-token blocks
        // the first request registers: bytes land like a private admission
        let a = s.admit_shared(1, 4 * BB, 4, &prompt).unwrap();
        assert_eq!(a.matched_blocks, 0);
        assert_eq!(a.registered_blocks, 2);
        assert_eq!(s.tier_used(Tier::CpuDram), 4 * BB);
        // the second request with the same prompt adopts both prefix
        // blocks: only its two private blocks cost new bytes
        let b = s.admit_shared(2, 4 * BB, 4, &prompt).unwrap();
        assert_eq!(b.matched_blocks, 2);
        assert_eq!(b.shared_tokens, 32);
        assert_eq!(b.registered_blocks, 0);
        assert_eq!(s.tier_used(Tier::CpuDram), 6 * BB, "two private blocks only");
        s.touch(2, 64, 0);
        assert_eq!(s.shared_prefix_tokens(2), 32);
        assert_eq!(s.share_stats().adoptions, 2);
    }

    #[test]
    fn sharing_admits_more_sequences_at_the_same_budget() {
        // eight dram blocks, 4-block sequences with a 3-block shareable
        // prefix: privately two fit; shared, the prefix is paid once
        let prompt = vec![b'p'; 48];
        let mut private = store(0, 0, 8);
        let fit_private =
            (0..10).filter(|&seq| private.admit(seq, 4 * BB, 4).is_ok()).count();
        assert_eq!(fit_private, 2);
        let mut shared = store(0, 0, 8);
        shared.enable_prefix_sharing();
        let fit_shared = (0..10)
            .filter(|&seq| shared.admit_shared(seq, 4 * BB, 4, &prompt).is_ok())
            .count();
        assert_eq!(fit_shared, 5, "3 registered + 5 × 1 private = 8 blocks");
        assert!(fit_shared > fit_private);
    }

    #[test]
    fn release_parks_entries_and_the_next_admission_revives_them() {
        let mut s = store(0, 0, 4);
        s.enable_prefix_sharing();
        let prompt = vec![b'q'; 32];
        s.admit_shared(1, 3 * BB, 3, &prompt).unwrap();
        s.release(1);
        // retirement decremented instead of freeing: the entries park
        assert_eq!(s.tier_used(Tier::CpuDram), 2 * BB, "registry still holds the prefix");
        assert_eq!(s.share_stats().releases, 2);
        // the next same-prefix request hits the parked cache
        let a = s.admit_shared(2, 3 * BB, 3, &prompt).unwrap();
        assert_eq!(a.matched_blocks, 2);
        assert_eq!(s.tier_used(Tier::CpuDram), 3 * BB);
    }

    #[test]
    fn capacity_pressure_trims_parked_entries_before_backpressure() {
        let mut s = store(0, 0, 4);
        s.enable_prefix_sharing();
        let prompt = vec![b'r'; 32];
        s.admit_shared(1, 3 * BB, 3, &prompt).unwrap();
        s.release(1); // two parked blocks keep 2×BB reserved as cache
        // a different prompt needs the whole tier: the parked cache trims
        // instead of backpressuring the admission
        s.admit_shared(2, 4 * BB, 4, &[b'z'; 8]).unwrap();
        assert!(s.share_stats().trimmed >= 2);
        assert_eq!(s.tier_used(Tier::CpuDram), 4 * BB);
    }

    #[test]
    fn park_prefix_deep_takes_a_private_clone_of_shared_blocks() {
        let mut s = store_cfg(0, 0, 8, |c| c.disk_bytes = 8 * BB);
        s.enable_prefix_sharing();
        let prompt = vec![b'c'; 32];
        s.admit_shared(1, 3 * BB, 3, &prompt).unwrap();
        s.admit_shared(2, 3 * BB, 3, &prompt).unwrap();
        assert_eq!(s.share_stats().adoptions, 2);
        // seq 2 migrates across shards: its shared prefix parks deep as a
        // copy-on-write private clone under its own reservation
        assert_eq!(s.park_prefix_deep(2, 32), 2);
        assert_eq!(s.share_stats().cow_clones, 2);
        assert_eq!(s.tier_used(Tier::DiskNvme), 2 * BB, "the clone holds its own bytes");
        assert_eq!(s.shared_prefix_tokens(2), 0, "diverged: no longer shared");
        // the shared original keeps its other dependent untouched
        s.touch(1, 48, 0);
        assert_eq!(s.shared_prefix_tokens(1), 32);
        s.release(1);
        s.release(2);
        assert_eq!(s.share_stats().releases, 2, "seq 2's refs left via CoW, not release");
    }

    #[test]
    fn shared_markers_are_never_spilled_dropped_or_promoted() {
        let mut s = store_cfg(0, 0, 4, |c| c.disk_bytes = 8 * BB);
        s.enable_prefix_sharing();
        let prompt = vec![b's'; 32];
        s.admit_shared(1, 3 * BB, 3, &prompt).unwrap();
        s.touch(1, 48, 48);
        // the spill scan passes over the shared prefix and takes the
        // private block above it
        assert!(s.spill_one().is_some());
        assert_eq!(s.stats().spills, 1);
        assert_eq!(s.shared_prefix_tokens(1), 32, "markers untouched by spill");
        // nothing droppable: the shared prefix ends the reclaim chain and
        // the private block above it is mid-spill
        assert!(s.reclaim_kv_one().is_none());
        assert_eq!(s.kv_dropped_tokens(1), 0);
    }

    #[test]
    fn promotion_walk_stops_at_the_shared_prefix() {
        let mut s = store(2, 0, 4);
        s.enable_prefix_sharing();
        let prompt = vec![b's'; 32];
        s.admit_shared(1, 3 * BB, 3, &prompt).unwrap();
        s.touch(1, 48, 0);
        // only the private top block is promotable; the walk breaks at the
        // shared markers instead of issuing transfers for them
        assert_eq!(s.begin_promotions(1, 4, MigrationClass::Promote), 1);
        assert_eq!(s.stats().promotions_started, 1);
        assert_eq!(s.stats().hops, 0);
        pump_and_land(&mut s, 1);
        assert_eq!(s.gpu_resident_tokens(1), 16);
        assert_eq!(s.shared_prefix_tokens(1), 32);
    }
}
